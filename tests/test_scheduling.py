"""Policy-driven scheduler: lazy growth, preemption, retained prefixes.

The load-bearing claims of the scheduling refactor, each asserted here:

  * DIFFERENTIAL: with preemption disabled, lazy-growth paged output is
    token-identical to eager whole-chain paged, the dense pool and the
    static baseline (fp32 in tier-1, bf16 in the slow matrix), and the
    jitted decode step still compiles exactly once across grow/preempt
    block churn;
  * PREEMPTION IS INVISIBLE: forcing mid-decode preemptions (scarce
    arena, long budgets) changes scheduling but not output — the
    continuation prefill (prompt + generated so far) recomputes exactly
    the state the evicted slot held, for greedy and sampled decode;
  * RETAINED PREFIXES: prefix blocks survive refcount 0 on a bounded
    LRU, revive copy-free for later waves, respect the bound, and are
    never aliased by live writes;
  * policies order admission as documented (fifo / arrival-deadline /
    prefix-affinity), the SLO path evicts stuck slots, and the
    scheduler's preempt/requeue preserves arrival order;
  * prefill admission groups pad to power-of-two sizes, bounding the
    prefill compile count at O(log max_batch) per length bucket.
"""
import numpy as np
import pytest

from conftest import make_serving_requests as make_requests
from conftest import setup_serving_arch as setup_arch
from repro.serving import (ArrivalDeadlinePolicy, BlockTableMap,
                           ContinuousEngine, NoBlocksError, PagedCachePool,
                           PolicyContext, PrefixAffinityPolicy, Request,
                           Scheduler, SchedulingPolicy, ServeEngine)

pytestmark = [pytest.mark.serving, pytest.mark.sched]

MAX_LEN = 48

SPEC = [(7, 4), (11, 6), (5, 1), (9, 3), (11, 4)]


# --------------------------------------------------------------------------
# the acceptance differential: lazy == eager == dense == static
# --------------------------------------------------------------------------

def _run_growth_quad(name, policy, prefix=16):
    """static / dense / paged-eager / paged-lazy over one workload, with
    preemption disabled so growth mode is the ONLY variable.

    Under bf16 the harness defaults to the tie-stable greedy argmax
    (sampler stable=1): the pools lay the same keys at different cache
    rows, so one-ulp rounding differences can break a RAW argmax tie
    differently across layouts — stable_argmax snaps logits to the bf16
    resolution before the tiebreak, making the quad layout-insensitive
    at every precision (the fp32-only restriction this harness carried
    through PR 5 is gone)."""
    arch, params = setup_arch(name)
    sampler = None if policy == "fp32" else "temperature=0,stable=1"
    outs = []
    for build in (
            lambda: ServeEngine(arch, params, max_len=MAX_LEN,
                                policy=policy, sampler=sampler),
            lambda: ContinuousEngine(arch, params, max_batch=2,
                                     max_len=MAX_LEN, policy=policy,
                                     cache="dense", prefill_bucket=8,
                                     sampler=sampler),
            lambda: ContinuousEngine(arch, params, max_batch=3,
                                     max_len=MAX_LEN, policy=policy,
                                     cache="paged", block_size=8,
                                     prefill_bucket=8, growth="eager",
                                     sampler=sampler),
            lambda: ContinuousEngine(arch, params, max_batch=3,
                                     max_len=MAX_LEN, policy=policy,
                                     cache="paged", block_size=8,
                                     prefill_bucket=8, growth="lazy",
                                     preempt=False, sampler=sampler)):
        reqs = make_requests(arch, SPEC, prefix=prefix)
        engine = build()
        engine.run_batch(reqs)
        outs.append((engine, reqs))
    return outs


@pytest.mark.paged
@pytest.mark.parametrize("name", ["gemma2-2b", "qwen2.5-14b"])
def test_lazy_growth_differential_fp32(name):
    """THE tentpole differential: on-demand chain growth must be
    invisible in the tokens — static == dense == paged-eager ==
    paged-lazy (shared prefixes included; gemma2 adds sliding-window
    ring wrap on top of qwen's plain full-attention ring) — and block
    churn from growth must never retrace the decode step."""
    (s, a), (d, b), (e, c), (l, q) = _run_growth_quad(name, "fp32")
    for ra, rb, rc, rq in zip(a, b, c, q):
        assert ra.generated.shape == (ra.max_new_tokens,)
        np.testing.assert_array_equal(ra.generated, rb.generated)
        np.testing.assert_array_equal(ra.generated, rc.generated)
        np.testing.assert_array_equal(ra.generated, rq.generated)
    assert l.pool.growth == "lazy" and e.pool.growth == "eager"
    assert l.preemptions == 0          # disabled AND never needed here
    assert l._step._cache_size() == 1
    assert e._step._cache_size() == 1
    l.pool.check_invariants()
    assert all(m.alloc.n_live == 0 for m in l.pool.maps.values())


@pytest.mark.slow
@pytest.mark.paged
def test_lazy_growth_differential_bf16_gemma2():
    """The full quad under the bf16 policy + stable argmax on gemma2
    (sliding-window ring wrap on the growth path): growth timing must
    not perturb block contents differently across pools."""
    (_, a), (_, b), (_, c), (l, q) = _run_growth_quad("gemma2-2b", "bf16")
    for ra, rb, rc, rq in zip(a, b, c, q):
        np.testing.assert_array_equal(ra.generated, rb.generated)
        np.testing.assert_array_equal(ra.generated, rc.generated)
        np.testing.assert_array_equal(ra.generated, rq.generated)
    l.pool.check_invariants()


@pytest.mark.paged
def test_lazy_growth_differential_bf16_qwen_stable():
    """The quad under bf16 on the workload whose raw argmax DOES tie
    cross-layout (qwen's request 1 — the documented fp32-only caveat
    since PR 4): with the harness's stable-argmax default the full
    static == dense == eager == lazy chain holds under bf16 too."""
    (_, a), (_, b), (_, c), (l, q) = _run_growth_quad("qwen2.5-14b", "bf16")
    for ra, rb, rc, rq in zip(a, b, c, q):
        np.testing.assert_array_equal(ra.generated, rb.generated)
        np.testing.assert_array_equal(ra.generated, rc.generated)
        np.testing.assert_array_equal(ra.generated, rq.generated)
    l.pool.check_invariants()


@pytest.mark.paged
def test_lazy_vs_eager_bf16_same_layout():
    """bf16 growth-mode pair on the arch whose workload DOES tie
    cross-layout (qwen): lazy and eager paged engines share one layout
    contract, so their bf16 greedy tokens must still be bit-equal even
    where dense-vs-paged legitimately flips."""
    arch, params = setup_arch("qwen2.5-14b")
    outs = []
    for growth in ("eager", "lazy"):
        reqs = make_requests(arch, SPEC, prefix=16)
        eng = ContinuousEngine(arch, params, max_batch=3, max_len=MAX_LEN,
                               policy="bf16", cache="paged", block_size=8,
                               prefill_bucket=8, growth=growth,
                               preempt=False)
        eng.run_batch(reqs)
        outs.append(reqs)
    for ra, rb in zip(*outs):
        np.testing.assert_array_equal(ra.generated, rb.generated)


# --------------------------------------------------------------------------
# preemption / requeue: forced evictions never change tokens
# --------------------------------------------------------------------------

PRESSURE_SPEC = [(8, 20), (8, 18), (8, 16)]


def _solo_outputs(arch, params, spec, sampler=None):
    eng = ContinuousEngine(arch, params, max_batch=1, max_len=MAX_LEN,
                           cache="dense", prefill_bucket=8, sampler=sampler,
                           policy="fp32")
    solos = make_requests(arch, spec)
    eng.run(solos)
    return solos


def _pressure_engine(arch, params, sampler=None, **kw):
    """A budget-1 arena under 4 slots with long budgets: lazy admission
    lets several prompts in, growth exhausts the arena mid-decode and
    the engine MUST preempt to finish."""
    return ContinuousEngine(arch, params, max_batch=4, max_len=MAX_LEN,
                            cache="paged", block_size=8, slots_budget=1,
                            prefill_bucket=8, share_prefix=False,
                            sampler=sampler, policy="fp32", **kw)


def test_preemption_requeue_token_identical_greedy():
    arch, params = setup_arch("qwen2.5-14b")
    solos = _solo_outputs(arch, params, PRESSURE_SPEC)
    eng = _pressure_engine(arch, params)
    reqs = make_requests(arch, PRESSURE_SPEC)
    eng.run(reqs)
    assert eng.preemptions > 0, "pressure workload failed to preempt"
    assert sum(r.trace.preemptions for r in reqs) == eng.preemptions
    for solo, r in zip(solos, reqs):
        assert r.generated.shape == (r.max_new_tokens,)
        np.testing.assert_array_equal(solo.generated, r.generated)
    assert eng._step._cache_size() == 1    # churn never retraced
    eng.pool.check_invariants()
    assert all(m.alloc.n_live == 0 for m in eng.pool.maps.values())


def test_preemption_sampled_stream_invariant():
    """Sampler keys derive from (seed, rid, token index) only, so a
    preempted-and-resumed sampled stream continues exactly where the
    evicted slot stopped."""
    arch, params = setup_arch("qwen2.5-14b")
    sampler = "temperature=0.7,top_k=20,seed=5"
    solos = _solo_outputs(arch, params, PRESSURE_SPEC, sampler=sampler)
    eng = _pressure_engine(arch, params, sampler=sampler)
    reqs = make_requests(arch, PRESSURE_SPEC)
    eng.run(reqs)
    assert eng.preemptions > 0
    for solo, r in zip(solos, reqs):
        np.testing.assert_array_equal(solo.generated, r.generated)


def test_preempt_disabled_raises_on_exhaustion():
    arch, params = setup_arch("qwen2.5-14b")
    eng = _pressure_engine(arch, params, preempt=False)
    with pytest.raises(RuntimeError, match="preemption disabled"):
        eng.run(make_requests(arch, PRESSURE_SPEC))


def test_scheduler_preempt_restores_arrival_order():
    sched = Scheduler(2)
    for i in range(5):
        sched.submit(f"r{i}")
    pairs = sched.assign()
    assert [r for _, r in pairs] == ["r0", "r1"]
    sched.preempt(pairs[0][0])            # r0 back to the queue
    assert sched.peek() == "r0"           # ...AHEAD of r2-r4
    sched.check_invariants()
    pairs2 = sched.assign()               # one slot free -> r0 re-admitted
    assert [r for _, r in pairs2] == ["r0"]
    sched.complete(pairs[1][0])           # r1 done; next admit is r2
    assert [r for _, r in sched.assign()] == ["r2"]
    sched.check_invariants()


# --------------------------------------------------------------------------
# retained-prefix LRU: persistence across waves, bound, no aliasing
# --------------------------------------------------------------------------

def test_retained_prefix_revival_across_waves():
    """Prefix blocks must survive a FULL drain (refcount 0 everywhere)
    and revive copy-free for a later wave with the same system prompt —
    token-identically."""
    arch, params = setup_arch("qwen2.5-14b")
    eng = ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                           cache="paged", block_size=8, prefill_bucket=8,
                           retain_blocks=4, policy="fp32")
    wave1 = make_requests(arch, [(4, 3), (6, 3)], prefix=16)
    eng.run(wave1)                         # drain: every slot evicts
    parked = eng.pool.retained_blocks()
    assert any(n > 0 for n in parked.values()), "nothing retained"
    assert all(n <= 4 for n in parked.values())
    eng.pool.check_invariants()            # retained never table-aliased

    # disjoint tails, same 16-token prefix, same padded lengths
    wave2 = make_requests(arch, [(5, 4), (7, 3)], seed=2, prefix=16,
                          prefix_seed=1)
    solos = make_requests(arch, [(5, 4), (7, 3)], seed=2, prefix=16,
                          prefix_seed=1)
    static = ServeEngine(arch, params, max_len=MAX_LEN, policy="fp32")
    for r in solos:
        static.run_batch([r])
    eng.run(wave2)
    assert eng.pool.retained_hits > 0, "wave 2 did not revive warm blocks"
    for solo, r in zip(solos, wave2):
        np.testing.assert_array_equal(solo.generated, r.generated)
    eng.pool.check_invariants()


def test_retained_lru_bound_and_pressure_reclaim():
    """Map-level: the LRU bound evicts oldest-first, revivals are
    flagged, and allocation pressure reclaims retained blocks instead
    of failing."""
    m = BlockTableMap(max_batch=4, ring_len=32, block_size=8, n_blocks=13,
                      retain_limit=2)
    prompts = [tuple(range(100 * k, 100 * k + 8)) for k in range(3)]
    for k, p in enumerate(prompts):        # 3 distinct 1-block prefixes
        m.insert(k, p, plen=8, padded_len=16, budget=4)
    for k in range(3):
        m.evict(k)
    # bound: only the two NEWEST prefixes stay warm
    assert m.n_retained == 2 and m.alloc.n_retained == 2
    assert not m.prefix_warm(prompts[0], 8, 16)      # LRU-evicted
    assert m.prefix_warm(prompts[1], 8, 16)
    assert m.prefix_warm(prompts[2], 8, 16)
    m.check_invariants()
    # revival: same prefix comes back shared WITHOUT a write
    placed = m.insert(0, prompts[1], plen=8, padded_len=16, budget=4)
    assert placed[0].shared and placed[0].revived
    assert m.retained_hits == 1
    m.check_invariants()
    # pressure: filling the arena reclaims the remaining retained block
    # rather than raising. Slot 0 holds 2 blocks, 1 is retained -> two
    # 4-block inserts leave 1 free block; the next 2-block insert MUST
    # reclaim the retained block to succeed.
    big = tuple(range(500, 532))
    m.insert(1, big, plen=25, padded_len=32, budget=8, share=False)
    m.insert(2, big, plen=25, padded_len=32, budget=8, share=False)
    assert m.alloc.n_free == 1 and m.n_retained == 1
    m.insert(3, tuple(range(700, 709)), plen=9, padded_len=16, budget=8,
             share=False)
    assert m.n_retained == 0              # LRU tail reclaimed under pressure
    assert m.alloc.n_free == 0
    m.check_invariants()
    for k in (0, 1, 2, 3):
        m.evict(k)
    m.check_invariants()
    # nothing leaked: free + retained partition the data blocks
    assert m.alloc.n_free + m.alloc.n_retained == 12


def test_rollback_insert_never_parks_unwritten_blocks():
    """Regression (review finding): PagedCachePool.insert's cross-map
    rollback undoes slot-types that had already placed their blocks —
    BEFORE any device write happened. Blocks the failed insert
    registered must be freed + unregistered, never parked on the
    retained LRU (a revival is read copy-free and would decode garbage
    KV); a REVIVED placement's still-valid block must instead re-park
    warm, with the hit counter corrected."""
    m = BlockTableMap(max_batch=2, ring_len=32, block_size=8, n_blocks=9,
                      retain_limit=4)
    prompt = tuple(range(8))
    placed = m.insert(0, prompt, plen=8, padded_len=16, budget=4)
    assert m.n_shared == 1                 # prefix block registered
    m.rollback_insert(0, placed)           # the cross-map rollback path
    assert m.n_retained == 0 and m.n_shared == 0, (
        "rollback parked an unwritten block as warm content")
    assert m.alloc.n_free == 8 and not m.table[0].any()
    m.check_invariants()
    # revived placements roll back to WARM (content was already valid)
    m.insert(0, prompt, plen=8, padded_len=16, budget=4)
    m.evict(0)                             # normal evict: parks warm
    assert m.n_retained == 1
    placed = m.insert(1, prompt, plen=8, padded_len=16, budget=4)
    assert placed[0].revived and m.retained_hits == 1
    m.rollback_insert(1, placed)
    assert m.n_retained == 1 and m.retained_hits == 0, (
        "rollback lost a revived block's warm content or its counter")
    m.check_invariants()


def test_grow_invalidates_stale_positions():
    """A freshly grown block may hold a previous occupant's position
    rows; flush_growth() must force them to -1 before the decode step
    gathers the block."""
    arch, params = setup_arch("qwen2.5-14b")
    pool = PagedCachePool(arch, max_batch=2, max_len=MAX_LEN, block_size=8,
                          growth="lazy", retain_blocks=0)
    _, req_cache = arch.prefill(
        params, {"tokens": np.arange(5, 13, dtype=np.int32)[None]},
        cache_len=MAX_LEN + 8, per_slot=True,
        positions=np.arange(8, dtype=np.int32)[None])
    # dirty the whole arena's positions to simulate stale occupants
    si = next(iter(pool.maps))
    slots = list(pool.cache["slots"])
    slots[si] = {**slots[si],
                 "pos": slots[si]["pos"].at[:].set(7)}
    pool.cache = {"slots": tuple(slots), "index": pool.cache["index"]}
    pool.insert(req_cache, 0, prompt=np.arange(5, 13), plen=8,
                padded_len=8, budget=16)
    tbl = pool.maps[si].table
    assert tbl[0, 0] != 0 and tbl[0, 1] == 0   # lazy: decode block unbacked
    assert pool.grow(0, 8) is True             # row 8 -> chain pos 1
    grown = int(pool.maps[si].table[0, 1])
    assert grown != 0
    pool.flush_growth()
    pos = np.asarray(pool.cache["slots"][si]["pos"])
    assert (pos[:, grown, :] == -1).all(), "stale positions survived grow"
    pool.check_invariants()


# --------------------------------------------------------------------------
# scheduling policies
# --------------------------------------------------------------------------

def _req(rid, submit_t):
    r = Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=2)
    r.rid = rid
    r.trace.submit_t = submit_t
    return r


def test_policy_parse_and_validation():
    assert SchedulingPolicy.parse(None).name == "fifo"
    assert SchedulingPolicy.parse("fifo").name == "fifo"
    assert isinstance(SchedulingPolicy.parse("arrival-deadline"),
                      ArrivalDeadlinePolicy)
    assert isinstance(SchedulingPolicy.parse("prefix-affinity"),
                      PrefixAffinityPolicy)
    p = SchedulingPolicy.parse("fifo", slo_s=1.5)
    assert p.slo_s == 1.5
    assert SchedulingPolicy.parse(p) is p
    with pytest.raises(ValueError):
        SchedulingPolicy.parse("shortest-job-first")
    with pytest.raises(ValueError):
        ContinuousEngine(*setup_arch("gemma2-2b"), max_batch=1,
                         max_len=MAX_LEN, sched_policy="nope")
    with pytest.raises(ValueError):
        ContinuousEngine(*setup_arch("gemma2-2b"), max_batch=1,
                         max_len=MAX_LEN, growth="sometimes")


def test_arrival_deadline_policy_orders_and_victimizes():
    pol = ArrivalDeadlinePolicy(slo_s=1.0)
    # queue arrival order r0, r1, r2 — but r2 SUBMITTED earliest (a
    # preempted continuation keeps its original submit time)
    queue = [(0, _req(0, 10.0)), (1, _req(1, 12.0)), (2, _req(2, 5.0))]
    ctx = PolicyContext(now=20.0, admit_seq={3: 1, 5: 2},
                        admit_t={3: 11.0, 5: 13.0},
                        active={3: _req(3, 10.0), 5: _req(5, 12.0)},
                        submit_t=lambda r: r.trace.submit_t)
    assert pol.pick(queue, ctx) == 2          # earliest deadline first
    assert pol.victim([3, 5], ctx) == 5       # latest deadline = most slack
    assert pol.overdue(3, ctx)                # 20 - 11 > 1.0
    assert not SchedulingPolicy(slo_s=None).overdue(3, ctx)
    # churn regression: slot 5 now holds a RE-ADMITTED continuation —
    # newest admit_t but the EARLIEST original submit/deadline. Victim
    # ranking must follow the deadline, not the admission time, or the
    # continuation would be re-preempted forever.
    ctx2 = PolicyContext(now=20.0, admit_seq={3: 1, 5: 9},
                         admit_t={3: 11.0, 5: 19.0},
                         active={3: _req(3, 10.0), 5: _req(5, 2.0)},
                         submit_t=lambda r: r.trace.submit_t)
    assert pol.victim([3, 5], ctx2) == 3


def test_prefix_affinity_prefers_warm_queue_entry():
    pol = PrefixAffinityPolicy()
    queue = [(0, "cold"), (1, "warm"), (2, "warm2")]
    ctx = PolicyContext(prefix_warm=lambda r: r.startswith("warm"))
    assert pol.pick(queue, ctx) == 1          # first WARM wins...
    ctx_cold = PolicyContext(prefix_warm=lambda r: False)
    assert pol.pick(queue, ctx_cold) == 0     # ...else arrival order
    assert pol.pick(queue, PolicyContext(prefix_warm=None)) == 0


def test_prefix_affinity_engine_reorders_admission():
    """With one decode slot and a warm prefix in the pool, the engine
    admits the warm request ahead of an earlier-arrived cold one — and
    the tokens still match the solo runs (scheduling never changes
    output)."""
    arch, params = setup_arch("qwen2.5-14b")
    warm_spec, cold_spec = [(4, 3)], [(9, 3)]
    solo_cold = _solo_outputs(arch, params, cold_spec)
    eng = ContinuousEngine(arch, params, max_batch=1, max_len=MAX_LEN,
                           cache="paged", block_size=8, prefill_bucket=8,
                           sched_policy="prefix-affinity", retain_blocks=8,
                           policy="fp32")
    prime = make_requests(arch, warm_spec, prefix=16)
    eng.run(prime)                        # park the warm prefix blocks
    cold = make_requests(arch, cold_spec)[0]
    warm = make_requests(arch, warm_spec, prefix=16)[0]
    eng.submit(cold)                      # arrives FIRST
    eng.submit(warm)
    eng.run()
    done = eng.scheduler.completed[1:]    # [0] is the priming request
    assert done[0] is warm and done[1] is cold
    assert eng.pool.retained_hits > 0
    np.testing.assert_array_equal(warm.generated, prime[0].generated)
    np.testing.assert_array_equal(cold.generated, solo_cold[0].generated)


def test_slo_eviction_finishes_stuck_slot():
    arch, params = setup_arch("gemma2-2b")
    eng = ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                           prefill_bucket=8, slo_ms=1e-6)
    reqs = make_requests(arch, [(6, 30), (7, 2)])
    eng.run(reqs)
    assert reqs[0].trace.evicted_slo       # stuck long request cut short
    assert 1 <= len(reqs[0].generated) < 30
    assert len(reqs[1].generated) == 2     # short one finished naturally
    eng.pool.check_invariants()


# --------------------------------------------------------------------------
# power-of-two prefill admission groups
# --------------------------------------------------------------------------

def test_prefill_group_pow2_compile_bound():
    """Admission groups of sizes 3, 5 and 6 in ONE padded-length bucket
    must reuse two compiles ((4, b) and (8, b)) — O(log max_batch) per
    bucket instead of one compile per distinct group size."""
    arch, params = setup_arch("qwen2.5-14b")
    eng = ContinuousEngine(arch, params, max_batch=8, max_len=MAX_LEN,
                           prefill_bucket=8, block_size=8)
    for n in (3, 5, 6):
        # budget-1 requests complete AT admission, so each wave admits
        # as one group and frees every slot before the next wave
        for r in make_requests(arch, [(5 + (i % 3), 1) for i in range(n)]):
            eng.submit(r)
        while eng.step():
            pass
    assert eng._prefill._cache_size() == 2, (
        "expected exactly {(4, b), (8, b)} prefill compiles")
    assert eng.steps_run == 0


def test_watermark_reserves_growth_headroom():
    m = BlockTableMap(max_batch=2, ring_len=32, block_size=8, n_blocks=9,
                      watermark=3)
    assert m.alloc.n_free == 8 and m.admissible() == 5
    arch, params = setup_arch("qwen2.5-14b")
    pool = PagedCachePool(arch, max_batch=2, max_len=MAX_LEN, block_size=8,
                          growth="lazy", watermark=2)
    base = {si: m.alloc.n_free - 2 for si, m in pool.maps.items()}
    assert pool.admissible_blocks() == base

"""Unit tests for the paper's optimizers (Algorithm 1, Algorithm 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optim import (adamw, apply_updates, bn_adamw, lamb, lans, sgd)
from repro.core.optim.base import WeightDecayMask, tree_paths
from repro.kernels import ref


def _tree(rng, shapes):
    return {k: jnp.asarray(rng.normal(size=s), jnp.float32)
            for k, s in shapes.items()}


SHAPES = {"w": (32, 16), "bias": (16,)}


def test_lans_matches_single_block_reference(rng):
    """scale_by_lans on a single weight tensor == ref.lans_step_ref."""
    params = {"w": jnp.asarray(rng.normal(size=(24, 8)), jnp.float32)}
    grads = {"w": jnp.asarray(rng.normal(size=(24, 8)), jnp.float32)}
    tx = lans(0.01)
    st = tx.init(params)
    p = params
    m = jnp.zeros((24, 8)); v = jnp.zeros((24, 8))
    x_ref = params["w"]
    for step in range(1, 4):
        upd, st = tx.update(grads, st, p)
        p = apply_updates(p, upd)
        out = ref.lans_step_ref(grads["w"], m, v, x_ref, eta=0.01, step=step)
        x_ref, m, v = out.x, out.m, out.v
        np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(x_ref),
                                   rtol=1e-5, atol=1e-6)


def test_lamb_matches_single_block_reference(rng):
    params = {"w": jnp.asarray(rng.normal(size=(24, 8)), jnp.float32)}
    g = jnp.asarray(rng.normal(size=(24, 8)), jnp.float32)
    gn = float(jnp.sqrt(jnp.sum(g * g)))
    clip = min(1.0, 1.0 / gn)
    tx = lamb(0.01)
    st = tx.init(params)
    upd, st = tx.update({"w": g}, st, params)
    p = apply_updates(params, upd)
    out = ref.lamb_step_ref(g * clip, jnp.zeros_like(g), jnp.zeros_like(g),
                            params["w"], eta=0.01, step=1)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(out.x),
                               rtol=1e-5, atol=1e-6)


def test_lans_update_is_convex_combination_of_unit_directions(rng):
    """Paper eq. (7): d = b1*u1 + (1-b1)*u2 with ||u1||=||u2||=phi(||x||)."""
    x = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    m = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    v = jnp.asarray(np.abs(rng.normal(size=(64,))), jnp.float32)
    beta1, lam, eps, eta = 0.9, 0.01, 1e-6, 1.0
    out = ref.lans_step_ref(g, m, v, x, eta=eta, beta1=beta1, lam=lam,
                            eps=eps, step=5)
    d = (x - out.x) / eta

    # reconstruct the two normalized directions
    gt = g / jnp.linalg.norm(g)
    m_new = beta1 * m + (1 - beta1) * gt
    v_new = 0.999 * v + 0.001 * gt**2
    denom = jnp.sqrt(v_new / (1 - 0.999**5)) + eps
    r_full = (m_new / (1 - beta1**5)) / denom + lam * x
    c_full = gt / denom + lam * x
    xn = jnp.linalg.norm(x)
    u1 = xn * r_full / jnp.linalg.norm(r_full)
    u2 = xn * c_full / jnp.linalg.norm(c_full)
    np.testing.assert_allclose(np.asarray(d),
                               np.asarray(beta1 * u1 + (1 - beta1) * u2),
                               rtol=1e-4, atol=1e-5)
    # both directions have norm phi(||x||) = ||x||
    np.testing.assert_allclose(float(jnp.linalg.norm(u1)), float(xn), rtol=1e-5)
    np.testing.assert_allclose(float(jnp.linalg.norm(u2)), float(xn), rtol=1e-5)


def test_lans_no_decay_blocks_fall_back_to_adam_style(rng):
    """bias/LN blocks: no trust normalization, no weight decay."""
    params = {"bias": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    grads = {"bias": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    tx = lans(0.01, weight_decay=0.5)  # large decay would show if applied
    st = tx.init(params)
    upd, _ = tx.update(grads, st, params)
    # reference without trust/decay
    out = ref.lans_step_ref(grads["bias"], jnp.zeros((8,)), jnp.zeros((8,)),
                            params["bias"], eta=0.01, lam=0.0, step=1,
                            apply_trust=False)
    np.testing.assert_allclose(np.asarray(apply_updates(params, upd)["bias"]),
                               np.asarray(out.x), rtol=1e-5, atol=1e-6)


def test_weight_decay_mask_excludes_norms_and_biases():
    mask = WeightDecayMask()
    assert mask("slot0/mixer/wq/kernel")
    assert not mask("slot0/mixer/wq/bias")
    assert not mask("final_norm/scale")
    assert not mask("embed_ln/bias")


def test_nag_equivalence_identity(rng):
    """sgd(nesterov) update == mu*m_t + g_t with m_t = mu*m_{t-1} + g_t."""
    p = {"w": jnp.zeros((4,), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    tx = sgd(1.0, mu=0.5, nesterov=True)
    st = tx.init(p)
    upd, st = tx.update(g, st, p)
    # m1 = g; update = -(0.5*g + g)
    np.testing.assert_allclose(np.asarray(upd["w"]),
                               np.asarray(-(1.5 * g["w"])), rtol=1e-6)


def test_bn_adamw_is_scale_invariant_per_block(rng):
    """Paper finetuning optimizer: eq (4) makes updates invariant to grad scale."""
    params = {"w": jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)}
    g_scaled = {"w": 1000.0 * g["w"]}
    tx = bn_adamw(0.01)
    u1, _ = tx.update(g, tx.init(params), params)
    u2, _ = tx.update(g_scaled, tx.init(params), params)
    np.testing.assert_allclose(np.asarray(u1["w"]), np.asarray(u2["w"]),
                               rtol=1e-5, atol=1e-7)


def test_optimizers_make_progress_on_quadratic(rng):
    """All optimizers reduce a simple strongly-convex objective."""
    target = jnp.asarray(rng.normal(size=(32,)), jnp.float32)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    # NB: zero init would freeze LAMB/LANS (phi(||x||)=0 trust ratio — a real
    # property of the family), so start from a random point.
    for name, tx in [("lans", lans(0.1, weight_decay=0.0)),
                     ("lamb", lamb(0.1, weight_decay=0.0)),
                     ("adamw", adamw(0.1, weight_decay=0.0)),
                     ("sgd", sgd(0.05, mu=0.9))]:
        p = {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
        st = tx.init(p)
        l0 = float(loss(p))
        for _ in range(60):
            g = jax.grad(loss)(p)
            upd, st = tx.update(g, st, p)
            p = apply_updates(p, upd)
        assert float(loss(p)) < 0.2 * l0, name


def test_tree_paths_structure():
    t = {"a": {"b": jnp.zeros(1), "c": [jnp.zeros(1), jnp.zeros(1)]}}
    paths = tree_paths(t)
    flat = jax.tree_util.tree_leaves(paths)
    assert flat == ["a/b", "a/c/0", "a/c/1"]

"""Loop-aware HLO cost model: validated against known-FLOP programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import HloModule, analyze_hlo_text


def _cost(f, *specs):
    compiled = jax.jit(f).lower(*specs).compile()
    return analyze_hlo_text(compiled.as_text())


def test_single_matmul_exact():
    M, K, N = 128, 256, 64
    c = _cost(lambda a, b: a @ b,
              jax.ShapeDtypeStruct((M, K), jnp.float32),
              jax.ShapeDtypeStruct((K, N), jnp.float32))
    assert c.flops == 2 * M * K * N


def test_scan_multiplies_by_trip_count():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        return jax.lax.scan(body, x, None, length=10)[0]

    c = _cost(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    want = 10 * 2 * 128**3
    assert abs(c.flops - want) / want < 0.01, (c.flops, want)


def test_nested_scans_multiply():
    def f(x):
        def outer(c, _):
            def inner(y, _):
                return y @ y, None
            return jax.lax.scan(inner, c, None, length=5)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    c = _cost(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    want = 15 * 2 * 64**3
    assert abs(c.flops - want) / want < 0.01


def test_xla_builtin_is_loop_blind():
    """Regression guard for WHY this module exists."""
    def f(x):
        def body(c, _):
            return c @ c, None
        return jax.lax.scan(body, x, None, length=10)[0]

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    xla = compiled.cost_analysis()
    xla = xla[0] if isinstance(xla, list) else xla
    ours = analyze_hlo_text(compiled.as_text()).flops
    # XLA reports ~1 body; we report ~10 bodies
    assert ours > 5 * float(xla.get("flops", 0))


def test_collectives_scaled_by_loops():
    import os
    text = """
HloModule test, entry_computation_layout={()->f32[8]{0}}

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8]{0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %ar = f32[8]{0} all-reduce(%x), to_apply=%sum
  ROOT %t = (s32[], f32[8]{0}) tuple(%i2, %ar)
}

%cond (p2: (s32[], f32[8])) -> pred[] {
  %p2 = (s32[], f32[8]{0}) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i3, %n), direction=LT
}

ENTRY %main () -> f32[8] {
  %c0 = s32[] constant(0)
  %x0 = f32[8]{0} constant({1,1,1,1,1,1,1,1})
  %tup = (s32[], f32[8]{0}) tuple(%c0, %x0)
  %w = (s32[], f32[8]{0}) while(%tup), condition=%cond, body=%body
  ROOT %r = f32[8]{0} get-tuple-element(%w), index=1
}
"""
    c = analyze_hlo_text(text)
    # 7 iterations x 32 bytes
    assert c.coll["all-reduce"] == 7 * 32, c.coll
    assert c.coll_counts["all-reduce"] == 7


def test_shape_parser_handles_dtypes():
    m = HloModule(
        "ENTRY %e (a: bf16[2,3]) -> bf16[2,3] {\n"
        "  %a = bf16[2,3]{1,0} parameter(0)\n"
        "  ROOT %z = bf16[2,3]{1,0} add(%a, %a)\n}")
    c = m.cost_of(m.entry)
    assert c.bytes >= 12  # 6 elems x 2 bytes result
    assert c.flops == 6

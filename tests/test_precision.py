"""Mixed-precision subsystem: policies, loss scaling, master weights, kernels."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import precision as prec
from repro.configs import reduced_arch
from repro.core.optim import apply_updates, lans
from repro.kernels import ops
from repro.precision import (
    DynamicLossScale,
    StaticLossScale,
    fused_mixed_lans,
    get_policy,
    loss_scale_value,
    mixed_precision,
    overflow_count,
)


def _tiny_params():
    return {
        "layer": {"kernel": jnp.ones((8, 4), jnp.float32) * 0.5,
                  "bias": jnp.zeros((4,), jnp.float32)},
        "ln": {"scale": jnp.ones((4,), jnp.float32),
               "bias": jnp.zeros((4,), jnp.float32)},
        "ids": jnp.arange(3, dtype=jnp.int32),  # non-float leaf passes through
    }


# ---------------------------------------------------------------------------
# Policy casting
# ---------------------------------------------------------------------------

def test_policy_casts_mixed_pytree_with_overrides():
    policy = get_policy("fp16_mixed")
    lp = policy.cast_params(_tiny_params())
    assert lp["layer"]["kernel"].dtype == jnp.float16
    # per-block overrides: LN scale + every bias stay fp32
    assert lp["layer"]["bias"].dtype == jnp.float32
    assert lp["ln"]["scale"].dtype == jnp.float32
    assert lp["ln"]["bias"].dtype == jnp.float32
    # integer leaves untouched
    assert lp["ids"].dtype == jnp.int32

    bf = get_policy("bf16").cast_params(_tiny_params())
    assert bf["layer"]["kernel"].dtype == jnp.bfloat16
    assert bf["ln"]["scale"].dtype == jnp.float32

    f32 = get_policy("fp32").cast_params(_tiny_params())
    assert all(l.dtype in (jnp.float32, jnp.int32)
               for l in jax.tree.leaves(f32))


def test_policy_registry_aliases():
    assert get_policy("fp16") is get_policy("fp16_mixed")
    with pytest.raises(KeyError):
        get_policy("fp8_e4m3")  # not (yet) a policy
    p = get_policy("fp32")
    assert get_policy(p) is p  # idempotent on Policy instances


# ---------------------------------------------------------------------------
# Loss-scale state machine
# ---------------------------------------------------------------------------

def test_dynamic_scale_overflow_halves_and_recovery_doubles():
    ls = DynamicLossScale(init_scale=1024.0, growth_interval=2)
    st = ls.init()
    bad = jnp.bool_(False)
    good = jnp.bool_(True)

    st = ls.adjust(st, bad)
    assert float(st.scale) == 512.0 and int(st.overflow_count) == 1
    st = ls.adjust(st, bad)
    assert float(st.scale) == 256.0 and int(st.overflow_count) == 2
    st = ls.adjust(st, good)
    assert float(st.scale) == 256.0 and int(st.good_steps) == 1
    st = ls.adjust(st, good)  # second clean step -> grow
    assert float(st.scale) == 512.0 and int(st.good_steps) == 0


def test_dynamic_scale_respects_bounds():
    ls = DynamicLossScale(init_scale=2.0, growth_interval=1,
                          min_scale=1.0, max_scale=4.0)
    st = ls.init()
    st = ls.adjust(st, jnp.bool_(True))
    st = ls.adjust(st, jnp.bool_(True))
    st = ls.adjust(st, jnp.bool_(True))
    assert float(st.scale) == 4.0  # clamped at max
    for _ in range(5):
        st = ls.adjust(st, jnp.bool_(False))
    assert float(st.scale) == 1.0  # clamped at min


def test_static_scale_never_moves():
    ls = StaticLossScale(1.0)
    st = ls.init()
    st = ls.adjust(st, jnp.bool_(False))
    assert float(st.scale) == 1.0 and int(st.overflow_count) == 1


# ---------------------------------------------------------------------------
# mixed_precision wrapper: overflow/recovery under jit
# ---------------------------------------------------------------------------

def test_overflow_skips_step_halves_scale_params_unchanged_under_jit():
    policy = get_policy("fp16_mixed")
    lp = policy.cast_params(_tiny_params())
    tx = mixed_precision(lans(1e-2), policy)
    state = tx.init(lp)
    scale0 = float(loss_scale_value(state))

    @jax.jit
    def step(p, s, g):
        u, s2 = tx.update(g, s, p)
        return apply_updates(p, u), s2

    def grads_like(p, fill):
        return jax.tree.map(
            lambda x: jnp.full(x.shape, fill, x.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else jnp.zeros_like(x), p)

    # seeded overflow: one inf leaf => whole step must be skipped
    bad = grads_like(lp, 1.0)
    bad["layer"]["kernel"] = bad["layer"]["kernel"].at[0, 0].set(jnp.inf)
    p2, s2 = step(lp, state, bad)

    assert float(loss_scale_value(s2)) == scale0 / 2       # halved
    assert int(overflow_count(s2)) == 1                     # counted
    for a, b in zip(jax.tree.leaves(lp), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # clean step afterwards trains normally at the reduced scale
    good = grads_like(lp, float(loss_scale_value(s2)))
    p3, s3 = step(p2, s2, good)
    assert int(overflow_count(s3)) == 1
    assert bool(jnp.any(p3["layer"]["kernel"] != p2["layer"]["kernel"]))


def test_dynamic_scale_grows_inside_jit_after_interval():
    policy = get_policy("fp16_mixed")
    lp = policy.cast_params(_tiny_params())
    ls = DynamicLossScale(init_scale=8.0, growth_interval=3)
    tx = mixed_precision(lans(1e-3), policy, loss_scale=ls)
    state = tx.init(lp)

    @jax.jit
    def step(p, s, g):
        u, s2 = tx.update(g, s, p)
        return apply_updates(p, u), s2

    g = jax.tree.map(
        lambda x: jnp.ones(x.shape, x.dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else jnp.zeros_like(x), lp)
    p, s = lp, state
    for _ in range(3):
        p, s = step(p, s, g)
    assert float(loss_scale_value(s)) == 16.0


# ---------------------------------------------------------------------------
# Master-weight round trip: fp16_mixed tracks fp32 LANS
# ---------------------------------------------------------------------------

def test_master_weight_parity_reduced_bert_large():
    """Identical gradient sequences through fp32 LANS vs fp16_mixed LANS:
    the fp32 master must evolve IDENTICALLY (the lp copy only affects the
    forward pass, which is pinned here), so the low-precision params equal
    the fp16 cast of the fp32 result to 1 ulp. This isolates the master
    round trip: stash/merge, power-of-two unscaling, cast-back."""
    arch = reduced_arch("bert-large")
    params0 = arch.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    B, S = 2, 32
    toks = rng.integers(0, arch.cfg.vocab, size=(B, S))
    batch = {"tokens": jnp.asarray(toks, jnp.int32),
             "mlm_labels": jnp.asarray(
                 np.where(rng.random((B, S)) < 0.15, toks, -100), jnp.int32),
             "nsp_labels": jnp.zeros((B,), jnp.int32)}
    # one real backward pass supplies the (fixed) gradient direction
    (_, _), g0 = jax.value_and_grad(arch.loss_fn, has_aux=True)(params0, batch)
    g0 = jax.tree.map(lambda x: x.astype(jnp.float32), g0)
    SCALE = 128.0  # power of two: scale/unscale round trip is exact in fp32

    def train_fp32(steps=3):
        tx = lans(5e-3)
        p, st = params0, tx.init(params0)
        for i in range(steps):
            g = jax.tree.map(lambda x: x * (1.0 + 0.1 * i), g0)
            u, st = tx.update(g, st, p)
            p = apply_updates(p, u)
        return p

    def train_fp16(steps=3):
        # fp32 moments so the only deltas are master-weight machinery
        policy = dataclasses.replace(get_policy("fp16_mixed"),
                                     moment_dtype=jnp.float32)
        tx = mixed_precision(lans(5e-3), policy,
                             loss_scale=StaticLossScale(SCALE))
        p = policy.cast_params(params0)
        st = tx.init(p)
        for i in range(steps):
            g = jax.tree.map(lambda x: x * (1.0 + 0.1 * i) * SCALE, g0)
            u, st = tx.update(g, st, p)
            p = apply_updates(p, u)
        return p

    p_ref = train_fp32()
    p_lp = train_fp16()
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(p_ref)[0],
            jax.tree_util.tree_flatten_with_path(p_lp)[0]):
        a_cast = np.asarray(a.astype(b.dtype), np.float32)  # 1-ulp headroom
        np.testing.assert_allclose(
            a_cast, np.asarray(b, np.float32), rtol=1e-3, atol=1e-6,
            err_msg=f"{jax.tree_util.keystr(path)}")


# ---------------------------------------------------------------------------
# Fused cast-and-apply path
# ---------------------------------------------------------------------------

def test_fused_mixed_kernel_lp_output_is_cast_of_master():
    rng = np.random.default_rng(0)
    n = 1 << 12
    g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    m = jnp.zeros((n,), jnp.float32)
    v = jnp.zeros((n,), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    out = ops.fused_lans_mixed_step(g, m, v, x, eta=0.01, step=1,
                                    lp_dtype=jnp.float16)
    ref = ops.fused_lans_step(g, m, v, x, eta=0.01, step=1)
    np.testing.assert_allclose(np.asarray(out.x), np.asarray(ref.x),
                               rtol=1e-6, atol=1e-7)
    assert out.x_lp.dtype == jnp.float16
    np.testing.assert_array_equal(
        np.asarray(out.x_lp), np.asarray(out.x.astype(jnp.float16)))


def test_fused_mixed_lans_matches_generic_wrapper():
    policy = dataclasses.replace(get_policy("fp16_mixed"),
                                 moment_dtype=jnp.float32)
    lp = policy.cast_params(_tiny_params())
    ls = StaticLossScale(64.0)

    def run(tx, steps=4):
        p, st = lp, tx.init(lp)
        for i in range(steps):
            fill = jnp.inf if i == 1 else 64.0 * (i + 1) * 0.01
            # step 1 overflows: both paths must skip identically (no moment
            # update, no schedule tick) or they diverge afterwards.
            g = jax.tree.map(
                lambda x: jnp.full(x.shape, fill, x.dtype)
                if jnp.issubdtype(x.dtype, jnp.floating)
                else jnp.zeros_like(x), p)
            u, st = tx.update(g, st, p)
            p = apply_updates(p, u)
        return p

    p_gen = run(mixed_precision(lans(1e-2, weight_decay=0.01), policy,
                                loss_scale=ls))
    p_fus = run(fused_mixed_lans(1e-2, policy, loss_scale=ls,
                                 weight_decay=0.01))
    for a, b in zip(jax.tree.leaves(p_gen), jax.tree.leaves(p_fus)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-4)


# ---------------------------------------------------------------------------
# build_train_step integration (mesh + sharding specs + seeded overflow)
# ---------------------------------------------------------------------------

def test_build_train_step_policy_end_to_end_with_seeded_overflow():
    from repro.distributed.steps import build_train_step, jit_train_step
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(data=1, model=1)
    policy = get_policy("fp16_mixed")

    def float_params(rng):
        p = dict(_tiny_params())
        del p["ids"]  # value_and_grad wants inexact inputs only
        return p

    # a loss whose grad explodes under the 2^15 scale on demand: the "boom"
    # feature multiplies params by a huge constant, so the scaled gradient
    # overflows fp32 -> the skip-and-halve path must execute under jax.jit.
    def loss_fn(params, batch):
        # 1e-2 keeps the scaled first-step grads inside fp16 range at the
        # apex default init scale (2^16)
        base = 1e-2 * sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                          for l in jax.tree.leaves(params))
        boom = batch["boom"] * 1e38 * jnp.sum(
            params["layer"]["kernel"].astype(jnp.float32))
        return base + boom, {}

    step_fn, init_fn, specs_for = build_train_step(
        loss_fn, lans(1e-2), mesh,
        param_init_fn=float_params,
        policy=policy)

    params, opt_state = init_fn(jax.random.PRNGKey(0))
    assert params["layer"]["kernel"].dtype == jnp.float16
    pspec, ospec = specs_for(params, opt_state)

    batch = {"boom": jnp.zeros((), jnp.float32)}
    jitted = jit_train_step(step_fn, mesh, pspec, ospec, batch)

    with mesh:
        p1, o1, m1 = jitted(params, opt_state, batch)
    init_scale = DynamicLossScale().init_scale
    assert bool(m1["grads_finite"])
    assert float(m1["loss_scale"]) == init_scale
    assert int(m1["overflow_count"]) == 0

    with mesh:
        p2, o2, m2 = jitted(p1, o1, {"boom": jnp.ones((), jnp.float32)})
    assert not bool(m2["grads_finite"])
    assert float(m2["loss_scale"]) == init_scale / 2  # halved
    assert int(m2["overflow_count"]) == 1
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    with mesh:
        p3, o3, m3 = jitted(p2, o2, batch)
    assert bool(m3["grads_finite"])
    assert bool(jnp.any(p3["layer"]["kernel"] != p2["layer"]["kernel"]))


def test_opt_state_bytes_smaller_than_fp32():
    """The sparse-master layout keeps lp optimizer state under fp32's."""
    def nbytes(tree):
        return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(tree))

    params = _tiny_params()
    st32 = lans(1e-3).init(params)

    policy = get_policy("fp16_mixed")
    lp = policy.cast_params(params)
    st16 = mixed_precision(lans(1e-3, mu_dtype=policy.moment_dtype),
                           policy).init(lp)
    assert nbytes(st16) < nbytes(st32)
    assert nbytes(st16) + nbytes(lp) < nbytes(st32) + nbytes(params)

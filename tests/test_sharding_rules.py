"""Partition-spec rule tests (no big meshes needed — rules are pure)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch, reduced_arch
from repro.distributed import sharding as shd
from repro.launch.mesh import make_local_mesh


class FakeMesh:
    """Shape-only stand-in (tests run on 1 CPU device)."""
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


MESH = FakeMesh(data=16, model=16)
POD_MESH = FakeMesh(pod=2, data=16, model=16)


def test_embedding_shards_vocab():
    s = shd.param_spec("embed/embedding", (131072, 6144), MESH)
    assert s == P("model", None)


def test_attention_column_and_row_parallel():
    assert shd.param_spec("slot0/mixer/wq/kernel", (13, 6144, 6144), MESH,
                          n_stack_dims=1) == P(None, None, "model")
    assert shd.param_spec("slot0/mixer/wo/kernel", (13, 6144, 6144), MESH,
                          n_stack_dims=1) == P(None, "model", None)


def test_mlp_column_row():
    assert shd.param_spec("slot0/ffn/up/kernel", (2, 1024, 4096), MESH,
                          n_stack_dims=1) == P(None, None, "model")
    assert shd.param_spec("slot0/ffn/down/kernel", (2, 4096, 1024), MESH,
                          n_stack_dims=1) == P(None, "model", None)


def test_moe_expert_parallel_when_divisible():
    # jamba: 16 experts on model=16 -> expert parallel
    s = shd.param_spec("slot1/ffn/up", (9, 16, 8192, 24576), MESH,
                       n_stack_dims=1)
    assert s == P(None, "model", None, None)


def test_moe_ff_fallback_when_not_divisible():
    # grok: 8 experts, granite: 40 experts -> shard the ff dim instead
    s = shd.param_spec("slot0/ffn/up", (64, 8, 6144, 32768), MESH,
                       n_stack_dims=1)
    assert s == P(None, None, None, "model")
    s = shd.param_spec("slot0/ffn/down", (64, 8, 32768, 6144), MESH,
                       n_stack_dims=1)
    assert s == P(None, None, "model", None)


def test_zero3_adds_data_axis():
    s = shd.param_spec("slot0/mixer/wq/kernel", (64, 6144, 6144), MESH,
                       zero3=True, n_stack_dims=1)
    assert s == P(None, "data", "model")


def test_bias_and_norms_replicated():
    assert shd.param_spec("slot0/pre_mixer_norm/scale", (64, 6144), MESH,
                          n_stack_dims=1) == P(None, None)
    assert shd.param_spec("final_norm/scale", (6144,), MESH) == P(None)


def test_batch_pspec_uses_pod_and_data():
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    s1 = shd.batch_pspec(batch, MESH)
    assert s1["tokens"] == P(("data",), None)
    s2 = shd.batch_pspec(batch, POD_MESH)
    assert s2["tokens"] == P(("pod", "data"), None)


def test_cache_pspec_kv_layout():
    cache = {"slots": ({"k": jax.ShapeDtypeStruct((13, 128, 32768, 8, 128),
                                                  jnp.bfloat16)},)}
    s = shd.cache_pspec(cache, MESH)
    assert s["slots"][0]["k"] == P(None, "data", None, None, "model")


def test_full_params_spec_no_crashes_and_divisible():
    """Every full arch: every sharded dim must divide the axis size."""
    mesh = FakeMesh(data=16, model=16)
    for name in ("grok-1-314b", "gemma2-2b", "jamba-1.5-large-398b",
                 "whisper-large-v3", "mamba2-130m"):
        arch = get_arch(name)
        params = arch.abstract_params()
        specs = shd.params_pspec(params, mesh, zero3=arch.zero3)
        for leaf, spec in zip(jax.tree.leaves(params),
                              jax.tree.leaves(specs,
                                              is_leaf=lambda x: isinstance(x, P))):
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = int(np.prod([mesh.shape[a] for a in axes]))
                assert dim % size == 0, (name, leaf.shape, spec)


def test_real_mesh_end_to_end_tiny():
    """1x1 local mesh: constrained train step still runs on CPU."""
    arch = reduced_arch("granite-moe-3b-a800m")
    mesh = make_local_mesh(data=1, model=1)
    params = arch.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    with mesh:
        loss, _ = jax.jit(arch.loss_fn)(params, batch)
    assert bool(jnp.isfinite(loss))

"""Chunked-prefill admission + open-loop traffic (serving/admission.py,
serving/traffic.py).

The load-bearing claims, each asserted here:

  * BUDGET PARTITION: plan_chunk never displaces a decode (size +
    n_active <= budget), emits only granularity * 2^k sizes (bounded
    compile set), never overshoots the prompt, and always progresses
    once spare capacity allows — property-tested as a hypothesis state
    machine that drives one task to completion under adversarial
    decode counts;
  * CHUNK-BOUNDARY EXACTNESS (the differential): chunked admission is
    token-identical to whole-prompt prefill — fp32 across ALL engine
    layouts (static == dense == paged == chunked), bf16 within the
    same layout (paged whole vs paged chunked, plain and tie-stable
    greedy), and on a mamba-hybrid arch whose SSD scan dictates the
    chunk granularity;
  * the PR 5 follow-ups folded into the controller: preemption-victim
    selection minimizes resume cost when the context carries one, and
    the dynamic-watermark gate + finalize requeue keep a scarce arena
    correct (preemption/requeue stays output-invisible);
  * OPEN-LOOP: the driver submits on the arrival clock (fake-clock
    deterministic test), SLO accounting flags exactly the violating
    traces, and chunked vs unchunked open-loop replays of one arrival
    schedule emit identical tokens;
  * telemetry: retained-LRU hit rate + prefix-miss counters surface in
    the report, and stable_argmax is one-ulp tie-invariant.
"""
import numpy as np
import pytest

from conftest import make_serving_requests as make_requests
from conftest import setup_serving_arch as setup_arch
from repro.serving import (AdmissionController, ContinuousEngine,
                           OpenLoopDriver, PolicyContext, SLO,
                           Sampler, SchedulingPolicy, ServeEngine,
                           bimodal_requests, chunk_granularity, hit_rate,
                           meets_slo, plan_chunk, poisson_arrivals,
                           slo_report, stable_argmax)
from repro.serving.metrics import RequestTrace

pytestmark = [pytest.mark.serving, pytest.mark.chunked]

MAX_LEN = 48

SPEC = [(7, 4), (23, 6), (5, 1), (17, 3), (11, 4)]


def tokens_of(reqs):
    return [list(r.generated) for r in reqs]


# --------------------------------------------------------------------------
# plan_chunk: the budget partition (pure host function)
# --------------------------------------------------------------------------

def test_plan_chunk_basics():
    # spare = 8 - 3 = 5, remaining 32 -> largest gran*2^k <= 5 is 4
    assert plan_chunk(8, 3, 2, 32) == 4
    # full decode batch leaves no spare
    assert plan_chunk(8, 8, 2, 32) == 0
    # nothing left to chunk
    assert plan_chunk(8, 0, 2, 0) == 0
    # idle step: whole budget, quantized to a power of two
    assert plan_chunk(12, 0, 2, 64) == 8
    # final partial chunk is exactly what remains
    assert plan_chunk(12, 0, 2, 4) == 4
    # mamba-style granularity
    assert plan_chunk(16, 3, 4, 64) == 8


def test_plan_chunk_state_machine():
    """Drive one prefill task to completion under adversarial decode
    counts: the budget partition must conserve the budget every step,
    quantize sizes, and finish the prompt with no unreachable tail."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import settings
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
    st = hypothesis.strategies

    class ChunkAccounting(RuleBasedStateMachine):
        @initialize(gran=st.sampled_from([2, 4]), budget_mult=st.integers(1, 8),
                    prompt_mult=st.integers(1, 24))
        def setup(self, gran, budget_mult, prompt_mult):
            self.gran = gran
            self.budget = gran * budget_mult
            self.padded = gran * prompt_mult
            self.offset = 0
            self.sizes = []

        @rule(n_active=st.integers(0, 32))
        def step(self, n_active):
            remaining = self.padded - self.offset
            size = plan_chunk(self.budget, n_active, self.gran, remaining)
            if size:
                # budget conservation: decodes always got their token
                assert size + n_active <= self.budget
                # quantized: granularity * 2^k exactly
                q = size // self.gran
                assert size % self.gran == 0 and q & (q - 1) == 0
                assert size <= remaining
            else:
                # no progress only when genuinely impossible
                assert remaining == 0 or \
                    self.budget - n_active < self.gran
            self.offset += size
            self.sizes.append(size)

        @invariant()
        def aligned_and_bounded(self):
            if not hasattr(self, "padded"):
                return      # before initialize
            assert 0 <= self.offset <= self.padded
            assert self.offset % self.gran == 0
            assert sum(self.sizes) == self.offset

    ChunkAccounting.TestCase.settings = settings(
        max_examples=60, deadline=None)
    ChunkAccounting.TestCase().runTest()


def test_controller_size_set_and_guards():
    arch, params = setup_arch("gemma2-2b")
    ctrl = AdmissionController(arch, params, chunk_budget=12,
                               prefill_len=MAX_LEN)
    # granularity * 2^k up to the budget: the whole compile set
    g = chunk_granularity(arch.cfg)
    assert ctrl.sizes() == [g * 2 ** k for k in range(4) if g * 2 ** k <= 12]
    assert set(plan_chunk(12, a, g, 64) for a in range(13)) <= \
        set(ctrl.sizes()) | {0}
    with pytest.raises(ValueError, match="granularity"):
        AdmissionController(arch, params, chunk_budget=1,
                            prefill_len=MAX_LEN)
    with pytest.raises(ValueError, match="paged"):
        ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                         cache="dense", chunk_budget=8)


# --------------------------------------------------------------------------
# the acceptance differential: chunked == whole-prompt prefill
# --------------------------------------------------------------------------

def _chunked_engine(arch, params, policy="fp32", sampler="greedy", **kw):
    kw.setdefault("chunk_budget", 6)
    return ContinuousEngine(arch, params, max_batch=3, max_len=MAX_LEN,
                            policy=policy, cache="paged", block_size=8,
                            prefill_bucket=8, sampler=sampler, **kw)


def test_chunked_quad_identity_fp32():
    """static == dense == paged == chunked, greedy fp32: chunk-resumable
    prefill is token-identical to whole-prompt prefill across every
    engine layout."""
    arch, params = setup_arch("gemma2-2b")
    outs = []
    for build in (
            lambda: ServeEngine(arch, params, max_len=MAX_LEN,
                                policy="fp32"),
            lambda: ContinuousEngine(arch, params, max_batch=2,
                                     max_len=MAX_LEN, policy="fp32",
                                     cache="dense", prefill_bucket=8),
            lambda: ContinuousEngine(arch, params, max_batch=3,
                                     max_len=MAX_LEN, policy="fp32",
                                     cache="paged", block_size=8,
                                     prefill_bucket=8),
            lambda: _chunked_engine(arch, params)):
        reqs = make_requests(arch, SPEC)
        build().run_batch(reqs)
        outs.append(tokens_of(reqs))
    assert outs[0] == outs[1] == outs[2] == outs[3]


def test_chunked_report_counters():
    arch, params = setup_arch("gemma2-2b")
    reqs = make_requests(arch, SPEC)
    eng = _chunked_engine(arch, params)
    eng.run_batch(reqs)
    eng.pool.check_invariants()
    stats = eng.report(1.0)
    assert stats["chunk_budget"] == 6
    # every admission was chunked: at least ceil(padded / budget-max)
    assert stats["chunk_steps"] >= len(SPEC)
    # padded rows chunked covers every prompt's padded length
    assert stats["chunk_tokens"] >= sum(-(-n // 8) * 8 for n, _ in SPEC)
    # share=False: chunked blocks are never content-addressed, so they
    # neither hit nor miss the prefix registry
    assert stats["prefix_misses"] == 0
    assert 0.0 <= stats["retained_hit_rate"] <= 1.0


@pytest.mark.paged
def test_chunked_bf16_same_layout():
    """Same-layout bf16 pair: paged whole-prefill vs paged chunked emit
    identical tokens under plain greedy AND the tie-stable argmax (the
    cross-layout bf16 caveat does not apply within one layout, and
    stable=1 additionally pins one-ulp ties to the lowest index)."""
    arch, params = setup_arch("qwen2.5-14b")
    for sampler in ("greedy", "temperature=0,stable=1"):
        outs = []
        for build in (
                lambda: ContinuousEngine(arch, params, max_batch=3,
                                         max_len=MAX_LEN, policy="bf16",
                                         cache="paged", block_size=8,
                                         prefill_bucket=8, sampler=sampler),
                lambda: _chunked_engine(arch, params, policy="bf16",
                                        sampler=sampler)):
            reqs = make_requests(arch, SPEC)
            build().run_batch(reqs)
            outs.append(tokens_of(reqs))
        assert outs[0] == outs[1], f"sampler={sampler}"


def test_chunked_mamba_granularity():
    """Hybrid attention+mamba arch: chunk sizes must be multiples of the
    SSD scan chunk, and chunked output still matches whole-prefill."""
    arch, params = setup_arch("jamba-1.5-large-398b")
    g = chunk_granularity(arch.cfg)
    assert g % arch.cfg.mamba_chunk == 0 and g >= 2
    outs = []
    for build in (
            lambda: ContinuousEngine(arch, params, max_batch=3,
                                     max_len=MAX_LEN, policy="fp32",
                                     cache="paged", block_size=8,
                                     prefill_bucket=8),
            lambda: _chunked_engine(arch, params, chunk_budget=4 * g)):
        reqs = make_requests(arch, SPEC)
        eng = build()
        eng.run_batch(reqs)
        outs.append(tokens_of(reqs))
    assert outs[0] == outs[1]
    # the engine rounded its prefill bucket up to a granularity multiple
    assert _chunked_engine(arch, params,
                           chunk_budget=4 * g).prefill_bucket % g == 0


@pytest.mark.sched
def test_chunked_scarce_arena_requeue_invisible():
    """Dynamic watermark + finalize requeue under a scarce arena: long
    budgets force growth preemptions around in-flight chunk tasks, and
    the output still matches an unconstrained whole-prefill run —
    preemption, requeue and re-chunking are output-invisible."""
    arch, params = setup_arch("gemma2-2b")
    spec = [(7, 10), (23, 10), (11, 10), (17, 10)]
    reqs = make_requests(arch, spec)
    ref = ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                           policy="fp32", cache="paged", block_size=8,
                           prefill_bucket=8)
    ref.run_batch(reqs)
    want = tokens_of(reqs)
    reqs = make_requests(arch, spec)
    eng = _chunked_engine(arch, params, slots_budget=2)
    eng.run_batch(reqs)
    eng.pool.check_invariants()
    assert tokens_of(reqs) == want


def test_resume_cost_victim():
    """Base victim rule: with resume_cost in the context pick the slot
    whose continuation re-chunks the fewest tokens (tie: youngest
    admission); without one, the classic youngest-admission victim."""
    pol = SchedulingPolicy()
    seq = {0: 1, 1: 2, 2: 3}
    ctx = PolicyContext(admit_seq=seq,
                        resume_cost=lambda s: {0: 40, 1: 8, 2: 16}[s])
    assert pol.victim([0, 1, 2], ctx) == 1
    tie = PolicyContext(admit_seq=seq,
                        resume_cost=lambda s: {0: 8, 1: 8, 2: 16}[s])
    assert pol.victim([0, 1, 2], tie) == 1    # tie -> youngest of the tied
    classic = PolicyContext(admit_seq=seq)
    assert pol.victim([0, 1, 2], classic) == 2


# --------------------------------------------------------------------------
# open-loop traffic
# --------------------------------------------------------------------------

def test_poisson_arrivals_seeded():
    a = poisson_arrivals(64, 10.0, seed=3)
    b = poisson_arrivals(64, 10.0, seed=3)
    assert np.array_equal(a, b)
    assert np.all(np.diff(a) > 0) and a[0] > 0
    # mean inter-arrival ~ 1/rate (loose: seeded, so deterministic)
    assert 0.05 < np.mean(np.diff(a)) < 0.2
    with pytest.raises(ValueError):
        poisson_arrivals(4, 0.0)


def test_bimodal_requests_deterministic():
    arch, _ = setup_arch("gemma2-2b")
    a = bimodal_requests(16, arch.cfg.vocab, short_len=8, long_len=64,
                         new_tokens=4, long_frac=0.5, seed=9)
    b = bimodal_requests(16, arch.cfg.vocab, short_len=8, long_len=64,
                         new_tokens=4, long_frac=0.5, seed=9)
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    lens = [len(r.prompt) for r in a]
    assert any(n >= 48 for n in lens) and any(n <= 8 for n in lens)


def _trace(submit, token_ts):
    t = RequestTrace(submit_t=submit)
    for ts in token_ts:
        t.mark_token(ts)
    return t


def test_slo_accounting():
    slo = SLO(ttft_ms=100.0, itl_ms=50.0)
    good = _trace(0.0, [0.05, 0.08, 0.12])
    late_first = _trace(0.0, [0.2, 0.22])           # TTFT 200ms
    stalled = _trace(0.0, [0.05, 0.30])             # one 250ms gap
    assert meets_slo(good, slo)
    assert not meets_slo(late_first, slo)
    assert not meets_slo(stalled, slo)              # ONE gap disqualifies

    class R:
        def __init__(self, trace, n):
            self.trace, self.generated = trace, list(range(n))
    reqs = [R(good, 3), R(late_first, 2), R(stalled, 2)]
    rep = slo_report(reqs, slo, wall_s=1.0)
    assert rep["goodput_tokens_per_s"] == 3.0       # only the good stream
    assert rep["tokens_per_s"] == 7.0
    assert rep["ttft_violations"] == 1 and rep["itl_violations"] == 1
    assert rep["slo_attainment"] == pytest.approx(1 / 3)
    with pytest.raises(ValueError):
        SLO(ttft_ms=0.0, itl_ms=1.0)


def test_open_loop_driver_fake_clock():
    """Deterministic driver semantics on a fake clock: requests submit
    at their arrival offsets (never early), the engine only steps when
    it has work, and idle time sleeps to the next arrival."""
    class FakeEngine:
        def __init__(self):
            self.log = []
            self.pending = 0

            class Sched:
                has_work = property(lambda s: self.pending > 0)
            self.scheduler = Sched()

        def submit(self, req):
            self.log.append(("submit", req, clock["t"]))
            self.pending += 1

        def step(self):
            self.log.append(("step", None, clock["t"]))
            clock["t"] += 0.01          # a step costs 10ms
            self.pending -= 1           # one req finishes per step

    clock = {"t": 5.0}                  # nonzero base: offsets, not epochs

    def sleep(dt):
        assert dt > 0
        # a real sleep always lands past the deadline; a pure `+= dt`
        # can round away below the clock's ulp and spin forever
        clock["t"] += max(dt, 1e-6)

    eng = FakeEngine()
    arrivals = [0.02, 0.30, 0.30]       # a burst after an idle gap
    drv = OpenLoopDriver(eng, ["a", "b", "c"], arrivals,
                         time_fn=lambda: clock["t"], sleep_fn=sleep)
    wall = drv.run()
    subs = [(r, t - 5.0) for op, r, t in eng.log if op == "submit"]
    # never submitted before its arrival offset
    for (r, t), arr in zip(subs, arrivals):
        assert t >= arr - 1e-9
    assert [r for r, _ in subs] == ["a", "b", "c"]
    assert sum(1 for op, _, _ in eng.log if op == "step") == 3
    assert wall == pytest.approx(clock["t"] - 5.0)
    with pytest.raises(ValueError):
        OpenLoopDriver(eng, ["a"], [0.1, 0.2])


def test_open_loop_replay_identity():
    """Chunked vs unchunked engines driven by the SAME seeded arrival
    schedule emit identical tokens — open-loop scheduling (arrival
    timing, queue order, chunk sizes) never leaks into the output."""
    arch, params = setup_arch("gemma2-2b")
    arrivals = poisson_arrivals(6, 50.0, seed=2)
    outs = []
    for chunk_budget in (None, 6):
        reqs = bimodal_requests(6, arch.cfg.vocab, short_len=5,
                                long_len=24, new_tokens=4, long_frac=0.5,
                                seed=4)
        eng = ContinuousEngine(arch, params, max_batch=3, max_len=MAX_LEN,
                               policy="fp32", cache="paged", block_size=8,
                               prefill_bucket=8, chunk_budget=chunk_budget)
        OpenLoopDriver(eng, reqs, arrivals).run()
        assert all(r.generated is not None for r in reqs)
        outs.append(tokens_of(reqs))
    assert outs[0] == outs[1]


# --------------------------------------------------------------------------
# telemetry + stable argmax
# --------------------------------------------------------------------------

def test_hit_rate_unit():
    assert hit_rate(0, 0) == 0.0
    assert hit_rate(3, 1) == 0.75
    assert hit_rate(0, 5) == 0.0


@pytest.mark.sched
def test_retained_hit_rate_telemetry():
    """Two waves sharing a system prompt: wave 2 revives wave 1's
    retained prefix blocks, and the report's retained_hit_rate /
    prefix_misses reflect exactly that."""
    arch, params = setup_arch("gemma2-2b")
    eng = ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                           policy="fp32", cache="paged", block_size=8,
                           prefill_bucket=8, retain_blocks=8)
    for seed in (1, 2):     # distinct tails, same prefix stream
        eng.run_batch(make_requests(arch, [(5, 2), (7, 2)], seed=seed,
                                    prefix=16, prefix_seed=1))
    stats = eng.report(1.0)
    assert stats["retained_block_hits"] >= 1
    assert stats["prefix_misses"] >= 1
    assert stats["retained_hit_rate"] == pytest.approx(
        hit_rate(stats["retained_block_hits"], stats["prefix_misses"]))
    assert stats["retained_hit_rate"] > 0.0


@pytest.mark.sched
def test_retain_blocks_default_covers_working_set():
    """The evidence behind the retain_blocks default (one BATCH's worth,
    max_batch * max_len / block_size): on cyclic multi-tenant waves the
    old one-request's-worth bound LRU-thrashes to a zero hit rate, while
    the default holds the whole working set warm."""
    arch, params = setup_arch("gemma2-2b")

    def run(retain_blocks):
        eng = ContinuousEngine(arch, params, max_batch=3, max_len=64,
                               policy="fp32", cache="paged", block_size=8,
                               prefill_bucket=8,
                               retain_blocks=retain_blocks)
        for wave in range(3):
            for tenant in range(3):    # per-tenant system prompt
                eng.run_batch(make_requests(
                    arch, [(5, 2), (9, 2)], seed=100 * wave + tenant,
                    prefix=16, prefix_seed=tenant))
        return eng.report(1.0)["retained_hit_rate"]

    assert run(64 // 8) == 0.0          # one request's worth: thrash
    assert run(None) > 0.5              # default (one batch's worth)


def test_stable_argmax_tie_invariance():
    import jax.numpy as jnp
    from repro.serving.sampler import BF16_EPS
    # a one-ulp tie: plain argmax picks whichever index holds the max
    # bit pattern; stable_argmax picks the LOWEST tied index either way
    row_a = jnp.asarray([[0.0, 1.0, 1.0 - BF16_EPS / 2, -3.0]])
    row_b = jnp.asarray([[0.0, 1.0 - BF16_EPS / 2, 1.0, -3.0]])
    assert int(stable_argmax(row_a)[0]) == 1
    assert int(stable_argmax(row_b)[0]) == 1
    # far-apart logits: degrades to plain argmax
    clear = jnp.asarray([[0.0, 5.0, 1.0]])
    assert int(stable_argmax(clear)[0]) == 1
    # batch shape + dtype
    out = stable_argmax(jnp.concatenate([row_a, row_b]))
    assert out.shape == (2,) and out.dtype == jnp.int32
    s = Sampler.parse("temperature=0,stable=1")
    assert s.greedy and s.stable_tiebreak
    assert int(s.sample(row_b, None)[0]) == 1

"""Marker / lane coverage audit: the test-tree <-> pytest.ini <->
scripts/run_tests.sh triangle stays closed.

Three claims, each of which has silently rotted in other projects:

  * every marker used anywhere under tests/ is REGISTERED in pytest.ini
    (an unregistered marker is a typo that silently deselects nothing);
  * every registered suite marker has a scripts/run_tests.sh lane, so
    each suite can be run in isolation (exemptions are pinned
    explicitly, with the reason);
  * the per-module marker inventory matches a pinned table — adding a
    test module or changing its family markers forces this audit to be
    updated in the same PR, which is the point.
"""
import configparser
import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parent.parent
TESTS = ROOT / "tests"

# Markers that deliberately have no run_tests.sh -m lane, and why.
LANE_EXEMPT = {
    "slow",      # the exclusion filter itself; included via --all
    "serving",   # spans most of tier-1 — the default lane covers it
}

# Pinned inventory: test module -> the pytest.ini markers it applies at
# module level or per-test. Modules absent from markers entirely map to
# the empty set (they run only in the default tier-1 lane).
EXPECTED_MODULE_MARKERS = {
    "test_admission.py": {"serving", "chunked", "paged", "sched"},
    "test_archs_smoke.py": set(),
    "test_bert_scoring.py": {"serving", "bert"},
    "test_distributed_steps.py": set(),
    "test_docs.py": set(),
    "test_encdec_serving.py": {"serving", "encdec"},
    "test_exactness_envelope.py": {"serving", "sharded"},
    "test_fused_integration.py": set(),
    "test_hlo_cost.py": set(),
    "test_kernels.py": {"kernels"},
    "test_markers.py": set(),
    "test_metrics_and_launchers.py": set(),
    "test_models.py": set(),
    "test_optimizers.py": set(),
    "test_paged_cache.py": {"serving", "paged"},
    "test_precision.py": set(),
    "test_properties.py": set(),
    "test_router.py": {"serving"},
    "test_sampling.py": {"serving"},
    "test_schedules_and_data.py": set(),
    "test_scheduling.py": {"serving", "sched", "paged", "slow"},
    "test_serving_engine.py": {"serving", "paged", "slow"},
    "test_serving_properties.py": {"paged", "sched", "spec"},
    "test_sharded_serving.py": {"serving", "sharded", "paged",
                                "chunked", "spec"},
    "test_sharding_rules.py": set(),
    "test_speculative.py": {"serving", "spec"},
    "test_system.py": set(),
}

_MARK_RE = re.compile(r"pytest\.mark\.([A-Za-z_][A-Za-z0-9_]*)")
# pytest builtins / structural marks that need no pytest.ini entry
_BUILTIN = {"parametrize", "skipif", "skip", "xfail", "usefixtures",
            "filterwarnings"}


def registered_markers():
    cp = configparser.ConfigParser()
    cp.read(ROOT / "pytest.ini")
    lines = cp.get("pytest", "markers").strip().splitlines()
    return {line.split(":", 1)[0].strip() for line in lines if line.strip()}


def module_markers(path):
    used = set(_MARK_RE.findall(path.read_text()))
    return used - _BUILTIN


def lane_markers():
    """Markers run_tests.sh exposes as `-m \"<marker>\"` lanes."""
    text = (ROOT / "scripts" / "run_tests.sh").read_text()
    return set(re.findall(r'-m "([a-z_]+)"', text))


def test_all_used_markers_are_registered():
    registered = registered_markers()
    for path in sorted(TESTS.glob("test_*.py")):
        unknown = module_markers(path) - registered
        assert not unknown, (
            f"{path.name} uses unregistered markers {sorted(unknown)}: "
            f"register them in pytest.ini")


def test_every_suite_marker_has_a_lane():
    lanes = lane_markers()
    missing = registered_markers() - lanes - LANE_EXEMPT
    assert not missing, (
        f"registered markers without a scripts/run_tests.sh lane: "
        f"{sorted(missing)} — add a --<marker> lane or pin an "
        f"exemption with its reason")
    stale = lanes - registered_markers()
    assert not stale, (
        f"run_tests.sh lanes for unregistered markers: {sorted(stale)}")


def test_module_marker_inventory_is_pinned():
    actual = {p.name: module_markers(p)
              for p in sorted(TESTS.glob("test_*.py"))}
    assert set(actual) == set(EXPECTED_MODULE_MARKERS), (
        "test modules added/removed: update EXPECTED_MODULE_MARKERS",
        sorted(set(actual) ^ set(EXPECTED_MODULE_MARKERS)))
    for name, markers in actual.items():
        assert markers == EXPECTED_MODULE_MARKERS[name], (
            f"{name} marker set changed: expected "
            f"{sorted(EXPECTED_MODULE_MARKERS[name])}, found "
            f"{sorted(markers)} — update the pinned inventory")


def test_every_family_module_carries_its_family_marker():
    """The two workload-family suites must stay runnable via their
    dedicated lanes (--bert / --encdec)."""
    assert "bert" in module_markers(TESTS / "test_bert_scoring.py")
    assert "encdec" in module_markers(TESTS / "test_encdec_serving.py")

import os

# Tests must see the real single-device CPU config (the 512-device override
# is dryrun.py-local). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)

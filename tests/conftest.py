import os

# Tests must see the real single-device CPU config (the 512-device override
# is dryrun.py-local). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# shared serving-test helpers (tests/test_serving_engine.py,
# test_paged_cache.py, test_sampling.py): one reduced-arch cache per run and
# ONE request-generation convention — the differential claims across files
# (static == dense == paged, sampled == greedy at temp 0, ...) are only
# comparable because every file builds byte-identical workloads.
# ---------------------------------------------------------------------------

_arch_cache = {}


def setup_serving_arch(name):
    """(reduced arch, params) memoized across the whole test session."""
    if name not in _arch_cache:
        import jax
        from repro.configs import reduced_arch
        arch = reduced_arch(name)
        _arch_cache[name] = (arch, arch.init(jax.random.PRNGKey(0)))
    return _arch_cache[name]


def make_serving_requests(arch, spec, seed=1, prefix=0):
    """spec: list of (prompt_len, max_new_tokens). Prompts are a pure
    function of (seed, index) so a request run solo is byte-identical to
    the same request inside any batch; prefix > 0 prepends that many
    COMMON tokens (the shared system prompt the paged pool dedups)."""
    from repro.serving import Request
    rng = np.random.default_rng([seed, 999])
    common = rng.integers(5, arch.cfg.vocab, size=prefix).astype(np.int32)
    return [Request(prompt=np.concatenate([
                        common,
                        np.random.default_rng([seed, i]).integers(
                            5, arch.cfg.vocab, size=n).astype(np.int32)]),
                    max_new_tokens=m)
            for i, (n, m) in enumerate(spec)]

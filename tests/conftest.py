import os

# Tests must see the real single-device CPU config (the 512-device override
# is dryrun.py-local). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# per-suite duration report (scripts/run_tests.sh --durations-report):
# REPRO_DURATIONS_JSON=<path> makes the session write accumulated
# setup+call+teardown wall clock per test module as machine-readable JSON,
# so successive PRs can track where tier-1 time goes without parsing -q
# output. Inert (zero hooks' work) when the env var is unset.
# ---------------------------------------------------------------------------

_suite_durations = {}


def pytest_runtest_logreport(report):
    if not os.environ.get("REPRO_DURATIONS_JSON"):
        return
    module = report.nodeid.split("::", 1)[0]
    _suite_durations[module] = (_suite_durations.get(module, 0.0)
                                + report.duration)


def pytest_sessionfinish(session, exitstatus):
    out = os.environ.get("REPRO_DURATIONS_JSON")
    if not out:
        return
    import json
    blob = {
        "total_s": round(sum(_suite_durations.values()), 3),
        "suites": {m: round(s, 3)
                   for m, s in sorted(_suite_durations.items(),
                                      key=lambda kv: -kv[1])},
    }
    with open(out, "w") as f:
        json.dump(blob, f, indent=2)
        f.write("\n")


# ---------------------------------------------------------------------------
# shared serving-test helpers (tests/test_serving_engine.py,
# test_paged_cache.py, test_sampling.py): one reduced-arch cache per run and
# ONE request-generation convention — the differential claims across files
# (static == dense == paged, sampled == greedy at temp 0, ...) are only
# comparable because every file builds byte-identical workloads.
# ---------------------------------------------------------------------------

_arch_cache = {}


def setup_serving_arch(name):
    """(reduced arch, params) memoized across the whole test session."""
    if name not in _arch_cache:
        import jax
        from repro.configs import reduced_arch
        arch = reduced_arch(name)
        _arch_cache[name] = (arch, arch.init(jax.random.PRNGKey(0)))
    return _arch_cache[name]


def make_serving_requests(arch, spec, seed=1, prefix=0, max_new_tokens=None,
                          prefix_seed=None):
    """spec: list of (prompt_len, max_new_tokens) pairs, or of bare
    prompt lengths with an EXPLICIT uniform `max_new_tokens` — every
    request always carries an explicit finite budget, which is what the
    lazy-growth differentials rely on (the budget IS the reservation /
    growth horizon; an implicit default would silently change what the
    allocator plans). Prompts are a pure function of (seed, index) so a
    request run solo is byte-identical to the same request inside any
    batch; prefix > 0 prepends that many COMMON tokens (the shared
    system prompt the paged pool dedups). prefix_seed (default: seed)
    decouples the prefix stream from the tails, so disjoint request
    waves can carry the SAME system prompt — the retained-LRU tests'
    across-wave revival shape."""
    from repro.serving import Request
    norm = []
    for entry in spec:
        if isinstance(entry, tuple):
            norm.append(entry)
        else:
            if max_new_tokens is None:
                raise ValueError(
                    "bare prompt lengths need an explicit max_new_tokens")
            norm.append((entry, max_new_tokens))
    rng = np.random.default_rng(
        [seed if prefix_seed is None else prefix_seed, 999])
    common = rng.integers(5, arch.cfg.vocab, size=prefix).astype(np.int32)
    return [Request(prompt=np.concatenate([
                        common,
                        np.random.default_rng([seed, i]).integers(
                            5, arch.cfg.vocab, size=n).astype(np.int32)]),
                    max_new_tokens=m)
            for i, (n, m) in enumerate(norm)]

"""ReplicaRouter tests: policy decisions, sticky affinity bookkeeping,
token identity across fleet layouts, and the fleet report schema.

The differential claim mirrors the engine suite's: ROUTING NEVER
CHANGES TOKENS. A request's output depends only on (params, prompt,
budget, sampler) — never on which replica serves it or who its slot
neighbours are — so one engine, a 2-replica prefix-affinity fleet and
a 2-replica round-robin fleet must all emit identical streams.
"""
import numpy as np
import pytest

from conftest import make_serving_requests as make_requests
from conftest import setup_serving_arch as setup_arch
from repro.serving import (ContinuousEngine, ROUTE_POLICIES, ReplicaRouter,
                           Request, prefix_route_key)

pytestmark = pytest.mark.serving

ARCH = "qwen2.5-14b"


def _prompt(seed, n, vocab=256):
    return np.random.default_rng(seed).integers(
        5, vocab, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
# prefix_route_key: the content-addressed affinity key
# ---------------------------------------------------------------------------

def test_route_key_sub_block_prompts_have_no_key():
    assert prefix_route_key(_prompt(0, 7), 8) is None
    assert prefix_route_key(_prompt(0, 8), 8) is not None


def test_route_key_depends_only_on_leading_block():
    p = _prompt(1, 24)
    q = np.concatenate([p[:8], _prompt(2, 40)])   # same leading block
    r = p.copy()
    r[3] += 1                                     # perturb inside block 0
    assert prefix_route_key(p, 8) == prefix_route_key(q, 8)
    assert prefix_route_key(p, 8) != prefix_route_key(r, 8)
    # block_size is part of the key: same tokens, different granularity
    assert prefix_route_key(p, 8) != prefix_route_key(p, 16)


# ---------------------------------------------------------------------------
# routing decisions on stub replicas (no jax work)
# ---------------------------------------------------------------------------

class _StubSched:
    def __init__(self):
        self.queued, self.active, self.completed = 0, {}, []

    @property
    def has_work(self):
        return bool(self.queued or self.active)


class _StubReplica:
    def __init__(self):
        self.scheduler = _StubSched()
        self.submitted = []

    def submit(self, req):
        self.submitted.append(req)
        self.scheduler.queued += 1


def _stub_router(n=3, **kw):
    return ReplicaRouter([_StubReplica() for _ in range(n)],
                         block_size=8, **kw)


def test_rr_policy_cycles():
    rt = _stub_router(policy="rr")
    reqs = [Request(prompt=_prompt(i, 16)) for i in range(7)]
    assert [rt.route(r) for r in reqs] == [0, 1, 2, 0, 1, 2, 0]


def test_depth_policy_picks_least_outstanding():
    rt = _stub_router(policy="depth")
    rt.replicas[0].scheduler.queued = 5
    rt.replicas[1].scheduler.queued = 1
    rt.replicas[2].scheduler.queued = 3
    assert rt.route(Request(prompt=_prompt(0, 16))) == 1
    rt.replicas[1].scheduler.active = {0: None, 1: None, 2: None, 3: None}
    assert rt.route(Request(prompt=_prompt(1, 16))) == 2


def test_prefix_policy_sticky_under_depth_changes():
    rt = _stub_router(policy="prefix")
    shared = _prompt(7, 8)
    first = Request(prompt=np.concatenate([shared, _prompt(1, 8)]))
    home = rt.route(first)
    # pile work onto the home replica: affinity must still win
    rt.replicas[home].scheduler.queued = 100
    later = Request(prompt=np.concatenate([shared, _prompt(2, 8)]))
    assert rt.route(later) == home
    assert rt.routed_affinity_hits == 1


def test_prefix_policy_sub_block_falls_back_to_depth():
    rt = _stub_router(policy="prefix")
    rt.replicas[0].scheduler.queued = 9
    rt.replicas[2].scheduler.queued = 9
    assert rt.route(Request(prompt=_prompt(0, 4))) == 1   # < one block
    assert rt.routed_fallback == 1
    assert rt.routed_affinity_hits == 0


def test_prefix_policy_distinct_prefixes_balance_by_depth():
    rt = _stub_router(policy="prefix")
    homes = []
    for i in range(4):
        req = Request(prompt=_prompt(100 + i, 16))
        home = rt.route(req)
        homes.append(home)
        rt.replicas[home].scheduler.queued += 10   # make it look busy
    # distinct keys spread out instead of stacking on one replica
    assert len(set(homes)) == 3


def test_affinity_map_is_bounded_lru():
    rt = _stub_router(policy="prefix", max_keys=2)
    keys = [_prompt(200 + i, 8) for i in range(3)]
    for p in keys:
        rt.route(Request(prompt=p))
    assert len(rt._affinity) == 2   # oldest binding evicted
    # the evicted key re-binds (a warm start, not an error)
    rt.route(Request(prompt=keys[0]))
    assert len(rt._affinity) == 2


def test_submit_lands_on_routed_replica_and_counts():
    rt = _stub_router(policy="rr")
    reqs = [Request(prompt=_prompt(i, 16)) for i in range(4)]
    for r in reqs:
        rt.submit(r)
    assert rt.routed_submits == 4
    assert [len(e.submitted) for e in rt.replicas] == [2, 1, 1]


def test_router_validation():
    with pytest.raises(ValueError, match="at least one replica"):
        ReplicaRouter([])
    with pytest.raises(ValueError, match="route policy"):
        _stub_router(policy="best-effort")
    with pytest.raises(ValueError, match="paged replicas"):
        ReplicaRouter([_StubReplica()], policy="prefix")  # no block_size
    assert set(ROUTE_POLICIES) == {"prefix", "depth", "rr"}


# ---------------------------------------------------------------------------
# live fleets: identity + schema
# ---------------------------------------------------------------------------

def _mk_engine(arch, params, **kw):
    return ContinuousEngine(arch, params, max_batch=2, max_len=48,
                            cache="paged", block_size=8, **kw)


def _mk_reqs(arch):
    # two tenant prefixes (>= one block each) + one sub-block prompt
    a = make_requests(arch, [8, 8], seed=3, prefix=16, max_new_tokens=5)
    b = make_requests(arch, [8, 8], seed=4, prefix=16, prefix_seed=11,
                      max_new_tokens=5)
    tiny = Request(prompt=_prompt(9, 6), max_new_tokens=5)
    reqs = [a[0], b[0], a[1], b[1], tiny]
    return [Request(prompt=r.prompt.copy(),
                    max_new_tokens=r.max_new_tokens) for r in reqs]


@pytest.mark.parametrize("policy", ROUTE_POLICIES)
def test_routed_tokens_match_single_engine(policy):
    arch, params = setup_arch(ARCH)
    solo = _mk_engine(arch, params)
    base = _mk_reqs(arch)
    solo.run(base)

    fleet = ReplicaRouter([_mk_engine(arch, params) for _ in range(2)],
                          policy=policy)
    reqs = _mk_reqs(arch)
    done = fleet.run(reqs)
    assert len(done) == len(base)
    for x, y in zip(base, reqs):
        assert np.array_equal(x.generated, y.generated)
    assert not fleet.scheduler.has_work
    assert fleet.routed_submits == len(base)


def test_router_report_schema():
    arch, params = setup_arch(ARCH)
    fleet = ReplicaRouter([_mk_engine(arch, params) for _ in range(2)],
                          policy="prefix")
    fleet.run(_mk_reqs(arch))
    rep = fleet.report(1.0)
    assert rep["replicas"] == 2
    assert rep["route_policy"] == "prefix"
    assert rep["completed"] == 5
    for key in ("routed_submits", "routed_affinity_hits", "routed_fallback"):
        assert isinstance(rep[key], int) and rep[key] >= 0
    # the sub-block request fell back; the repeat-prefix requests hit
    assert rep["routed_fallback"] >= 1
    assert rep["routed_affinity_hits"] >= 2
    for key in ("tokens_per_s", "retained_hit_rate"):
        assert isinstance(rep[key], float) and np.isfinite(rep[key])
    assert len(rep["per_replica"]) == 2
    for idx, sub in enumerate(rep["per_replica"]):
        assert sub["replica"] == idx
        assert np.isfinite(sub["tokens_per_s"])


def test_router_block_size_defaults_from_paged_replica():
    arch, params = setup_arch(ARCH)
    fleet = ReplicaRouter([_mk_engine(arch, params)], policy="prefix")
    assert fleet.block_size == 8

"""Encoder-decoder serving family: one ContinuousEngine core serves
whisper-style encdec requests with the encoder output registered in the
content-addressed cross-attention block arena.

The differential claims:

  * the decode step is ONE fixed-shape jit for the engine's lifetime
    (`_cache_size() == 1`) — admission/finish churn, varied prompt
    lengths and varied budgets never retrace it;
  * same-input requests SHARE encoder blocks: the cross arena stores
    each distinct `frames` input once (refcounted, like shared prompt
    prefixes), pinned by allocator accounting (ref == 2 mid-run, zero
    live blocks after drain) and by the pool's shared-hit counters;
  * the batch-1 latency path (run_one) is token-identical to pooled
    serving — the dense cross K/V is padded to the arena's blocked
    frame count so both paths contract the same masked length.
"""
import numpy as np
import pytest

from conftest import setup_serving_arch as setup_arch
from repro.serving import (ContinuousEngine, Request,
                           synthetic_encdec_requests)

pytestmark = [pytest.mark.serving, pytest.mark.encdec]

ARCH = "whisper-large-v3"


def _engine(arch, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 32)
    kw.setdefault("prefill_bucket", 8)
    kw.setdefault("block_size", 8)
    kw.setdefault("cache", "paged")
    return ContinuousEngine(arch, params, **kw)


def _requests(arch, n, *, n_inputs=None, seed=3, prompt_len=6,
              new_tokens=8):
    return synthetic_encdec_requests(
        n, arch.cfg.vocab, n_frames=arch.cfg.n_frames,
        d_model=arch.cfg.d_model, prompt_len=prompt_len,
        new_tokens=new_tokens, n_inputs=n_inputs, seed=seed)


# ---------------------------------------------------------------------------
# engine lifecycle + the no-recompile pin
# ---------------------------------------------------------------------------

def test_engine_serves_encdec_with_one_decode_compile():
    arch, params = setup_arch(ARCH)
    eng = _engine(arch, params)
    reqs = _requests(arch, 6, n_inputs=2)
    eng.run(reqs)
    assert len(eng.scheduler.completed) == 6
    for r in reqs:
        assert len(r.generated) == r.max_new_tokens
        assert (np.asarray(r.generated) >= 0).all()
    # varied prompt lengths, varied budgets, admission churn across two
    # waves of slots: exactly ONE decode-step compile
    assert eng._step._cache_size() == 1
    eng.pool.check_invariants()


def test_same_input_requests_share_encoder_blocks():
    """Two decodes of the same input share the encoder's cross blocks:
    mid-run the arena holds ONE refcount-2 chain (not two copies), and
    draining returns every block to free/retained — the allocator
    accounting the tentpole acceptance pins."""
    arch, params = setup_arch(ARCH)
    eng = _engine(arch, params)
    frames = np.random.default_rng(11).standard_normal(
        (arch.cfg.n_frames, arch.cfg.d_model)).astype(np.float32)
    a = Request(prompt=np.arange(5, 11, dtype=np.int32), max_new_tokens=6,
                frames=frames)
    b = Request(prompt=np.arange(7, 13, dtype=np.int32), max_new_tokens=6,
                frames=frames.copy())      # same CONTENT, distinct array
    eng.submit(a)
    eng.submit(b)
    eng.step()                             # both admitted (4 free slots)
    m = eng.pool.map
    blocks_per_input = eng.pool.padded_frames // eng.pool.block_size
    shared = [bi for bi in range(1, m.alloc.n_blocks)
              if m.alloc.ref[bi] == 2]
    assert len(shared) == blocks_per_input, (
        "second decode of the same input must alias the first's "
        "encoder blocks", shared)
    assert eng.pool.shared_hits >= blocks_per_input
    while eng.step():
        pass
    assert len(eng.scheduler.completed) == 2
    assert m.alloc.n_live == 0             # drained: nothing stays live
    eng.pool.check_invariants()


def test_distinct_inputs_do_not_share():
    arch, params = setup_arch(ARCH)
    eng = _engine(arch, params)
    reqs = _requests(arch, 2, n_inputs=2, seed=5)
    for r in reqs:
        eng.submit(r)
    eng.step()
    assert eng.pool.shared_hits == 0
    while eng.step():
        pass
    eng.pool.check_invariants()


def test_retained_cross_blocks_revive_across_waves():
    """Encoder blocks survive refcount 0 on the retained LRU and are
    revived copy-free when the same input returns in a later wave."""
    arch, params = setup_arch(ARCH)
    eng = _engine(arch, params)
    wave1 = _requests(arch, 3, n_inputs=1, seed=9)
    eng.run(wave1)                         # drains: refcounts hit 0
    hits_before = eng.pool.retained_hits
    wave2 = _requests(arch, 3, n_inputs=1, seed=9)   # same frames stream
    eng.run(wave2)
    assert eng.pool.retained_hits > hits_before
    assert eng._step._cache_size() == 1    # revival never retraces
    eng.pool.check_invariants()


# ---------------------------------------------------------------------------
# batch-1 latency mode: token-identical, compiled once
# ---------------------------------------------------------------------------

def test_run_one_matches_pooled_engine_bitwise():
    arch, params = setup_arch(ARCH)
    eng = _engine(arch, params)
    pooled = _requests(arch, 5, n_inputs=2, seed=7)
    eng.run(pooled)
    solo = _requests(arch, 5, n_inputs=2, seed=7)    # byte-identical
    for r in solo:
        eng.run_one(r)
    for p, s in zip(pooled, solo):
        np.testing.assert_array_equal(np.asarray(p.generated),
                                      np.asarray(s.generated))
    assert eng._lat_step._cache_size() == 1
    assert eng._step._cache_size() == 1


# ---------------------------------------------------------------------------
# validation: the family contract is explicit, not emergent
# ---------------------------------------------------------------------------

def test_encdec_requires_paged_cache():
    arch, params = setup_arch(ARCH)
    with pytest.raises(ValueError, match="cache='paged'"):
        _engine(arch, params, cache="dense")


def test_encdec_rejects_scoring_task_and_decoder_only_features():
    arch, params = setup_arch(ARCH)
    with pytest.raises(ValueError, match="bert arch"):
        _engine(arch, params, task="score")
    with pytest.raises(ValueError, match="decoder-only"):
        _engine(arch, params, chunk_budget=8)
    with pytest.raises(ValueError, match="decoder-only"):
        _engine(arch, params, spec_draft=(arch, params))


def test_submit_requires_frames_of_the_configured_length():
    arch, params = setup_arch(ARCH)
    eng = _engine(arch, params)
    with pytest.raises(ValueError, match="frames"):
        eng.submit(Request(prompt=np.arange(5, 9, dtype=np.int32),
                           max_new_tokens=2))
    bad = np.zeros((arch.cfg.n_frames + 1, arch.cfg.d_model), np.float32)
    with pytest.raises(ValueError, match="frames"):
        eng.submit(Request(prompt=np.arange(5, 9, dtype=np.int32),
                           max_new_tokens=2, frames=bad))

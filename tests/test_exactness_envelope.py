"""Standalone exactness-envelope regression suite, parametrized over
ALL THREE workload families (decoder generation, BERT scoring/embedding,
encoder-decoder).

The envelope (first pinned for decoder engines in the sharded-serving
suite, re-stated here as its own regression matrix so the family
dimension can grow without entangling the live-fleet tests):

  * a pure DATA mesh (Dx1) distributes bookkeeping only — engines on it
    are BIT-EXACT against their unsharded twins under EVERY precision
    policy (native, fp32, bf16, fp16_mixed), for every family: tokens,
    MLM scoring ids, and pooled embeddings alike;
  * a MODEL mesh (1xM) splits contractions; CROSS-layout identity
    (sharded vs unsharded) is claimed under policy="fp32" ONLY — under
    bf16 the psum rounding drifts past one-ulp ties, so the bf16 side
    of the envelope is same-layout-only and lives with the live-fleet
    tests. The fp32 identity is over TOKEN outputs (generated ids, MLM
    scoring ids): fp32 keeps every argmax on the same side of its
    boundary. RAW float outputs (the scoring family's pooled embedding)
    are the measured edge of the envelope — the split contraction's
    psum reassociates the fp32 sum, so embeddings drift at the few-ulp
    level (~1e-5 relative observed) and are pinned to a tight tolerance
    instead, NOT bitwise.

Every family builds byte-identical workloads for both engines (the
synthetic_* helpers are pure functions of their arguments), so any
mismatch is the mesh's doing, not the workload's.

These tests need >= 2 local devices; tier-1 (single-device CPU) skips
them. Run via:  scripts/run_tests.sh --sharded
(XLA_FLAGS=--xla_force_host_platform_device_count=2).
"""
import jax
import numpy as np
import pytest

from conftest import make_serving_requests as make_requests
from conftest import setup_serving_arch as setup_arch

pytestmark = [
    pytest.mark.sharded,
    pytest.mark.serving,
    pytest.mark.skipif(
        jax.device_count() < 2,
        reason="needs >= 2 devices: scripts/run_tests.sh --sharded sets "
               "XLA_FLAGS=--xla_force_host_platform_device_count=2"),
]

POLICIES = [None, "fp32", "bf16", "fp16_mixed"]   # "every policy"


def _mesh(kind):
    from repro.launch.mesh import make_local_mesh
    axes = {"data2": dict(data=2, model=1),
            "model2": dict(data=1, model=2)}[kind]
    return make_local_mesh(**axes)


# ---------------------------------------------------------------------------
# family runners: build an engine, run a byte-identical workload, return
# the family's FULL output surface split by kind —
# (token arrays, raw float arrays)
# ---------------------------------------------------------------------------

def _run_decoder(policy, mesh):
    from repro.serving import ContinuousEngine
    arch, params = setup_arch("qwen2.5-14b")
    reqs = make_requests(arch, [(8, 5), (12, 6), (8, 4)], seed=2, prefix=16)
    eng = ContinuousEngine(arch, params, cache="paged", block_size=8,
                           max_batch=2, max_len=48, policy=policy,
                           mesh=mesh)
    eng.run(reqs)
    return [np.asarray(r.generated) for r in reqs], []


def _run_scoring(policy, mesh):
    from repro.serving import ContinuousEngine, synthetic_scoring_requests
    arch, params = setup_arch("bert-large")
    reqs = synthetic_scoring_requests(5, arch.cfg.vocab, prompt_len=12,
                                      seed=3)
    eng = ContinuousEngine(arch, params, task="score", max_batch=4,
                           max_len=16, policy=policy, mesh=mesh)
    eng.run(reqs)
    # scoring's output surface is tokens AND the pooled embedding
    return ([np.asarray(r.generated) for r in reqs],
            [np.asarray(r.embedding) for r in reqs])


def _run_encdec(policy, mesh):
    from repro.serving import ContinuousEngine, synthetic_encdec_requests
    arch, params = setup_arch("whisper-large-v3")
    reqs = synthetic_encdec_requests(
        5, arch.cfg.vocab, n_frames=arch.cfg.n_frames,
        d_model=arch.cfg.d_model, prompt_len=6, new_tokens=8,
        n_inputs=2, seed=4)
    eng = ContinuousEngine(arch, params, cache="paged", block_size=8,
                           prefill_bucket=8, max_batch=4, max_len=32,
                           policy=policy, mesh=mesh)
    eng.run(reqs)
    return [np.asarray(r.generated) for r in reqs], []


FAMILIES = {"decoder": _run_decoder,
            "scoring": _run_scoring,
            "encdec": _run_encdec}

# unsharded baselines memoized per (family, policy): every mesh variant
# compares against ONE baseline run, not a fresh recompute per test
_baseline_cache = {}


def _baseline(family, policy):
    key = (family, policy)
    if key not in _baseline_cache:
        _baseline_cache[key] = FAMILIES[family](policy, None)
    return _baseline_cache[key]


# ---------------------------------------------------------------------------
# the envelope
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("policy", POLICIES,
                         ids=[str(p) for p in POLICIES])
def test_data_mesh_bit_exact_under_every_policy(family, policy):
    """Dx1 re-places bookkeeping only: bit-exact at ANY precision,
    for every family and every output — tokens, MLM ids AND raw
    embeddings alike."""
    base_tok, base_f = _baseline(family, policy)
    got_tok, got_f = FAMILIES[family](policy, _mesh("data2"))
    assert len(base_tok) == len(got_tok) and len(base_f) == len(got_f)
    for x, y in zip(base_tok + base_f, got_tok + got_f):
        np.testing.assert_array_equal(x, y)


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_model_mesh_fp32_cross_layout_identity(family):
    """1xM splits contractions; fp32 keeps every argmax on the same
    side of its boundary, so TOKEN outputs are identical across
    layouts for all three families. Raw float outputs (scoring's
    pooled embedding) sit at the envelope's measured edge: the psum
    reassociates the fp32 sum, so they are pinned to a few-ulp
    tolerance, not bitwise — tightening this would be claiming an
    identity the arithmetic does not provide."""
    base_tok, base_f = _baseline(family, "fp32")
    got_tok, got_f = FAMILIES[family]("fp32", _mesh("model2"))
    assert len(base_tok) == len(got_tok) and len(base_f) == len(got_f)
    for x, y in zip(base_tok, got_tok):
        np.testing.assert_array_equal(x, y)
    for x, y in zip(base_f, got_f):
        np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-6)

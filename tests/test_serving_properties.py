"""Hypothesis properties: scheduler, block allocator, loss-scale machine.

Skips cleanly when the optional `hypothesis` extra is absent (see
requirements.txt) — deterministic versions of the core scheduler and
allocator checks live in tests/test_serving_engine.py and
tests/test_paged_cache.py so tier-1 still covers them.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional test extra (see requirements.txt)")
from hypothesis import given, settings, strategies as st
from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                 invariant, rule)

from repro.precision.loss_scale import (DynamicLossScale, StaticLossScale,
                                        unscale_grads)
from repro.serving.block_allocator import BlockTableMap, NoBlocksError
from repro.serving.scheduler import Scheduler, SchedulerError


# --------------------------------------------------------------------------
# scheduler: no double assignment, FIFO admission, full completion
# --------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(n_slots=st.integers(1, 5),
       n_requests=st.integers(0, 25),
       choices=st.lists(st.integers(0, 2 ** 16), min_size=0, max_size=200))
def test_scheduler_invariants_under_random_schedules(n_slots, n_requests,
                                                     choices):
    """Random interleavings of submit/assign/complete keep every invariant:
    a slot never holds two requests, admissions are FIFO, and draining
    completes every submitted request exactly once."""
    sched = Scheduler(n_slots)
    pending = [f"r{i}" for i in range(n_requests)]
    admitted_order = []
    it = iter(choices)
    for c in it:
        op = c % 3
        if op == 0 and pending:
            sched.submit(pending.pop(0))
        elif op == 1:
            for slot, req in sched.assign():
                admitted_order.append(req)
        elif op == 2 and sched.active:
            slots = sorted(sched.active)
            sched.complete(slots[next(it, 0) % len(slots)]
                           if slots else slots[0])
        sched.check_invariants()
        # a request is in at most one place
        states = (list(sched.active.values()) + sched.completed
                  + [r for _, r in sched.queue_items()] + pending)
        assert len(states) == n_requests
        assert len(set(states)) == n_requests
    # drain: everything submitted eventually completes, exactly once
    while pending:
        sched.submit(pending.pop(0))
    while sched.has_work:
        for slot, req in sched.assign():
            admitted_order.append(req)
        for slot in sorted(sched.active):
            sched.complete(slot)
        sched.check_invariants()
    assert admitted_order == [f"r{i}" for i in range(n_requests)]  # FIFO
    assert sorted(sched.completed) == sorted(f"r{i}"
                                             for i in range(n_requests))


@settings(max_examples=40, deadline=None)
@given(n_slots=st.integers(1, 4),
       budgets=st.lists(st.integers(1, 7), min_size=0, max_size=15))
def test_engine_loop_emits_exactly_max_new_tokens(n_slots, budgets):
    """Pure-python mirror of ContinuousEngine.step()'s control flow (prefill
    emits token 1, each decode step emits one more per active slot, the
    slot frees at its budget): every admitted request ends with exactly
    max_new_tokens tokens and the loop terminates."""
    sched = Scheduler(n_slots)
    emitted = {}
    counts = {}
    for i, b in enumerate(budgets):
        sched.submit((i, b))
    guard = 0
    while sched.has_work:
        guard += 1
        assert guard < 10_000, "engine loop failed to terminate"
        # admissions: prefill produces the first token; 1-token requests
        # complete immediately, freeing the slot for the next in queue
        while True:
            pairs = sched.assign()
            if not pairs:
                break
            for slot, (rid, budget) in pairs:
                emitted[slot] = 1
                counts[rid] = 1
                if emitted[slot] >= budget:
                    sched.complete(slot)
        # one decode step over the active slots
        for slot in sorted(sched.active):
            rid, budget = sched.active[slot]
            emitted[slot] += 1
            counts[rid] += 1
            if emitted[slot] >= budget:
                sched.complete(slot)
        sched.check_invariants()
    assert counts == {i: b for i, (b) in enumerate(budgets)}


# --------------------------------------------------------------------------
# paged-cache block allocator: refcounts, sharing, no leaks
# --------------------------------------------------------------------------

@pytest.mark.paged
@settings(max_examples=60, deadline=None)
@given(data=st.data(),
       max_batch=st.integers(1, 4),
       max_blocks=st.integers(1, 5),
       extra_blocks=st.integers(0, 12))
def test_block_table_map_random_insert_evict_never_leaks(data, max_batch,
                                                         max_blocks,
                                                         extra_blocks):
    """Random interleavings of insert (tiny token alphabet, so prefix-
    registry hits are common) and evict over a small arena keep every
    allocator invariant: refcounts never negative and always equal to
    the table references, a block never sits in two tables unless it is
    a registered shared block, free + live blocks partition the arena,
    and failed inserts roll back completely. Draining evicts returns
    every block: nothing leaks."""
    bs = 4
    ring = max_blocks * bs
    n_blocks = 1 + max_batch + extra_blocks     # null + a scarce arena
    m = BlockTableMap(max_batch, ring, bs, n_blocks)
    occupied = set()
    for _ in range(data.draw(st.integers(0, 25), label="n_ops")):
        if occupied and data.draw(st.booleans(), label="evict?"):
            slot = data.draw(st.sampled_from(sorted(occupied)),
                             label="evict_slot")
            freed = m.evict(slot)
            occupied.discard(slot)
            assert all(m.alloc.ref[b] == 0 for b in freed)
        else:
            free = sorted(set(range(max_batch)) - occupied)
            if not free:
                continue
            slot = data.draw(st.sampled_from(free), label="slot")
            plen = data.draw(st.integers(1, 2 * ring), label="plen")
            padded = -(-plen // bs) * bs
            budget = data.draw(st.integers(1, ring), label="budget")
            prompt = tuple(data.draw(
                st.lists(st.integers(1, 2), min_size=plen, max_size=plen),
                label="prompt"))
            n_free_before = m.alloc.n_free
            need = m.blocks_needed(prompt, plen, padded, budget)
            try:
                placed = m.insert(slot, prompt, plen, padded, budget)
            except NoBlocksError:
                assert need > n_free_before      # gate would have said no
                assert m.alloc.n_free == n_free_before   # full rollback
                assert not m.table[slot].any()
            else:
                occupied.add(slot)
                assert need <= n_free_before
                assert sum(not p.shared for p in placed) == need
        m.check_invariants()
    for slot in sorted(occupied):
        m.evict(slot)
    m.check_invariants()
    assert m.alloc.n_free == n_blocks - 1 and m.alloc.n_live == 0
    assert m.n_shared == 0


@settings(max_examples=40, deadline=None)
@given(n_slots=st.integers(1, 4),
       n_requests=st.integers(0, 12),
       choices=st.lists(st.integers(0, 2 ** 16), min_size=0, max_size=120))
def test_scheduler_preempt_requeue_preserves_arrival_order(n_slots,
                                                           n_requests,
                                                           choices):
    """Random interleavings of submit/assign/PREEMPT/complete: a
    preempted request re-enters the queue at its arrival-ticket
    position, so the queue is always sorted by original submission
    index no matter how many evict/requeue round-trips happen, and
    draining completes every request exactly once."""
    sched = Scheduler(n_slots)
    pending = [f"r{i:04d}" for i in range(n_requests)]
    it = iter(choices)
    for c in it:
        op = c % 4
        if op == 0 and pending:
            sched.submit(pending.pop(0))
        elif op == 1:
            sched.assign()
        elif op == 2 and sched.active:
            slots = sorted(sched.active)
            sched.preempt(slots[next(it, 0) % len(slots)])
        elif op == 3 and sched.active:
            slots = sorted(sched.active)
            sched.complete(slots[next(it, 0) % len(slots)])
        sched.check_invariants()
        queued = [r for _, r in sched.queue_items()]
        assert queued == sorted(queued), (
            "preempt/requeue broke arrival order", queued)
    while pending:
        sched.submit(pending.pop(0))
    while sched.has_work:
        sched.assign()
        for slot in sorted(sched.active):
            sched.complete(slot)
        sched.check_invariants()
    assert sorted(sched.completed) == [f"r{i:04d}" for i in range(n_requests)]


@pytest.mark.paged
@pytest.mark.sched
@settings(max_examples=60, deadline=None)
@given(data=st.data(),
       max_batch=st.integers(1, 4),
       max_blocks=st.integers(1, 5),
       extra_blocks=st.integers(0, 12),
       retain_limit=st.integers(0, 4))
def test_block_table_map_lazy_grow_preempt_retained_lru(data, max_batch,
                                                        max_blocks,
                                                        extra_blocks,
                                                        retain_limit):
    """The lazy-growth/retained-LRU contract under random interleavings
    of lazy and eager inserts, on-demand grows, and evict-as-preempt:

      * the admission accounting is exact: insert fails iff the plan
        (fresh + retained hits) exceeds free + reclaimable-retained,
        and failure rolls back completely;
      * fresh placements + revivals always equal the plan (reclaim can
        convert a retained hit to a miss mid-insert, but the total
        block consumption is conversion-invariant);
      * grow() only fails when free AND retained are both empty (the
        engine's preemption trigger), and the machine recovers by
        evicting a victim — no state corruption either way;
      * check_invariants() holds THROUGHOUT: refcounts == table refs,
        retained blocks are never table-aliased (so live writes cannot
        touch them), and the LRU bound is respected;
      * draining evicts returns every block: free + retained partition
        the arena, nothing leaks, nothing double-frees.
    """
    bs = 4
    ring = max_blocks * bs
    n_blocks = 1 + max_batch + extra_blocks     # null + a scarce arena
    m = BlockTableMap(max_batch, ring, bs, n_blocks,
                      retain_limit=retain_limit)
    live = {}                                   # slot -> (next_row, last_row)
    for _ in range(data.draw(st.integers(0, 30), label="n_ops")):
        ops = ["insert"] + (["grow", "grow", "evict"] if live else [])
        op = data.draw(st.sampled_from(ops), label="op")
        if op == "evict":
            slot = data.draw(st.sampled_from(sorted(live)),
                             label="evict_slot")
            m.evict(slot)           # finish or preempt: map-identical
            del live[slot]
        elif op == "grow":
            slot = data.draw(st.sampled_from(sorted(live)),
                             label="grow_slot")
            nxt, last = live[slot]
            if nxt > last:
                continue            # chain fully grown (or budget 1)
            avail = m.alloc.n_free + m.alloc.n_retained
            try:
                b = m.grow(slot, nxt)
            except NoBlocksError:
                assert avail == 0, "grow failed with reclaimable blocks"
                victim = data.draw(st.sampled_from(sorted(live)),
                                   label="victim")
                m.evict(victim)     # the engine's preempt path
                del live[victim]
            else:
                if b is not None:
                    assert m.alloc.ref[b] == 1   # exclusively owned
                live[slot] = (nxt + 1, last)
        else:
            free_slots = sorted(set(range(max_batch)) - set(live))
            if not free_slots:
                continue
            slot = data.draw(st.sampled_from(free_slots), label="slot")
            plen = data.draw(st.integers(1, 2 * ring), label="plen")
            padded = -(-plen // bs) * bs
            budget = data.draw(st.integers(1, ring), label="budget")
            lazy = data.draw(st.booleans(), label="lazy")
            prompt = tuple(data.draw(
                st.lists(st.integers(1, 2), min_size=plen, max_size=plen),
                label="prompt"))
            fresh, hits = m.admission_plan(prompt, plen, padded, budget,
                                           lazy=lazy)
            avail = m.alloc.n_free + m.alloc.n_retained
            try:
                placed = m.insert(slot, prompt, plen, padded, budget,
                                  lazy=lazy)
            except NoBlocksError:
                assert fresh + hits > avail      # gate would have said no
                assert not m.table[slot].any()   # full rollback
                assert m.alloc.n_free + m.alloc.n_retained == avail
            else:
                assert fresh + hits <= avail
                consumed = (sum(1 for p in placed if not p.shared)
                            + sum(1 for p in placed if p.revived))
                assert consumed == fresh + hits, (
                    "plan not conversion-invariant", placed)
                live[slot] = (plen, plen + budget - 2)
        m.check_invariants()
    for slot in sorted(live):
        m.evict(slot)
    m.check_invariants()
    assert m.alloc.n_live == 0
    assert m.alloc.n_free + m.alloc.n_retained == n_blocks - 1   # no leaks
    assert m.n_retained <= retain_limit


# --------------------------------------------------------------------------
# ReplicaRouter sticky bounded-LRU affinity map (serving/router.py)
# --------------------------------------------------------------------------

class _StubRouterSched:
    """No-jax engine stub: just the scheduler surface _depth reads."""

    def __init__(self):
        self.queued, self.active, self.completed = 0, {}, []

    @property
    def has_work(self):
        return bool(self.queued or self.active)


class _StubRouterReplica:
    def __init__(self):
        self.scheduler = _StubRouterSched()

    def submit(self, req):
        self.scheduler.queued += 1


class RouterAffinityMachine(RuleBasedStateMachine):
    """The sticky bounded-LRU map's state machine, mirrored against a
    pure-python model. Invariants (checked after EVERY rule):

      * the map never exceeds its bound, and overflow evicts exactly
        the least-recently-USED key (OrderedDict equality is
        order-sensitive, so the mirror pins the LRU order too);
      * sticky beats depth: a mapped key routes to its bound replica
        no matter how lopsided the fleet's outstanding work is;
      * an unseen (or evicted-and-returning) key binds to the replica
        with the LEAST outstanding work at decision time;
      * replica drain never orphans keys: every binding remains a
        valid replica index and keeps routing — a stale binding costs
        a warm start, never an error.
    """

    N_REPLICAS = 3
    MAX_KEYS = 3
    BLOCK = 8

    @initialize()
    def setup(self):
        import collections
        from repro.serving import ReplicaRouter, Request
        self.Request = Request
        self.rt = ReplicaRouter(
            [_StubRouterReplica() for _ in range(self.N_REPLICAS)],
            policy="prefix", block_size=self.BLOCK,
            max_keys=self.MAX_KEYS)
        self.model = collections.OrderedDict()   # key -> replica
        self.prompts = {}                        # prefix id -> prompt

    def _prompt(self, pid):
        if pid not in self.prompts:
            # distinct leading blocks: each pid is its own affinity key
            self.prompts[pid] = np.full(self.BLOCK, 5 + pid,
                                        dtype=np.int32)
        return self.prompts[pid]

    def _least_depth(self):
        return min(range(self.N_REPLICAS),
                   key=lambda i: (self.rt.replicas[i].scheduler.queued
                                  + len(self.rt.replicas[i]
                                        .scheduler.active), i))

    @rule(pid=st.integers(0, 7), load=st.booleans())
    def route(self, pid, load):
        from repro.serving import prefix_route_key
        prompt = self._prompt(pid)
        key = prefix_route_key(prompt, self.BLOCK)
        sticky = self.model.get(key)
        expect = sticky if sticky is not None else self._least_depth()
        home = self.rt.route(self.Request(prompt=prompt))
        assert home == expect, (
            "sticky-beats-depth / least-depth bind violated",
            pid, home, expect)
        if sticky is not None:
            self.model.move_to_end(key)
        else:
            self.model[key] = home
            if len(self.model) > self.MAX_KEYS:
                self.model.popitem(last=False)   # LRU eviction
        if load:       # routed requests usually become outstanding work
            self.rt.replicas[home].submit(None)

    @rule(i=st.integers(0, N_REPLICAS - 1), n=st.integers(1, 5))
    def add_load(self, i, n):
        self.rt.replicas[i].scheduler.queued += n

    @rule(i=st.integers(0, N_REPLICAS - 1))
    def drain_replica(self, i):
        """Replica finishes everything: keys bound to it must survive
        (sticky by design — they are bindings, not work references)."""
        sched = self.rt.replicas[i].scheduler
        sched.queued, sched.active = 0, {}

    @invariant()
    def map_mirrors_model_and_respects_bound(self):
        if not hasattr(self, "rt"):
            return
        assert len(self.rt._affinity) <= self.MAX_KEYS
        assert self.rt._affinity == self.model   # content AND LRU order
        assert all(0 <= i < self.N_REPLICAS
                   for i in self.rt._affinity.values()), "orphan binding"


RouterAffinityMachine.TestCase.settings = settings(
    max_examples=40, stateful_step_count=30, deadline=None)
TestRouterAffinityMachine = RouterAffinityMachine.TestCase


# --------------------------------------------------------------------------
# dynamic loss scale: skip-and-halve state machine (precision/loss_scale.py)
# --------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(flags=st.lists(st.booleans(), min_size=0, max_size=60),
       growth_interval=st.integers(1, 5),
       init_pow=st.integers(0, 10))
def test_dynamic_loss_scale_matches_reference_machine(flags, growth_interval,
                                                      init_pow):
    """Fold an arbitrary finite/overflow history through adjust(): the jit
    state machine must track the apex reference exactly — halve on
    overflow (floored at min_scale), double after `growth_interval`
    consecutive clean steps (capped at max_scale), count every skip."""
    scaler = DynamicLossScale(init_scale=2.0 ** init_pow,
                              growth_interval=growth_interval,
                              min_scale=1.0, max_scale=2.0 ** 12)
    state = scaler.init()
    scale, good, overflows = 2.0 ** init_pow, 0, 0
    for finite in flags:
        state = scaler.adjust(state, jnp.bool_(finite))
        if finite:
            good += 1
            if good >= growth_interval:
                scale = min(scale * 2.0, 2.0 ** 12)
                good = 0
        else:
            scale = max(scale * 0.5, 1.0)
            good = 0
            overflows += 1
        assert float(state.scale) == scale
        assert int(state.good_steps) == good
        assert int(state.overflow_count) == overflows
        # structural invariants, independent of the reference
        assert 1.0 <= float(state.scale) <= 2.0 ** 12
        assert 0 <= int(state.good_steps) < growth_interval


@settings(max_examples=30, deadline=None)
@given(flags=st.lists(st.booleans(), min_size=1, max_size=40))
def test_static_loss_scale_never_moves(flags):
    scaler = StaticLossScale(scale_value=8.0)
    state = scaler.init()
    for finite in flags:
        state = scaler.adjust(state, jnp.bool_(finite))
        assert float(state.scale) == 8.0
    assert int(state.overflow_count) == sum(1 for f in flags if not f)


@settings(max_examples=30, deadline=None)
@given(scale_pow=st.integers(0, 16), seed=st.integers(0, 2 ** 31 - 1))
def test_unscale_divides_float_leaves_exactly(scale_pow, seed):
    """Power-of-two scales divide out bit-exactly; int leaves untouched."""
    scaler = DynamicLossScale(init_scale=2.0 ** scale_pow)
    state = scaler.init()
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(5, 3)), jnp.float32),
         "step": jnp.asarray(7, jnp.int32)}
    scaled = {"w": g["w"] * state.scale, "step": g["step"]}
    out = unscale_grads(scaled, state)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(g["w"]))
    assert out["step"].dtype == jnp.int32 and int(out["step"]) == 7


# --------------------------------------------------------------------------
# speculative accept-then-rollback against the REAL device pool
# --------------------------------------------------------------------------

_spec_pool_fixture = {}


def _spec_pool_arch():
    """Reduced gemma2 (sliding window 16: chains wrap, wrap-COW fires)
    + one memoized 8-token prefill cache — the hypothesis loop reuses
    both; only host bookkeeping and small device scatters vary."""
    if not _spec_pool_fixture:
        from conftest import setup_serving_arch
        arch, params = setup_serving_arch("gemma2-2b")
        _, req_cache = arch.prefill(
            params, {"tokens": np.arange(5, 13, dtype=np.int32)[None]},
            cache_len=32, per_slot=True,
            positions=np.arange(8, dtype=np.int32)[None])
        _spec_pool_fixture["arch"] = arch
        _spec_pool_fixture["req"] = req_cache
    return _spec_pool_fixture["arch"], _spec_pool_fixture["req"]


@pytest.mark.paged
@pytest.mark.spec
@settings(max_examples=12, deadline=None)
@given(data=st.data(), retain_limit=st.integers(0, 3))
def test_paged_pool_accept_rollback_state_machine(data, retain_limit):
    """Random speculative rounds (grow K rows -> write positions ->
    accept a prefix -> roll back the rest) interleaved with admissions
    and evictions against a REAL PagedCachePool, mirroring exactly what
    ContinuousEngine._spec_round does:

      * rollback is ONLY a min-scatter + cursor replace: the rolled-back
        rows' positions read -1 from every layer afterwards (no stale
        pos visible to the kernel) while accepted rows keep theirs;
      * grow() hands the writer exclusively-owned blocks even when the
        chain wraps onto SHARED prompt blocks (wrap-COW) — so the
        simulated verify writes never touch another holder's content,
        and COW composes with a rollback in the same round;
      * check_invariants() holds throughout (refcount == table refs,
        retained blocks never table-aliased, free/live/retained
        partition) and draining evicts leaks nothing.
    """
    from repro.serving import NoBlocksError, PagedCachePool

    arch, req_cache = _spec_pool_arch()
    K = 4
    max_batch = 3
    pool = PagedCachePool(arch, max_batch, max_len=24, block_size=4,
                          growth="lazy", retain_blocks=retain_limit,
                          row_margin=K - 1)
    n_blocks = {si: m.alloc.n_blocks for si, m in pool.maps.items()}
    live = {}                      # slot -> {"cursor": int, "end": int}
    cursors = np.zeros(max_batch, np.int32)

    def write_rows(slot, rows):
        """Simulate the verify scatter: pos[row] = row at each grown
        row's (block, offset) — only ever into exclusive blocks."""
        slots = list(pool.cache["slots"])
        for si, m in pool.maps.items():
            pos = slots[si]["pos"]
            for r in rows:
                rr = r % m.ring_len
                blk = int(m.table[slot, rr // m.block_size])
                assert blk != 0, "grown row left unbacked"
                assert m.alloc.ref[blk] == 1, (
                    "verify write would hit a shared block (COW missed)")
                pos = pos.at[:, blk, rr % m.block_size].set(r)
            slots[si] = {**slots[si], "pos": pos}
        pool.cache = {"slots": tuple(slots), "index": pool.cache["index"]}

    def pos_at(si, slot, r):
        m = pool.maps[si]
        rr = r % m.ring_len
        blk = int(m.table[slot, rr // m.block_size])
        return np.asarray(
            pool.cache["slots"][si]["pos"])[:, blk, rr % m.block_size]

    prompts = [tuple([v] * 8) for v in (1, 2)]   # tiny alphabet: sharing
    for _ in range(data.draw(st.integers(1, 12), label="n_ops")):
        ops = ["insert"] + (["round", "round", "evict"] if live else [])
        op = data.draw(st.sampled_from(ops), label="op")
        if op == "evict":
            slot = data.draw(st.sampled_from(sorted(live)), label="evict")
            pool.evict(slot)
            del live[slot]
        elif op == "insert":
            free = sorted(set(range(max_batch)) - set(live))
            if not free:
                continue
            slot = data.draw(st.sampled_from(free), label="slot")
            prompt = data.draw(st.sampled_from(prompts), label="prompt")
            budget = data.draw(st.integers(2, 16), label="budget")
            try:
                pool.insert(req_cache, slot, prompt=prompt, plen=8,
                            padded_len=8, budget=budget)
            except NoBlocksError:
                assert not any(m.table[slot].any()
                               for m in pool.maps.values())  # atomic
            else:
                live[slot] = {"cursor": 8, "end": 8 + budget - 2}
                cursors[slot] = 8
        else:                                    # one speculative round
            slot = data.draw(st.sampled_from(sorted(live)), label="round")
            st_ = live[slot]
            if st_["cursor"] > st_["end"]:
                pool.evict(slot)                 # budget exhausted
                del live[slot]
                continue
            n = min(K, st_["end"] - st_["cursor"] + 1)
            q = st_["cursor"]
            grown, blocked = [], False
            for r in range(q, q + n):
                try:
                    pool.grow(slot, r)
                except NoBlocksError:
                    blocked = True
                    break
                grown.append(r)
            pool.flush_growth()
            if blocked:
                # the engine would preempt a victim; evicting the slot
                # itself is the simplest legal recovery (partial growth
                # stays in the table and eviction returns it)
                pool.evict(slot)
                del live[slot]
                pool.check_invariants()
                continue
            write_rows(slot, grown)
            ne = data.draw(st.integers(0, n), label="accepted")
            if ne != K:
                cursors[slot] = q + ne
                pool.rollback_rows({slot: range(q + ne, q + K)},
                                   cursors, max_batch * K)
                for r in range(q + ne, q + n):   # rolled-back, was grown
                    for si in pool.maps:
                        assert (pos_at(si, slot, r) == -1).all(), (
                            "stale pos visible after rollback", si, r)
            else:
                cursors[slot] = q + K
            for r in range(q, q + ne):           # accepted rows keep pos
                for si in pool.maps:
                    assert (pos_at(si, slot, r) == r).all()
            st_["cursor"] = q + ne
            if st_["cursor"] > st_["end"]:
                pool.evict(slot)
                del live[slot]
        pool.check_invariants()
    for slot in sorted(live):
        pool.evict(slot)
    pool.check_invariants()
    for si, m in pool.maps.items():
        assert m.alloc.n_live == 0
        assert m.alloc.n_free + m.alloc.n_retained == n_blocks[si] - 1

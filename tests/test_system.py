"""End-to-end behaviour tests for the paper's system.

The headline claims, at CPU scale:
  1. LANS trains BERT (MLM+NSP) and the loss decreases.
  2. At an aggressive large-batch learning rate, LANS stays at least as
     stable as LAMB — the paper's Table 2 phenomenon.
  3. The warmup-hold-decay schedule (eq 9) reaches a loss at least as good
     as the linear schedule (eq 8) at the same capped eta (Fig. 1).
  4. The full pipeline (sharded data -> train -> checkpoint -> restore)
     round-trips; the serving engine generates tokens.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.configs import reduced_arch
from repro.core.optim import apply_updates, lamb, lans
from repro.core.schedules import warmup_hold_decay, warmup_linear_decay
from repro.data.corpus import SyntheticCorpus, mlm_batch_iterator
from repro.data.sharding import ShardSpec


def _bert_setup(seed=0, batch=8, seq=64):
    arch = reduced_arch("bert-large")
    corpus = SyntheticCorpus(vocab=arch.cfg.vocab, num_docs=512,
                             doc_len=256, seed=seed)
    spec = ShardSpec(num_samples=512, num_workers=1, worker=0, seed=seed)
    data = mlm_batch_iterator(corpus, spec, per_worker_batch=batch,
                              seq_len=seq, seed=seed)
    params = arch.init(jax.random.PRNGKey(seed))
    return arch, params, data


def _train(arch, params, data, tx, steps):
    st = tx.init(params)

    @jax.jit
    def step(params, st, batch):
        (l, _), g = jax.value_and_grad(arch.loss_fn, has_aux=True)(params, batch)
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        upd, st = tx.update(g, st, params)
        return apply_updates(params, upd), st, l

    losses = []
    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, st, l = step(params, st, batch)
        losses.append(float(l))
    return params, losses


def test_lans_trains_bert_loss_decreases():
    arch, params, data = _bert_setup()
    sched = warmup_hold_decay(5e-3, 41, 8, 12)
    _, losses = _train(arch, params, data, lans(sched), steps=40)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-8:]) < np.mean(losses[:5]) - 0.15, losses


def test_lans_no_worse_than_lamb_under_hostile_lr():
    """Table 2 phenomenon, directional at toy scale: under an aggressively
    large eta, LANS stays finite and accumulates no more loss than LAMB
    (at paper scale LAMB outright diverges; a 2-layer CPU BERT cannot
    reproduce the divergence cleanly, so the test asserts the ordering)."""
    eta = 0.25  # far beyond stable for this toy setup
    totals = {}
    for name, txf in (("lans", lans), ("lamb", lamb)):
        sums = []
        for seed in (1, 2):
            arch, params, data = _bert_setup(seed=seed)
            _, losses = _train(arch, params, data, txf(eta), steps=18)
            if name == "lans":
                assert np.isfinite(losses).all()
            sums.append(np.sum(np.minimum(losses, 1e4)))
        totals[name] = float(np.mean(sums))
    assert totals["lans"] <= totals["lamb"] * 1.10, totals


def test_hold_schedule_beats_linear_at_capped_eta():
    steps, eta = 40, 2e-3
    arch, params, data = _bert_setup(seed=2)
    lin = warmup_linear_decay(eta, steps + 1, max(1, steps // 5))
    hold = warmup_hold_decay(eta, steps + 1, max(1, steps // 5),
                             int(steps * 0.4))
    _, l_lin = _train(arch, params, data, lans(lin), steps=steps)

    arch2, params2, data2 = _bert_setup(seed=2)
    _, l_hold = _train(arch2, params2, data2, lans(hold), steps=steps)
    assert np.mean(l_hold[-5:]) <= np.mean(l_lin[-5:]) + 0.05


def test_checkpoint_roundtrip(tmp_path):
    arch, params, data = _bert_setup(seed=3)
    params, losses = _train(arch, params, data, lans(1e-3), steps=2)
    save(str(tmp_path), 2, params, metadata={"loss": losses[-1]})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    restored = restore(str(tmp_path), 2, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serving_engine_generates():
    from repro.serving.engine import Request, ServeEngine
    arch = reduced_arch("gemma2-2b")
    params = arch.init(jax.random.PRNGKey(0))
    eng = ServeEngine(arch, params)
    reqs = [Request(prompt=np.arange(5, 13, dtype=np.int32), max_new_tokens=4),
            Request(prompt=np.arange(3, 9, dtype=np.int32), max_new_tokens=4)]
    done = eng.run_batch(reqs)
    for r in done:
        assert r.generated.shape == (4,)
        assert (r.generated >= 0).all() and (r.generated < arch.cfg.vocab).all()


def test_grad_accumulation_aligns_with_full_batch():
    """Microbatched mean gradient ~ full-batch gradient (cosine > 0.98):
    what makes the paper's 96K global batch implementable."""
    arch, params, data = _bert_setup(seed=4, batch=8)
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}

    def loss_fn(p, b):
        return arch.loss_fn(p, b)[0]

    g_full = jax.grad(loss_fn)(params, batch)
    g_mb = jax.tree.map(jnp.zeros_like, params)
    for i in range(2):
        sl = {k: v[i * 4:(i + 1) * 4] for k, v in batch.items()}
        g = jax.grad(loss_fn)(params, sl)
        g_mb = jax.tree.map(lambda a, b: a + b / 2, g_mb, g)
    fa = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g_full)])
    fb = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g_mb)])
    cos = float(fa @ fb / (jnp.linalg.norm(fa) * jnp.linalg.norm(fb)))
    assert cos > 0.98, cos

"""Paged KV cache: allocator, block tables, pool roundtrip, no-recompile.

The load-bearing claims, each asserted here (tier-1 unless marked slow):

  * the refcounted allocator + block-table map keep their invariants
    (free/live partition, refcount == table references, shared blocks
    registered) through inserts, shared-prefix hits and evictions, and
    admission is ATOMIC — an insert that runs out of blocks rolls back
    completely;
  * the device pool stores a shared prefix once (block ids equal across
    sharing slots), evicts blocks back to the free list, and keeps the
    null block invalid;
  * the jitted decode step compiles EXACTLY once for the engine's
    lifetime: block churn (admissions, evictions, table rewrites) only
    changes array VALUES, never shapes — the ROADMAP-pinned
    no-recompilation property of the serving step;
  * at equal arena memory the paged pool sustains >= 2x the dense pool's
    concurrency on a shared-prefix workload, token-identically;
  * the production-mesh sharding rules put paged arenas blocks-over-data
    / head_dim-over-model and never model-shard integer bookkeeping.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import make_serving_requests as make_requests
from conftest import setup_serving_arch as setup_arch
from repro.distributed import sharding as shd
from repro.serving import (BlockAllocator, BlockTableMap, ContinuousEngine,
                           NoBlocksError, PagedCachePool)

pytestmark = [pytest.mark.serving, pytest.mark.paged]

MAX_LEN = 48


# --------------------------------------------------------------------------
# allocator + table map (host state machines)
# --------------------------------------------------------------------------

def test_allocator_alloc_retain_release():
    a = BlockAllocator(4)                 # 3 data blocks + null
    b1, b2, b3 = a.alloc(), a.alloc(), a.alloc()
    assert sorted((b1, b2, b3)) == [1, 2, 3] and a.n_free == 0
    with pytest.raises(NoBlocksError):
        a.alloc()
    a.retain(b1)
    assert not a.release(b1)              # still referenced
    assert a.release(b1) and a.n_free == 1
    a.check_invariants()
    with pytest.raises(ValueError):
        a.release(b1)                     # double free
    with pytest.raises(ValueError):
        a.retain(0)                       # null block is never allocable


def test_table_map_shares_full_prefix_blocks():
    m = BlockTableMap(max_batch=4, ring_len=32, block_size=8, n_blocks=17)
    prompt = tuple(range(100, 120))       # plen 20 -> blocks 0,1 shareable
    p0 = m.insert(0, prompt, plen=20, padded_len=24, budget=4)
    assert [p.shared for p in p0] == [False, False, False]
    p1 = m.insert(1, prompt, plen=20, padded_len=24, budget=4)
    assert [p.shared for p in p1] == [True, True, False]
    assert m.table[0, 0] == m.table[1, 0] and m.table[0, 1] == m.table[1, 1]
    assert m.table[0, 2] != m.table[1, 2]     # tails stay exclusive
    assert m.alloc.ref[m.table[0, 0]] == 2
    m.check_invariants()
    # different padded length -> different reduction shapes -> no sharing
    p2 = m.insert(2, prompt, plen=20, padded_len=32, budget=4)
    assert not any(p.shared for p in p2)
    m.check_invariants()
    # eviction drops refs; the last holder frees + unregisters
    assert len(m.evict(2)) == 3           # all exclusive -> all freed
    assert m.evict(1) == [p1[-1].block]   # shared prefix still held by 0
    shared_block = int(m.table[0, 0])
    m.evict(0)
    assert m.alloc.ref[shared_block] == 0
    assert m.alloc.n_free == 16 and m.n_shared == 0
    m.check_invariants()


def test_table_map_insert_is_atomic_on_exhaustion():
    m = BlockTableMap(max_batch=2, ring_len=32, block_size=8, n_blocks=5)
    prompt = tuple(range(40))
    m.insert(0, prompt, plen=9, padded_len=16, budget=8)   # 2 blocks
    with pytest.raises(NoBlocksError):                      # needs 4 > 2 left
        m.insert(1, tuple(range(200, 232)), plen=25, padded_len=32, budget=8)
    assert not m.table[1].any()
    m.check_invariants()
    assert m.alloc.n_free == 2            # rollback returned everything


def test_table_map_never_shares_ring_overwritten_blocks():
    # ring_len 16: decode rows wrap into the prefix region -> those chain
    # positions must be exclusive even though they hold full prompt blocks
    m = BlockTableMap(max_batch=4, ring_len=16, block_size=8, n_blocks=13)
    prompt = tuple(range(16))
    m.insert(0, prompt, plen=16, padded_len=16, budget=16)
    p1 = m.insert(1, prompt, plen=16, padded_len=16, budget=16)
    assert not any(p.shared for p in p1)  # wrap overwrites both blocks
    # a small budget only wraps into block 0: block 1 is registered by the
    # first such insert and shared by the second
    p2 = m.insert(2, prompt, plen=16, padded_len=16, budget=8)
    assert [p.shared for p in p2] == [False, False]
    p3 = m.insert(3, prompt, plen=16, padded_len=16, budget=8)
    assert [p.shared for p in p3] == [False, True]
    m.check_invariants()


# --------------------------------------------------------------------------
# device pool
# --------------------------------------------------------------------------

def test_paged_pool_insert_evict_roundtrip():
    arch, params = setup_arch("gemma2-2b")
    pool = PagedCachePool(arch, max_batch=3, max_len=MAX_LEN, block_size=8)
    _, req_cache = arch.prefill(
        params, {"tokens": np.arange(5, 13, dtype=np.int32)[None]},
        cache_len=MAX_LEN + 8, per_slot=True,
        positions=np.arange(8, dtype=np.int32)[None])
    pool.insert(req_cache, 1, prompt=np.arange(5, 13), plen=8,
                padded_len=8, budget=4)
    assert pool.lengths().tolist() == [0, 8, 0]
    full_si = 1                           # gemma2 superblock: (local, full)
    table = pool.maps[full_si].table
    assert table[1, 0] != 0 and not table[0].any() and not table[2].any()
    # the written block's positions are live; the null block stays invalid
    pos = np.asarray(pool.cache["slots"][full_si]["pos"])
    blk = int(table[1, 0])
    assert (pos[:, blk, :] >= 0).all()
    assert (pos[:, 0, :] == -1).all()
    pool.check_invariants()
    pool.evict(1)
    assert pool.lengths().tolist() == [0, 0, 0]
    assert not pool.maps[full_si].table.any()
    assert all(m.alloc.n_live == 0 for m in pool.maps.values())
    pool.check_invariants()
    with pytest.raises(IndexError):
        pool.insert(req_cache, 3, prompt=np.arange(5, 13), plen=8,
                    padded_len=8, budget=4)


def test_decode_step_compiles_once_across_block_churn():
    """THE no-recompile property: admissions, evictions and block-table
    rewrites between steps must never retrace the jitted decode step (the
    tables/cursors are traced VALUES), and prefill compiles once per
    padded bucket."""
    arch, params = setup_arch("gemma2-2b")
    eng = ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                           cache="paged", block_size=8, prefill_bucket=8)
    # 5 requests through 2 slots: slot reuse, mixed budgets, one shared
    # prefix pair -> plenty of table churn
    reqs = make_requests(arch, [(7, 4), (11, 6), (5, 1), (9, 3), (11, 4)],
                         prefix=8)
    eng.run(reqs)
    assert eng.steps_run > 5
    assert eng._step._cache_size() == 1
    assert eng._prefill._cache_size() <= 3   # one compile per padded bucket


def test_paged_pool_equal_memory_2x_concurrency():
    """Mini version of benchmarks/serving_load.py --workload shared-prefix:
    same arena memory (slots_budget == dense max_batch), 4x the slots,
    >= 2x the peak concurrency, token-identical output."""
    arch, params = setup_arch("qwen2.5-14b")
    spec = [(4 + (i % 3), 6) for i in range(10)]
    dense = ContinuousEngine(arch, params, max_batch=3, max_len=MAX_LEN,
                             cache="dense", prefill_bucket=8)
    a = make_requests(arch, spec, prefix=24)
    dense.run(a)
    paged = ContinuousEngine(arch, params, max_batch=12, max_len=MAX_LEN,
                             cache="paged", block_size=8, slots_budget=3,
                             prefill_bucket=8)
    b = make_requests(arch, spec, prefix=24)
    paged.run(b)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.generated, rb.generated)
    assert paged.max_concurrent >= 2 * dense.max_concurrent
    assert paged.pool.shared_hits > 0
    paged.pool.check_invariants()


def test_null_block_survives_zero_pad_rolled_sharing():
    """Regression (review finding): a sliding-window slot-type whose
    prompt exactly fills the ring with zero left-pad (plen == padded ==
    window) has NO pos==-1 filler row in its rolled prefill cache; the
    shared chain positions of a second identical prompt must still write
    position -1 into the null block — otherwise every slot with unbacked
    table entries starts attending to null-block garbage."""
    arch, params = setup_arch("gemma2-2b")     # reduced window = 16
    # (0, 4) tails + 16-token common prefix: two IDENTICAL prompts that
    # exactly fill the window ring, zero pad at bucket 16; plus a short
    # bystander whose window chain leaves unbacked (null) table entries.
    def reqs_of():
        return (make_requests(arch, [(0, 4), (0, 4)], prefix=16)
                + make_requests(arch, [(5, 3)], seed=3))
    solos = reqs_of()
    ref = ContinuousEngine(arch, params, max_batch=1, max_len=MAX_LEN,
                           cache="dense", prefill_bucket=16)
    ref.run(solos)
    eng = ContinuousEngine(arch, params, max_batch=3, max_len=MAX_LEN,
                           cache="paged", block_size=4, prefill_bucket=16)
    reqs = reqs_of()
    # the two sharers alone first: the null block must already be clean
    # right after the shared (skipped-write) insert — a later insert with
    # pad > 0 would paper over the corruption by rewriting it
    eng.submit(reqs[0])
    eng.submit(reqs[1])
    eng.step()
    assert eng.pool.shared_hits > 0            # the rolled prompts shared
    for si in eng.pool.maps:
        pos = np.asarray(eng.pool.cache["slots"][si]["pos"])
        assert (pos[:, 0, :] == -1).all(), f"null block corrupted (slot {si})"
    eng.submit(reqs[2])                        # bystander with unbacked
    while eng.step():                          # window table entries
        pass
    for solo, r in zip(solos, reqs):
        np.testing.assert_array_equal(solo.generated, r.generated)
    eng.pool.check_invariants()


def test_admission_gate_serializes_when_blocks_run_out():
    """A budget-1 arena with 4 decode slots: requests that each need most
    of the arena must flow through one at a time (FIFO head-of-line
    gating), never crash the allocator, and still match their solo
    output. Any (prompt + budget) <= max_len fits a budget-1 arena by
    construction, so admission can stall but never deadlock."""
    arch, params = setup_arch("qwen2.5-14b")
    spec = [(30, 8), (28, 6), (31, 5)]
    solos = make_requests(arch, spec)
    solo_eng = ContinuousEngine(arch, params, max_batch=1, max_len=MAX_LEN,
                                cache="dense", prefill_bucket=8)
    solo_eng.run(solos)
    eng = ContinuousEngine(arch, params, max_batch=4, max_len=MAX_LEN,
                           cache="paged", block_size=8, slots_budget=1,
                           prefill_bucket=8, share_prefix=False)
    reqs = make_requests(arch, spec)
    eng.run(reqs)
    assert eng.max_concurrent == 1        # gate admitted one at a time
    for solo, r in zip(solos, reqs):
        np.testing.assert_array_equal(solo.generated, r.generated)
    eng.pool.check_invariants()


# --------------------------------------------------------------------------
# Pallas paged-attention kernel vs the XLA arena gather (PR 4 tentpole)
# --------------------------------------------------------------------------

KSPEC = [(7, 4), (11, 6), (5, 1), (9, 3), (11, 4)]


def _run_kernel_pair(name, policy, prefix=16):
    """Same workload through attn_kernel='xla' and attn_kernel='paged'."""
    arch, params = setup_arch(name)
    outs = []
    for kern in ("xla", "paged"):
        reqs = make_requests(arch, KSPEC, prefix=prefix)
        eng = ContinuousEngine(arch, params, max_batch=3, max_len=MAX_LEN,
                               cache="paged", block_size=8, prefill_bucket=8,
                               policy=policy, attn_kernel=kern)
        eng.run(reqs)
        outs.append((eng, reqs))
    return outs


@pytest.mark.parametrize("policy", [None, "bf16"])
def test_pallas_kernel_token_identical_to_xla_gather(policy):
    """THE kernel-differential claim: streaming K/V blocks through the
    fused Pallas kernel emits byte-identical greedy tokens to the dense
    arena[table] gather, fp32 and bf16 policies alike, shared prefixes
    included (gemma2 covers GQA + sliding window + logit softcap), and
    the kernel path keeps the no-recompile property."""
    (ex, a), (ep, b) = _run_kernel_pair("gemma2-2b", policy)
    for ra, rb in zip(a, b):
        assert ra.generated.shape == (ra.max_new_tokens,)
        np.testing.assert_array_equal(ra.generated, rb.generated)
    assert ep.pool.attn_kernel == "paged" and ex.pool.attn_kernel == "xla"
    assert ep.pool.shared_hits > 0            # prefix blocks on the path
    assert ep._step._cache_size() == 1        # block churn never retraces
    ep.pool.check_invariants()


@pytest.mark.parametrize("policy", ["fp32", "bf16"])
def test_pallas_kernel_four_way_differential(policy):
    """Acceptance chain: static == dense == paged-xla == paged-pallas.
    qwen2.5-14b exercises the plain full-attention ring (no window).

    The four implementations lay the same keys out at different cache
    rows, so under bf16 compute a one-ulp rounding difference can break
    a RAW argmax tie differently across layouts (this workload ties on
    request 1). The bf16 leg therefore runs the tie-stable greedy
    argmax — logits snapped to bf16 resolution before the index
    tiebreak — which makes the chain hold at every precision; the
    fp32-only restriction this differential carried since PR 4 is
    gone."""
    from repro.serving import ServeEngine
    arch, params = setup_arch("qwen2.5-14b")
    sampler = None if policy == "fp32" else "temperature=0,stable=1"
    builders = [
        lambda: ServeEngine(arch, params, max_len=MAX_LEN, policy=policy,
                            sampler=sampler),
        lambda: ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                                 cache="dense", prefill_bucket=8,
                                 policy=policy, sampler=sampler),
        lambda: ContinuousEngine(arch, params, max_batch=3, max_len=MAX_LEN,
                                 cache="paged", block_size=8, policy=policy,
                                 prefill_bucket=8, attn_kernel="xla",
                                 sampler=sampler),
        lambda: ContinuousEngine(arch, params, max_batch=3, max_len=MAX_LEN,
                                 cache="paged", block_size=8, policy=policy,
                                 prefill_bucket=8, attn_kernel="paged",
                                 sampler=sampler),
    ]
    all_reqs = []
    for build in builders:
        reqs = make_requests(arch, KSPEC, prefix=16)
        build().run_batch(reqs)
        all_reqs.append(reqs)
    for quad in zip(*all_reqs):
        for other in quad[1:]:
            np.testing.assert_array_equal(quad[0].generated, other.generated)


def test_pallas_kernel_hybrid_arch():
    """jamba: the kernel runs inside the period scan NEXT to slot-resident
    mamba state and dropless MoE routing — still token-identical."""
    arch, params = setup_arch("jamba-1.5-large-398b")
    outs = []
    for kern in ("xla", "paged"):
        reqs = make_requests(arch, [(7, 3), (9, 4)])
        eng = ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                               cache="paged", block_size=8,
                               prefill_bucket=16, attn_kernel=kern)
        eng.run(reqs)
        outs.append([r.generated for r in reqs])
    for ra, rb in zip(*outs):
        np.testing.assert_array_equal(ra, rb)


def test_attn_kernel_validation():
    arch, params = setup_arch("gemma2-2b")
    with pytest.raises(ValueError):
        ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                         cache="dense", attn_kernel="paged")
    with pytest.raises(ValueError):
        ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                         attn_kernel="mosaic")
    with pytest.raises(ValueError):
        PagedCachePool(arch, 2, MAX_LEN, block_size=8, attn_kernel="nope")
    # the interpret escape hatch only exists on the Pallas kernel path
    with pytest.raises(ValueError, match="kernel_interpret"):
        ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                         attn_kernel="xla", kernel_interpret=True)
    # tile/VMEM validation runs at pool construction: off-TPU the test
    # shapes (head_dim off the 128-lane grid) are ADVISORY, not fatal —
    # the interpret-mode kernel executes any layout
    pool = PagedCachePool(arch, 2, MAX_LEN, block_size=8,
                          attn_kernel="paged")
    assert isinstance(pool.tile_problems, list)
    assert PagedCachePool(arch, 2, MAX_LEN, block_size=8,
                          attn_kernel="xla").tile_problems == []


def test_fused_kernel_lowers_zero_arena_scatters():
    """Structural pin of the epilogue fusion: the decode step under
    decode_kernel='paged' lowers with ZERO scatter ops — the K/V/pos
    writes live inside the kernel against the ALIASED arenas — where the
    XLA branch lowers (at least) the three arena scatters the fusion
    removed. Counted in the pre-optimization lowering via
    launch/hlo_analysis.op_counts (the CPU backend's optimizer expands
    scatter into while loops, so the optimized text is not portable)."""
    from repro.launch.hlo_analysis import op_counts
    from repro.models.attention import AttnConfig, attn_apply, attn_init
    rng = np.random.default_rng(0)
    B, bs, nb, n_blocks = 2, 8, 3, 8
    x = jnp.asarray(rng.normal(size=(B, 1, 16)), jnp.float32)
    positions = jnp.zeros((B, 1), jnp.int32)
    cache = {
        "k": jnp.zeros((n_blocks, bs, 1, 8)),
        "v": jnp.zeros((n_blocks, bs, 1, 8)),
        "pos": jnp.full((n_blocks, bs), -1, jnp.int32),
        "table": jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32),
        "index": jnp.zeros((B,), jnp.int32),
    }
    counts = {}
    for kern in ("xla", "paged"):
        cfg = AttnConfig(d_model=16, n_heads=2, n_kv_heads=1, head_dim=8,
                         decode_kernel=kern)
        p = attn_init(jax.random.PRNGKey(0), cfg)
        step = jax.jit(lambda x, cache, p=p, cfg=cfg: attn_apply(
            p, cfg, x, positions=positions, cache=cache))
        hlo = step.lower(x, cache).as_text()
        counts[kern] = op_counts(hlo, ("scatter",))["scatter"]
    assert counts["xla"] >= 3, counts          # k, v, pos arena scatters
    assert counts["paged"] == 0, counts        # the epilogue carries them


def test_fused_and_xla_engines_agree_on_arena_bytes():
    """Beyond token equality: after identical workloads the two kernel
    paths leave BIT-IDENTICAL K/V/pos bytes in every DATA block of every
    attention arena (same admission order -> same allocator decisions ->
    same destinations; selection-only epilogue writes). The null block is
    the one legal divergence: the XLA scatter parks invalid rows' K/V in
    null row 0 where the fused kernel writes nothing — both keep its
    positions -1, so attention cannot observe the difference."""
    (ex, _), (ep, _) = _run_kernel_pair("gemma2-2b", None)
    for si in ex.pool.maps:
        a = ex.pool.cache["slots"][si]
        b = ep.pool.cache["slots"][si]
        np.testing.assert_array_equal(
            np.asarray(a["pos"]), np.asarray(b["pos"]),
            err_msg=f"slot-type {si} pos arenas diverged")
        for part in ("k", "v"):
            # arena layout (layers, blocks, bs, ...); skip only the null
            # block (block 0), where the XLA scatter parks invalid rows
            np.testing.assert_array_equal(
                np.asarray(a[part][:, 1:]), np.asarray(b[part][:, 1:]),
                err_msg=f"slot-type {si} {part} data blocks diverged")


# --------------------------------------------------------------------------
# production-mesh sharding of the paged layout
# --------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


def test_paged_cache_pspec_blocks_over_data():
    arch, _ = setup_arch("gemma2-2b")
    mesh = FakeMesh(data=16, model=16)
    cache = jax.eval_shape(lambda: arch.init_paged_cache(
        64, 256, block_size=16, n_blocks={0: 255, 1: 255}))
    spec = shd.cache_pspec(cache, mesh)
    full = spec["slots"][1]
    assert full["k"] == P(None, "data", None, None, "model")
    # integer bookkeeping never model-shards
    assert full["pos"] == P(None, "data", None)
    assert spec["tables"][1] == P("data", None)
    assert spec["index"] == P(None)
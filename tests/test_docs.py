"""Docs smoke check: every ```python fence in docs/*.md and README.md
must at least parse — so documentation code can't silently rot.

Shell fences (```bash) are checked against the repo's entry points: any
`python -m <module>` they invoke must be an importable module path.
Collected dynamically: adding a doc file or fence adds test cases.
"""
import ast
import importlib.util
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted(ROOT.glob("docs/*.md")) + [ROOT / "README.md"]

# Opener may carry an info string (```python title=x); the closer is a
# bare ``` — matching them separately keeps the open/close state correct
# for any opener a future doc uses.
_OPEN = re.compile(r"^```(\w*)")
_CLOSE = re.compile(r"^```\s*$")


def _fences(path, lang):
    """(start_line, code) for every ```lang fence in the file."""
    out, buf, start, active = [], [], 0, False
    for i, line in enumerate(path.read_text().splitlines(), 1):
        if not active and _OPEN.match(line):
            active, tag, start, buf = True, _OPEN.match(line).group(1), i, []
        elif active and _CLOSE.match(line):
            active = False
            if tag == lang:
                out.append((start, "\n".join(buf)))
        elif active:
            buf.append(line)
    assert not active, f"{path}: unterminated code fence at line {start}"
    return out


def _cases(lang):
    return [pytest.param(path, line, code,
                         id=f"{path.relative_to(ROOT)}:{line}")
            for path in DOC_FILES if path.exists()
            for line, code in _fences(path, lang)]


def test_docs_exist_and_are_linked():
    for name in ("architecture.md", "kernels.md", "serving.md"):
        assert (ROOT / "docs" / name).exists(), f"docs/{name} missing"
        assert f"docs/{name}" in (ROOT / "README.md").read_text(), (
            f"README does not link docs/{name}")


def test_serving_doc_covers_scheduler_contract():
    """The lazy-growth/scheduling rewrite of docs/serving.md must keep
    its section anchors AND runnable fences (the fences themselves are
    smoke-checked by the dynamic tests below — this pins that they
    exist, so a future edit cannot silently drop the examples)."""
    text = (ROOT / "docs" / "serving.md").read_text()
    for anchor in ("Lazy chain growth", "When preemption fires",
                   "Retained prefixes survive refcount 0",
                   "## Scheduling policies"):
        assert anchor in text, f"serving.md lost its '{anchor}' section"
    sched = text.split("## Scheduling policies", 1)[1]
    sched = sched.split("## Differential guarantees", 1)[0]
    path = ROOT / "docs" / "serving.md"
    assert any(code in sched for _, code in _fences(path, "python")), (
        "scheduling section lost its python example")
    assert any(code in sched for _, code in _fences(path, "bash")), (
        "scheduling section lost its bash example")


def test_serving_doc_covers_chunked_prefill():
    """The chunked-prefill/open-loop section of docs/serving.md must
    keep its anchors and runnable fences: the budget partition, the
    chunk-boundary exactness argument, the share=False rationale and
    the SLO/goodput definitions are the contracts tests/test_admission.py
    and the open-loop benchmark gate on."""
    text = (ROOT / "docs" / "serving.md").read_text()
    for anchor in ("## Chunked prefill and open-loop goodput",
                   "Budget partition", "Chunk-boundary exactness",
                   "share=False", "SLOs and goodput"):
        assert anchor in text, f"serving.md lost its '{anchor}' anchor"
    sect = text.split("## Chunked prefill and open-loop goodput", 1)[1]
    sect = sect.split("## Flag map", 1)[0]
    path = ROOT / "docs" / "serving.md"
    assert any(code in sect for _, code in _fences(path, "python")), (
        "chunked-prefill section lost its python example")
    assert any(code in sect for _, code in _fences(path, "bash")), (
        "chunked-prefill section lost its bash example")
    for flag in ("--chunk-budget", "--arrival-rate", "--ttft-slo-ms",
                 "--itl-slo-ms"):
        assert flag in text, f"serving.md flag map lost {flag}"
        assert flag in (ROOT / "README.md").read_text(), (
            f"README flag table lost {flag}")


def test_serving_doc_covers_speculative_decoding():
    """The speculative-decoding + wrap-COW rewrite must keep its
    anchors: the spec invariants section (rewind, acceptance exactness,
    draft lifecycle) with runnable fences, the wrap-COW contract that
    REPLACED the no-COW-ever rule, the stable-argmax-by-default bf16
    differential story, the kernels.md S>1 worked example, and the
    `--spec-draft` / `--spec-k` flag rows in both flag tables."""
    serving = (ROOT / "docs" / "serving.md").read_text()
    for anchor in ("## Speculative decoding",
                   "Rollback is a rewind",
                   "Acceptance sampling is exact",
                   "Draft-slot lifecycle",
                   "at the ring wrap",
                   "stable_argmax"):
        assert anchor in serving, f"serving.md lost its '{anchor}' anchor"
    assert "No copy-on-write, ever" not in serving, (
        "the no-COW-ever rule is dead: grow() copy-on-writes at the "
        "ring wrap so wrapped prefixes stay shared")
    sect = serving.split("## Speculative decoding", 1)[1]
    sect = sect.split("## Flag map", 1)[0]
    path = ROOT / "docs" / "serving.md"
    assert any(code in sect for _, code in _fences(path, "python")), (
        "speculative section lost its python example")
    assert any(code in sect for _, code in _fences(path, "bash")), (
        "speculative section lost its bash example")
    kernels = (ROOT / "docs" / "kernels.md").read_text()
    assert "Small-S query blocks" in kernels, (
        "kernels.md lost the S>1 query-block worked example")
    readme = (ROOT / "README.md").read_text()
    for flag in ("--spec-draft", "--spec-k"):
        assert flag in serving, f"serving.md flag map lost {flag}"
        assert flag in readme, f"README flag table lost {flag}"


def test_kernels_doc_covers_epilogue_fusion():
    """The scatter-in-epilogue rewrite of docs/kernels.md must keep its
    anchors: the fused section with the aliasing rules (flattened-input
    indices counting scalar-prefetch operands), the flush-map and
    null-block contracts, the oracle-carries-the-write rationale, the
    tile-padding table, and the autotuner section with a runnable
    fence pointing at the checked-in tuned table; the README keeps the
    `--interpret` flag row and the machine-readable bench artifact."""
    path = ROOT / "docs" / "kernels.md"
    kernels = path.read_text()
    for anchor in ("## Scatter in the epilogue",
                   "input_output_aliases",
                   "The flush map",
                   "Why the oracle carries the write",
                   "Tile padding",
                   "## The block/grid autotuner",
                   "paged_attn_tuned.json",
                   "BENCH_kernels.json"):
        assert anchor in kernels, f"kernels.md lost its '{anchor}' anchor"
    sect = kernels.split("## The block/grid autotuner", 1)[1]
    sect = sect.split("## How", 1)[0]
    assert any(code in sect for _, code in _fences(path, "bash")), (
        "autotuner section lost its bash example")
    assert (ROOT / "src/repro/configs/paged_attn_tuned.json").exists(), (
        "checked-in tuned table missing")
    readme = (ROOT / "README.md").read_text()
    assert "--interpret" in readme, "README flag table lost --interpret"
    assert "BENCH_kernels.json" in readme, (
        "README lost the machine-readable kernel-bench artifact")


def test_serving_doc_covers_sharded_router():
    """The live-sharded engine + multi-replica router section must keep
    its anchors: the exactness envelope (data mesh any policy; model
    mesh fp32 cross-layout, bf16 same-layout with stable argmax), the
    router contract with runnable fences, the `--mesh` / `--replicas` /
    `--route-policy` flag rows in both tables, and the architecture.md
    router diagram."""
    serving = (ROOT / "docs" / "serving.md").read_text()
    for anchor in ("## Sharded serving and the replica router",
                   "Exactness envelope",
                   "The replica router"):
        assert anchor in serving, f"serving.md lost its '{anchor}' anchor"
    sect = serving.split("## Sharded serving and the replica router", 1)[1]
    sect = sect.split("## Flag map", 1)[0]
    path = ROOT / "docs" / "serving.md"
    assert any(code in sect for _, code in _fences(path, "python")), (
        "sharded/router section lost its python example")
    assert any(code in sect for _, code in _fences(path, "bash")), (
        "sharded/router section lost its bash example")
    readme = (ROOT / "README.md").read_text()
    for flag in ("--mesh", "--replicas", "--route-policy"):
        assert flag in serving, f"serving.md flag map lost {flag}"
        assert flag in readme, f"README flag table lost {flag}"
    arch = (ROOT / "docs" / "architecture.md").read_text()
    assert "## Multi-replica routing" in arch, (
        "architecture.md lost the multi-replica router diagram section")


def test_serving_doc_covers_workload_families():
    """The three-family engine section must keep its anchors: the
    encdec cross-attention prefix invariants (read-only refcounted
    chains, no COW case, retained revival), the scoring
    complete-at-admission lifecycle, the batch-1 run_one path with its
    bitwise-identity claim, runnable fences, and the `--task` /
    `--shared-inputs` flag rows in both tables."""
    serving = (ROOT / "docs" / "serving.md").read_text()
    for anchor in ("## Workload families",
                   "Encoder-decoder",
                   "BERT scoring / embedding",
                   "Batch-1 latency mode",
                   "complete AT ADMISSION",
                   "READ-ONLY"):
        assert anchor in serving, f"serving.md lost its '{anchor}' anchor"
    sect = serving.split("## Workload families", 1)[1]
    sect = sect.split("## Flag map", 1)[0]
    path = ROOT / "docs" / "serving.md"
    assert any(code in sect for _, code in _fences(path, "python")), (
        "workload-families section lost its python example")
    assert any(code in sect for _, code in _fences(path, "bash")), (
        "workload-families section lost its bash example")
    readme = (ROOT / "README.md").read_text()
    for flag in ("--task", "--shared-inputs"):
        assert flag in serving, f"serving.md flag map lost {flag}"
        assert flag in readme, f"README flag table lost {flag}"


@pytest.mark.parametrize("path,line,code", _cases("python"))
def test_python_fences_parse(path, line, code):
    try:
        ast.parse(code)
    except SyntaxError as e:
        pytest.fail(f"{path.name}:{line} python fence does not parse: {e}")


def _module_exists(mod: str) -> bool:
    """Repo module file / package (with __init__.py), or any importable
    module (installed tools like pytest) — bare directories don't count."""
    rel = mod.replace(".", "/")
    for base in (ROOT / "src", ROOT):
        if (base / f"{rel}.py").exists() or \
                (base / rel / "__init__.py").exists():
            return True
    try:
        return importlib.util.find_spec(mod) is not None
    except (ImportError, ModuleNotFoundError, ValueError):
        return False


@pytest.mark.parametrize("path,line,code", _cases("bash"))
def test_bash_fences_reference_real_modules(path, line, code):
    """`python -m repro.x.y` / `-m benchmarks.z` in docs must resolve to
    real modules (the flags themselves are exercised by the CLIs' own
    tests)."""
    for mod in re.findall(r"python -m ([\w.]+)", code):
        assert _module_exists(mod), (
            f"{path.name}:{line} references unknown module {mod}")

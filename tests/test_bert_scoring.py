"""BERT scoring/embedding serving family: the same ContinuousEngine
core serves masked-LM scoring and pooled-embedding requests.

The family contract:

  * requests complete AT admission — one fixed (max_batch, score_len)
    score call serves up to max_batch requests, there is no KV cache
    and no decode loop, and slots free inside the same step;
  * the score jit compiles exactly once for the engine's lifetime
    (short batches replicate their last row — the pow2-group padding
    idiom collapsed to a single bucket), as does the batch-1 run_one
    path's (1, score_len) jit;
  * batched and batch-1 outputs are bitwise identical (per-row
    independence + the same left-pad masking).
"""
import numpy as np
import pytest

from conftest import setup_serving_arch as setup_arch
from repro.serving import (ContinuousEngine, Request,
                           synthetic_scoring_requests)

pytestmark = [pytest.mark.serving, pytest.mark.bert]

ARCH = "bert-large"


def _engine(arch, params, task="score", **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 16)
    return ContinuousEngine(arch, params, task=task, **kw)


def _requests(arch, n, *, seed=2, prompt_len=12):
    return synthetic_scoring_requests(n, arch.cfg.vocab,
                                      prompt_len=prompt_len, seed=seed)


# ---------------------------------------------------------------------------
# scoring lifecycle: complete-at-admission, one compile
# ---------------------------------------------------------------------------

def test_scoring_completes_all_requests_with_one_compile():
    arch, params = setup_arch(ARCH)
    eng = _engine(arch, params)
    reqs = _requests(arch, 7)              # 2 batches: one full, one short
    eng.run(reqs)
    assert len(eng.scheduler.completed) == 7
    for r in reqs:
        assert len(r.generated) == len(r.prompt)   # MLM ids, valid tail
        assert r.embedding.shape == (arch.cfg.d_model,)
        assert r.embedding.dtype == np.float32
    # full batches, a replicated-row short batch, varied prompt lengths:
    # one (max_batch, score_len) compile covers them all
    assert eng._score._cache_size() == 1
    eng.scheduler.check_invariants()


def test_scoring_admits_in_policy_order():
    """fifo admission: the first max_batch submissions finish in the
    first step, the rest in the second — completion order is arrival
    order because scoring slots free at completion."""
    arch, params = setup_arch(ARCH)
    eng = _engine(arch, params)
    reqs = _requests(arch, 6, seed=4)
    for r in reqs:
        eng.submit(r)
    eng.step()
    assert [r.rid for r in eng.scheduler.completed] == \
        [r.rid for r in reqs[:4]]
    eng.step()
    assert [r.rid for r in eng.scheduler.completed] == \
        [r.rid for r in reqs]
    assert not eng.scheduler.has_work


def test_embed_task_returns_embedding_only():
    arch, params = setup_arch(ARCH)
    eng = _engine(arch, params, task="embed")
    reqs = _requests(arch, 3, seed=6)
    eng.run(reqs)
    for r in reqs:
        assert len(r.generated) == 0       # no token output
        assert r.embedding.shape == (arch.cfg.d_model,)


# ---------------------------------------------------------------------------
# batch-1 latency mode: bitwise identical, compiled once
# ---------------------------------------------------------------------------

def test_run_one_matches_batched_scoring_bitwise():
    arch, params = setup_arch(ARCH)
    eng = _engine(arch, params)
    batched = _requests(arch, 6, seed=8)
    eng.run(batched)
    solo = _requests(arch, 6, seed=8)      # byte-identical workload
    for r in solo:
        eng.run_one(r)
    for b, s in zip(batched, solo):
        np.testing.assert_array_equal(np.asarray(b.generated),
                                      np.asarray(s.generated))
        np.testing.assert_array_equal(b.embedding, s.embedding)
    assert eng._lat_score._cache_size() == 1
    assert eng._score._cache_size() == 1


def test_run_one_embed_matches_batched():
    arch, params = setup_arch(ARCH)
    eng = _engine(arch, params, task="embed")
    batched = _requests(arch, 3, seed=10)
    eng.run(batched)
    solo = _requests(arch, 3, seed=10)
    for r in solo:
        eng.run_one(r)
    for b, s in zip(batched, solo):
        np.testing.assert_array_equal(b.embedding, s.embedding)
        assert len(s.generated) == 0


# ---------------------------------------------------------------------------
# validation: the family contract is explicit, not emergent
# ---------------------------------------------------------------------------

def test_bert_arch_rejects_generate_task():
    arch, params = setup_arch(ARCH)
    with pytest.raises(ValueError, match="task='score'"):
        ContinuousEngine(arch, params, task="generate")


def test_decoder_arch_rejects_scoring_task():
    arch, params = setup_arch("gemma2-2b")
    with pytest.raises(ValueError, match="bert arch"):
        ContinuousEngine(arch, params, task="score")


def test_bert_rejects_decoder_only_features_and_long_prompts():
    arch, params = setup_arch(ARCH)
    with pytest.raises(ValueError, match="decoder-only"):
        _engine(arch, params, chunk_budget=8)
    with pytest.raises(ValueError, match="position table"):
        _engine(arch, params, max_len=arch.cfg.max_pos + 1)
    eng = _engine(arch, params)
    with pytest.raises(ValueError, match="scoring prompt length"):
        eng.submit(Request(
            prompt=np.arange(5, 5 + eng.score_len + 1, dtype=np.int32),
            max_new_tokens=1))

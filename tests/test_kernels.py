"""Per-kernel shape/dtype sweeps against the pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(1,), (7,), (128,), (300,), (129, 130), (8, 16, 32), (2, 3, 5, 7)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(rng, shape, dtype):
    g = jnp.asarray(rng.normal(size=shape), dtype)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    m = jnp.asarray(rng.normal(size=shape), jnp.float32)
    v = jnp.asarray(np.abs(rng.normal(size=shape)), jnp.float32)
    return g, m, v, x


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_lans_sweep(rng, shape, dtype):
    g, m, v, x = _mk(rng, shape, dtype)
    got = ops.fused_lans_step(g, m, v, x, eta=0.02, step=4, lam=0.02)
    want = ref.lans_step_ref(g, m, v, x, eta=0.02, step=4, lam=0.02)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    for a, b, nm in zip(got, want, "xmv"):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol, err_msg=f"{shape} {nm}")


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_lamb_sweep(rng, shape, dtype):
    g, m, v, x = _mk(rng, shape, dtype)
    got = ops.fused_lamb_step(g, m, v, x, eta=0.02, step=4, lam=0.02)
    want = ref.lamb_step_ref(g, m, v, x, eta=0.02, step=4, lam=0.02)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    for a, b, nm in zip(got, want, "xmv"):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol, err_msg=f"{shape} {nm}")


@pytest.mark.parametrize("shape", SHAPES)
def test_block_sq_norm_sweep(rng, shape):
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    np.testing.assert_allclose(float(ops.block_sq_norm(x)),
                               float(ref.sq_norm_ref(x)), rtol=1e-5)


def test_fused_lans_zero_gradient_block(rng):
    """A zero gradient block must not produce NaNs (guarded normalization)."""
    shape = (64,)
    g = jnp.zeros(shape)
    m = jnp.zeros(shape)
    v = jnp.zeros(shape)
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    out = ops.fused_lans_step(g, m, v, x, eta=0.01, step=1)
    assert bool(jnp.all(jnp.isfinite(out.x)))
    want = ref.lans_step_ref(g, m, v, x, eta=0.01, step=1)
    np.testing.assert_allclose(np.asarray(out.x), np.asarray(want.x),
                               rtol=1e-5, atol=1e-6)


def test_fused_no_trust_variant(rng):
    g, m, v, x = _mk(rng, (40,), jnp.float32)
    got = ops.fused_lans_step(g, m, v, x, eta=0.01, step=2, lam=0.0,
                              apply_trust=False)
    want = ref.lans_step_ref(g, m, v, x, eta=0.01, step=2, lam=0.0,
                             apply_trust=False)
    np.testing.assert_allclose(np.asarray(got.x), np.asarray(want.x),
                               rtol=2e-5, atol=2e-6)


def test_multi_step_trajectory_parity(rng):
    """5 fused steps == 5 reference steps (state threading correct)."""
    g0, m, v, x = _mk(rng, (96,), jnp.float32)
    xr, mr, vr = x, m, v
    xk, mk, vk = x, m, v
    for step in range(1, 6):
        g = jnp.asarray(rng.normal(size=(96,)), jnp.float32)
        outk = ops.fused_lans_step(g, mk, vk, xk, eta=0.05, step=step)
        outr = ref.lans_step_ref(g, mr, vr, xr, eta=0.05, step=step)
        xk, mk, vk = outk
        xr, mr, vr = outr
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                               rtol=1e-4, atol=1e-5)

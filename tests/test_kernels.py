"""Per-kernel shape/dtype sweeps against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import paged_attention_kernel as pak
from repro.kernels.paged_attention_kernel import (
    ensure_kernel_fit, paged_attention, paged_attention_fused,
    tile_alignment_problems, tuned_grid_order)

pytestmark = pytest.mark.kernels

SHAPES = [(1,), (7,), (128,), (300,), (129, 130), (8, 16, 32), (2, 3, 5, 7)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(rng, shape, dtype):
    g = jnp.asarray(rng.normal(size=shape), dtype)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    m = jnp.asarray(rng.normal(size=shape), jnp.float32)
    v = jnp.asarray(np.abs(rng.normal(size=shape)), jnp.float32)
    return g, m, v, x


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_lans_sweep(rng, shape, dtype):
    g, m, v, x = _mk(rng, shape, dtype)
    got = ops.fused_lans_step(g, m, v, x, eta=0.02, step=4, lam=0.02)
    want = ref.lans_step_ref(g, m, v, x, eta=0.02, step=4, lam=0.02)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    for a, b, nm in zip(got, want, "xmv"):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol, err_msg=f"{shape} {nm}")


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_lamb_sweep(rng, shape, dtype):
    g, m, v, x = _mk(rng, shape, dtype)
    got = ops.fused_lamb_step(g, m, v, x, eta=0.02, step=4, lam=0.02)
    want = ref.lamb_step_ref(g, m, v, x, eta=0.02, step=4, lam=0.02)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    for a, b, nm in zip(got, want, "xmv"):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol, err_msg=f"{shape} {nm}")


@pytest.mark.parametrize("shape", SHAPES)
def test_block_sq_norm_sweep(rng, shape):
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    np.testing.assert_allclose(float(ops.block_sq_norm(x)),
                               float(ref.sq_norm_ref(x)), rtol=1e-5)


def test_fused_lans_zero_gradient_block(rng):
    """A zero gradient block must not produce NaNs (guarded normalization)."""
    shape = (64,)
    g = jnp.zeros(shape)
    m = jnp.zeros(shape)
    v = jnp.zeros(shape)
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    out = ops.fused_lans_step(g, m, v, x, eta=0.01, step=1)
    assert bool(jnp.all(jnp.isfinite(out.x)))
    want = ref.lans_step_ref(g, m, v, x, eta=0.01, step=1)
    np.testing.assert_allclose(np.asarray(out.x), np.asarray(want.x),
                               rtol=1e-5, atol=1e-6)


def test_fused_no_trust_variant(rng):
    g, m, v, x = _mk(rng, (40,), jnp.float32)
    got = ops.fused_lans_step(g, m, v, x, eta=0.01, step=2, lam=0.0,
                              apply_trust=False)
    want = ref.lans_step_ref(g, m, v, x, eta=0.01, step=2, lam=0.0,
                             apply_trust=False)
    np.testing.assert_allclose(np.asarray(got.x), np.asarray(want.x),
                               rtol=2e-5, atol=2e-6)


# --------------------------------------------------------------------------
# paged-attention decode kernel (kernels/paged_attention_kernel.py)
# --------------------------------------------------------------------------

def _paged_case(rng, *, B=4, h=4, n_kv=2, hd=16, bs=8, nb=5, n_blocks=12,
                dtype=jnp.bfloat16, max_pos=30):
    """Random decode-shaped inputs: arenas with a pos=-1 null block, random
    (possibly aliasing) block tables, one dead (all-null) slot."""
    q = jnp.asarray(rng.normal(size=(B, h, hd)), dtype)
    ka = jnp.asarray(rng.normal(size=(n_blocks, bs, n_kv, hd)), dtype)
    va = jnp.asarray(rng.normal(size=(n_blocks, bs, n_kv, hd)), dtype)
    pos = rng.integers(-1, max_pos, size=(n_blocks, bs)).astype(np.int32)
    pos[0] = -1                               # reserved null block
    tbl = rng.integers(0, n_blocks, size=(B, nb)).astype(np.int32)
    tbl[-1] = 0                               # dead slot: every entry null
    qpos = rng.integers(0, max_pos, size=(B,)).astype(np.int32)
    return q, ka, va, jnp.asarray(pos), jnp.asarray(tbl), jnp.asarray(qpos)


PAGED_VARIANTS = [
    dict(),                                   # plain causal GQA
    dict(window=8),                           # sliding-window mask
    dict(softcap=5.0),                        # gemma2-style logit cap
    dict(causal=False),                       # bidirectional
    dict(window=4, softcap=10.0),
]


@pytest.mark.parametrize("kwargs", PAGED_VARIANTS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_paged_attention_matches_ref(rng, kwargs, dtype):
    args = _paged_case(rng, dtype=dtype)
    got = paged_attention(*args, scale=0.25, **kwargs)
    want = ref.paged_attention_ref(*args, scale=0.25, **kwargs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("h,n_kv", [(4, 4), (8, 2), (6, 1)])
def test_paged_attention_gqa_head_mapping(rng, h, n_kv):
    """MHA / grouped / MQA head layouts all match the repeat-heads oracle."""
    args = _paged_case(rng, h=h, n_kv=n_kv, dtype=jnp.float32)
    got = paged_attention(*args, scale=0.125)
    want = ref.paged_attention_ref(*args, scale=0.125)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


def test_paged_attention_pos_minus_one_masked_on_chip(rng):
    """THE masking property: pos == -1 rows (null block, unwritten ring
    slots) must contribute exactly nothing — huge garbage K/V planted in
    every masked row leaves the output bitwise unchanged, and a slot whose
    table references no valid key at all returns exactly 0, not NaN."""
    q, ka, va, pos, tbl, qpos = _paged_case(rng, dtype=jnp.float32)
    clean = paged_attention(q, ka, va, pos, tbl, qpos, scale=0.25)
    masked = np.asarray(pos) < 0
    garbage = jnp.where(jnp.asarray(masked)[:, :, None, None], 1e30, 0.0)
    out = paged_attention(q, ka + garbage, va + garbage, pos, tbl, qpos,
                          scale=0.25)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(clean))
    # dead slot (table all null-block): exact zeros, finite everywhere
    assert (np.asarray(out[-1]) == 0.0).all()
    assert np.isfinite(np.asarray(out)).all()


def test_paged_attention_causal_and_window_masking(rng):
    """Keys in the future of q_pos (and beyond the sliding window) are
    masked even when their positions are valid (>= 0)."""
    B, h, n_kv, hd, bs, nb = 2, 2, 2, 8, 4, 2
    rngs = np.random.default_rng(7)
    ka = jnp.asarray(rngs.normal(size=(1 + nb, bs, n_kv, hd)), jnp.float32)
    va = jnp.asarray(rngs.normal(size=(1 + nb, bs, n_kv, hd)), jnp.float32)
    q = jnp.asarray(rngs.normal(size=(B, h, hd)), jnp.float32)
    pos = np.concatenate([np.full((1, bs), -1, np.int32),
                          np.arange(nb * bs, dtype=np.int32).reshape(nb, bs)])
    tbl = jnp.asarray(np.tile(np.arange(1, 1 + nb, dtype=np.int32), (B, 1)))
    qpos = jnp.asarray(np.array([3, nb * bs - 1], np.int32))
    pos = jnp.asarray(pos)
    out = paged_attention(q, ka, va, pos, tbl, qpos, scale=0.5, window=4)
    # slot 0 sees positions 0..3 only; slot 1 the last 4 positions: editing
    # keys outside those windows must not change anything
    ka2 = ka.at[2:, :].add(100.0)            # positions >= bs: hidden from slot 0
    out2 = paged_attention(q, ka2, va, pos, tbl, qpos, scale=0.5, window=4)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out2[0]))
    assert not np.array_equal(np.asarray(out[1]), np.asarray(out2[1]))
    want = ref.paged_attention_ref(q, ka, va, pos, tbl, qpos, scale=0.5,
                                   window=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


# --------------------------------------------------------------------------
# scatter-in-epilogue fused kernel (paged_attention_fused)
# --------------------------------------------------------------------------

def _fused_case(rng, *, S=1, dtype=jnp.float32, wrap=False,
                B=3, h=4, n_kv=2, hd=16, bs=8, nb=5, n_blocks=12):
    """Pool-shaped decode state: slots own exclusive blocks, history fills
    the ring up to each cursor, destination rows are unwritten (pos -1) —
    or, under wrap, window-expired per the row_margin contract. Slot B-1
    is dead (all-null table, q_pos -1)."""
    ring = nb * bs
    ka = jnp.asarray(rng.normal(size=(n_blocks, bs, n_kv, hd)), dtype)
    va = jnp.asarray(rng.normal(size=(n_blocks, bs, n_kv, hd)), dtype)
    pos = np.full((n_blocks, bs), -1, np.int32)
    tbl = np.zeros((B, nb), np.int32)
    tbl[0] = np.arange(1, 1 + nb)
    tbl[1, :2] = [6, 7]                        # short chain, rest null
    if wrap:
        cur0, qbase = ring - 2, 3 * ring - 2   # dest rows straddle the wrap
        dests = {(cur0 + s) % ring for s in range(S)}
        for r in range(ring):
            if r not in dests:                 # stale wrapped rows stay,
                pos[tbl[0, r // bs], r % bs] = qbase - ((cur0 - r) % ring)
    else:
        cur0, qbase = 17, 17
        for r in range(cur0):
            pos[tbl[0, r // bs], r % bs] = r
    for r in range(9):
        pos[tbl[1, r // bs], r % bs] = r
    cursor = np.array([cur0, 9, 0][:B], np.int32)
    qpos = np.stack([qbase + np.arange(S), 9 + np.arange(S),
                     np.full(S, -1)][:B]).astype(np.int32)
    q = jnp.asarray(rng.normal(size=(B, S, h, hd)), dtype)
    k_new = jnp.asarray(rng.normal(size=(B, S, n_kv, hd)), dtype)
    v_new = jnp.asarray(rng.normal(size=(B, S, n_kv, hd)), dtype)
    if S == 1:                                 # exercise the 3-D squeeze
        q, k_new, v_new, qpos = q[:, 0], k_new[:, 0], v_new[:, 0], qpos[:, 0]
    return (q, k_new, v_new, ka, va, jnp.asarray(pos), jnp.asarray(tbl),
            jnp.asarray(qpos), jnp.asarray(cursor))


FUSED_VARIANTS = [
    dict(S=1), dict(S=4), dict(S=1, softcap=5.0),
    dict(S=4, window=24, wrap=True), dict(S=1, window=24, wrap=True),
]


@pytest.mark.parametrize("kwargs", FUSED_VARIANTS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_paged_attention_fused_matches_oracle(rng, kwargs, dtype):
    """out matches the scatter-then-attend oracle; arenas are BIT-exact
    on every block (the oracle carries the write — kernels/ref.py)."""
    kw = dict(kwargs)
    case_kw = {k: kw.pop(k) for k in ("S", "wrap") if k in kw}
    args = _fused_case(rng, dtype=dtype, **case_kw)
    got = paged_attention_fused(*args, scale=0.25, **kw)
    want = ref.paged_attention_fused_ref(*args, scale=0.25, **kw)
    for g, w, name in zip(got[1:], want[1:], ("k", "v", "pos")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"{name} arena not bit-exact")
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=2e-6, atol=2e-6)


def test_paged_attention_fused_equals_scatter_then_kernel(rng):
    """The fused launch == XLA scatter followed by the read-side kernel:
    same arenas bit-for-bit, same attention to fp32 tolerance."""
    for S in (1, 4):
        args = _fused_case(rng, S=S)
        out_f, kf, vf, pf = paged_attention_fused(*args, scale=0.25)
        _, k2, v2, p2 = ref.paged_attention_fused_ref(*args, scale=0.25)
        np.testing.assert_array_equal(np.asarray(kf), np.asarray(k2))
        np.testing.assert_array_equal(np.asarray(vf), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(pf), np.asarray(p2))
        out_k = paged_attention(args[0], k2, v2, p2, args[6], args[7],
                                scale=0.25)
        np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_k),
                                   rtol=2e-6, atol=2e-6)


def test_paged_attention_fused_rollback_churn_bit_equality(rng):
    """Speculative reject-after-fused-verify, at the kernel level: three
    S=4 verify rounds where each round's tail is REJECTED. Rollback is
    the engine's host-side op — invalidate the rejected rows' positions
    (pos=-1 scatter) and rewind the cursor — so the next fused launch
    re-writes rows the previous launch just wrote, over stale K/V bytes
    that only pos masks. After every round the fused arenas must stay
    bit-identical to scatter-then-kernel arenas evolved by the SAME
    churn, and the attention outputs must agree to fp32 tolerance."""
    S = 4
    q, k_new, v_new, ka, va, pos, tbl, qpos, cursor = _fused_case(rng, S=S)
    # lazy growth, done up front: back slot 1's chain with a free block
    # so the churn below never runs a dest row into the null block
    tbl = jnp.asarray(np.asarray(tbl)).at[1, 2].set(8)
    kb, vb, pb = ka, va, pos                   # oracle-evolved copies
    bs, nb = ka.shape[1], tbl.shape[1]
    ring = nb * bs
    cursor = np.asarray(cursor).copy()
    churn = np.random.default_rng(5)
    for acc in (2, 0, 3):                      # accepted proposals per round
        cur = jnp.asarray(cursor)
        out_f, kf, vf, pf = paged_attention_fused(
            q, k_new, v_new, ka, va, pos, tbl, qpos, cur, scale=0.25)
        out_r, k2, v2, p2 = ref.paged_attention_fused_ref(
            q, k_new, v_new, kb, vb, pb, tbl, qpos, cur, scale=0.25)
        for g, w, name in zip((kf, vf, pf), (k2, v2, p2), ("k", "v", "pos")):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w),
                err_msg=f"{name} arena diverged at acc={acc}")
        np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_r),
                                   rtol=2e-6, atol=2e-6)
        # rollback: keep acc accepted rows + the correction token, park
        # pos=-1 on the rejected tail of both arena lineages (K/V bytes
        # stay — exactly the stale-garbage state the next round masks)
        pn = np.asarray(pf).copy()
        qp = np.asarray(qpos)
        for b in range(2):                     # slot 2 is dead
            for s in range(acc + 1, S):
                r = int(qp[b, s]) % ring
                pn[tbl[b, r // bs], r % bs] = -1
            cursor[b] += acc + 1
        ka, va, pos = kf, vf, jnp.asarray(pn)
        kb, vb, pb = k2, v2, jnp.asarray(pn)
        qpos = jnp.asarray(np.stack(
            [qp[0, 0] + (acc + 1) + np.arange(S),
             qp[1, 0] + (acc + 1) + np.arange(S),
             np.full(S, -1)]).astype(np.int32))
        q = jnp.asarray(churn.normal(size=q.shape), q.dtype)
        k_new = jnp.asarray(churn.normal(size=k_new.shape), k_new.dtype)
        v_new = jnp.asarray(churn.normal(size=v_new.shape), v_new.dtype)


def test_paged_attention_fused_null_block_and_bystanders_immutable(rng):
    """Blocks the write never targets keep their exact input bytes: the
    null block (index 0 — which the XLA scatter would dirty with invalid
    rows' K/V), every unreferenced arena block, and every history block
    of live slots. Dead slots output exactly 0."""
    q, k_new, v_new, ka, va, pos, tbl, qpos, cursor = _fused_case(rng, S=4)
    out, kf, vf, pf = paged_attention_fused(
        q, k_new, v_new, ka, va, pos, tbl, qpos, cursor, scale=0.25)
    ring = tbl.shape[1] * ka.shape[1]
    dest = {(b, int((cursor[b] + s) % ring))
            for b in range(q.shape[0]) for s in range(q.shape[1])
            if int(qpos[b, s]) >= 0}
    dest_blocks = {int(tbl[b, r // ka.shape[1]]) for b, r in dest}
    for blk in range(ka.shape[0]):
        if blk in dest_blocks:
            continue
        np.testing.assert_array_equal(np.asarray(kf[blk]),
                                      np.asarray(ka[blk]), err_msg=f"k {blk}")
        np.testing.assert_array_equal(np.asarray(vf[blk]),
                                      np.asarray(va[blk]), err_msg=f"v {blk}")
        np.testing.assert_array_equal(np.asarray(pf[blk]),
                                      np.asarray(pos[blk]),
                                      err_msg=f"pos {blk}")
    assert 0 not in dest_blocks                # the null block is immutable
    assert (np.asarray(out[-1]) == 0.0).all()  # dead slot: exact zeros


def test_paged_attention_fused_grid_order_is_pure_schedule(rng):
    """grid_order='parallel' (megacore dimension semantics) is a schedule
    choice only: outputs and arenas identical to the sequential grid."""
    args = _fused_case(rng, S=4, wrap=True)
    a = paged_attention_fused(*args, scale=0.25, window=24,
                              grid_order="arbitrary")
    b = paged_attention_fused(*args, scale=0.25, window=24,
                              grid_order="parallel")
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    with pytest.raises(ValueError, match="grid_order"):
        paged_attention_fused(*args, scale=0.25, grid_order="bogus")


# --------------------------------------------------------------------------
# tile alignment / VMEM fit + the tuned-config table
# --------------------------------------------------------------------------

def test_tile_alignment_problems():
    """(block_size, head_dim) vs the TPU (8/16, 128) tile grid: clean
    production shapes pass, off-grid shapes name the failing dim; bf16
    needs the 16-row sublane where fp32 needs 8."""
    assert tile_alignment_problems(16, 128, jnp.float32) == []
    assert tile_alignment_problems(16, 128, jnp.bfloat16) == []
    probs = tile_alignment_problems(8, 64, jnp.bfloat16)
    assert len(probs) == 2                     # lane AND sublane off-grid
    assert any("head_dim" in p for p in probs)
    assert any("block_size" in p for p in probs)
    assert tile_alignment_problems(8, 128, jnp.float32) == []
    assert tile_alignment_problems(8, 128, jnp.bfloat16) != []


def test_ensure_kernel_fit_gates_compiled_only():
    """Problems raise only when the kernel would COMPILE (interpret
    False); the interpret escape hatch downgrades them to advisory."""
    probs = ensure_kernel_fit(8, 64, 8, 2, jnp.bfloat16, interpret=True)
    assert probs                               # advisory, returned
    with pytest.raises(ValueError, match="interpret"):
        ensure_kernel_fit(8, 64, 8, 2, jnp.bfloat16, interpret=False)
    assert ensure_kernel_fit(16, 128, 8, 2, jnp.bfloat16,
                             interpret=False) == []
    # VMEM gate: production head counts must fit the scratch budget
    big = pak.kernel_fit_problems(2048, 128, 128, 8, jnp.bfloat16, S=16)
    assert any("VMEM" in p for p in big)


def test_tuned_table_lookup_and_fallback(monkeypatch):
    """Exact (backend, head_dim, n_kv, block_size, S) hits return the
    recorded winner; ANY miss — key, backend, or absent table — falls
    back to the documented sequential 'arbitrary' grid."""
    fake = {"cpu": {"hd64_kv2": {"bs16_S1": {"grid_order": "parallel",
                                             "us": 1.0}}}}
    monkeypatch.setattr(pak, "tuned_table", lambda: fake)
    assert tuned_grid_order("cpu", 64, 2, 16, 1) == "parallel"
    assert tuned_grid_order("cpu", 64, 2, 16, 4) == "arbitrary"
    assert tuned_grid_order("cpu", 128, 2, 16, 1) == "arbitrary"
    assert tuned_grid_order("tpu", 64, 2, 16, 1) == "arbitrary"
    monkeypatch.setattr(pak, "tuned_table", dict)
    assert tuned_grid_order("cpu", 64, 2, 16, 1) == "arbitrary"


def test_checked_in_tuned_table_is_consistent():
    """The committed autotuner table parses and every entry is a valid
    grid order under a well-formed key — the contract paged_attention's
    trace-time lookup relies on."""
    table = pak.tuned_table()
    assert table, "src/repro/configs/paged_attn_tuned.json missing/empty"
    for backend, groups in table.items():
        for gkey, entries in groups.items():
            assert gkey.startswith("hd") and "_kv" in gkey, gkey
            for ekey, entry in entries.items():
                assert ekey.startswith("bs") and "_S" in ekey, ekey
                assert entry["grid_order"] in ("arbitrary", "parallel")
                assert entry["us"] > 0


def test_kv_valid_len_guard_on_fused_path(rng):
    """attn_apply's fused-kernel branch refuses kv_valid_len loudly (the
    kernel has no valid-length operand); the XLA branch accepts it."""
    from repro.models.attention import AttnConfig, attn_apply, attn_init
    cfg = AttnConfig(d_model=16, n_heads=2, n_kv_heads=1, head_dim=8,
                     decode_kernel="paged")
    p = attn_init(jax.random.PRNGKey(0), cfg)
    B, bs, nb, n_blocks = 2, 4, 2, 5
    cache = {
        "k": jnp.zeros((n_blocks, bs, 1, 8)),
        "v": jnp.zeros((n_blocks, bs, 1, 8)),
        "pos": jnp.full((n_blocks, bs), -1, jnp.int32),
        "table": jnp.asarray([[1, 2], [3, 4]], jnp.int32),
        "index": jnp.zeros((B,), jnp.int32),
    }
    x = jnp.asarray(rng.normal(size=(B, 1, 16)), jnp.float32)
    positions = jnp.zeros((B, 1), jnp.int32)
    with pytest.raises(NotImplementedError, match="kv_valid_len"):
        attn_apply(p, cfg, x, positions=positions, cache=cache,
                   kv_valid_len=jnp.ones((B,), jnp.int32))
    out, new_cache = attn_apply(p, cfg, x, positions=positions, cache=cache)
    assert out.shape == (B, 1, 16)
    xla_cfg = AttnConfig(d_model=16, n_heads=2, n_kv_heads=1, head_dim=8)
    out2, _ = attn_apply(p, xla_cfg, x, positions=positions, cache=cache,
                         kv_valid_len=jnp.ones((B,), jnp.int32))
    assert out2.shape == (B, 1, 16)


def test_multi_step_trajectory_parity(rng):
    """5 fused steps == 5 reference steps (state threading correct)."""
    g0, m, v, x = _mk(rng, (96,), jnp.float32)
    xr, mr, vr = x, m, v
    xk, mk, vk = x, m, v
    for step in range(1, 6):
        g = jnp.asarray(rng.normal(size=(96,)), jnp.float32)
        outk = ops.fused_lans_step(g, mk, vk, xk, eta=0.05, step=step)
        outr = ref.lans_step_ref(g, mr, vr, xr, eta=0.05, step=step)
        xk, mk, vk = outk
        xr, mr, vr = outr
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                               rtol=1e-4, atol=1e-5)

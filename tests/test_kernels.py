"""Per-kernel shape/dtype sweeps against the pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.paged_attention_kernel import paged_attention

SHAPES = [(1,), (7,), (128,), (300,), (129, 130), (8, 16, 32), (2, 3, 5, 7)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(rng, shape, dtype):
    g = jnp.asarray(rng.normal(size=shape), dtype)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    m = jnp.asarray(rng.normal(size=shape), jnp.float32)
    v = jnp.asarray(np.abs(rng.normal(size=shape)), jnp.float32)
    return g, m, v, x


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_lans_sweep(rng, shape, dtype):
    g, m, v, x = _mk(rng, shape, dtype)
    got = ops.fused_lans_step(g, m, v, x, eta=0.02, step=4, lam=0.02)
    want = ref.lans_step_ref(g, m, v, x, eta=0.02, step=4, lam=0.02)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    for a, b, nm in zip(got, want, "xmv"):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol, err_msg=f"{shape} {nm}")


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_lamb_sweep(rng, shape, dtype):
    g, m, v, x = _mk(rng, shape, dtype)
    got = ops.fused_lamb_step(g, m, v, x, eta=0.02, step=4, lam=0.02)
    want = ref.lamb_step_ref(g, m, v, x, eta=0.02, step=4, lam=0.02)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    for a, b, nm in zip(got, want, "xmv"):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol, err_msg=f"{shape} {nm}")


@pytest.mark.parametrize("shape", SHAPES)
def test_block_sq_norm_sweep(rng, shape):
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    np.testing.assert_allclose(float(ops.block_sq_norm(x)),
                               float(ref.sq_norm_ref(x)), rtol=1e-5)


def test_fused_lans_zero_gradient_block(rng):
    """A zero gradient block must not produce NaNs (guarded normalization)."""
    shape = (64,)
    g = jnp.zeros(shape)
    m = jnp.zeros(shape)
    v = jnp.zeros(shape)
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    out = ops.fused_lans_step(g, m, v, x, eta=0.01, step=1)
    assert bool(jnp.all(jnp.isfinite(out.x)))
    want = ref.lans_step_ref(g, m, v, x, eta=0.01, step=1)
    np.testing.assert_allclose(np.asarray(out.x), np.asarray(want.x),
                               rtol=1e-5, atol=1e-6)


def test_fused_no_trust_variant(rng):
    g, m, v, x = _mk(rng, (40,), jnp.float32)
    got = ops.fused_lans_step(g, m, v, x, eta=0.01, step=2, lam=0.0,
                              apply_trust=False)
    want = ref.lans_step_ref(g, m, v, x, eta=0.01, step=2, lam=0.0,
                             apply_trust=False)
    np.testing.assert_allclose(np.asarray(got.x), np.asarray(want.x),
                               rtol=2e-5, atol=2e-6)


# --------------------------------------------------------------------------
# paged-attention decode kernel (kernels/paged_attention_kernel.py)
# --------------------------------------------------------------------------

def _paged_case(rng, *, B=4, h=4, n_kv=2, hd=16, bs=8, nb=5, n_blocks=12,
                dtype=jnp.bfloat16, max_pos=30):
    """Random decode-shaped inputs: arenas with a pos=-1 null block, random
    (possibly aliasing) block tables, one dead (all-null) slot."""
    q = jnp.asarray(rng.normal(size=(B, h, hd)), dtype)
    ka = jnp.asarray(rng.normal(size=(n_blocks, bs, n_kv, hd)), dtype)
    va = jnp.asarray(rng.normal(size=(n_blocks, bs, n_kv, hd)), dtype)
    pos = rng.integers(-1, max_pos, size=(n_blocks, bs)).astype(np.int32)
    pos[0] = -1                               # reserved null block
    tbl = rng.integers(0, n_blocks, size=(B, nb)).astype(np.int32)
    tbl[-1] = 0                               # dead slot: every entry null
    qpos = rng.integers(0, max_pos, size=(B,)).astype(np.int32)
    return q, ka, va, jnp.asarray(pos), jnp.asarray(tbl), jnp.asarray(qpos)


PAGED_VARIANTS = [
    dict(),                                   # plain causal GQA
    dict(window=8),                           # sliding-window mask
    dict(softcap=5.0),                        # gemma2-style logit cap
    dict(causal=False),                       # bidirectional
    dict(window=4, softcap=10.0),
]


@pytest.mark.parametrize("kwargs", PAGED_VARIANTS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_paged_attention_matches_ref(rng, kwargs, dtype):
    args = _paged_case(rng, dtype=dtype)
    got = paged_attention(*args, scale=0.25, **kwargs)
    want = ref.paged_attention_ref(*args, scale=0.25, **kwargs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("h,n_kv", [(4, 4), (8, 2), (6, 1)])
def test_paged_attention_gqa_head_mapping(rng, h, n_kv):
    """MHA / grouped / MQA head layouts all match the repeat-heads oracle."""
    args = _paged_case(rng, h=h, n_kv=n_kv, dtype=jnp.float32)
    got = paged_attention(*args, scale=0.125)
    want = ref.paged_attention_ref(*args, scale=0.125)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


def test_paged_attention_pos_minus_one_masked_on_chip(rng):
    """THE masking property: pos == -1 rows (null block, unwritten ring
    slots) must contribute exactly nothing — huge garbage K/V planted in
    every masked row leaves the output bitwise unchanged, and a slot whose
    table references no valid key at all returns exactly 0, not NaN."""
    q, ka, va, pos, tbl, qpos = _paged_case(rng, dtype=jnp.float32)
    clean = paged_attention(q, ka, va, pos, tbl, qpos, scale=0.25)
    masked = np.asarray(pos) < 0
    garbage = jnp.where(jnp.asarray(masked)[:, :, None, None], 1e30, 0.0)
    out = paged_attention(q, ka + garbage, va + garbage, pos, tbl, qpos,
                          scale=0.25)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(clean))
    # dead slot (table all null-block): exact zeros, finite everywhere
    assert (np.asarray(out[-1]) == 0.0).all()
    assert np.isfinite(np.asarray(out)).all()


def test_paged_attention_causal_and_window_masking(rng):
    """Keys in the future of q_pos (and beyond the sliding window) are
    masked even when their positions are valid (>= 0)."""
    B, h, n_kv, hd, bs, nb = 2, 2, 2, 8, 4, 2
    rngs = np.random.default_rng(7)
    ka = jnp.asarray(rngs.normal(size=(1 + nb, bs, n_kv, hd)), jnp.float32)
    va = jnp.asarray(rngs.normal(size=(1 + nb, bs, n_kv, hd)), jnp.float32)
    q = jnp.asarray(rngs.normal(size=(B, h, hd)), jnp.float32)
    pos = np.concatenate([np.full((1, bs), -1, np.int32),
                          np.arange(nb * bs, dtype=np.int32).reshape(nb, bs)])
    tbl = jnp.asarray(np.tile(np.arange(1, 1 + nb, dtype=np.int32), (B, 1)))
    qpos = jnp.asarray(np.array([3, nb * bs - 1], np.int32))
    pos = jnp.asarray(pos)
    out = paged_attention(q, ka, va, pos, tbl, qpos, scale=0.5, window=4)
    # slot 0 sees positions 0..3 only; slot 1 the last 4 positions: editing
    # keys outside those windows must not change anything
    ka2 = ka.at[2:, :].add(100.0)            # positions >= bs: hidden from slot 0
    out2 = paged_attention(q, ka2, va, pos, tbl, qpos, scale=0.5, window=4)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out2[0]))
    assert not np.array_equal(np.asarray(out[1]), np.asarray(out2[1]))
    want = ref.paged_attention_ref(q, ka, va, pos, tbl, qpos, scale=0.5,
                                   window=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


def test_multi_step_trajectory_parity(rng):
    """5 fused steps == 5 reference steps (state threading correct)."""
    g0, m, v, x = _mk(rng, (96,), jnp.float32)
    xr, mr, vr = x, m, v
    xk, mk, vk = x, m, v
    for step in range(1, 6):
        g = jnp.asarray(rng.normal(size=(96,)), jnp.float32)
        outk = ops.fused_lans_step(g, mk, vk, xk, eta=0.05, step=step)
        outr = ref.lans_step_ref(g, mr, vr, xr, eta=0.05, step=step)
        xk, mk, vk = outk
        xr, mr, vr = outr
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                               rtol=1e-4, atol=1e-5)

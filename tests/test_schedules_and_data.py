"""Scheduler exactness vs the paper's published numbers + data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.schedules import (StageSchedule, figure1_settings,
                                  paper_stage_schedules, schedule_auc,
                                  sqrt_scaling_rule, warmup_hold_decay,
                                  warmup_linear_decay)
from repro.data.corpus import (FIRST_NORMAL_ID, MASK_ID, SyntheticCorpus,
                               build_mlm_example, lm_batch_iterator,
                               mlm_batch_iterator)
from repro.data.sharding import ShardSpec


def test_figure1_auc_gaps_match_paper():
    """Paper Fig. 1: gap(ideal, feasible-linear) = 5.28; eq (9) cuts it to
    1.91. Reproduced exactly from the published T/warmup/const settings."""
    s = figure1_settings()
    a_feas = schedule_auc(warmup_linear_decay(
        s["eta_feasible"], s["total_steps"], s["warmup_steps"]), s["total_steps"])
    a_ideal = schedule_auc(warmup_linear_decay(
        s["eta_ideal"], s["total_steps"], s["warmup_steps"]), s["total_steps"])
    a_hold = schedule_auc(warmup_hold_decay(
        s["eta_feasible"], s["total_steps"], s["warmup_steps"],
        s["hold_steps"]), s["total_steps"])
    assert abs((a_ideal - a_feas) - 5.28) < 0.02
    assert abs((a_ideal - a_hold) - 1.91) < 0.02


def test_paper_stage_schedules_table1():
    s1, s2 = paper_stage_schedules()
    assert (s1.batch_size, s1.total_steps, s1.eta) == (96 * 1024, 3519, 0.00675)
    assert (s2.batch_size, s2.total_steps, s2.eta) == (33 * 1024, 782, 0.005)
    assert abs(s1.ratio_warmup + s1.ratio_const - 0.70) < 1e-6
    assert abs(s2.ratio_warmup + s2.ratio_const - 0.30) < 1e-6
    # schedules build and are finite over the whole run
    for st in (s1, s2):
        sched = st.schedule()
        vals = np.asarray(jax.vmap(sched)(jnp.arange(st.total_steps)))
        assert np.isfinite(vals).all() and vals.max() <= st.eta * (1 + 1e-5)


def test_sqrt_scaling_rule():
    assert abs(sqrt_scaling_rule(1e-3, 512, 2048) - 2e-3) < 1e-9


def test_total_steps_4301():
    """Paper: 3519 + 782 = 4301 total iterations (Table 2)."""
    s1, s2 = paper_stage_schedules()
    assert s1.total_steps + s2.total_steps == 4301


def test_mlm_example_masking_stats(rng):
    corpus = SyntheticCorpus(vocab=1024, num_docs=32, doc_len=512)
    ex = build_mlm_example(corpus, 0, rng, seq_len=128)
    assert ex["tokens"].shape == (128,)
    lab = ex["mlm_labels"]
    n_masked = (lab != -100).sum()
    assert 2 <= n_masked <= 40          # ~15% of ~120 maskable
    # labels hold the ORIGINAL token at masked positions
    masked_pos = np.where(lab != -100)[0]
    assert (lab[masked_pos] >= FIRST_NORMAL_ID).all()
    # token types: segment B marked 1
    assert ex["token_types"].max() == 1


def test_mlm_batches_deterministic_per_worker():
    corpus = SyntheticCorpus(vocab=512, num_docs=64, doc_len=256)
    spec = ShardSpec(num_samples=64, num_workers=2, worker=0, seed=7)
    a = next(mlm_batch_iterator(corpus, spec, per_worker_batch=4, seq_len=64,
                                seed=7))
    b = next(mlm_batch_iterator(corpus, spec, per_worker_batch=4, seq_len=64,
                                seed=7))
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_lm_batches_shift_by_one():
    corpus = SyntheticCorpus(vocab=512, num_docs=64, doc_len=256)
    spec = ShardSpec(num_samples=64, num_workers=1, worker=0)
    b = next(lm_batch_iterator(corpus, spec, per_worker_batch=4, seq_len=32))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_workers_see_disjoint_docs():
    corpus = SyntheticCorpus(vocab=512, num_docs=100, doc_len=64)
    seen = {}
    for w in range(4):
        spec = ShardSpec(num_samples=100, num_workers=4, worker=w)
        b = next(lm_batch_iterator(corpus, spec, per_worker_batch=8,
                                   seq_len=16))
        seen[w] = b
    # different workers -> different docs -> (overwhelmingly) different data
    assert not np.array_equal(seen[0]["tokens"], seen[1]["tokens"])

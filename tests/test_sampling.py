"""Sampled decode: determinism, engine equivalence, greedy degradation.

Sampler keys derive from (seed, request id, token index) only — never
from slot placement, admission order or batch composition — so:

  * a fixed seed reproduces the same tokens across runs;
  * batched prefill (everything admitted in one pass) emits the same
    tokens as single-request prefill (slots freed one at a time);
  * the static lockstep engine and the continuous engine agree;
  * temperature=0 goes through the sampler code path and still matches
    the greedy engine bit-exactly;
  * top-k=1 is argmax regardless of temperature.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_serving_requests as make_requests
from conftest import setup_serving_arch as setup_arch
from repro.serving import ContinuousEngine, Sampler, ServeEngine

pytestmark = pytest.mark.serving

MAX_LEN = 48


SPEC = [(7, 5), (11, 4), (5, 6), (9, 3)]
SAMPLER = Sampler(temperature=0.9, top_k=50, top_p=0.95, seed=7)


def run_continuous(sampler, *, max_batch=2, name="gemma2-2b", **kw):
    arch, params = setup_arch(name)
    reqs = make_requests(arch, SPEC)
    ContinuousEngine(arch, params, max_batch=max_batch, max_len=MAX_LEN,
                     prefill_bucket=8, sampler=sampler, **kw).run(reqs)
    return reqs


def test_fixed_seed_reproduces_across_runs():
    a = run_continuous(SAMPLER)
    b = run_continuous(SAMPLER)
    for ra, rb in zip(a, b):
        assert ra.generated.shape == (ra.max_new_tokens,)
        np.testing.assert_array_equal(ra.generated, rb.generated)
    c = run_continuous(Sampler(temperature=0.9, top_k=50, top_p=0.95,
                               seed=8))
    assert any(not np.array_equal(x.generated, y.generated)
               for x, y in zip(a, c))    # the seed actually matters


def test_batched_vs_single_prefill_identical():
    """max_batch=4 admits everything in ONE batched prefill pass;
    max_batch=1 prefills each request alone — keys depend only on
    (seed, rid, token index), so the streams must match."""
    a = run_continuous(SAMPLER, max_batch=4)
    b = run_continuous(SAMPLER, max_batch=1)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.generated, rb.generated)


def test_static_engine_matches_continuous():
    arch, params = setup_arch("gemma2-2b")
    a = make_requests(arch, SPEC)
    ServeEngine(arch, params, max_len=MAX_LEN, sampler=SAMPLER).run_batch(a)
    b = run_continuous(SAMPLER)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.generated, rb.generated)


def test_temperature_zero_is_bitexact_greedy():
    """temperature=0 must degrade to argmax through the sampler path —
    equal to the sampler-less greedy engine, dense or paged."""
    a = run_continuous(Sampler(temperature=0.0, seed=123))
    b = run_continuous(None)
    c = run_continuous(Sampler(temperature=0.0), cache="dense")
    for ra, rb, rc in zip(a, b, c):
        np.testing.assert_array_equal(ra.generated, rb.generated)
        np.testing.assert_array_equal(ra.generated, rc.generated)


def test_paged_and_dense_agree_under_sampling():
    a = run_continuous(SAMPLER, cache="paged")
    b = run_continuous(SAMPLER, cache="dense")
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.generated, rb.generated)


def test_top_k1_is_argmax():
    logits = jnp.asarray(np.random.default_rng(0).normal(
        size=(4, 64)).astype(np.float32))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(4, dtype=jnp.uint32))
    out = Sampler(temperature=2.0, top_k=1, seed=0).sample(logits, keys)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.argmax(np.asarray(logits), axis=-1))


def test_top_p_masks_tail():
    """With one dominant logit and top_p below its mass, every draw picks
    it; with top_p=1 the tail is reachable."""
    logits = np.full((1, 16), -3.0, np.float32)
    logits[0, 5] = 5.0                     # softmax mass ~ 0.997
    logits = jnp.asarray(np.repeat(logits, 64, axis=0))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(64, dtype=jnp.uint32))
    tight = Sampler(temperature=1.0, top_p=0.9, seed=0).sample(logits, keys)
    assert (np.asarray(tight) == 5).all()
    loose = Sampler(temperature=3.0, top_p=1.0, seed=0).sample(logits, keys)
    assert len(np.unique(np.asarray(loose))) > 1


def test_sampler_parse_and_validation():
    s = Sampler.parse("temperature=0.8,top_k=40,top_p=0.95,seed=3")
    assert s == Sampler(temperature=0.8, top_k=40, top_p=0.95, seed=3)
    assert Sampler.parse("greedy").greedy
    assert Sampler.parse(None) is None
    with pytest.raises(ValueError):
        Sampler.parse("nucleus=0.9")
    with pytest.raises(ValueError):
        Sampler(temperature=-1.0)
    with pytest.raises(ValueError):
        Sampler(top_p=0.0)
"""Continuous-batching serving engine: correctness against the static path.

The load-bearing claims, each asserted here:

  * the continuous engine — PAGED pool (the default) and the dense PR 2
    pool alike — emits token-identical greedy output to the static
    lockstep baseline for the same request set, under fp32 and bf16
    policies, across the three decoder families (dense+sliding window,
    pure-SSM, MoE), including requests that share a prompt prefix (whose
    KV blocks the paged pool stores once);
  * slots are safely reused after eviction (later occupants see none of
    the previous request's KV/SSM state);
  * requests admitted mid-stream (while other slots keep decoding)
    produce the same tokens as running alone;
  * batched left-padded prefill is pad-invariant: a request's tokens do
    not depend on its batch-mates' prompt lengths.
"""
import jax
import numpy as np
import pytest

from conftest import make_serving_requests as make_requests
from conftest import setup_serving_arch as setup_arch
from repro.serving import (CachePool, ContinuousEngine, Request, Scheduler,
                           ServeEngine, pad_prompts, throughput_probe)

pytestmark = pytest.mark.serving

# dense + sliding-window / pure-SSM / mixture-of-experts
ARCHS = ["gemma2-2b", "mamba2-130m", "granite-moe-3b-a800m"]
MAX_LEN = 48


SPEC = [(7, 4), (11, 6), (5, 1), (9, 3), (11, 4)]


def _run_both(name, policy):
    arch, params = setup_arch(name)
    a = make_requests(arch, SPEC)
    b = make_requests(arch, SPEC)
    ServeEngine(arch, params, max_len=MAX_LEN, policy=policy).run_batch(a)
    # max_batch < len(requests): admission + slot reuse are on the path
    ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                     policy=policy).run_batch(b)
    return a, b


def _run_trio(name, policy, prefix=0):
    """static / dense-pool / paged-pool over the same workload. prefix
    puts shared-prefix blocks on the paged decode path."""
    arch, params = setup_arch(name)
    outs = []
    for build in (
            lambda: ServeEngine(arch, params, max_len=MAX_LEN,
                                policy=policy),
            lambda: ContinuousEngine(arch, params, max_batch=2,
                                     max_len=MAX_LEN, policy=policy,
                                     cache="dense", prefill_bucket=8),
            lambda: ContinuousEngine(arch, params, max_batch=3,
                                     max_len=MAX_LEN, policy=policy,
                                     cache="paged", block_size=8,
                                     prefill_bucket=8)):
        reqs = make_requests(arch, SPEC, prefix=prefix)
        engine = build()
        engine.run_batch(reqs)
        outs.append((engine, reqs))
    return outs


@pytest.mark.parametrize("name", ARCHS)
def test_continuous_matches_static_fp32(name):
    a, b = _run_both(name, None)
    for ra, rb in zip(a, b):
        assert ra.generated.shape == (ra.max_new_tokens,)
        np.testing.assert_array_equal(ra.generated, rb.generated)


@pytest.mark.slow
@pytest.mark.parametrize("name", ARCHS)
def test_continuous_matches_static_bf16(name):
    """Precision-aware decode: bf16 param/compute cast, fp32 greedy — the
    cast must not desynchronize the two engines."""
    a, b = _run_both(name, "bf16")
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.generated, rb.generated)


@pytest.mark.paged
@pytest.mark.parametrize("name", ARCHS)
def test_paged_matches_dense_and_static_shared_prefix_fp32(name):
    """The differential harness of this PR: the paged engine is token-
    identical to the dense PR 2 engine and the static baseline, with
    every request carrying a 16-token shared prefix whose KV the paged
    pool stores once (shared_hits > 0 on attention archs — pure-SSM
    state is slot-resident, nothing to share)."""
    (s_eng, a), (d_eng, b), (p_eng, c) = _run_trio(name, None, prefix=16)
    for ra, rb, rc in zip(a, b, c):
        assert ra.generated.shape == (ra.max_new_tokens,)
        np.testing.assert_array_equal(ra.generated, rb.generated)
        np.testing.assert_array_equal(ra.generated, rc.generated)
    if p_eng.pool.maps:
        assert p_eng.pool.shared_hits > 0
    p_eng.pool.check_invariants()
    assert all(m.alloc.n_live == 0 for m in p_eng.pool.maps.values())


@pytest.mark.slow
@pytest.mark.paged
@pytest.mark.parametrize("name", ARCHS)
def test_paged_matches_dense_and_static_shared_prefix_bf16(name):
    """Same trio under the bf16 policy: the cast must not perturb block
    contents differently across pool layouts."""
    (_, a), (_, b), (p_eng, c) = _run_trio(name, "bf16", prefix=16)
    for ra, rb, rc in zip(a, b, c):
        np.testing.assert_array_equal(ra.generated, rb.generated)
        np.testing.assert_array_equal(ra.generated, rc.generated)
    p_eng.pool.check_invariants()


def test_bf16_policy_casts_params_and_matches_static():
    """Tier-1 single-arch version of the bf16 matrix: policy actually
    changes the parameter copy AND the engines still agree."""
    import jax.numpy as jnp
    from repro.serving.engine import apply_serving_policy
    arch, params = setup_arch("gemma2-2b")
    cast_arch, cast = apply_serving_policy(arch, params, "bf16")
    dtypes = {str(l.dtype) for l in jax.tree.leaves(cast)}
    assert "bfloat16" in dtypes        # matmul weights cast
    assert "float32" in dtypes         # LN/bias overrides kept fp32
    assert cast_arch.cfg.compute_dtype == jnp.bfloat16
    a, b = _run_both("gemma2-2b", "bf16")
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.generated, rb.generated)


@pytest.mark.parametrize("name", ARCHS)
def test_left_pad_invariance(name):
    """A short request batched with a longer one (forcing left-padding)
    generates the same tokens as when it runs alone."""
    arch, params = setup_arch(name)
    engine = ServeEngine(arch, params, max_len=MAX_LEN)
    solo = make_requests(arch, [(5, 4)])
    engine.run_batch(solo)
    pair = make_requests(arch, [(5, 4), (13, 4)])
    engine.run_batch(pair)
    np.testing.assert_array_equal(solo[0].generated, pair[0].generated)


def test_slot_reuse_after_eviction():
    """max_batch=1: every request reuses the single slot; the second and
    third must not see the first's cache rows."""
    arch, params = setup_arch("gemma2-2b")
    spec = [(9, 5), (6, 3), (11, 4)]
    solos = make_requests(arch, spec)
    static = ServeEngine(arch, params, max_len=MAX_LEN)
    for r in solos:
        static.run_batch([r])
    eng = ContinuousEngine(arch, params, max_batch=1, max_len=MAX_LEN)
    reqs = make_requests(arch, spec)
    eng.run(reqs)
    assert eng.scheduler.completed == reqs  # FIFO order preserved
    for solo, r in zip(solos, reqs):
        np.testing.assert_array_equal(solo.generated, r.generated)


def test_mid_stream_admission():
    """A request submitted while others are mid-decode joins a freed slot
    and still matches its solo output."""
    arch, params = setup_arch("gemma2-2b")
    static = ServeEngine(arch, params, max_len=MAX_LEN)
    spec = [(7, 8), (9, 2), (6, 5)]
    solos = make_requests(arch, spec)
    for r in solos:
        static.run_batch([r])

    eng = ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN)
    r0, r1, r2 = make_requests(arch, spec)
    eng.submit(r0)
    eng.submit(r1)
    for _ in range(3):        # r1 (2 tokens) completes during these steps
        eng.step()
    assert r1.generated is not None and len(eng.scheduler.active) == 1
    eng.submit(r2)            # admitted mid-stream into r1's old slot
    while eng.step():
        pass
    for solo, r in zip(solos, (r0, r1, r2)):
        np.testing.assert_array_equal(solo.generated, r.generated)
    assert eng.steps_run < 8 + 2 + 5  # slots overlapped, not serialized


def test_one_token_request_completes_at_admission():
    arch, params = setup_arch("gemma2-2b")
    eng = ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN)
    reqs = make_requests(arch, [(6, 1), (6, 1), (6, 1)])
    eng.run(reqs)
    assert all(r.generated.shape == (1,) for r in reqs)
    assert eng.steps_run == 0  # never needed a decode step


def test_request_validation():
    arch, params = setup_arch("gemma2-2b")
    eng = ContinuousEngine(arch, params, max_batch=1, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(make_requests(arch, [(15, 4)])[0])   # 15 + 4 > 16
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=0))


def test_cache_pool_insert_evict_roundtrip():
    arch, params = setup_arch("gemma2-2b")
    pool = CachePool(arch, max_batch=3, max_len=MAX_LEN)
    _, req_cache = arch.prefill(
        params, {"tokens": np.arange(5, 13, dtype=np.int32)[None]},
        cache_len=MAX_LEN, per_slot=True)
    pool.insert(req_cache, 1)
    assert pool.lengths().tolist() == [0, 8, 0]
    # the occupied slot's first 8 positions are live, the rest invalid
    pos = np.asarray(pool.cache["slots"][1]["pos"])  # full-attn slot
    assert (pos[:, 1, :8] >= 0).all() and (pos[:, 1, 8:] == -1).all()
    assert (pos[:, 0] == -1).all() and (pos[:, 2] == -1).all()
    pool.evict(1)
    assert pool.lengths().tolist() == [0, 0, 0]
    assert (np.asarray(pool.cache["slots"][1]["pos"]) == -1).all()
    with pytest.raises(IndexError):
        pool.insert(req_cache, 3)


def test_pad_prompts_layout():
    tokens, positions, lens = pad_prompts(
        [np.array([3, 4, 5], np.int32), np.array([7], np.int32)],
        granularity=4)
    assert tokens.shape == (2, 4)
    assert tokens[0].tolist() == [0, 3, 4, 5]
    assert positions[0].tolist() == [-1, 0, 1, 2]
    assert positions[1].tolist() == [-3, -2, -1, 0]
    assert lens.tolist() == [3, 1]
    with pytest.raises(ValueError):
        pad_prompts([np.arange(5, dtype=np.int32)], pad_len=4)


def test_scheduler_fifo_and_invariants():
    sched = Scheduler(2)
    for i in range(5):
        sched.submit(f"r{i}")
    pairs = sched.assign()
    assert [r for _, r in pairs] == ["r0", "r1"]
    assert sched.assign() == []           # pool full
    sched.check_invariants()
    slot0 = pairs[0][0]
    assert sched.complete(slot0) == "r0"
    pairs2 = sched.assign()
    assert [r for _, r in pairs2] == ["r2"] and pairs2[0][0] == slot0
    sched.check_invariants()
    # drain everything FIFO
    done = []
    while sched.has_work:
        for slot in sorted(sched.active):
            done.append(sched.complete(slot))
        sched.assign()
        sched.check_invariants()
    assert sorted(sched.completed) == [f"r{i}" for i in range(5)]
    from repro.serving import SchedulerError
    with pytest.raises(SchedulerError):
        sched.complete(0)                 # all slots free: nothing to release


def test_throughput_probe_excludes_compile():
    arch, params = setup_arch("gemma2-2b")
    engine = ServeEngine(arch, params, max_len=MAX_LEN)
    reqs = make_requests(arch, [(6, 3), (8, 3)])
    stats = throughput_probe(engine, reqs)
    assert stats["warmup"] is True
    assert stats["tokens"] == 6 and stats["tokens_per_s"] > 0
    # warmed-up runs should not include multi-second jit compiles
    assert stats["wall_s"] < 5.0


def test_chunked_attention_accepts_per_batch_positions():
    """Regression (review finding): the remat-chunked query-block path must
    handle 2-D (B, S) positions — a batched left-padded serving prefill
    long enough to trip q_chunk_threshold used to crash on the reshape."""
    import dataclasses
    import jax.numpy as jnp
    from repro.models.attention import AttnConfig, attn_apply, attn_init
    cfg = AttnConfig(d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                     q_chunk_threshold=8, q_block=4)
    ref_cfg = dataclasses.replace(cfg, q_chunk_threshold=10 ** 9)
    p = attn_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
    pos = jnp.stack([jnp.arange(8) - 3, jnp.arange(8)])  # row 0 left-padded
    out_chunked, _ = attn_apply(p, cfg, x, positions=pos,
                                compute_dtype=jnp.float32)
    out_ref, _ = attn_apply(p, ref_cfg, x, positions=pos,
                            compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out_chunked), np.asarray(out_ref),
                               rtol=2e-5, atol=2e-5)

"""Distributed step builders on a local 1x1 mesh (API-level integration)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_arch
from repro.core.optim import lans
from repro.distributed import sharding as shd
from repro.distributed.steps import build_train_step, jit_train_step
from repro.launch.mesh import make_local_mesh


def test_build_and_jit_train_step_local_mesh():
    arch = reduced_arch("qwen2.5-14b")
    mesh = make_local_mesh(data=1, model=1)
    tx = lans(1e-3)

    step_fn, init_fn, specs_for = build_train_step(
        arch.loss_fn, tx, mesh, microbatches=2,
        param_init_fn=lambda rng: arch.init(rng))

    params, opt_state = init_fn(jax.random.PRNGKey(0))
    pspec, ospec = specs_for(params, opt_state)

    batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
             "labels": jnp.zeros((4, 32), jnp.int32)}
    jitted = jit_train_step(step_fn, mesh, pspec, ospec, batch)
    with mesh:
        p2, o2, metrics = jitted(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    moved = any(bool(jnp.any(a != b))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


def test_zero1_moment_spec_sharded_over_data():
    arch = reduced_arch("qwen2.5-14b")
    params = arch.abstract_params()

    class FakeMesh:
        shape = {"data": 4, "model": 2}
        axis_names = ("data", "model")

    mesh = FakeMesh()
    pspec = shd.params_pspec(params, mesh, zero3=False)
    mspec = shd.params_pspec(params, mesh, zero3=True)
    tx = lans(1e-3)
    opt = jax.eval_shape(tx.init, params)
    ospec = shd.opt_state_pspec(opt, pspec, moments_spec=mspec)
    # at least one moment leaf picked up the extra "data" axis
    flat = jax.tree.leaves(
        ospec[0].mu, is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval"))
    import itertools
    names = set(itertools.chain.from_iterable(
        (ax if isinstance(ax, tuple) else (ax,))
        for spec in flat for ax in spec if ax is not None))
    assert "data" in names


def test_microbatch_aux_averaged_not_last():
    """Regression: with microbatches > 1 the step used to report only the
    LAST microbatch's aux (jax.tree.map(lambda a: a[-1], auxs)); numeric
    aux must be the mean over all microbatches."""
    mesh = make_local_mesh(data=1, model=1)

    def loss_fn(params, batch):
        x = batch["x"]
        loss = jnp.mean((x * params["w"]) ** 2)
        return loss, {"x_mean": jnp.mean(x),
                      "mb_id": jnp.max(x).astype(jnp.int32)}

    step_fn, init_fn, _ = build_train_step(
        loss_fn, lans(1e-3), mesh, microbatches=2,
        param_init_fn=lambda rng: {"w": jnp.ones((4,))})
    params, opt_state = init_fn(jax.random.PRNGKey(0))
    # microbatch 0 is all 1.0, microbatch 1 all 3.0
    batch = {"x": jnp.concatenate([jnp.full((2, 4), 1.0),
                                   jnp.full((2, 4), 3.0)])}
    _, _, metrics = step_fn(params, opt_state, batch)
    assert float(metrics["x_mean"]) == pytest.approx(2.0)  # mean, not 3.0
    assert int(metrics["mb_id"]) == 3  # non-float aux keeps last-mb value

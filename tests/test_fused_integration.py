"""Kernel-backed (fused) optimizers inside a real training loop."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_arch
from repro.core.optim import apply_updates, lans
from repro.core.optim.fused import fused_lans
from repro.models.common import maybe_constrain, ambient_axis_size
from repro.launch.mesh import make_local_mesh


def test_fused_lans_trains_like_reference():
    """3 steps of fused-vs-reference LANS on a real model: same params."""
    arch = reduced_arch("mamba2-130m")
    params0 = arch.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          arch.cfg.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                          arch.cfg.vocab)}

    def train(tx):
        params = params0
        st = tx.init(params)
        for _ in range(3):
            (_, _), g = jax.value_and_grad(arch.loss_fn, has_aux=True)(
                params, batch)
            g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
            upd, st = tx.update(g, st, params)
            params = apply_updates(params, upd)
        return params

    p_ref = train(lans(5e-3))
    p_fused = train(fused_lans(5e-3))
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_fused)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)


def test_maybe_constrain_noop_without_mesh():
    x = jnp.ones((4, 8))
    y = maybe_constrain(x, "data", "model")  # no ambient mesh -> no-op
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert ambient_axis_size("data") == 1


def test_maybe_constrain_degrades_nondivisible_dims():
    mesh = make_local_mesh(data=1, model=1)

    @jax.jit
    def f(x):
        return maybe_constrain(x, "data", "model") * 1.0

    with mesh:
        out = f(jnp.ones((3, 5)))  # 3 % 1 == 0 trivially; no crash
    assert out.shape == (3, 5)

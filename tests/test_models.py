"""Model-level behaviour tests: decode parity, masking, MoE routing, SSD."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models.attention import AttnConfig, attn_apply, attn_init
from repro.models.decoder import (DecoderConfig, decoder_apply, decoder_init,
                                  init_decoder_cache, chunked_lm_loss, lm_loss)


def _dense_cfg(**over):
    base = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab=97)
    base.update(over)
    return DecoderConfig(**base)


# The three decode-parity tests pin decode-vs-full-forward agreement in
# fp32 compute: cached decode intentionally runs fp32 softmax probs (the
# Pallas paged-kernel comparability contract — see models/attention.py),
# so under bf16 compute it is now MORE precise than the bf16 full
# forward and parity is only bounded by bf16 rounding (~7e-3).

def test_prefill_decode_parity_dense():
    cfg = _dense_cfg(compute_dtype=jnp.float32)
    params = decoder_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    full, _, _ = decoder_apply(params, cfg, toks)
    cache = init_decoder_cache(cfg, 2, 24, dtype=jnp.float32)
    outs = []
    for i in range(24):
        lg, cache, _ = decoder_apply(params, cfg, toks[:, i:i+1], caches=cache)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(full - jnp.stack(outs, 1))))
    assert err < 1e-3, err


def test_prefill_decode_parity_dense_bf16_loose():
    """bf16-compute variant at the bf16-rounding-bounded tolerance:
    cached decode (fp32 probs) vs the bf16 full forward. Keeps bf16-only
    regressions in the cache branches (wrong cast, dropped constrain)
    visible now that the tight parity tests run fp32."""
    cfg = _dense_cfg()                      # default compute_dtype: bf16
    params = decoder_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    full, _, _ = decoder_apply(params, cfg, toks)
    cache = init_decoder_cache(cfg, 2, 24, dtype=jnp.float32)
    outs = []
    for i in range(24):
        lg, cache, _ = decoder_apply(params, cfg, toks[:, i:i+1], caches=cache)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(full - jnp.stack(outs, 1))))
    assert err < 2e-2, err


def test_sliding_window_ring_cache_matches_full_history():
    """Ring-buffer local attention == full-cache attention with window mask."""
    cfg = _dense_cfg(sliding_window=8, compute_dtype=jnp.float32,
                     superblock=(("attn_local", "mlp"),))
    params = decoder_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 20), 0, cfg.vocab)
    full, _, _ = decoder_apply(params, cfg, toks)
    # ring cache (length = window = 8 < 20)
    cache = init_decoder_cache(cfg, 1, 20, dtype=jnp.float32)
    assert cache["slots"][0]["k"].shape[2] == 8  # ring-sized
    outs = []
    for i in range(20):
        lg, cache, _ = decoder_apply(params, cfg, toks[:, i:i+1], caches=cache)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(full - jnp.stack(outs, 1))))
    assert err < 1e-3, err


def test_causal_masking_no_future_leak():
    """Changing future tokens must not change past logits."""
    cfg = _dense_cfg()
    params = decoder_init(jax.random.PRNGKey(0), cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
    t2 = t1.at[0, 12:].set((t1[0, 12:] + 1) % cfg.vocab)
    l1, _, _ = decoder_apply(params, cfg, t1)
    l2, _, _ = decoder_apply(params, cfg, t2)
    np.testing.assert_allclose(np.asarray(l1[:, :12]), np.asarray(l2[:, :12]),
                               atol=1e-5)


def test_chunked_lm_loss_matches_plain():
    cfg = _dense_cfg(vocab=256)
    params = decoder_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, cfg.vocab)
    logits, _, _ = decoder_apply(params, cfg, toks)
    plain = lm_loss(logits, labels)
    hidden, _, _ = decoder_apply(params, cfg, toks, return_hidden=True)
    chunked = chunked_lm_loss(params, cfg, hidden, labels, chunk=16)
    np.testing.assert_allclose(float(plain), float(chunked), rtol=1e-5)


def test_moe_router_top_k_and_combine_weights():
    cfg = moe_lib.MoeConfig(d_model=32, d_ff=64, n_experts=8, top_k=2)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out, aux = moe_lib.moe_apply(p, cfg, x, compute_dtype=jnp.float32)
    assert out.shape == x.shape
    assert float(aux["moe_aux_loss"]) > 0
    # aux loss is minimized (==1) under perfectly uniform routing
    assert float(aux["moe_aux_loss"]) >= 1.0 - 1e-3


def test_moe_capacity_drops_tokens_gracefully():
    cfg = moe_lib.MoeConfig(d_model=16, d_ff=32, n_experts=4, top_k=1,
                            capacity_factor=0.25)  # tiny capacity
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16))
    out, _ = moe_lib.moe_apply(p, cfg, x, compute_dtype=jnp.float32)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_mamba_chunked_equals_recurrent_decode():
    """SSD chunked scan == step-by-step recurrence (state-space duality)."""
    cfg = mamba_lib.MambaConfig(d_model=32, d_inner=64, headdim=16,
                                dstate=8, chunk=4)
    p = mamba_lib.mamba_init(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    full, _ = mamba_lib.mamba_apply(p, cfg, x, compute_dtype=jnp.float32)
    cache = mamba_lib.init_mamba_cache(2, cfg)
    outs = []
    for i in range(16):
        o, cache = mamba_lib.mamba_apply(p, cfg, x[:, i:i+1], cache=cache,
                                         compute_dtype=jnp.float32)
        outs.append(o[:, 0])
    err = float(jnp.max(jnp.abs(full - jnp.stack(outs, 1))))
    assert err < 1e-4, err


def test_mamba_state_carried_across_prefill_chunks():
    """Two half-sequence prefills with cache == one full prefill."""
    cfg = mamba_lib.MambaConfig(d_model=32, d_inner=64, headdim=16,
                                dstate=8, chunk=4)
    p = mamba_lib.mamba_init(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))
    full, _ = mamba_lib.mamba_apply(p, cfg, x, compute_dtype=jnp.float32)
    cache = mamba_lib.init_mamba_cache(1, cfg)
    o1, cache = mamba_lib.mamba_apply(p, cfg, x[:, :8], cache=cache,
                                      compute_dtype=jnp.float32)
    o2, cache = mamba_lib.mamba_apply(p, cfg, x[:, 8:], cache=cache,
                                      compute_dtype=jnp.float32)
    err = float(jnp.max(jnp.abs(full - jnp.concatenate([o1, o2], 1))))
    assert err < 1e-4, err


def test_gqa_head_grouping():
    """GQA with kv=2,h=4: each kv head serves 2 query heads (shape check +
    equality with manual repeat)."""
    cfg = AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8)
    p = attn_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    out, _ = attn_apply(p, cfg, x, compute_dtype=jnp.float32)
    assert out.shape == (1, 8, 32)


def test_softcap_bounds_logits():
    from repro.models.common import softcap
    x = jnp.asarray([-1e6, -10.0, 0.0, 10.0, 1e6], jnp.float32)
    y = softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0 + 1e-4
    np.testing.assert_allclose(float(y[2]), 0.0, atol=1e-6)


def test_qk_norm_changes_attention_but_stays_finite():
    cfg = dataclasses.replace(
        AttnConfig(d_model=32, n_heads=4, n_kv_heads=4, head_dim=8),
        qk_norm=True)
    p = attn_init(jax.random.PRNGKey(0), cfg)
    x = 100.0 * jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    out, _ = attn_apply(p, cfg, x, compute_dtype=jnp.float32)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_prefill_through_ring_then_decode_matches_full():
    """32k-style prefill into a window-sized ring cache, then decode."""
    cfg = _dense_cfg(sliding_window=8, compute_dtype=jnp.float32,
                     superblock=(("attn_local", "mlp"), ("attn", "mlp")))
    params = decoder_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 28), 0, cfg.vocab)
    full, _, _ = decoder_apply(params, cfg, toks)
    cache = init_decoder_cache(cfg, 1, 28, dtype=jnp.float32)
    assert cache["slots"][0]["k"].shape[2] == 8       # local ring
    assert cache["slots"][1]["k"].shape[2] == 28      # global full
    pre, cache, _ = decoder_apply(params, cfg, toks[:, :24], caches=cache)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :24]),
                               atol=1e-3)
    outs = []
    for i in range(24, 28):
        lg, cache, _ = decoder_apply(params, cfg, toks[:, i:i+1], caches=cache)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full[:, 24:]), atol=1e-3)

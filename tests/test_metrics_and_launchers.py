"""Metrics logger + CLI launcher smoke tests (subprocess entry points)."""
import json
import os
import subprocess
import sys

import numpy as np

from repro.metrics import MetricsLogger, read_metrics

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_metrics_logger_roundtrip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with MetricsLogger(path, window=3) as log:
        for i in range(5):
            log.log(i, loss=5.0 - i, lr=1e-3)
        assert abs(log.smoothed_loss - 2.0) < 1e-6  # mean of (3,2,1)
    recs = list(read_metrics(path))
    assert len(recs) == 5
    assert recs[0]["step"] == 0 and abs(recs[0]["loss"] - 5.0) < 1e-9
    assert all("wall_s" in r for r in recs)


def _run_cli(args, timeout=420):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-m", *args], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_train_cli_smoke(tmp_path):
    metrics = str(tmp_path / "train.jsonl")
    r = _run_cli(["repro.launch.train", "--arch", "mamba2-130m",
                  "--steps", "4", "--batch", "2", "--seq", "32",
                  "--optimizer", "lans", "--metrics", metrics])
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert np.isfinite(out["final_loss"])
    assert len(list(read_metrics(metrics))) == 4


def test_serve_cli_smoke():
    r = _run_cli(["repro.launch.serve", "--arch", "gemma2-2b",
                  "--batch", "2", "--prompt-len", "8", "--new-tokens", "3"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tokens_per_s" in r.stdout

"""Metrics logger + CLI launcher smoke tests (subprocess entry points)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.metrics import MetricsLogger, read_metrics

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_metrics_logger_roundtrip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with MetricsLogger(path, window=3) as log:
        for i in range(5):
            log.log(i, loss=5.0 - i, lr=1e-3)
        assert abs(log.smoothed_loss - 2.0) < 1e-6  # mean of (3,2,1)
    recs = list(read_metrics(path))
    assert len(recs) == 5
    assert recs[0]["step"] == 0 and abs(recs[0]["loss"] - 5.0) < 1e-9
    assert all("wall_s" in r for r in recs)


def _run_cli(args, timeout=420):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-m", *args], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_train_cli_smoke(tmp_path):
    metrics = str(tmp_path / "train.jsonl")
    r = _run_cli(["repro.launch.train", "--arch", "mamba2-130m",
                  "--steps", "4", "--batch", "2", "--seq", "32",
                  "--optimizer", "lans", "--metrics", metrics])
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert np.isfinite(out["final_loss"])
    assert len(list(read_metrics(metrics))) == 4


def test_serve_cli_smoke():
    r = _run_cli(["repro.launch.serve", "--arch", "gemma2-2b",
                  "--batch", "2", "--prompt-len", "8", "--new-tokens", "3"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tokens_per_s" in r.stdout


def test_serve_cli_routed_smoke(tmp_path):
    metrics = str(tmp_path / "serve.jsonl")
    r = _run_cli(["repro.launch.serve", "--arch", "qwen2.5-14b",
                  "--batch", "2", "--prompt-len", "8", "--new-tokens", "3",
                  "--requests", "6", "--block-size", "8",
                  "--shared-prefix", "8", "--replicas", "2",
                  "--route-policy", "prefix", "--metrics", metrics])
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["replicas"] == 2 and out["route_policy"] == "prefix"
    assert out["routed_submits"] == 6
    assert out["completed"] == 6
    # every request shares ONE system prompt, so affinity pins them all
    # to a single replica: 1 binding miss + 5 sticky hits ...
    assert out["routed_affinity_hits"] == 5
    # ... and the per-step JSONL records (which carry the emitting
    # replica's index) all come from that one home replica
    recs = [rec for rec in read_metrics(metrics) if rec["step"] >= 0]
    assert recs and len({rec["replica"] for rec in recs}) == 1


# ---------------------------------------------------------------------------
# serve.py flag-compatibility matrix (in-process: build_parser + flag_errors)
# ---------------------------------------------------------------------------

from repro.launch.serve import (CONTINUOUS_ONLY_FLAGS, PAGED_ONLY_FLAGS,
                                build_parser, flag_errors, parse_mesh)

# one argv fragment per gated flag, keyed by the matrix's display name
_FLAG_ARGV = {
    "--growth": ["--growth", "eager"],
    "--slots-budget": ["--slots-budget", "2"],
    "--retain-blocks": ["--retain-blocks", "4"],
    "--watermark": ["--watermark", "1"],
    "--chunk-budget": ["--chunk-budget", "8"],
    "--spec-draft": ["--spec-draft", "self"],
    "--spec-k": ["--spec-k", "4"],
    "--replicas": ["--replicas", "2"],
    "--route-policy": ["--route-policy", "rr"],
    "--attn-kernel paged": ["--attn-kernel", "paged"],
    # bare --interpret also fails the attn-kernel cross-check, so the
    # clean-parse half of the matrix needs the kernel path enabled
    "--interpret": ["--interpret", "--attn-kernel", "paged"],
    "--sched-policy": ["--sched-policy", "arrival-deadline"],
    "--slo-ms": ["--slo-ms", "100"],
    "--no-preempt": ["--no-preempt"],
    "--arrival-rate": ["--arrival-rate", "5"],
    "--mesh": ["--mesh", "1x1"],
}


def _errs(argv):
    return flag_errors(build_parser().parse_args(argv))


def test_flag_matrix_covers_every_gated_flag():
    # a gated flag without an argv fragment here is an untested gate
    gated = {f for f, _ in PAGED_ONLY_FLAGS + CONTINUOUS_ONLY_FLAGS}
    assert gated == set(_FLAG_ARGV)


@pytest.mark.parametrize("flag", [f for f, _ in PAGED_ONLY_FLAGS])
@pytest.mark.parametrize("base", [["--engine", "static"],
                                  ["--cache", "dense"]],
                         ids=["static", "dense"])
def test_paged_only_flags_fail_fast_uniformly(flag, base):
    errs = _errs(base + _FLAG_ARGV[flag])
    assert any(flag in e for e in errs), (flag, base, errs)
    assert any("--engine continuous --cache paged" in e for e in errs)
    # the same flag on the paged continuous engine parses clean
    assert _errs(_FLAG_ARGV[flag]) == []


@pytest.mark.parametrize("flag", [f for f, _ in CONTINUOUS_ONLY_FLAGS])
def test_continuous_only_flags_fail_fast_on_static(flag):
    errs = _errs(["--engine", "static"] + _FLAG_ARGV[flag])
    assert any(flag in e for e in errs), (flag, errs)
    assert any("--engine continuous" in e for e in errs)
    assert _errs(_FLAG_ARGV[flag]) == []
    # scheduler flags are cache-agnostic: fine on the dense pool
    assert _errs(["--cache", "dense"] + _FLAG_ARGV[flag]) == []


def test_flag_errors_lists_every_offender_at_once():
    errs = _errs(["--engine", "static", "--replicas", "2",
                  "--chunk-budget", "8", "--mesh", "1x1"])
    joined = "; ".join(errs)
    for flag in ("--replicas", "--chunk-budget", "--mesh"):
        assert flag in joined
    assert len(errs) == 2    # one paged-pool line + one scheduler line


def test_defaults_parse_clean():
    assert _errs([]) == []
    assert _errs(["--engine", "static"]) == []
    assert _errs(["--cache", "dense"]) == []


def test_parse_mesh_specs():
    assert parse_mesh(None) is None
    mesh = parse_mesh("1x1")
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.shape == (1, 1)
    bare = parse_mesh("1")          # bare N means 1xN tensor parallel
    assert bare.devices.shape == (1, 1)


# ---------------------------------------------------------------------------
# ContinuousEngine.report() / per-step JSONL schema
# ---------------------------------------------------------------------------

# every key report() must emit, with its permitted types; paged/chunked/
# spec engines extend the base set and must never drop a base key
_REPORT_BASE = {
    "requests": int, "tokens": int, "wall_s": float, "tokens_per_s": float,
    "ttft_p50_ms": float, "ttft_p99_ms": float,
    "itl_p50_ms": float, "itl_p99_ms": float,
    "preemptions": int, "slo_evictions": int, "slot_utilization": float,
    "decode_steps": int, "max_concurrent": int, "sched_policy": str,
    "mesh_devices": int, "queue_depth_max": int, "queue_depth_mean": float,
    "queue_depth_p50": float,
}
_REPORT_PAGED = {
    "growth": str, "shared_block_hits": int, "retained_block_hits": int,
    "prefix_misses": int, "retained_hit_rate": float,
}
_REPORT_CHUNKED = {"chunk_budget": int, "chunk_steps": int,
                   "chunk_tokens": int}
_REPORT_SPEC = {"spec_k": int, "spec_rounds": int, "drafted_tokens": int,
                "accepted_tokens": int, "acceptance_rate": float}


def _check_schema(rep, schema):
    for key, typ in schema.items():
        assert key in rep, f"missing {key}"
        val = rep[key]
        if typ in (int, float):
            # bool is an int subclass; a bool-typed count is a bug
            assert isinstance(val, typ) and not isinstance(val, bool), \
                f"{key}: {val!r} is not {typ.__name__}"
            assert np.isfinite(val), f"{key} not finite: {val!r}"
        else:
            assert isinstance(val, typ), f"{key}: {val!r}"


def test_engine_report_schema(tmp_path):
    from conftest import make_serving_requests, setup_serving_arch
    from repro.serving import ContinuousEngine, make_spec_pair

    arch, params = setup_serving_arch("qwen2.5-14b")
    metrics = str(tmp_path / "steps.jsonl")
    with MetricsLogger(metrics) as log:
        def on_step(rec):
            log.log(rec["step"], active=rec["active"],
                    queued=rec["queued"], preemptions=rec["preemptions"],
                    replica=0)

        eng = ContinuousEngine(arch, params, max_batch=2, max_len=48,
                               cache="paged", block_size=8,
                               on_step=on_step)
        eng.run(make_serving_requests(arch, [8, 8, 8], seed=5,
                                      max_new_tokens=4))
        rep = eng.report(1.0)
    _check_schema(rep, _REPORT_BASE)
    _check_schema(rep, _REPORT_PAGED)
    assert rep["mesh_devices"] == 1

    # every per-step JSONL record carries the full step schema
    recs = [r for r in read_metrics(metrics) if r["step"] >= 0]
    assert len(recs) == rep["decode_steps"]
    for r in recs:
        for key in ("step", "active", "queued", "preemptions", "replica"):
            assert key in r and np.isfinite(r[key])

    # chunked + speculative extensions, base keys intact
    chunk = ContinuousEngine(arch, params, max_batch=2, max_len=48,
                             cache="paged", block_size=8, chunk_budget=8)
    chunk.run(make_serving_requests(arch, [8, 8], seed=6,
                                    max_new_tokens=4))
    crep = chunk.report(1.0)
    _check_schema(crep, {**_REPORT_BASE, **_REPORT_PAGED,
                         **_REPORT_CHUNKED})

    tparams, darch, dparams = make_spec_pair(arch, params)
    spec = ContinuousEngine(arch, tparams, max_batch=2, max_len=48,
                            cache="paged", block_size=8,
                            spec_draft=(darch, dparams), spec_k=3)
    spec.run(make_serving_requests(arch, [8, 8], seed=7,
                                   max_new_tokens=6))
    srep = spec.report(1.0)
    _check_schema(srep, {**_REPORT_BASE, **_REPORT_PAGED, **_REPORT_SPEC})

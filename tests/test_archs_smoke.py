"""Per-arch smoke tests: reduced variant, one forward + one train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, SHAPES, get_arch, reduced_arch
from repro.core.optim import apply_updates, lans

ALL = ASSIGNED + ["bert-large"]


def _batch(arch, rng, B=2, S=32):
    cfg = arch.cfg
    if arch.kind == "bert":
        return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
                "token_types": jnp.zeros((B, S), jnp.int32),
                "mlm_labels": jnp.where(
                    jax.random.bernoulli(rng, 0.15, (B, S)),
                    jax.random.randint(rng, (B, S), 0, cfg.vocab), -100),
                "nsp_labels": jnp.zeros((B,), jnp.int32)}
    if arch.kind == "encdec":
        return {"frames": jax.random.normal(rng, (B, cfg.n_frames, cfg.d_model)),
                "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
                "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if arch.embeds_input:
        return {"embeds": 0.02 * jax.random.normal(rng, (B, S, cfg.d_model)),
                "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("name", ALL)
def test_reduced_forward_and_train_step(name):
    arch = reduced_arch(name)
    rng = jax.random.PRNGKey(0)
    params = arch.init(rng)
    batch = _batch(arch, rng)

    loss, aux = arch.loss_fn(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name

    tx = lans(1e-3)
    st = tx.init(params)

    @jax.jit
    def step(params, st, batch):
        (l, _), g = jax.value_and_grad(arch.loss_fn, has_aux=True)(params, batch)
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        upd, st = tx.update(g, st, params)
        return apply_updates(params, upd), st, l

    p2, st, l = step(params, st, batch)
    assert bool(jnp.isfinite(l)), name
    for leaf in jax.tree.leaves(p2):
        assert bool(jnp.all(jnp.isfinite(leaf))), name
    # params actually moved
    moved = any(bool(jnp.any(a != b))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, name


@pytest.mark.parametrize("name", [n for n in ASSIGNED
                                  if get_arch(n).kind != "bert"])
def test_reduced_decode_step(name):
    """prefill + 2 decode steps on the reduced variant; shapes + finiteness."""
    arch = reduced_arch(name)
    rng = jax.random.PRNGKey(1)
    params = arch.init(rng)
    B, S = 2, 16
    toks = jax.random.randint(rng, (B, S), 0, arch.cfg.vocab)

    if arch.kind == "encdec":
        frames = jax.random.normal(rng, (B, arch.cfg.n_frames, arch.cfg.d_model))
        logits, cache = arch.prefill(params, {"frames": frames, "tokens": toks},
                                     cache_len=S + 4)
        step_extra = {"memory": __import__("repro.models.encdec",
                                           fromlist=["encode"]).encode(
                                               params, arch.cfg, frames)}
    else:
        logits, cache = arch.prefill(params, {"tokens": toks}, cache_len=S + 4)
        step_extra = {}
    assert logits.shape[:2] == (B, 1)
    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    for _ in range(2):
        batch = {"tokens": nxt[:, None], **step_extra}
        logits, cache = arch.decode_step(params, batch, cache)
        assert logits.shape == (B, 1, arch.cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits))), name
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)


@pytest.mark.parametrize("name", ALL)
def test_full_config_matches_assignment(name):
    """The FULL configs carry the exact assigned hyperparameters."""
    arch = get_arch(name)
    expected = {
        "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48,
                            n_kv_heads=8, d_ff=32768, vocab=131072,
                            n_experts=8, top_k=2),
        "granite-moe-3b-a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                     n_kv_heads=8, d_ff=512, vocab=49155,
                                     n_experts=40, top_k=8),
        "qwen2.5-14b": dict(n_layers=48, d_model=5120, n_heads=40,
                            n_kv_heads=8, d_ff=13824, vocab=152064,
                            qkv_bias=True),
        "qwen2.5-32b": dict(n_layers=64, d_model=5120, n_heads=40,
                            n_kv_heads=8, d_ff=27648, vocab=152064,
                            qkv_bias=True),
        "chameleon-34b": dict(n_layers=48, d_model=8192, n_heads=64,
                              n_kv_heads=8, d_ff=22016, vocab=65536),
        "whisper-large-v3": dict(n_layers=32, d_model=1280, n_heads=20,
                                 d_ff=5120, vocab=51866),
        "mistral-nemo-12b": dict(n_layers=40, d_model=5120, n_heads=32,
                                 n_kv_heads=8, d_ff=14336, vocab=131072),
        "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, n_heads=64,
                                     n_kv_heads=8, d_ff=24576, vocab=65536,
                                     n_experts=16, top_k=2),
        "mamba2-130m": dict(n_layers=24, d_model=768, vocab=50280,
                            mamba_dstate=128),
        "gemma2-2b": dict(n_layers=26, d_model=2304, n_heads=8,
                          n_kv_heads=4, d_ff=9216, vocab=256000),
        "bert-large": dict(n_layers=24, d_model=1024, n_heads=16,
                           d_ff=4096, vocab=30522),
    }[name]
    for k, v in expected.items():
        assert getattr(arch.cfg, k) == v, (name, k, getattr(arch.cfg, k), v)


def test_param_counts_match_assigned_sizes():
    """Total parameters land near the names on the tin."""
    sizes = {"grok-1-314b": 314e9, "qwen2.5-14b": 14e9, "qwen2.5-32b": 32e9,
             "chameleon-34b": 34e9, "mistral-nemo-12b": 12e9,
             "jamba-1.5-large-398b": 398e9, "mamba2-130m": 130e6,
             "gemma2-2b": 2.6e9}
    for name, want in sizes.items():
        got = get_arch(name).param_count()
        assert 0.8 * want <= got <= 1.25 * want, (name, got, want)


def test_long_500k_support_flags():
    """Sub-quadratic archs run long_500k; pure full-attention archs skip."""
    runs = {n for n in ASSIGNED if get_arch(n).supports("long_500k")}
    assert runs == {"mamba2-130m", "jamba-1.5-large-398b", "gemma2-2b",
                    "mistral-nemo-12b"}


def test_input_specs_cover_all_supported_shapes():
    for name in ASSIGNED:
        arch = get_arch(name)
        for shape in SHAPES:
            if not arch.supports(shape):
                continue
            specs = arch.input_specs(shape)
            assert specs, (name, shape)
            for k, v in specs.items():
                assert hasattr(v, "shape") and hasattr(v, "dtype"), (name, k)

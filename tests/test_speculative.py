"""Speculative draft-verify decoding: exactness, rollback, wrap-COW.

The load-bearing claims of the speculative stack, each asserted here:

  * EXACTNESS: a speculative engine emits bit-identical greedy tokens
    to the static / dense / paged non-speculative engines (fp32 quad),
    REGARDLESS of draft quality — a randomly-initialised draft whose
    proposals are almost always rejected still matches, because every
    emitted token saw a fully-accepted context;
  * the same-layout bf16 pair (paged vs speculative-paged, tie-stable
    greedy argmax) also matches: the spec engine's row-margined rings
    change reduction shapes, and stable_argmax absorbs the one-ulp ties;
  * ROLLBACK-SAFE KEYING: sampled streams are keyed fold(request_key,
    token_index) by POSITION, not by step — so a reject-heavy
    speculative run and a preemption-replayed non-speculative run both
    reproduce the straight-line sampled stream bit-exactly (no PRNG key
    is ever reused or skipped across a cursor rewind);
  * accept/reject churn and budget-truncated rounds never retrace the
    verify or draft steps (`_cache_size() == 1`);
  * WRAP-COW: a sliding-window slot whose decode wraps its ring COWs
    the shared prompt blocks instead of unregistering them, so a
    post-wrap second wave still shares/revives the prefix (the ROADMAP
    bug carried since PR 3);
  * constructor validation rejects unusable configurations.
"""
import jax
import numpy as np
import pytest

from conftest import make_serving_requests as make_requests
from conftest import setup_serving_arch as setup_arch
from repro.serving import ContinuousEngine, ServeEngine, make_spec_pair

pytestmark = [pytest.mark.serving, pytest.mark.spec]

MAX_LEN = 48
SSPEC = [(7, 4), (11, 6), (5, 1), (9, 3), (11, 4)]

_draft_cache = {}


def draft_of(arch, seed=7):
    """An arbitrary (same-config, independently initialised) draft: its
    proposals are wrong essentially always, which makes it the
    reject-churn stressor — correctness must not depend on acceptance."""
    if seed not in _draft_cache:
        _draft_cache[seed] = arch.init(jax.random.PRNGKey(seed))
    return arch, _draft_cache[seed]


def spec_engine(arch, params, draft, **kw):
    base = dict(max_batch=3, max_len=MAX_LEN, cache="paged", block_size=8,
                prefill_bucket=8, spec_draft=draft, spec_k=4)
    base.update(kw)
    return ContinuousEngine(arch, params, **base)


# --------------------------------------------------------------------------
# exactness differentials
# --------------------------------------------------------------------------

def test_spec_greedy_quad_fp32():
    """static == dense == paged == SPECULATIVE-paged, greedy fp32, with
    a reject-heavy random draft: acceptance hovers near zero, so every
    round exercises the rollback path — and the tokens still match."""
    arch, params = setup_arch("qwen2.5-14b")
    builders = [
        lambda: ServeEngine(arch, params, max_len=MAX_LEN, policy="fp32"),
        lambda: ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                                 cache="dense", prefill_bucket=8,
                                 policy="fp32"),
        lambda: ContinuousEngine(arch, params, max_batch=3, max_len=MAX_LEN,
                                 cache="paged", block_size=8,
                                 prefill_bucket=8, policy="fp32"),
        lambda: spec_engine(arch, params, draft_of(arch), policy="fp32"),
    ]
    all_reqs, engines = [], []
    for build in builders:
        reqs = make_requests(arch, SSPEC, prefix=16)
        eng = build()
        eng.run_batch(reqs)
        all_reqs.append(reqs)
        engines.append(eng)
    for quad in zip(*all_reqs):
        for other in quad[1:]:
            np.testing.assert_array_equal(quad[0].generated, other.generated)
    spec = engines[-1]
    assert spec.spec_rounds > 0 and spec.drafted_tokens > 0
    # reject churn + budget-truncated rounds never retrace anything
    assert spec._verify._cache_size() == 1
    assert spec._draft_step._cache_size() == 1
    spec.pool.check_invariants()


def test_spec_bf16_same_layout_pair():
    """Same-layout bf16 equality under the tie-stable greedy argmax:
    the speculative engine's row-margined rings reorder reductions, and
    stable_argmax keeps one-ulp logit ties from flipping tokens."""
    arch, params = setup_arch("qwen2.5-14b")
    sampler = "temperature=0,stable=1"
    a = make_requests(arch, SSPEC, prefix=16)
    ContinuousEngine(arch, params, max_batch=3, max_len=MAX_LEN,
                     cache="paged", block_size=8, prefill_bucket=8,
                     policy="bf16", sampler=sampler).run(a)
    b = make_requests(arch, SSPEC, prefix=16)
    spec_engine(arch, params, draft_of(arch), policy="bf16",
                sampler=sampler).run(b)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.generated, rb.generated)


def test_spec_full_acceptance_pair_emits_blocks():
    """make_spec_pair's doctored target accepts EVERY proposal, the
    other extreme of the acceptance spectrum: one verify step per
    spec_k-token block, identical tokens, and the all-accept fast path
    (no rollback) keeps device cursors consistent across rounds."""
    arch, params = setup_arch("qwen2.5-14b")
    tgt_params, draft_arch, draft_params = make_spec_pair(arch, params)
    a = make_requests(arch, SSPEC, prefix=16)
    plain = ContinuousEngine(arch, tgt_params, max_batch=3, max_len=MAX_LEN,
                             cache="paged", block_size=8, prefill_bucket=8)
    plain.run(a)
    b = make_requests(arch, SSPEC, prefix=16)
    spec = spec_engine(arch, tgt_params, (draft_arch, draft_params))
    spec.run(b)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.generated, rb.generated)
    rep = spec.report(1.0)
    assert rep["acceptance_rate"] == 1.0
    # full blocks: decode rounds ~ tokens / spec_k, not tokens
    assert spec.steps_run < plain.steps_run
    spec.pool.check_invariants()


# --------------------------------------------------------------------------
# rollback-safe sampler keying (satellite: keys by position, not by step)
# --------------------------------------------------------------------------

SAMPLER = "temperature=0.8,top_k=20,seed=11"


def _straight_line_sampled(arch, params, spec=SSPEC, prefix=16):
    reqs = make_requests(arch, spec, prefix=prefix)
    ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                     cache="dense", prefill_bucket=8, policy="fp32",
                     sampler=SAMPLER).run(reqs)
    return reqs


def test_spec_sampled_stream_survives_reject_churn():
    """Reject-heavy speculative sampling == straight-line sampling,
    bit-exact: verify row i samples with fold(request_key, emitted + i),
    the key the non-speculative step would use at that token index, so
    a rollback neither reuses nor skips a key."""
    arch, params = setup_arch("qwen2.5-14b")
    base = _straight_line_sampled(arch, params)
    reqs = make_requests(arch, SSPEC, prefix=16)
    eng = spec_engine(arch, params, draft_of(arch), policy="fp32",
                      sampler=SAMPLER)
    eng.run(reqs)
    # the random draft must actually have caused rejections (else this
    # test silently stopped covering the rollback path)
    assert eng.accepted_tokens < eng.drafted_tokens
    for ra, rb in zip(base, reqs):
        np.testing.assert_array_equal(ra.generated, rb.generated)


def test_preempted_sampled_stream_matches_straight_line():
    """The existing preemption-replay path under the same position-keyed
    contract: a scarce arena forces mid-decode evictions, the
    continuation prefill replays prompt + generated, and the sampled
    stream continues at the SAME token indices — bit-identical to the
    unpreempted baseline."""
    arch, params = setup_arch("qwen2.5-14b")
    # long budgets + a budget-1 arena: growth exhausts mid-decode and
    # the engine MUST preempt (test_scheduling's pressure shape)
    pressure = [(8, 20), (8, 18), (8, 16)]
    base = _straight_line_sampled(arch, params, spec=pressure, prefix=0)
    reqs = make_requests(arch, pressure)
    eng = ContinuousEngine(arch, params, max_batch=4, max_len=MAX_LEN,
                           cache="paged", block_size=8, prefill_bucket=8,
                           policy="fp32", sampler=SAMPLER, slots_budget=1,
                           share_prefix=False)
    eng.run(reqs)
    assert eng.preemptions > 0
    for ra, rb in zip(base, reqs):
        np.testing.assert_array_equal(ra.generated, rb.generated)


# --------------------------------------------------------------------------
# wrap-time COW: ring wrap must not kill prefix sharing (ROADMAP PR 3 bug)
# --------------------------------------------------------------------------

def test_wrap_cow_preserves_prefix_sharing_across_waves():
    """gemma2's reduced sliding window is 16 rows: a 16-token shared
    prompt exactly fills the window ring, so the FIRST decode token
    wraps onto the shared prompt blocks. Pre-COW, that write forced the
    blocks private and unregistered them forever — a second wave could
    never share or revive them. With wrap-time COW the writer gets a
    private copy, the originals stay registered, and wave 2 gets
    shared/retained hits while every stream stays solo-identical."""
    arch, params = setup_arch("gemma2-2b")
    assert arch.cfg.sliding_window == 16

    def wave(seed):
        # 16-token common prefix + short tails; budgets wrap the window
        return make_requests(arch, [(2, 6), (3, 6)], seed=seed, prefix=16,
                             prefix_seed=99)

    solos = []
    solo_eng = ContinuousEngine(arch, params, max_batch=1, max_len=MAX_LEN,
                                cache="dense", prefill_bucket=8)
    for seed in (1, 2):
        s = wave(seed)
        solo_eng.run(s)
        solos.extend(s)

    eng = ContinuousEngine(arch, params, max_batch=2, max_len=MAX_LEN,
                           cache="paged", block_size=8, prefill_bucket=8,
                           retain_blocks=8)
    w1 = wave(1)
    eng.run(w1)
    assert eng.pool.shared_hits > 0          # wave 1 shared the prefix
    hits1 = eng.pool.shared_hits + eng.pool.retained_hits
    w2 = wave(2)
    eng.run(w2)
    hits2 = eng.pool.shared_hits + eng.pool.retained_hits
    assert hits2 > hits1, (
        "post-wrap wave got no shared/retained prefix blocks: ring wrap "
        "killed the registry (wrap-COW regression)")
    for solo, r in zip(solos, w1 + w2):
        np.testing.assert_array_equal(solo.generated, r.generated)
    eng.pool.check_invariants()


# --------------------------------------------------------------------------
# constructor validation
# --------------------------------------------------------------------------

def test_spec_validation_errors():
    arch, params = setup_arch("qwen2.5-14b")
    draft = draft_of(arch)
    with pytest.raises(ValueError, match="spec_k"):
        spec_engine(arch, params, draft, spec_k=1)
    with pytest.raises(ValueError, match="paged"):
        spec_engine(arch, params, draft, cache="dense")
    with pytest.raises(ValueError, match="chunked"):
        spec_engine(arch, params, draft, chunk_budget=8)
    hybrid, hparams = setup_arch("jamba-1.5-large-398b")
    with pytest.raises(ValueError, match="attention-only"):
        spec_engine(hybrid, hparams, (hybrid, hparams))


def test_spec_fused_kernel_reject_churn_matches_and_arenas_agree():
    """Reject churn on the scatter-in-epilogue kernel: a reject-heavy
    draft makes most rounds roll the cursor back past rows the FUSED
    verify step just wrote into the aliased arenas. Tokens must match
    the XLA-kernel speculative engine bit-exactly and the verify step
    must still trace once. Arena contract across the two kernel paths
    (arena layout is (layers, blocks, block_size, ...); block 0 is the
    null block):

      * pos arenas are bit-identical EVERYWHERE — rollback invalidation
        is a host-side scatter shared by both paths, and the fused
        epilogue's position writes are selection-only;
      * layer-0 K/V data blocks are bit-identical — layer-0 projections
        see identical token embeddings, so any epilogue ADDRESSING bug
        (wrong block/offset/wrap) shows up here bit-exactly;
      * deeper layers' VALID rows agree to roundoff only — their K/V
        embed the previous layer's attention output, where the fused
        online-softmax and the XLA gather differ by summation order
        (the exact bit-equality claim for fused vs scatter-then-kernel
        under churn is test_kernels.py's rollback_churn differential);
      * the null block's K/V may diverge (the XLA scatter parks
        rejected/padding rows there; the fused kernel writes nothing)
        but its positions stay -1 on both, so attention cannot see it."""
    arch, params = setup_arch("qwen2.5-14b")
    a = make_requests(arch, SSPEC, prefix=16)
    ex = spec_engine(arch, params, draft_of(arch), policy="fp32",
                     attn_kernel="xla")
    ex.run_batch(a)
    b = make_requests(arch, SSPEC, prefix=16)
    ep = spec_engine(arch, params, draft_of(arch), policy="fp32",
                     attn_kernel="paged")
    ep.run_batch(b)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.generated, rb.generated)
    assert ep.spec_rounds > 0 and ep.drafted_tokens > ep.accepted_tokens
    assert ep._verify._cache_size() == 1
    assert ep._draft_step._cache_size() == 1
    for si in ep.pool.maps:
        xa = ex.pool.cache["slots"][si]
        pa = ep.pool.cache["slots"][si]
        np.testing.assert_array_equal(
            np.asarray(xa["pos"]), np.asarray(pa["pos"]),
            err_msg=f"slot-type {si} pos arenas diverged")
        valid = np.asarray(xa["pos"]) >= 0          # (L, blocks, bs)
        for part in ("k", "v"):
            A, B = np.asarray(xa[part]), np.asarray(pa[part])
            np.testing.assert_array_equal(
                A[0, 1:], B[0, 1:],
                err_msg=f"slot-type {si} layer-0 {part} blocks diverged")
            np.testing.assert_allclose(
                # one-ulp slack in the ARENA dtype (bf16: 2^-8 relative)
                A[valid], B[valid], rtol=8e-3, atol=2e-4,
                err_msg=f"slot-type {si} {part} valid rows diverged")
    ep.pool.check_invariants()

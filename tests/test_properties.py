"""Hypothesis property tests on the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional test extra (see requirements.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.optim import apply_updates, lans
from repro.core.schedules import (schedule_auc, warmup_hold_decay,
                                  warmup_linear_decay)
from repro.data.sharding import ShardSpec, epoch_indices, minibatches, shard_bounds
from repro.kernels import ref

finite_f = st.floats(min_value=-100.0, max_value=100.0,
                     allow_nan=False, allow_infinity=False, width=32)


@settings(max_examples=25, deadline=None)
@given(scale=st.floats(min_value=1e-3, max_value=1e3),
       seed=st.integers(0, 2**31 - 1))
def test_lans_gradient_scale_invariance(scale, seed):
    """Paper §3.1: blockwise normalization makes LANS invariant to the
    per-block gradient SCALE — the property that removes gradient clipping."""
    r = np.random.default_rng(seed)
    g = jnp.asarray(r.normal(size=(17, 5)), jnp.float32)
    m = jnp.asarray(r.normal(size=(17, 5)), jnp.float32)
    v = jnp.asarray(np.abs(r.normal(size=(17, 5))), jnp.float32)
    x = jnp.asarray(r.normal(size=(17, 5)), jnp.float32)
    a = ref.lans_step_ref(g, m, v, x, eta=0.01, step=3)
    b = ref.lans_step_ref(scale * g, m, v, x, eta=0.01, step=3)
    np.testing.assert_allclose(np.asarray(a.x), np.asarray(b.x),
                               rtol=2e-4, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_lans_update_norm_bounded_by_phi(seed):
    """||d|| <= phi(||x||): trust-scaled directions cannot blow up."""
    r = np.random.default_rng(seed)
    g = jnp.asarray(r.normal(size=(64,)) * r.uniform(0.01, 100), jnp.float32)
    m = jnp.asarray(r.normal(size=(64,)), jnp.float32)
    v = jnp.asarray(np.abs(r.normal(size=(64,))), jnp.float32)
    x = jnp.asarray(r.normal(size=(64,)), jnp.float32)
    out = ref.lans_step_ref(g, m, v, x, eta=1.0, step=2)
    d = x - out.x
    xn = float(jnp.linalg.norm(x))
    assert float(jnp.linalg.norm(d)) <= xn * (1.0 + 1e-4)


@settings(max_examples=20, deadline=None)
@given(total=st.integers(10, 2000),
       warm_frac=st.floats(0.05, 0.5),
       hold_frac=st.floats(0.0, 0.4),
       eta=st.floats(1e-5, 1.0))
def test_warmup_hold_decay_shape(total, warm_frac, hold_frac, eta):
    """eq (9): piecewise linear-const-linear, max == eta, ends near 0."""
    warm = max(1, int(total * warm_frac))
    hold = int(total * hold_frac)
    if warm + hold >= total:
        hold = max(0, total - warm - 1)
    if warm + hold >= total or warm >= total:
        return
    sched = warmup_hold_decay(eta, total, warm, hold)
    ts = np.arange(total)
    vals = np.asarray(jax.vmap(sched)(jnp.asarray(ts)))
    assert vals.max() <= eta * (1 + 1e-5)
    # hold region is exactly eta
    hold_region = vals[warm:warm + hold]
    if len(hold_region):
        np.testing.assert_allclose(hold_region, eta, rtol=1e-5)
    # final step ~ 0 within one decay increment
    decay_steps = max(total - warm - hold, 1)
    assert vals[-1] <= eta / decay_steps * (1 + 1e-3) + 1e-9


@settings(max_examples=20, deadline=None)
@given(total=st.integers(20, 500), warm_frac=st.floats(0.1, 0.4),
       hold_frac=st.floats(0.05, 0.4), eta=st.floats(1e-4, 0.1))
def test_hold_schedule_auc_dominates_linear(total, warm_frac, hold_frac, eta):
    """The paper's point: eq (9) has strictly more area than eq (8) at the
    same eta — the hold phase recovers training progress."""
    warm = max(1, int(total * warm_frac))
    hold = max(1, int(total * hold_frac))
    if warm + hold >= total:
        return
    a8 = schedule_auc(warmup_linear_decay(eta, total, warm), total)
    a9 = schedule_auc(warmup_hold_decay(eta, total, warm, hold), total)
    assert a9 > a8


@settings(max_examples=15, deadline=None)
@given(n=st.integers(32, 4096), workers=st.integers(1, 17),
       epoch=st.integers(0, 3), seed=st.integers(0, 1000))
def test_sharding_partition_and_no_replacement(n, workers, epoch, seed):
    """§3.4: shards are disjoint, cover the dataset, and each epoch's
    in-shard order is a permutation (sampling without replacement)."""
    all_idx = []
    for w in range(workers):
        spec = ShardSpec(num_samples=n, num_workers=workers, worker=w,
                         seed=seed)
        lo, hi = shard_bounds(spec)
        idx = epoch_indices(spec, epoch)
        assert sorted(idx) == list(range(lo, hi))  # permutation of the shard
        all_idx.extend(idx)
    assert sorted(all_idx) == list(range(n))       # disjoint cover


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_minibatches_within_epoch_unique(seed):
    spec = ShardSpec(num_samples=256, num_workers=4, worker=1, seed=seed)
    it = minibatches(spec, per_worker_batch=8)
    seen = set()
    for _ in range(8):  # one epoch = 64 samples = 8 batches
        b = next(it)
        assert len(set(b.tolist()) & seen) == 0
        seen.update(b.tolist())


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       shape=st.sampled_from([(5,), (33,), (128,), (16, 9)]))
def test_fused_kernel_matches_reference_property(seed, shape):
    """Pallas fused LANS == jnp oracle across random shapes/values."""
    from repro.kernels import ops
    r = np.random.default_rng(seed)
    g = jnp.asarray(r.normal(size=shape), jnp.float32)
    m = jnp.asarray(r.normal(size=shape), jnp.float32)
    v = jnp.asarray(np.abs(r.normal(size=shape)), jnp.float32)
    x = jnp.asarray(r.normal(size=shape), jnp.float32)
    a = ops.fused_lans_step(g, m, v, x, eta=0.01, step=2)
    b = ref.lans_step_ref(g, m, v, x, eta=0.01, step=2)
    for ka, kb in zip(a, b):
        np.testing.assert_allclose(np.asarray(ka), np.asarray(kb),
                                   rtol=3e-5, atol=3e-6)

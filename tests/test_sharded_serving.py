"""Live sharded-engine differentials: a ContinuousEngine built with
mesh= must emit the SAME tokens as the unsharded engine — paged pool,
lazy growth, chunked prefill and speculative decode included — because
sharding only re-places the same computation (params per the
distributed param rules, KV arenas blocks-over-data / head_dim-over-
model, integer bookkeeping replicated).

Exactness envelope (the same one tests/test_distributed_steps.py pins
for the raw step fns):
  * a pure data mesh (Dx1) distributes bookkeeping only — bit-exact
    under ANY precision policy;
  * a model mesh (1xM) splits contractions. CROSS-layout identity
    (sharded vs unsharded) holds under policy="fp32"; under bf16 the
    psum rounding drifts past one-ulp ties, so cross-layout identity is
    NOT claimed. What bf16 does keep — with the tie-stable greedy
    argmax (sampler "temperature=0,stable=1") — is SAME-layout
    identity: engine variants on the same mesh (paged vs chunked-paged)
    stay bit-identical, chunk boundaries invisible.

These tests need >= 2 local devices; tier-1 (single-device CPU) skips
them. Run via:  scripts/run_tests.sh --sharded
(XLA_FLAGS=--xla_force_host_platform_device_count=2).
"""
import jax
import numpy as np
import pytest

from conftest import make_serving_requests as make_requests
from conftest import setup_serving_arch as setup_arch

pytestmark = [
    pytest.mark.sharded,
    pytest.mark.serving,
    pytest.mark.skipif(
        jax.device_count() < 2,
        reason="needs >= 2 devices: scripts/run_tests.sh --sharded sets "
               "XLA_FLAGS=--xla_force_host_platform_device_count=2"),
]

ARCH = "qwen2.5-14b"
MESH_AXES = {"data2": dict(data=2, model=1),
             "model2": dict(data=1, model=2)}


def _mesh(kind):
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(**MESH_AXES[kind])


def _reqs(arch, seed=2):
    # mixed lengths/budgets + a shared 16-token prefix: exercises
    # bucketed prefill, block sharing and mid-stream admission churn
    return make_requests(arch, [(8, 5), (12, 6), (8, 4), (16, 5)],
                         seed=seed, prefix=16)


def _engine(arch, params, **kw):
    from repro.serving import ContinuousEngine
    kw.setdefault("cache", "paged")
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 48)
    return ContinuousEngine(arch, params, block_size=8, **kw)


def _run(arch, params, **kw):
    reqs = _reqs(arch)
    eng = _engine(arch, params, **kw)
    eng.run(reqs)
    return eng, [r.generated for r in reqs]


def test_fp32_quad_data_mesh():
    """Data-mesh engines (paged AND dense pools) == their unsharded
    twins, token for token, under the engine default policy (None =
    the arch's native compute dtype, bf16 for qwen — a data mesh is
    exact under ANY precision because it only re-places bookkeeping)."""
    arch, params = setup_arch(ARCH)
    mesh = _mesh("data2")
    _, base_paged = _run(arch, params)
    _, base_dense = _run(arch, params, cache="dense")
    eng, mesh_paged = _run(arch, params, mesh=mesh)
    _, mesh_dense = _run(arch, params, cache="dense", mesh=mesh)
    for got in (base_dense, mesh_paged, mesh_dense):
        for x, y in zip(base_paged, got):
            assert np.array_equal(x, y)
    assert eng.report(1.0)["mesh_devices"] == 2


def test_model_mesh_fp32_policy_identity():
    arch, params = setup_arch(ARCH)
    _, base = _run(arch, params, policy="fp32")
    _, got = _run(arch, params, policy="fp32", mesh=_mesh("model2"))
    for x, y in zip(base, got):
        assert np.array_equal(x, y)


def test_model_mesh_bf16_stable_same_layout_pair():
    """Same-layout bf16 pair ON the model mesh: paged vs chunked-paged
    share one sharded layout, so their logits round identically and the
    tie-stable greedy argmax pins the remaining one-ulp chunk-boundary
    ties — chunking stays invisible under sharded bf16."""
    arch, params = setup_arch(ARCH)
    mesh = _mesh("model2")
    kw = dict(policy="bf16", sampler="temperature=0,stable=1", mesh=mesh)
    _, base = _run(arch, params, **kw)
    _, got = _run(arch, params, chunk_budget=8, **kw)
    for x, y in zip(base, got):
        assert np.array_equal(x, y)


@pytest.mark.chunked
@pytest.mark.parametrize("kind", ["data2", "model2"])
def test_chunked_identity_under_mesh(kind):
    """Chunked-prefill admission under a mesh: chunk boundaries stay
    invisible AND the controller's resumable chunk caches carry the
    sharded layout (satellite: cache_pspec threads through
    AdmissionController)."""
    arch, params = setup_arch(ARCH)
    _, base = _run(arch, params)          # unchunked, unsharded
    eng, got = _run(arch, params, chunk_budget=8, mesh=_mesh(kind))
    for x, y in zip(base, got):
        assert np.array_equal(x, y)
    assert eng._admission._cache_sh is not None
    assert eng.report(1.0)["chunk_steps"] > 0


@pytest.mark.spec
@pytest.mark.parametrize("kind", ["data2", "model2"])
def test_speculative_identity_under_mesh(kind):
    """Draft-verify decode under a mesh: the draft CachePool mirror and
    both verify/draft steps run sharded, tokens unchanged, acceptance
    still exactly 1.0 (make_spec_pair's constructed agreement)."""
    from repro.serving import make_spec_pair
    arch, params = setup_arch(ARCH)
    tparams, darch, dparams = make_spec_pair(arch, params)
    _, base = _run(arch, tparams)         # plain decode, unsharded
    eng, got = _run(arch, tparams, spec_draft=(darch, dparams), spec_k=3,
                    mesh=_mesh(kind))
    for x, y in zip(base, got):
        assert np.array_equal(x, y)
    rep = eng.report(1.0)
    assert rep["acceptance_rate"] == pytest.approx(1.0)
    assert eng.draft_pool.mesh is not None


@pytest.mark.paged
@pytest.mark.parametrize("kind,engine_kw", [
    # model mesh: float arenas shard head_dim over 'model'
    ("model2", {}),
    # data mesh: arena block dim (n_blocks+1 = 3*7+1 = 22) is even, so
    # blocks shard over 'data' (the default 48/8-block arena yields an
    # odd 13 and replicates — divisibility is per slot-type)
    ("data2", dict(max_len=56, slots_budget=3)),
], ids=["model2", "data2"])
def test_pool_placement_matches_cache_pspec(kind, engine_kw):
    """The live pool commits EXACTLY the shardings cache_shardings
    derives from cache_pspec: arenas actually distributed, integer
    bookkeeping never sharded over 'model'."""
    from jax.sharding import NamedSharding
    from repro.distributed import sharding as shd

    arch, params = setup_arch(ARCH)
    mesh = _mesh(kind)
    eng, _ = _run(arch, params, mesh=mesh, **engine_kw)

    expected = shd.cache_shardings(
        jax.eval_shape(lambda: eng.pool.cache), mesh)
    flat_c = jax.tree.leaves(eng.pool.cache)
    flat_e = jax.tree.leaves(
        expected, is_leaf=lambda x: isinstance(x, NamedSharding))
    assert len(flat_c) == len(flat_e)
    sharded_leaves = 0
    for leaf, sh in zip(flat_c, flat_e):
        assert leaf.sharding == sh, (leaf.shape, leaf.sharding, sh)
        if not sh.is_fully_replicated:
            sharded_leaves += 1
        if jax.numpy.issubdtype(leaf.dtype, jax.numpy.integer):
            assert "model" not in jax.tree.leaves(sh.spec)
    assert sharded_leaves > 0

    # block tables ride to device with their own pinned shardings
    tables = eng.pool.device_tables()
    for t in jax.tree.leaves(tables):
        assert isinstance(t.sharding, NamedSharding)

    # params follow the distributed param rules on the same mesh
    psh = shd.params_sharding(jax.eval_shape(lambda: eng.params), mesh)
    for leaf, sh in zip(jax.tree.leaves(eng.params),
                        jax.tree.leaves(psh)):
        assert leaf.sharding == sh


def test_parse_mesh_multi_device():
    from repro.launch.serve import parse_mesh
    assert parse_mesh("2x1").devices.shape == (2, 1)
    assert parse_mesh("1x2").devices.shape == (1, 2)
    assert parse_mesh("2").devices.shape == (1, 2)   # bare N = 1xN


def test_router_over_sharded_replicas():
    """The tentpole end-to-end: a prefix-affinity fleet of LIVE
    data-mesh replicas emits the same streams as one unsharded
    engine."""
    from repro.serving import ReplicaRouter
    arch, params = setup_arch(ARCH)
    _, base = _run(arch, params)
    mesh = _mesh("data2")
    fleet = ReplicaRouter(
        [_engine(arch, params, mesh=mesh) for _ in range(2)],
        policy="prefix")
    reqs = _reqs(arch)
    fleet.run(reqs)
    for x, y in zip(base, reqs):
        assert np.array_equal(x, y.generated)
    rep = fleet.report(1.0)
    assert rep["replicas"] == 2
    assert all(sub["mesh_devices"] == 2 for sub in rep["per_replica"])

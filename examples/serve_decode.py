"""Batched serving demo: prefill + incremental greedy decode.

Runs the gemma2-family reduced model through the ServeEngine — the same
`prefill`/`decode_step` functions the decode_32k / long_500k dry-run
shapes lower on the production mesh — and reports tokens/s.

  PYTHONPATH=src python examples/serve_decode.py --arch gemma2-2b
"""
import argparse

import jax
import numpy as np

from repro.configs import reduced_arch
from repro.serving.engine import Request, ServeEngine, throughput_probe


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    arch = reduced_arch(args.arch)
    if arch.kind not in ("decoder",):
        raise SystemExit(f"{args.arch} ({arch.kind}) is not a decoder arch")
    params = arch.init(jax.random.PRNGKey(0))
    engine = ServeEngine(arch, params,
                         max_len=args.prompt_len + args.new_tokens)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(5, arch.cfg.vocab,
                                        size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.batch)]
    stats = throughput_probe(engine, reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: prompt[:6]={r.prompt[:6].tolist()} "
              f"-> generated={r.generated.tolist()}")
    print({k: round(v, 2) if isinstance(v, float) else v
           for k, v in stats.items()})
    print("serve_decode OK")


if __name__ == "__main__":
    main()

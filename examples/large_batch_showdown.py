"""LANS vs LAMB vs AdamW across batch sizes — the paper's core claim.

For each optimizer and batch size, train the reduced BERT with the
square-root-scaled learning rate (LAMB's rule) and report the final loss.
The expected pattern (paper §3.3 / Table 2): all match at small batch;
as batch (and therefore eta) grows, LAMB/AdamW destabilize first while
LANS + the hold schedule keep training.

  PYTHONPATH=src python examples/large_batch_showdown.py
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_arch
from repro.core.optim import adamw, apply_updates, lamb, lans
from repro.core.schedules import sqrt_scaling_rule, warmup_hold_decay
from repro.data.corpus import SyntheticCorpus, mlm_batch_iterator
from repro.data.sharding import ShardSpec


def train(arch, tx, batch, steps, seed=0):
    corpus = SyntheticCorpus(vocab=arch.cfg.vocab, num_docs=2048,
                             doc_len=200, seed=seed)
    spec = ShardSpec(num_samples=2048, num_workers=1, worker=0, seed=seed)
    data = mlm_batch_iterator(corpus, spec, per_worker_batch=batch,
                              seq_len=64, seed=seed)
    params = arch.init(jax.random.PRNGKey(seed))
    st = tx.init(params)

    @jax.jit
    def step(params, st, b):
        (l, _), g = jax.value_and_grad(arch.loss_fn, has_aux=True)(params, b)
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        u, st = tx.update(g, st, params)
        return apply_updates(params, u), st, l

    losses = []
    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, st, l = step(params, st, b)
        losses.append(float(l))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--eta-ref", type=float, default=1.5e-3,
                    help="reference LR at the smallest batch")
    args = ap.parse_args()

    arch = reduced_arch("bert-large")
    batches = [4, 16, 64]
    print(f"{'optimizer':10s} " +
          " ".join(f"batch={b:<4d} (eta={sqrt_scaling_rule(args.eta_ref, batches[0], b):.1e})"
                   for b in batches))
    results = {}
    for name, txf in (("lans", lans), ("lamb", lamb), ("adamw", adamw)):
        finals = []
        for b in batches:
            eta = sqrt_scaling_rule(args.eta_ref, batches[0], b)
            sched = warmup_hold_decay(eta, args.steps + 1,
                                      max(1, args.steps // 5),
                                      args.steps // 3)
            losses = train(arch, txf(sched), b, args.steps)
            final = float(np.mean(losses[-5:]))
            finals.append(final if np.isfinite(losses).all() else float("inf"))
        results[name] = finals
        print(f"{name:10s} " + " ".join(f"{x:>22.3f}" for x in finals))

    # headline check: at the largest batch, LANS is no worse than LAMB
    assert results["lans"][-1] <= results["lamb"][-1] * 1.1 + 0.1
    print("large_batch_showdown OK")


if __name__ == "__main__":
    main()

"""End-to-end driver: the paper's experiment at CPU scale.

Two-stage BERT pretraining (the paper's phase 1 / phase 2 structure:
short sequences first, then long) with LANS + eq. (9) schedules whose
warmup/hold ratios follow Table 1, on the sharded synthetic corpus, with
checkpointing between stages — a scale model of the 54-minute run.

~100M-parameter BERT (12L/512d) for a few hundred steps by default; scale
down with --steps/--layers for smoke runs.

  PYTHONPATH=src python examples/bert_pretraining.py --steps 150
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.configs import get_arch
from repro.core.optim import apply_updates, lans
from repro.core.schedules import StageSchedule
from repro.data.corpus import SyntheticCorpus, mlm_batch_iterator
from repro.data.sharding import ShardSpec
from repro.models.bert import BertConfig


def build_arch(layers, d_model, heads, vocab):
    base = get_arch("bert-large")
    cfg = dataclasses.replace(base.cfg, n_layers=layers, d_model=d_model,
                              n_heads=heads, d_ff=4 * d_model, vocab=vocab)
    return dataclasses.replace(base, cfg=cfg)


def run_stage(arch, params, stage: StageSchedule, *, batch, workers, seed,
              log_every=20):
    sched = stage.schedule()
    tx = lans(sched)
    opt_state = tx.init(params)

    corpus = SyntheticCorpus(vocab=arch.cfg.vocab, num_docs=8192,
                             doc_len=2 * stage.seq_len + 8, seed=seed)
    spec = ShardSpec(num_samples=8192, num_workers=workers, worker=0,
                     seed=seed)
    data = mlm_batch_iterator(corpus, spec, per_worker_batch=batch,
                              seq_len=stage.seq_len, seed=seed)

    @jax.jit
    def step(params, opt_state, b):
        (loss, aux), grads = jax.value_and_grad(
            arch.loss_fn, has_aux=True)(params, b)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        updates, opt_state = tx.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss, aux

    losses, t0 = [], time.time()
    for i in range(stage.total_steps):
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt_state, loss, aux = step(params, opt_state, b)
        losses.append(float(loss))
        if (i + 1) % log_every == 0 or i == 0:
            print(f"[{stage.name}] step {i+1:4d}/{stage.total_steps}  "
                  f"loss {losses[-1]:.4f}  mlm {float(aux['mlm_loss']):.4f}  "
                  f"nsp {float(aux['nsp_loss']):.4f}  "
                  f"lr {float(sched(jnp.asarray(i))):.2e}  "
                  f"{(time.time()-t0)/(i+1):.2f}s/it", flush=True)
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200,
                    help="stage-1 steps (stage 2 = steps * 782/3519)")
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/bert_lans_ckpt")
    args = ap.parse_args()

    arch = build_arch(args.layers, args.d_model, args.d_model // 64,
                      args.vocab)
    n = arch.param_count()
    print(f"model: {args.layers}L/{args.d_model}d = {n/1e6:.1f}M params")

    # Table-1 ratio structure, scaled to this run's step counts.
    s2_steps = max(10, round(args.steps * 782 / 3519))
    stage1 = StageSchedule("stage1_seq128", batch_size=args.batch,
                           seq_len=128, total_steps=args.steps, eta=4e-3,
                           ratio_warmup=0.4265, ratio_const=0.2735)
    stage2 = StageSchedule("stage2_seq512", batch_size=args.batch,
                           seq_len=256, total_steps=s2_steps, eta=2e-3,
                           ratio_warmup=0.192, ratio_const=0.108)

    params = arch.init(jax.random.PRNGKey(0))
    params, l1 = run_stage(arch, params, stage1, batch=args.batch,
                           workers=args.workers, seed=0)
    save(args.ckpt, stage1.total_steps, params,
         metadata={"stage": 1, "loss": l1[-1]})
    print(f"stage 1 done: loss {np.mean(l1[:10]):.3f} -> "
          f"{np.mean(l1[-10:]):.3f}; checkpoint saved")

    # stage 2 restores from the stage-1 checkpoint (paper's 2-phase setup)
    params = restore(args.ckpt, stage1.total_steps,
                     jax.tree.map(jnp.zeros_like, params))
    params, l2 = run_stage(arch, params, stage2, batch=args.batch,
                           workers=args.workers, seed=1)
    print(f"stage 2 done: loss {np.mean(l2[:5]):.3f} -> "
          f"{np.mean(l2[-5:]):.3f}")
    assert np.mean(l1[-10:]) < np.mean(l1[:10]), "stage 1 must make progress"
    print("bert_pretraining OK")


if __name__ == "__main__":
    main()

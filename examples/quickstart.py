"""Quickstart: the paper's recipe in ~40 lines of public API.

LANS optimizer + warmup-hold-decay schedule + sharded-without-replacement
data, training a small causal LM on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import reduced_arch
from repro.core.optim import apply_updates, lans
from repro.core.schedules import warmup_hold_decay
from repro.data.corpus import SyntheticCorpus, lm_batch_iterator
from repro.data.sharding import ShardSpec

STEPS, BATCH, SEQ = 30, 8, 64

# 1. a model from the assigned-architecture zoo (reduced for CPU)
arch = reduced_arch("qwen2.5-14b")
params = arch.init(jax.random.PRNGKey(0))

# 2. the paper's optimizer (Algorithm 2) + LR schedule (eq. 9)
schedule = warmup_hold_decay(eta=3e-3, total_steps=STEPS + 1,
                             warmup_steps=6, hold_steps=10)
tx = lans(schedule)
opt_state = tx.init(params)

# 3. the paper's data sharding (§3.4): this process is worker 0 of 4
corpus = SyntheticCorpus(vocab=arch.cfg.vocab, num_docs=1024, doc_len=256)
shard = ShardSpec(num_samples=1024, num_workers=4, worker=0)
data = lm_batch_iterator(corpus, shard, per_worker_batch=BATCH, seq_len=SEQ)


@jax.jit
def train_step(params, opt_state, batch):
    (loss, _), grads = jax.value_and_grad(
        arch.loss_fn, has_aux=True)(params, batch)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    updates, opt_state = tx.update(grads, opt_state, params)
    return apply_updates(params, updates), opt_state, loss


for step in range(STEPS):
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    params, opt_state, loss = train_step(params, opt_state, batch)
    if step % 5 == 0 or step == STEPS - 1:
        print(f"step {step:3d}  loss {float(loss):.4f}  "
              f"lr {float(schedule(jnp.asarray(step))):.2e}")
print("quickstart OK")

"""Ablation of the paper's two LANS components (beyond-paper analysis).

Four optimizers on the same toy-BERT stream at a stressed learning rate:
  lamb-noclip       = neither component (baseline LAMB form, no global clip)
  +block-norm       = eq. (4) only
  +nesterov         = eq. (7) only
  lans (full)       = both (Algorithm 2)

Reports final losses. Expectation: block normalization supplies most of the
large-LR robustness (it bounds the moment inputs), Nesterov refines early
progress — consistent with the paper's framing.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_arch
from repro.core.optim import apply_updates
from repro.core.optim.lans import lans
from repro.core.schedules import warmup_hold_decay
from repro.data.corpus import SyntheticCorpus, mlm_batch_iterator
from repro.data.sharding import ShardSpec

STEPS = 22
ETA = 0.08


def _run(tx, seed=0):
    arch = reduced_arch("bert-large")
    corpus = SyntheticCorpus(vocab=arch.cfg.vocab, num_docs=512, doc_len=256,
                             seed=seed)
    spec = ShardSpec(num_samples=512, num_workers=1, worker=0, seed=seed)
    data = mlm_batch_iterator(corpus, spec, per_worker_batch=8, seq_len=64,
                              seed=seed)
    params = arch.init(jax.random.PRNGKey(seed))
    st = tx.init(params)

    @jax.jit
    def step(params, st, batch):
        (l, _), g = jax.value_and_grad(arch.loss_fn, has_aux=True)(params, batch)
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        upd, st = tx.update(g, st, params)
        return apply_updates(params, upd), st, l

    losses = []
    for _ in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, st, l = step(params, st, batch)
        losses.append(float(l))
    return losses


def run():
    sched = warmup_hold_decay(ETA, STEPS + 1, max(1, STEPS // 4), STEPS // 3)
    variants = {
        "lamb-noclip": lans(sched, normalize_grads=False, nesterov=False),
        "+block-norm": lans(sched, normalize_grads=True, nesterov=False),
        "+nesterov": lans(sched, normalize_grads=False, nesterov=True),
        "lans-full": lans(sched, normalize_grads=True, nesterov=True),
    }
    t0 = time.perf_counter()
    finals = {}
    rows = []
    for name, tx in variants.items():
        losses = _run(tx)
        fin = (float(np.mean(losses[-4:])) if np.isfinite(losses).all()
               else float("inf"))
        finals[name] = fin
        rows.append((f"ablation/{name}",
                     (time.perf_counter() - t0) * 1e6 / len(variants),
                     f"final={fin:.3f} start={losses[0]:.3f} @ eta={ETA}"))
    ok = (np.isfinite(finals["lans-full"])
          and finals["lans-full"] <= finals["lamb-noclip"] * 1.15 + 0.1)
    rows.append(("ablation/verdict", 0.0,
                 "full LANS no worse than ablated variants under stress"
                 if ok else "UNEXPECTED ORDERING"))
    return rows, bool(ok)

"""Table 1 reproduction: the stage hyper-parameters and derived step counts.

Verifies ratio_warmup + ratio_const = 70% / 30% and that the generated
schedules integrate to the same totals the paper trains with.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedules import paper_stage_schedules, schedule_auc


def run():
    t0 = time.perf_counter()
    s1, s2 = paper_stage_schedules()
    rows = []
    for st in (s1, s2):
        sched = st.schedule()
        vals = np.asarray(jax.vmap(sched)(jnp.arange(st.total_steps)))
        rows.append((
            f"table1/{st.name}", (time.perf_counter() - t0) * 1e6,
            f"eta={st.eta} warmup={st.warmup_steps} const={st.hold_steps} "
            f"T={st.total_steps} max={vals.max():.5f} auc={vals.sum():.2f}",
        ))
    total = s1.total_steps + s2.total_steps
    rows.append(("table1/total_steps", 0.0,
                 f"{total} (paper Table 2: 4301)"))
    ok = (total == 4301
          and abs(s1.ratio_warmup + s1.ratio_const - 0.70) < 1e-9
          and abs(s2.ratio_warmup + s2.ratio_const - 0.30) < 1e-9)
    return rows, ok

"""Precision-policy sweep: step time, HBM bytes-moved, state bytes.

For each policy (fp32 / bf16 / fp16_mixed) on the reduced archs, build the
real train step (LANS + mixed_precision wrapper where the policy needs it),
then report:

  * measured wall-time per step (median of N), and
  * bytes-moved per step from the loop-aware HLO cost model
    (launch/hlo_cost.py) on the compiled step, and
  * resident state bytes: model params, optimizer state (sparse fp32
    masters + moments in the policy's moment dtype), and their sum.

The paper's speed claim leans on exactly these levers: fp16 halves the
GEMM/memory traffic of the train step (Pati et al.) and the sparse-master
layout keeps optimizer state BELOW the fp32 baseline despite the extra
master copy. PASS requires bf16/fp16 optimizer state and total state to be
strictly smaller than fp32's.

  PYTHONPATH=src python -m benchmarks.precision_sweep [--arch bert-large]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import precision as prec
from repro.configs import reduced_arch
from repro.core.optim import lans
from repro.distributed.steps import build_train_step, jit_train_step
from repro.launch.hlo_cost import analyze_hlo_text
from repro.launch.mesh import make_local_mesh

POLICIES = ("fp32", "bf16", "fp16_mixed")


def _tree_bytes(tree) -> int:
    return int(sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(tree)))


def _mlm_batch(arch, batch: int, seq: int):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, arch.cfg.vocab, size=(batch, seq))
    labels = np.where(rng.random((batch, seq)) < 0.15, toks, -100)
    return {"tokens": jnp.asarray(toks, jnp.int32),
            "mlm_labels": jnp.asarray(labels, jnp.int32),
            "nsp_labels": jnp.zeros((batch,), jnp.int32)}


def sweep_arch(arch_name: str, *, batch: int = 8, seq: int = 64,
               steps: int = 5):
    arch = reduced_arch(arch_name)
    batch_data = _mlm_batch(arch, batch, seq) if arch.kind == "bert" else {
        "tokens": jnp.zeros((batch, seq), jnp.int32),
        "labels": jnp.zeros((batch, seq), jnp.int32)}
    results = {}
    import dataclasses
    mesh = make_local_mesh(data=1, model=1)
    for name in POLICIES:
        policy = prec.get_policy(name)
        tx = lans(2e-3, mu_dtype=policy.moment_dtype)
        p_arch = dataclasses.replace(arch, cfg=policy.apply_to_cfg(arch.cfg))

        # the REAL train step: build_train_step wraps tx with mixed_precision
        # and wires the loss scaling exactly as launch/train and tests do.
        step_fn, init_fn, specs_for = build_train_step(
            p_arch.loss_fn, tx, mesh,
            param_init_fn=lambda rng: p_arch.init(rng), policy=policy)
        params, opt_state = init_fn(jax.random.PRNGKey(0))
        pspec, ospec = specs_for(params, opt_state)
        jitted = jit_train_step(step_fn, mesh, pspec, ospec, batch_data)

        with mesh:
            compiled = jitted.lower(params, opt_state, batch_data).compile()
            cost = analyze_hlo_text(compiled.as_text())

            params, opt_state, _ = jitted(params, opt_state, batch_data)
            times = []
            for _ in range(steps):
                t0 = time.perf_counter()
                params, opt_state, metrics = jitted(
                    params, opt_state, batch_data)
                jax.block_until_ready(metrics["loss"])
                times.append(time.perf_counter() - t0)

        results[name] = {
            "step_ms": float(np.median(times) * 1e3),
            "hlo_bytes": cost.bytes,
            "hlo_flops": cost.flops,
            "param_bytes": _tree_bytes(params),
            "opt_bytes": _tree_bytes(opt_state),
        }
        results[name]["state_bytes"] = (results[name]["param_bytes"]
                                        + results[name]["opt_bytes"])
    return results


def run(archs=("bert-large",)):
    rows, ok = [], True
    for arch_name in archs:
        res = sweep_arch(arch_name)
        base = res["fp32"]
        for pname, r in res.items():
            rows.append((
                f"precision/{arch_name}/{pname}",
                r["step_ms"] * 1e3,
                f"hlo {r['hlo_bytes']/1e6:.1f}MB moved/step, "
                f"params {r['param_bytes']/1e3:.1f}kB, "
                f"opt {r['opt_bytes']/1e3:.1f}kB, "
                f"total state {r['state_bytes']/1e3:.1f}kB",
            ))
        for pname in ("bf16", "fp16_mixed"):
            smaller = (res[pname]["opt_bytes"] < base["opt_bytes"]
                       and res[pname]["state_bytes"] < base["state_bytes"])
            rows.append((
                f"precision/{arch_name}/{pname}_vs_fp32",
                0.0,
                f"opt {res[pname]['opt_bytes']}/{base['opt_bytes']}B "
                f"state {res[pname]['state_bytes']}/{base['state_bytes']}B "
                f"hlo-bytes x{res[pname]['hlo_bytes']/base['hlo_bytes']:.2f} "
                f"-> {'smaller OK' if smaller else 'NOT SMALLER'}",
            ))
            ok = ok and smaller
    return rows, ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    help="repeatable; default bert-large")
    args = ap.parse_args()
    rows, ok = run(tuple(args.arch) if args.arch else ("bert-large",))
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f'{name},{us:.1f},"{derived}"')
    print("STATUS:", "PASS" if ok else "FAIL")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()

"""apex fused_lans analogue: fused Pallas optimizer step vs unfused jnp.

On CPU the Pallas kernels run in interpret mode (Python-loop execution),
so wall-time favours the unfused XLA path — the meaningful numbers here
are (a) correctness at size and (b) the HBM-traffic model: the fused
3-phase pipeline reads/writes each tensor O(1) times vs O(#ops) for the
unfused chain. We report measured us/call for both plus the analytic
bytes-touched ratio that predicts the TPU win.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

SIZE = 1 << 16  # 64k-element block


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(SIZE,)), jnp.float32)
    m = jnp.zeros((SIZE,), jnp.float32)
    v = jnp.zeros((SIZE,), jnp.float32)
    x = jnp.asarray(rng.normal(size=(SIZE,)), jnp.float32)

    fused = lambda: ops.fused_lans_step(g, m, v, x, eta=0.01, step=1)
    unfused = jax.jit(lambda: ref.lans_step_ref(g, m, v, x, eta=0.01, step=1))

    t_fused = _time(lambda: fused())
    t_unfused = _time(lambda: unfused())

    a = fused()
    b = unfused()
    err = float(jnp.max(jnp.abs(a.x - b.x)))

    # HBM traffic model (bytes touched per element, fp32):
    #   fused: phase0 reads g; phase1 reads g,m,v,x writes m,v; phase2 reads
    #          g,m,v,x writes x  -> 13 R/W per element
    #   unfused (op-at-a-time, ~20 elementwise passes over 4 tensors): ~40+
    bytes_fused = 13 * 4
    bytes_unfused = 40 * 4
    rows = [
        ("kernel/fused_lans_us", t_fused,
         f"interpret-mode on CPU; max|dx|={err:.2e} vs oracle"),
        ("kernel/unfused_lans_us", t_unfused, "jnp reference under jit"),
        ("kernel/hbm_bytes_per_elem", 0.0,
         f"fused {bytes_fused}B vs unfused ~{bytes_unfused}B "
         f"-> {bytes_unfused/bytes_fused:.1f}x traffic reduction on TPU"),
    ]
    return rows, err < 1e-4

"""Pallas kernel benchmarks: fused optimizer step + paged decode attention.

On CPU the Pallas kernels run in interpret mode (Python-loop execution),
so wall-time favours the XLA paths — the meaningful numbers here are
(a) correctness at size and (b) the HBM-traffic model that predicts the
TPU win:

  fused_lans       the 3-phase pipeline reads/writes each tensor O(1)
                   times vs O(#ops) for the unfused elementwise chain;
  paged_attention  the read-side kernel streams exactly the block-table's
                   K/V blocks HBM->VMEM once, vs the XLA gather which
                   reads the arena, WRITES a dense (B, ring_len) K/V
                   copy and reads it back — ~3x the unavoidable bytes
                   on a memory-bound decode step;
  paged_attention_fused
                   additionally folds the decode token's K/V/pos scatter
                   into the kernel epilogue (arenas aliased in/out), so
                   the separate scatter round-trip disappears too: the
                   model drops to ~(1 + 1/nb)x the unavoidable K/V
                   bytes, gated at <= 1.1x below and machine-readably in
                   BENCH_kernels.json.

The XLA-path byte models are cross-checked against the compiled HLO's
own cost analysis (`measured/model` in the derived column) — the same
bytes-accessed source benchmarks/roofline_report.py aggregates — so the
3x claim is measured, not asserted; the fused-kernel model is arithmetic
over the BlockSpecs (interpret mode has no HBM counters to measure).

  PYTHONPATH=src python -m benchmarks.kernel_throughput                 # both
  PYTHONPATH=src python -m benchmarks.kernel_throughput --iters 1       # smoke
  PYTHONPATH=src python -m benchmarks.kernel_throughput --kernel paged_attention

The block/grid autotuner sweeps (block_size, S, grid order) per
(backend, head_dim, n_kv) and records each winner:

  PYTHONPATH=src python -m benchmarks.kernel_throughput --autotune
  PYTHONPATH=src python -m benchmarks.kernel_throughput --autotune --write-table

--write-table persists the winners to src/repro/configs/
paged_attn_tuned.json, the table `paged_attention` consults at trace
time (exact (backend, head_dim, n_kv, block_size, S) match; miss falls
back to the sequential "arbitrary" grid). The checked-in table carries
CPU/interpret results — harmless (grid order cannot change numerics,
only megacore utilization) and replaced by rerunning on real TPU.
"""
import argparse
import json
import pathlib
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.paged_attention_kernel import (
    paged_attention, paged_attention_fused)

ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_JSON = ROOT / "BENCH_kernels.json"
TUNED_TABLE = ROOT / "src" / "repro" / "configs" / "paged_attn_tuned.json"

SIZE = 1 << 16  # 64k-element block (fused_lans)

# paged-attention decode workload: 8 slots, ring 256 in 16-row blocks
PA_SHAPE = dict(B=8, h=8, n_kv=2, hd=64, bs=16, nb=16)

# fused-model gate: bytes over the unavoidable K/V reads must stay under
FUSED_RATIO_LIMIT = 1.1

_iters_default = 5


def _time(fn, *args, iters=5):
    """Per-iteration wall times in us (callers reduce: p50 for tuning).

    The warmup result is block_until_ready'd BEFORE the timed region —
    otherwise compile + dispatch tail from the warmup leaks into the
    first timed iteration — and every iteration blocks on its own
    result, so each sample is a full dispatch+execute.
    """
    jax.block_until_ready(fn(*args))  # compile + drain
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return times


def _p50(fn, *args, iters=5):
    return statistics.median(_time(fn, *args, iters=iters))


def _measured_bytes(fn, *args):
    """bytes-accessed of the compiled fn per XLA's own cost analysis —
    the number roofline_report feeds the memory roofline term."""
    cost = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return float(cost.get("bytes accessed", 0.0))


def run_lans(iters=_iters_default):
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(SIZE,)), jnp.float32)
    m = jnp.zeros((SIZE,), jnp.float32)
    v = jnp.zeros((SIZE,), jnp.float32)
    x = jnp.asarray(rng.normal(size=(SIZE,)), jnp.float32)

    fused = lambda: ops.fused_lans_step(g, m, v, x, eta=0.01, step=1)
    unfused = jax.jit(lambda: ref.lans_step_ref(g, m, v, x, eta=0.01, step=1))

    t_fused = _p50(fused, iters=iters)
    t_unfused = _p50(unfused, iters=iters)

    a = fused()
    b = unfused()
    err = float(jnp.max(jnp.abs(a.x - b.x)))

    # HBM traffic model (bytes touched per element, fp32):
    #   fused: phase0 reads g; phase1 reads g,m,v,x writes m,v; phase2 reads
    #          g,m,v,x writes x  -> 13 R/W per element
    #   unfused (op-at-a-time, ~20 elementwise passes over 4 tensors): ~40+
    bytes_fused = 13 * 4
    bytes_unfused = 40 * 4
    rows = [
        ("kernel/fused_lans_us", t_fused,
         f"interpret-mode on CPU; max|dx|={err:.2e} vs oracle"),
        ("kernel/unfused_lans_us", t_unfused, "jnp reference under jit"),
        ("kernel/hbm_bytes_per_elem", 0.0,
         f"fused {bytes_fused}B vs unfused ~{bytes_unfused}B "
         f"-> {bytes_unfused/bytes_fused:.1f}x traffic reduction on TPU"),
    ]
    return rows, err < 1e-4


def _pa_case(B, h, n_kv, hd, bs, nb, *, S=1, seed=0):
    """Dense-equivalent paged decode workload: slot b owns data blocks
    [1 + b*nb, 1 + (b+1)*nb); history fills the ring up to the cursor,
    the S rows at the cursor are unwritten (pos -1) — the state one
    fused decode/verify step consumes."""
    n_blocks = B * nb + 1
    rng = np.random.default_rng(seed)
    q_shape = (B, h, hd) if S == 1 else (B, S, h, hd)
    q = jnp.asarray(rng.normal(size=q_shape), jnp.bfloat16)
    ka = jnp.asarray(rng.normal(size=(n_blocks, bs, n_kv, hd)), jnp.bfloat16)
    va = jnp.asarray(rng.normal(size=(n_blocks, bs, n_kv, hd)), jnp.bfloat16)
    cur = (nb - 1) * bs + bs // 2              # first unwritten ring row
    pos = np.full((n_blocks, bs), -1, np.int32)
    tbl = 1 + np.arange(B * nb, dtype=np.int32).reshape(B, nb)
    for r in range(cur):                       # history: pos == ring row
        pos[tbl[:, r // bs], r % bs] = r
    kn_shape = (B, n_kv, hd) if S == 1 else (B, S, n_kv, hd)
    k_new = jnp.asarray(rng.normal(size=kn_shape), jnp.bfloat16)
    v_new = jnp.asarray(rng.normal(size=kn_shape), jnp.bfloat16)
    if S == 1:
        qpos = np.full((B,), cur, np.int32)
    else:
        qpos = np.tile(cur + np.arange(S, dtype=np.int32), (B, 1))
    cursor = np.full((B,), cur, np.int32)
    return dict(q=q, ka=ka, va=va, pos=jnp.asarray(pos),
                tbl=jnp.asarray(tbl), qpos=jnp.asarray(qpos),
                k_new=k_new, v_new=v_new, cursor=jnp.asarray(cursor),
                scale=1.0 / float(np.sqrt(hd)))


def run_paged_attention(iters=_iters_default):
    """Read-side and scatter-fused kernels vs the XLA gather/scatter."""
    B, h, n_kv, hd, bs, nb = (PA_SHAPE[k] for k in
                              ("B", "h", "n_kv", "hd", "bs", "nb"))
    c = _pa_case(B, h, n_kv, hd, bs, nb)
    ring = nb * bs

    # ----- read side: arenas already scattered ---------------------------
    scat = ref.paged_attention_fused_ref(
        c["q"], c["k_new"], c["v_new"], c["ka"], c["va"], c["pos"],
        c["tbl"], c["qpos"], c["cursor"], scale=c["scale"])
    ka2, va2, pos2 = scat[1], scat[2], scat[3]
    pallas_fn = lambda: paged_attention(
        c["q"], ka2, va2, pos2, c["tbl"], c["qpos"], scale=c["scale"])
    xla_fn = jax.jit(lambda: ref.paged_attention_ref(
        c["q"], ka2, va2, pos2, c["tbl"], c["qpos"], scale=c["scale"]))
    t_pallas = _p50(pallas_fn, iters=iters)
    t_xla = _p50(xla_fn, iters=iters)
    err = float(jnp.max(jnp.abs(pallas_fn() - xla_fn())))

    # ----- fused: pre-scatter arenas, the kernel carries the write -------
    fused_fn = lambda: paged_attention_fused(
        c["q"], c["k_new"], c["v_new"], c["ka"], c["va"], c["pos"],
        c["tbl"], c["qpos"], c["cursor"], scale=c["scale"])
    xla_fused = lambda ka, va, pos: ref.paged_attention_fused_ref(
        c["q"], c["k_new"], c["v_new"], ka, va, pos,
        c["tbl"], c["qpos"], c["cursor"], scale=c["scale"])
    t_fused = _p50(fused_fn, iters=iters)
    t_xla_fused = _p50(jax.jit(xla_fused), c["ka"], c["va"], c["pos"],
                       iters=iters)
    fo, fk, fv, fp = fused_fn()
    ro, rk, rv, rp = xla_fused(c["ka"], c["va"], c["pos"])
    err_f = float(jnp.max(jnp.abs(fo - ro)))
    arenas_exact = all(bool(jnp.array_equal(a, b))
                       for a, b in ((fk, rk), (fv, rv), (fp, rp)))

    # HBM traffic per decode step per layer (bf16 = 2 bytes):
    #   every path must read the referenced K+V blocks once (kv_bytes);
    #   the XLA gather additionally WRITES the dense (B, ring, kv, hd)
    #   K+V copy and READS it back for the attention contraction (3x),
    #   and the separate XLA scatter round-trips the touched arena rows
    #   on top. The fused kernel re-writes only the destination block per
    #   slot (1/nb of the reads) plus the new-row operands themselves.
    kv_bytes = B * ring * n_kv * hd * 2 * 2   # K+V blocks, read once
    xla_bytes = 3 * kv_bytes                  # + dense-copy write + read
    fused_bytes = (kv_bytes                   # block reads
                   + B * bs * n_kv * hd * 2 * 2   # dest-block K+V writes
                   + B * n_kv * hd * 2 * 2)       # new-row operands
    fused_ratio = fused_bytes / kv_bytes
    measured = _measured_bytes(xla_fused, c["ka"], c["va"], c["pos"])
    # The model is a LOWER bound on the compiled program's bytes: the
    # HLO must at least round-trip what the model charges. On CPU the
    # unfused graph also materializes every intermediate (repeated GQA
    # heads, fp32 logits, softmax temps), so measured/model lands well
    # above 1 here; TPU fusion is what brings it toward 1 — the gate is
    # therefore measured >= model, with the ratio reported for the
    # roofline comparison rather than pinned.
    meas_ratio = measured / xla_bytes if xla_bytes else 0.0

    rows = [
        ("kernel/paged_attn_pallas_us", t_pallas,
         f"interpret-mode on CPU; max|do|={err:.2e} vs XLA gather"),
        ("kernel/paged_attn_xla_us", t_xla,
         f"dense arena[table] gather under jit (B={B}, ring={ring})"),
        ("kernel/paged_attn_fused_us", t_fused,
         f"scatter-in-epilogue kernel; max|do|={err_f:.2e}, arenas "
         f"{'bit-exact' if arenas_exact else 'MISMATCH'} vs XLA scatter"),
        ("kernel/paged_attn_xla_fused_us", t_xla_fused,
         "XLA scatter + gather + attention under one jit"),
        ("kernel/paged_attn_hbm_bytes", 0.0,
         f"gather ~{xla_bytes}B vs fused {fused_bytes}B per step/layer "
         f"over {kv_bytes}B unavoidable -> {xla_bytes/kv_bytes:.1f}x vs "
         f"{fused_ratio:.2f}x (limit {FUSED_RATIO_LIMIT}x)"),
        ("kernel/paged_attn_measured_bytes", 0.0,
         f"XLA-path HLO cost_analysis {measured:.3g}B vs {xla_bytes}B "
         f"modeled -> measured/model {meas_ratio:.2f} (>= 1 required; "
         f"roofline_report uses the same bytes-accessed source)"),
    ]
    ok = (err < 1e-5 and err_f < 1e-5 and arenas_exact
          and fused_ratio <= FUSED_RATIO_LIMIT
          and meas_ratio >= 1.0)
    payload = {
        "kernels": [
            {"name": n, "us": round(us, 2), "derived": d}
            for n, us, d in rows],
        "bytes_model": {
            "kv_bytes_unavoidable": kv_bytes,
            "xla_gather_bytes": xla_bytes,
            "fused_bytes": fused_bytes,
            "fused_ratio": round(fused_ratio, 4),
            "fused_ratio_limit": FUSED_RATIO_LIMIT,
            "xla_measured_bytes": measured,
            "xla_measured_over_model": round(meas_ratio, 4),
        },
        "pass": bool(ok),
    }
    return rows, ok, payload


def autotune(iters=_iters_default, write_table=False):
    """Sweep (block_size, S, grid order) per (backend, head_dim, n_kv)
    on the fused kernel; winner = p50-fastest grid order per
    (block_size, S). Returns (rows, table)."""
    backend = jax.default_backend()
    rows, table = [], {backend: {}}
    h, n_kv, hd = PA_SHAPE["h"], PA_SHAPE["n_kv"], PA_SHAPE["hd"]
    B, nb = 4, 4                                  # small tuning workload
    for bs in (8, 16, 32):
        for S in (1, 4):
            best = None
            for order in ("arbitrary", "parallel"):
                c = _pa_case(B, h, n_kv, hd, bs, nb, S=S)
                fn = lambda: paged_attention_fused(
                    c["q"], c["k_new"], c["v_new"], c["ka"], c["va"],
                    c["pos"], c["tbl"], c["qpos"], c["cursor"],
                    scale=c["scale"], grid_order=order)
                us = statistics.median(_time(fn, iters=iters))
                rows.append((f"autotune/hd{hd}_kv{n_kv}_bs{bs}_S{S}_{order}",
                             us, f"backend={backend}"))
                if best is None or us < best[1]:
                    best = (order, us)
            table[backend].setdefault(f"hd{hd}_kv{n_kv}", {})[
                f"bs{bs}_S{S}"] = {"grid_order": best[0],
                                   "us": round(best[1], 2)}
    if write_table:
        existing = {}
        if TUNED_TABLE.exists():
            existing = json.loads(TUNED_TABLE.read_text())
        existing.update(table)                    # replace this backend
        TUNED_TABLE.write_text(json.dumps(existing, indent=2,
                                          sort_keys=True) + "\n")
        rows.append(("autotune/table_written", 0.0, str(TUNED_TABLE)))
    return rows, table


KERNELS = {"lans": run_lans, "paged_attention": run_paged_attention}


def run(kernel: str = "all", iters: int = _iters_default):
    """benchmarks/run.py entry point: rows + combined PASS flag. Also
    emits BENCH_kernels.json (name/us/bytes-model/PASS) whenever the
    paged-attention bench runs, so the perf trajectory is machine-
    trackable across PRs."""
    names = list(KERNELS) if kernel == "all" else [kernel]
    rows, ok, payload = [], True, None
    for name in names:
        out = KERNELS[name](iters=iters)
        r, o = out[0], out[1]
        if len(out) > 2:
            payload = out[2]
        rows += r
        ok = ok and o
    if payload is not None:
        payload["pass"] = bool(payload["pass"] and ok)
        BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    return rows, ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", default="all", choices=["all", *KERNELS])
    ap.add_argument("--iters", type=int, default=_iters_default,
                    help="timed iterations per kernel (p50 reported); "
                         "--iters 1 is the CI smoke mode")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep (block_size, S, grid order) on the fused "
                         "kernel and report winners per configuration")
    ap.add_argument("--write-table", action="store_true",
                    help="with --autotune: persist winners to "
                         "src/repro/configs/paged_attn_tuned.json (the "
                         "table paged_attention consults at trace time)")
    args = ap.parse_args()
    if args.write_table and not args.autotune:
        raise SystemExit("--write-table requires --autotune")
    rows, ok = run(args.kernel, iters=args.iters)
    if args.autotune:
        tune_rows, _ = autotune(iters=args.iters,
                                write_table=args.write_table)
        rows += tune_rows
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f'{name},{us:.1f},"{derived}"')
    print(f"kernel_throughput/STATUS,0,{'PASS' if ok else 'FAIL'}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()

"""Pallas kernel benchmarks: fused optimizer step + paged decode attention.

On CPU the Pallas kernels run in interpret mode (Python-loop execution),
so wall-time favours the XLA paths — the meaningful numbers here are
(a) correctness at size and (b) the HBM-traffic model that predicts the
TPU win:

  fused_lans       the 3-phase pipeline reads/writes each tensor O(1)
                   times vs O(#ops) for the unfused elementwise chain;
  paged_attention  the fused kernel streams exactly the block-table's
                   K/V blocks HBM->VMEM once, vs the XLA gather which
                   reads the arena, WRITES a dense (B, ring_len) K/V
                   copy and reads it back — ~3x the unavoidable bytes
                   on a memory-bound decode step.

  PYTHONPATH=src python -m benchmarks.kernel_throughput                 # both
  PYTHONPATH=src python -m benchmarks.kernel_throughput --kernel paged_attention
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.paged_attention_kernel import paged_attention

SIZE = 1 << 16  # 64k-element block (fused_lans)

# paged-attention decode workload: 8 slots, ring 128 in 16-row blocks
PA_SHAPE = dict(B=8, h=8, n_kv=2, hd=64, bs=16, nb=8)


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run_lans():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(SIZE,)), jnp.float32)
    m = jnp.zeros((SIZE,), jnp.float32)
    v = jnp.zeros((SIZE,), jnp.float32)
    x = jnp.asarray(rng.normal(size=(SIZE,)), jnp.float32)

    fused = lambda: ops.fused_lans_step(g, m, v, x, eta=0.01, step=1)
    unfused = jax.jit(lambda: ref.lans_step_ref(g, m, v, x, eta=0.01, step=1))

    t_fused = _time(lambda: fused())
    t_unfused = _time(lambda: unfused())

    a = fused()
    b = unfused()
    err = float(jnp.max(jnp.abs(a.x - b.x)))

    # HBM traffic model (bytes touched per element, fp32):
    #   fused: phase0 reads g; phase1 reads g,m,v,x writes m,v; phase2 reads
    #          g,m,v,x writes x  -> 13 R/W per element
    #   unfused (op-at-a-time, ~20 elementwise passes over 4 tensors): ~40+
    bytes_fused = 13 * 4
    bytes_unfused = 40 * 4
    rows = [
        ("kernel/fused_lans_us", t_fused,
         f"interpret-mode on CPU; max|dx|={err:.2e} vs oracle"),
        ("kernel/unfused_lans_us", t_unfused, "jnp reference under jit"),
        ("kernel/hbm_bytes_per_elem", 0.0,
         f"fused {bytes_fused}B vs unfused ~{bytes_unfused}B "
         f"-> {bytes_unfused/bytes_fused:.1f}x traffic reduction on TPU"),
    ]
    return rows, err < 1e-4


def run_paged_attention():
    """Fused block-streaming decode attention vs the XLA arena gather."""
    B, h, n_kv, hd, bs, nb = (PA_SHAPE[k] for k in
                              ("B", "h", "n_kv", "hd", "bs", "nb"))
    n_blocks = B * nb + 1                     # dense-equivalent arena + null
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, h, hd)), jnp.bfloat16)
    ka = jnp.asarray(rng.normal(size=(n_blocks, bs, n_kv, hd)), jnp.bfloat16)
    va = jnp.asarray(rng.normal(size=(n_blocks, bs, n_kv, hd)), jnp.bfloat16)
    # every data block fully valid except the null block (pos -1) and a
    # partially-written tail block per slot — the masking the kernel does
    # on-chip from the streamed positions
    pos = np.tile(np.arange(bs, dtype=np.int32), (n_blocks, 1))
    pos += (np.arange(n_blocks, dtype=np.int32)[:, None] - 1) % nb * bs
    pos[0] = -1
    # slot b owns blocks [1 + b*nb, 1 + (b+1)*nb), last block half-written
    tbl = (1 + np.arange(B * nb, dtype=np.int32).reshape(B, nb))
    pos[tbl[:, -1], bs // 2:] = -1
    qpos = np.full((B,), (nb - 1) * bs + bs // 2 - 1, np.int32)
    pos_a, tbl_a, qpos_a = map(jnp.asarray, (pos, tbl, qpos))
    scale = 1.0 / float(np.sqrt(hd))

    pallas_fn = lambda: paged_attention(q, ka, va, pos_a, tbl_a, qpos_a,
                                        scale=scale)
    xla_fn = jax.jit(lambda: ref.paged_attention_ref(
        q, ka, va, pos_a, tbl_a, qpos_a, scale=scale))

    t_pallas = _time(lambda: pallas_fn())
    t_xla = _time(lambda: xla_fn())
    err = float(jnp.max(jnp.abs(pallas_fn() - xla_fn())))

    # HBM traffic per decode step per layer (bf16 = 2 bytes):
    #   both paths must read the referenced K+V blocks once;
    #   the XLA gather additionally WRITES the dense (B, ring, kv, hd)
    #   K+V copy and READS it back for the attention contraction.
    ring = nb * bs
    kv_bytes = B * ring * n_kv * hd * 2 * 2   # K+V blocks, read once
    xla_bytes = 3 * kv_bytes                  # + dense-copy write + read
    rows = [
        ("kernel/paged_attn_pallas_us", t_pallas,
         f"interpret-mode on CPU; max|do|={err:.2e} vs XLA gather"),
        ("kernel/paged_attn_xla_us", t_xla,
         f"dense arena[table] gather under jit (B={B}, ring={ring})"),
        ("kernel/paged_attn_hbm_bytes", 0.0,
         f"fused {kv_bytes}B vs gather ~{xla_bytes}B per step/layer "
         f"-> {xla_bytes/kv_bytes:.1f}x traffic reduction on TPU"),
    ]
    return rows, err < 1e-5


KERNELS = {"lans": run_lans, "paged_attention": run_paged_attention}


def run(kernel: str = "all"):
    """benchmarks/run.py entry point: rows + combined PASS flag."""
    names = list(KERNELS) if kernel == "all" else [kernel]
    rows, ok = [], True
    for name in names:
        r, o = KERNELS[name]()
        rows += r
        ok = ok and o
    return rows, ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", default="all",
                    choices=["all", *KERNELS])
    args = ap.parse_args()
    rows, ok = run(args.kernel)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f'{name},{us:.1f},"{derived}"')
    print(f"kernel_throughput/STATUS,0,{'PASS' if ok else 'FAIL'}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()

"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark and a final
PASS/FAIL summary line per module.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table2     # one
"""
import importlib
import sys

MODULES = [
    "figure1_schedule",     # paper Fig. 1: AUC gaps 5.28 / 1.91
    "table1_hparams",       # paper Table 1: stage hyper-parameters
    "table2_convergence",   # paper Table 2: LANS vs LAMB at hostile LR
    "sharding_variance",    # paper §3.4: sampling variance bounds
    "ablation_lans",        # beyond-paper: eq(4)/eq(7) component ablation
    "kernel_throughput",    # apex fused_lans analogue (Pallas pipeline)
    "precision_sweep",      # mixed-precision policies: time/bytes/state
    "roofline_report",      # assignment §Roofline aggregation
]


def main() -> None:
    wanted = sys.argv[1:] or MODULES
    failures = []
    print("name,us_per_call,derived")
    for name in wanted:
        name = name.replace("benchmarks.", "")
        mod = importlib.import_module(f"benchmarks.{name}")
        try:
            rows, ok = mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{name}/EXCEPTION,0,{type(e).__name__}: {e}")
            failures.append(name)
            continue
        for rname, us, derived in rows:
            print(f'{rname},{us:.1f},"{derived}"')
        status = "PASS" if ok else "FAIL"
        print(f"{name}/STATUS,0,{status}")
        if not ok:
            failures.append(name)
    if failures:
        print(f"SUMMARY,0,FAILED: {failures}")
        raise SystemExit(1)
    print("SUMMARY,0,ALL PASS")


if __name__ == "__main__":
    main()

"""Aggregates the dry-run JSONs into the §Roofline table.

Not a paper table — the assignment's roofline deliverable. Reads
experiments/dryrun/*.json produced by repro.launch.dryrun.
"""
import glob
import json
import os

from repro.launch.mesh import HBM_BYTES


def load_records(out_dir="experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def format_table(recs, mesh="pod1"):
    lines = []
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>8s} {'bound':>10s} {'useful':>7s} {'temp_GB':>8s}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"{r['arch']:22s} {r['shape']:12s} "
                         f"{'skipped: ' + r['reason'][:46]}")
            continue
        if r["status"] != "ok":
            lines.append(f"{r['arch']:22s} {r['shape']:12s} ERROR")
            continue
        t = r["roofline"]
        temp = r["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {t['compute_s']:10.3f} "
            f"{t['memory_s']:10.3f} {t['collective_s']:8.3f} "
            f"{t['dominant'].replace('_s',''):>10s} "
            f"{r.get('useful_flops_ratio', 0):7.2f} {temp:8.1f}")
    return "\n".join(lines)


def run():
    recs = load_records()
    ok_count = sum(1 for r in recs if r["status"] == "ok")
    skip_count = sum(1 for r in recs if r["status"] == "skipped")
    err_count = sum(1 for r in recs if r["status"] not in ("ok", "skipped"))
    rows = [
        ("roofline/records", 0.0,
         f"{ok_count} ok, {skip_count} skipped, {err_count} error "
         f"(of {len(recs)})"),
    ]
    if recs:
        # dominant-term census over ok records (pod1)
        from collections import Counter
        c = Counter(r["roofline"]["dominant"] for r in recs
                    if r["status"] == "ok" and r["mesh"] == "pod1")
        rows.append(("roofline/dominant_census_pod1", 0.0, dict(c)))
        over = [f"{r['arch']}/{r['shape']}" for r in recs
                if r["status"] == "ok" and r["mesh"] == "pod1"
                and r["memory_analysis"].get("temp_size_in_bytes", 0)
                + r["memory_analysis"].get("argument_size_in_bytes", 0)
                > HBM_BYTES]
        rows.append(("roofline/over_hbm_pod1", 0.0,
                     over if over else "all fit 16GiB"))
    ok = err_count == 0 and ok_count > 0
    return rows, ok

"""Figure 1 reproduction: area-under-curve gap of the LR schedules.

Paper: with T=3519, warmup=1500, const=963 —
  AUC(eq8, eta=0.01) - AUC(eq8, eta=0.007) = 5.28
  AUC(eq8, eta=0.01) - AUC(eq9, eta=0.007) = 1.91
"""
import time

from repro.core.schedules import (figure1_settings, schedule_auc,
                                  warmup_hold_decay, warmup_linear_decay)


def run():
    s = figure1_settings()
    t0 = time.perf_counter()
    a_feas = schedule_auc(warmup_linear_decay(
        s["eta_feasible"], s["total_steps"], s["warmup_steps"]),
        s["total_steps"])
    a_ideal = schedule_auc(warmup_linear_decay(
        s["eta_ideal"], s["total_steps"], s["warmup_steps"]),
        s["total_steps"])
    a_hold = schedule_auc(warmup_hold_decay(
        s["eta_feasible"], s["total_steps"], s["warmup_steps"],
        s["hold_steps"]), s["total_steps"])
    dt = (time.perf_counter() - t0) * 1e6

    gap8 = a_ideal - a_feas
    gap9 = a_ideal - a_hold
    rows = [
        ("figure1/auc_gap_eq8", dt / 3, f"{gap8:.3f} (paper: 5.28)"),
        ("figure1/auc_gap_eq9", dt / 3, f"{gap9:.3f} (paper: 1.91)"),
        ("figure1/recovered_frac", dt / 3,
         f"{(gap8 - gap9) / gap8:.3f} of the lost area recovered by eq(9)"),
    ]
    ok = abs(gap8 - 5.28) < 0.02 and abs(gap9 - 1.91) < 0.02
    return rows, ok

"""Serving load generator: paged vs dense pools, continuous vs static,
lazy vs eager chain growth, chunked prefill under open-loop traffic,
speculative draft-verify decode on a low-entropy stream, prefix-affinity
routing over a replica fleet, and the two non-decoder workload families
(BERT scoring, encoder-decoder) served by the same engine core.

Eight workloads:

  mixed          (default) heterogeneous prompt lengths and generation
                 budgets with NO common prefix — the traffic shape where
                 paging buys nothing, used as the regression gate: the
                 paged pool must not cost throughput against the dense
                 pool (>= --paged-tol x dense tokens/s), and the
                 continuous engine must beat the static waves baseline.
  shared-prefix  every request carries the same --prefix-len system
                 prompt plus a short unique tail — the "millions of users,
                 one system prompt" shape. The paged pool is given the
                 SAME arena memory as the dense pool (slots_budget =
                 --max-batch) but 4x the decode slots, and must sustain
                 >= 2x the dense pool's peak concurrency by storing the
                 shared prefix blocks once (refcounted, copy-free).
  bursty-long    a burst of requests whose generation BUDGETS are much
                 larger than their prompts — the shape where whole-chain
                 reservation strands arena memory on rows nobody has
                 written yet. Lazy growth (decode blocks allocated as
                 the cursor crosses block boundaries, preempt/requeue on
                 exhaustion) must sustain >= --lazy-ratio (1.5) x the
                 eager reservation's admitted concurrency at EQUAL arena
                 memory, token-identically. A second phase replays two
                 DISJOINT request waves (same system prompt, different
                 tails) through a retention-enabled engine and must show
                 retained-prefix revivals > 0 on the second wave: the
                 prefix blocks survive refcount 0 on the bounded LRU and
                 are reused copy-free across waves.
  low-entropy    speculative decoding's best case, constructed rather
                 than sampled: make_spec_pair doctors the target so its
                 upper periods are inert (output projections zeroed —
                 identity residual blocks) and hands the bottom period
                 to a one-period draft sharing the embedding and head,
                 so the draft proposes EXACTLY what the target verifies
                 and every round commits a full --spec-k block. At each
                 batch size 1-4 a speculative engine races the plain
                 paged engine on the same seeded stream: tokens must
                 match bit-exactly (greedy fp32), acceptance must be
                 1.0, spec ITL p50 must undercut plain by
                 --spec-itl-ratio (a round stamps spec_k tokens per
                 verify step), and the verify/draft steps must compile
                 exactly once across admission/finish churn and the
                 budget-truncated rollbacks at non-multiple-of-K
                 budgets.
  open-loop      mostly-short prompts with a long-prompt minority,
                 arriving on a seeded Poisson clock that does NOT wait
                 for the server (serving/traffic.py). Phase A re-checks
                 token identity closed-loop: static == dense == paged ==
                 CHUNKED-paged under greedy fp32, plus the same-layout
                 bf16 pair (paged vs chunked-paged, tie-stable greedy).
                 Phase B replays the same arrival schedule through an
                 unchunked and a chunked engine (--chunk-budget) and
                 gates GOODPUT — tokens/s of requests meeting their TTFT
                 SLO and EVERY inter-token-gap ITL SLO: the unchunked
                 baseline must violate the ITL SLO (whole-prompt prefill
                 stalls every running stream), the chunked controller
                 must win goodput and keep its ITL p99 <= --tail-ratio x
                 its own p50. SLOs auto-calibrate from a WARM unchunked
                 closed-loop pass (--itl-slo-mult x its ITL p50;
                 override with --ttft-slo-ms / --itl-slo-ms).
  multi-tenant-routed
                 --tenants tenant populations, each with its OWN
                 --prefix-len system prompt, arrival order shuffled
                 across tenants, served by --replicas paged engine
                 replicas behind a ReplicaRouter. Prefix-affinity
                 routing (sticky content-addressed leading-block key,
                 serving/router.py) races round-robin over an IDENTICAL
                 fleet: each replica's arena is deliberately too small
                 to hold EVERY tenant's prefix blocks plus useful
                 decode concurrency, and each retained LRU is bounded
                 to ~tenants/replicas prefix working sets. Affinity
                 lands each tenant on one replica, so prefixes are
                 stored once fleet-wide (more admitted concurrency at
                 fixed arena memory) and each LRU holds a partition of
                 the tenants instead of thrashing over all of them
                 (revival hits across the interleaved passes).
  bert-scoring   BERT masked-LM scoring / embedding served by the SAME
                 ContinuousEngine core (task=score): scoring requests
                 complete AT admission — one fixed (max_batch,
                 score_len) score call serves up to max_batch requests,
                 no KV growth, slots free immediately. The batched path
                 races the engine's OWN batch-1 latency mode (run_one,
                 a lazily-built (1, score_len) jit) on the same seeded
                 workload: batched must amortize dispatch to >=
                 --score-batch-ratio (2.0) x the batch-1 tokens/s,
                 token- AND embedding-identically, and each path must
                 compile exactly once across the whole run.
  encdec         whisper-style encoder-decoder serving: the encoder
                 runs as a prefill-like pass and its output K/V is
                 registered in the content-addressed cross-attention
                 block arena keyed by the raw frames (frames_key), so
                 the --shared-inputs distinct encoder inputs reused
                 round-robin across --requests requests store their
                 encoder blocks ONCE (refcounted, copy-free) — shared
                 prompt prefixes, generalized to encoder outputs. The
                 pooled engine races its own batch-1 run_one path:
                 tokens must match bitwise (the batch-1 dense cross
                 K/V is padded to the arena's blocked frame count, so
                 both paths contract the same masked length), shared
                 cross-block hits must land, and the decode and
                 batch-1 steps must each compile exactly once.

Every engine pair runs the byte-identical seeded workload and must emit
identical tokens per request — scheduling, cache layout, growth mode and
preemption must never change output (the differential property
tests/test_serving_engine.py + tests/test_scheduling.py lock down; the
benchmark re-checks it end to end). Reports tokens/s, p50/p99 TTFT /
inter-token latency, decode-step counts, peak concurrency, preemptions
and shared/retained block hits, all measured on WARM engines (compiles
cached) with interleaved best-of passes — see measure_interleaved.

  PYTHONPATH=src python -m benchmarks.serving_load                # mixed
  PYTHONPATH=src python -m benchmarks.serving_load --workload shared-prefix
  PYTHONPATH=src python -m benchmarks.serving_load --workload bursty-long

Runs on CPU in a few minutes at the defaults. Alongside the human
PASS/FAIL line, every run prints (and --json-out writes) a
machine-readable JSON blob with each gate's measured value, threshold
and verdict, so successive PRs can track the perf trajectory:

  {"workload": ..., "gates": {"concurrency_ratio":
      {"measured": 3.2, "threshold": 1.5, "op": ">=", "pass": true}, ...},
   "engines": {"lazy": {"tokens_per_s": ..., ...}, ...}, "pass": true}

PASS (mixed): zero token mismatches, paged >= --paged-tol x dense
tokens/s, continuous >= --static-tol x static tokens/s, AND the
deterministic scheduling claim — the continuous engine finishes the
workload in no more decode steps than the static waves burn (slots
refill instead of idling until the wave's longest budget). At the
reduced CPU scale a decode step costs ~1 ms, so wall-clock ratios are
dispatch-overhead-bound and carry wide error bars (hence the
tolerances); the step-count gate is exact. PASS (shared-prefix): paged
peak concurrency >= 2x dense at equal arena memory, zero mismatches.
PASS (bursty-long): lazy admitted concurrency >= --lazy-ratio x eager
at equal arena memory, zero mismatches (preemption included), and
wave-2 retained-prefix revivals > 0. PASS (open-loop): zero mismatches
in both identity sets, chunked goodput >= --goodput-ratio x unchunked,
unchunked ITL violations >= 1, chunked ITL p99 <= --tail-ratio x p50.
PASS (low-entropy): zero spec-vs-plain mismatches, acceptance >= 0.999,
plain ITL p50 >= --spec-itl-ratio x spec ITL p50 at every batch size
1-4, verify/draft `_cache_size() == 1`.
PASS (multi-tenant-routed): zero routed-vs-round-robin mismatches
(routing never changes tokens), routed aggregate tokens/s >=
--routed-ratio (1.2) x the round-robin fleet, routed decode steps <=
round-robin's, and routed retained_hit_rate STRICTLY above round-robin
(the LRU-partitioning mechanism, not just the throughput symptom).
PASS (bert-scoring): zero token AND embedding mismatches batched vs
batch-1 on every measured pass, batched tokens/s >= --score-batch-ratio
x batch-1, and both the batched score jit and the batch-1 jit stay at
`_cache_size() == 1`. PASS (encdec): zero pooled-vs-batch-1 token
mismatches, shared cross-attention block hits >= 1 (encoder outputs
stored once across same-input requests), and the pooled decode step and
batch-1 step stay at `_cache_size() == 1`.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import reduced_arch
from repro.serving import (ContinuousEngine, ReplicaRouter, Request,
                           ServeEngine, Sampler,
                           synthetic_encdec_requests, synthetic_requests,
                           synthetic_scoring_requests)
from repro.serving.metrics import aggregate


def make_static(arch, params, workload, args, max_len):
    """Returns a measured-pass closure over ONE persistent engine, so jit
    tracing and XLA compiles never land inside the measured wall clock
    (each engine instance owns its jit caches — a fresh engine would
    recompile)."""
    engine = ServeEngine(arch, params, max_len=max_len,
                         policy=args.precision, sampler=args.sampler)

    def one():
        reqs = workload()
        steps = 0
        t0 = time.perf_counter()
        for r in reqs:         # the whole workload is waiting from t0:
            r.trace.mark_submit()  # TTFT includes the inter-wave queue wait
        for i in range(0, len(reqs), args.max_batch):
            wave = reqs[i:i + args.max_batch]
            engine.run_batch(wave)
            # decode-step INVOCATIONS, comparable to ContinuousEngine's
            # steps_run: the wave's first token comes from prefill
            steps += max(r.max_new_tokens for r in wave) - 1
        dt = time.perf_counter() - t0
        stats = aggregate([r.trace for r in reqs], dt,
                          sum(len(r.generated) for r in reqs))
        stats["decode_steps"] = steps
        return stats, reqs

    return one


def make_continuous(arch, params, workload, args, max_len, *, cache,
                    slot_factor=1, **engine_kw):
    engine = ContinuousEngine(
        arch, params, max_batch=slot_factor * args.max_batch,
        max_len=max_len, policy=args.precision,
        prefill_bucket=args.prefill_bucket, cache=cache,
        block_size=args.block_size, slots_budget=args.max_batch,
        sampler=args.sampler, **engine_kw)

    def one():
        reqs = workload()
        steps0, preempt0 = engine.steps_run, engine.preemptions
        t0 = time.perf_counter()
        engine.run(reqs)
        dt = time.perf_counter() - t0
        stats = aggregate([r.trace for r in reqs], dt,
                          sum(len(r.generated) for r in reqs))
        stats["decode_steps"] = engine.steps_run - steps0
        stats["max_concurrent"] = engine.max_concurrent
        stats["preemptions"] = engine.preemptions - preempt0
        if engine.paged:
            stats["shared_block_hits"] = engine.pool.shared_hits
            stats["retained_block_hits"] = engine.pool.retained_hits
        return stats, reqs

    return one


def measure_interleaved(runners: dict, reps: int):
    """Warm every engine first, then INTERLEAVE the measured passes
    (rep 0 of every engine, then rep 1, ...), keeping each engine's
    fastest stats. Warm passes at this reduced scale take a few hundred
    ms — the same order as container CPU noise and thermal drift — so
    measuring engines in sequential blocks systematically biases against
    whichever runs last; interleaving spreads the drift evenly and
    best-of filters the spikes. Returns every rep's outputs so the
    caller can gate token identity on ALL passes, not just the fastest.

    max_concurrent is engine-lifetime (not per-pass), so it is taken
    from the LAST stats — identical workloads peak identically.
    """
    for one in runners.values():
        one()                  # warmup: compiles cached per engine
    best = {}
    rep_outputs = []
    for _ in range(reps):
        outs = {}
        for name, one in runners.items():
            stats, reqs = one()
            outs[name] = reqs
            if (name not in best
                    or stats["tokens_per_s"] > best[name]["tokens_per_s"]):
                best[name] = stats
            if "max_concurrent" in stats:
                best[name]["max_concurrent"] = stats["max_concurrent"]
        rep_outputs.append(outs)
    return best, rep_outputs


def check_tokens(outputs: dict, baseline: str) -> int:
    base = outputs[baseline]
    return sum(not np.array_equal(x.generated, y.generated)
               for name, out in outputs.items() if name != baseline
               for x, y in zip(base, out))


def print_stats(results: dict):
    for name, s in results.items():
        extra = ""
        if "max_concurrent" in s:
            extra = f" | peak slots {s['max_concurrent']:3d}"
        if s.get("preemptions"):
            extra += f" | preempts {s['preemptions']}"
        if "shared_block_hits" in s:
            extra += (f" | shared hits {s['shared_block_hits']}"
                      f" | retained hits {s.get('retained_block_hits', 0)}")
        print(f"{name:>10}: {s['tokens_per_s']:8.1f} tok/s | "
              f"ttft p50 {s['ttft_p50_ms']:7.2f} ms p99 "
              f"{s['ttft_p99_ms']:7.2f} ms | itl p50 "
              f"{s['itl_p50_ms']:6.2f} ms p99 {s['itl_p99_ms']:6.2f} ms | "
              f"decode steps {s['decode_steps']}{extra}")


def gate(measured, threshold, op=">="):
    """One machine-readable PASS gate record."""
    ok = {">=": measured >= threshold, "<=": measured <= threshold,
          ">": measured > threshold}[op]
    return {"measured": round(float(measured), 3),
            "threshold": threshold, "op": op, "pass": bool(ok)}


def run_bursty_long(arch, params, args, mk_workload, max_len):
    """Lazy vs eager growth at equal arena memory, then retained-prefix
    persistence across two disjoint request waves."""
    workload = mk_workload(args.seed)
    mk = (arch, params, workload, args, max_len)
    runners = {
        # dense pool = token baseline (slots == arena budget, no paging)
        "dense": make_continuous(*mk, cache="dense"),
        "eager": make_continuous(*mk, cache="paged", slot_factor=4,
                                 growth="eager"),
        "lazy": make_continuous(*mk, cache="paged", slot_factor=4,
                                growth="lazy", watermark=1),
    }
    results, rep_outputs = measure_interleaved(runners, args.reps)
    mismatch = sum(check_tokens(outs, "dense") for outs in rep_outputs)
    print_stats(results)

    ratio = (results["lazy"]["max_concurrent"]
             / max(results["eager"]["max_concurrent"], 1))
    gates = {
        "token_mismatches": gate(mismatch, 0, op="<="),
        "concurrency_ratio": gate(ratio, args.lazy_ratio),
    }

    # ---- phase 2: retained-prefix persistence across disjoint waves ----
    # one synthetic_requests() call split in half: same system prompt,
    # disjoint tails — so wave 2 can only reuse prefix blocks that
    # SURVIVED wave 1's evictions on the retained LRU (refcount 0).
    both = synthetic_requests(
        2 * args.requests, arch.cfg.vocab, prompt_len=args.prompt_len,
        new_tokens=args.new_tokens // 2, seed=args.seed + 1,
        min_new_frac=0.5, shared_prefix=args.prefix_len)
    wave1, wave2 = both[:args.requests], both[args.requests:]
    wave_engine = ContinuousEngine(
        arch, params, max_batch=args.max_batch, max_len=max_len,
        policy=args.precision, prefill_bucket=args.prefill_bucket,
        cache="paged", block_size=args.block_size, sampler=args.sampler)
    wave_engine.run(wave1)                    # drains: every slot evicts
    hits_before = wave_engine.pool.retained_hits
    wave_engine.run(wave2)
    wave2_hits = wave_engine.pool.retained_hits - hits_before
    print(f"retained-prefix wave 2: {wave2_hits} revived blocks "
          f"({wave_engine.pool.retained_blocks()} still parked)")
    gates["wave2_retained_hits"] = gate(wave2_hits, 1)
    results["waves"] = {"retained_block_hits_wave2": wave2_hits,
                        "preemptions": wave_engine.preemptions}
    return results, gates


def run_low_entropy(arch, params, args, max_len):
    """Speculative decoding gate at batch 1..4 (see module docstring,
    PASS (low-entropy)). The target/draft pair comes from
    make_spec_pair: the target's upper periods are inert, the draft IS
    the bottom period, so acceptance is 1.0 by construction and every
    round commits a full spec_k block — isolating the mechanics
    (draft micro-steps, S=K verify, rollback plumbing) from draft
    quality. --spec-draft self swaps in the UNdoctored target as its
    own draft: same tokens, still acceptance 1.0, but rounds cost full
    target steps — the correctness soak, not the latency demo."""
    from repro.serving import ContinuousEngine, make_spec_pair
    if args.spec_draft == "truncated":
        params, draft_arch, draft_params = make_spec_pair(arch, params)
    else:                                  # self-draft soak
        draft_arch, draft_params = arch, params

    def mk_reqs():
        return synthetic_requests(
            args.requests, arch.cfg.vocab, prompt_len=args.prompt_len,
            new_tokens=args.new_tokens, seed=args.seed, min_new_frac=0.75)

    results, gates = {}, {}
    mismatch = 0
    for mb in (1, 2, 3, 4):
        engines = {}
        for name, kw in (("plain", {}),
                         ("spec", {"spec_draft": (draft_arch, draft_params),
                                   "spec_k": args.spec_k})):
            engines[name] = ContinuousEngine(
                arch, params, max_batch=mb, max_len=max_len,
                policy=args.precision, prefill_bucket=args.prefill_bucket,
                cache="paged", block_size=args.block_size,
                sampler=args.sampler, **kw)
        best, outs = {}, {}
        for rep in range(args.reps + 1):
            for name, eng in engines.items():
                reqs = mk_reqs()
                t0 = time.perf_counter()
                eng.run(reqs)
                dt = time.perf_counter() - t0
                outs[name] = reqs
                if rep == 0:
                    continue               # warmup: compiles cached
                stats = aggregate([r.trace for r in reqs], dt,
                                  sum(len(r.generated) for r in reqs))
                if (name not in best or stats["tokens_per_s"]
                        > best[name]["tokens_per_s"]):
                    best[name] = stats
            if rep > 0:
                mismatch += check_tokens(outs, "plain")
        spec_eng = engines["spec"]
        rep_stats = spec_eng.report(1.0)
        for name in best:
            best[name]["decode_steps"] = engines[name].steps_run
        best["spec"]["acceptance_rate"] = rep_stats["acceptance_rate"]
        best["spec"]["spec_rounds"] = rep_stats["spec_rounds"]
        print(f"--- batch {mb} (acceptance "
              f"{rep_stats['acceptance_rate']:.3f}, "
              f"{rep_stats['spec_rounds']} rounds) ---")
        print_stats(best)
        # a full-acceptance round commits spec_k tokens against ONE
        # itl timestamp gap, so spec p50 collapses versus one-token
        # rounds; cap the ratio like goodput_ratio does
        ratio = min(best["plain"]["itl_p50_ms"]
                    / max(best["spec"]["itl_p50_ms"], 1e-9), 100.0)
        gates[f"itl_ratio_b{mb}"] = gate(ratio, args.spec_itl_ratio)
        gates[f"acceptance_b{mb}"] = gate(
            rep_stats["acceptance_rate"], 0.999)
        # accept/finish churn must never retrace the verify or draft
        # steps (the _cache_size()==1 claim of the rollback design)
        gates[f"verify_compiles_b{mb}"] = gate(
            spec_eng._verify._cache_size(), 1, op="<=")
        gates[f"draft_compiles_b{mb}"] = gate(
            spec_eng._draft_step._cache_size(), 1, op="<=")
        results[f"plain_b{mb}"] = best["plain"]
        results[f"spec_b{mb}"] = best["spec"]
    gates["token_mismatches"] = gate(mismatch, 0, op="<=")
    return results, gates


def run_open_loop(arch, params, args, max_len):
    """Chunked-prefill admission under open-loop Poisson traffic:
    token identity first (closed loop), then goodput at a fixed
    arrival rate (see module docstring, PASS (open-loop))."""
    from repro.serving import (OpenLoopDriver, SLO, ContinuousEngine,
                               bimodal_requests, poisson_arrivals,
                               slo_report)
    from repro.serving.metrics import percentile

    def mk_reqs(seed):
        return bimodal_requests(
            args.requests, arch.cfg.vocab, short_len=args.prompt_len,
            long_len=args.long_len, new_tokens=args.new_tokens,
            long_frac=args.long_frac, seed=seed)

    # ---- phase A: closed-loop token identity on the bimodal mix ------
    # greedy fp32 quad: the chunked engine must emit the same tokens as
    # every unchunked layout (chunk boundaries are invisible)
    mk = (arch, params, lambda: mk_reqs(args.seed), args, max_len)
    runners = {
        "static": make_static(*mk),
        "dense": make_continuous(*mk, cache="dense"),
        "paged": make_continuous(*mk, cache="paged"),
        "chunked": make_continuous(*mk, cache="paged",
                                   chunk_budget=args.chunk_budget),
    }
    results, rep_outputs = measure_interleaved(runners, 1)
    mismatch = sum(check_tokens(outs, "dense") for outs in rep_outputs)
    print_stats(results)

    # same-layout bf16 pair (paged vs chunked-paged): one-ulp logit ties
    # are pinned by the tie-stable greedy argmax (--sampler ...,stable=1)
    bf_args = argparse.Namespace(**{
        **vars(args), "precision": "bf16",
        "sampler": Sampler.parse("temperature=0,stable=1")})
    mk_bf = (arch, params, lambda: mk_reqs(args.seed), bf_args, max_len)
    bf_runners = {
        "paged": make_continuous(*mk_bf, cache="paged"),
        "chunked": make_continuous(*mk_bf, cache="paged",
                                   chunk_budget=args.chunk_budget),
    }
    _, bf_outputs = measure_interleaved(bf_runners, 1)
    bf_mismatch = sum(check_tokens(outs, "paged") for outs in bf_outputs)
    print(f"bf16 paged/chunked pair: {bf_mismatch} token mismatches")

    # ---- phase B: goodput at a fixed arrival rate --------------------
    def open_engine(chunk_budget=None):
        return ContinuousEngine(
            arch, params, max_batch=args.max_batch, max_len=max_len,
            policy=args.precision, prefill_bucket=args.prefill_bucket,
            cache="paged", block_size=args.block_size,
            slots_budget=args.max_batch, sampler=args.sampler,
            chunk_budget=chunk_budget)

    base_eng = open_engine()
    chunk_eng = open_engine(chunk_budget=args.chunk_budget)
    chunk_eng._admission.warmup()   # chunk sizes depend on runtime load
    warm = {}
    for name, eng in (("base", base_eng), ("chunked", chunk_eng)):
        wreqs = mk_reqs(args.seed + 7)
        eng.run(wreqs)              # compiles cached; traces collected
        warm[name] = wreqs

    # SLO calibration from the WARM unchunked pass: its ITL p50 is the
    # undisturbed decode gap; whole-prompt prefill stalls sit far above
    # --itl-slo-mult x that, metered chunks below it. TTFT stays
    # deliberately loose — chunking trades a little TTFT for ITL, and
    # this workload gates the ITL side.
    base_itls = [g for r in warm["base"] for g in r.trace.inter_token_s]
    itl_slo = args.itl_slo_ms or \
        args.itl_slo_mult * percentile(base_itls, 50) * 1e3
    ttft_slo = args.ttft_slo_ms or max(1000.0, 40 * itl_slo)
    slo = SLO(ttft_ms=ttft_slo, itl_ms=itl_slo)
    print(f"SLO (warm-calibrated): ttft <= {ttft_slo:.1f} ms, "
          f"itl <= {itl_slo:.2f} ms")

    arrivals = poisson_arrivals(args.requests, args.arrival_rate,
                                seed=args.seed)

    def measure(eng):               # identical requests + arrival clock
        reqs = mk_reqs(args.seed)
        wall = OpenLoopDriver(eng, reqs, arrivals).run()
        return slo_report(reqs, slo, wall), reqs

    def tail(rep):
        return rep["itl_p99_ms"] / max(rep["itl_p50_ms"], 1e-9)

    # --reps alternating passes per engine, best-of — the same CPU-noise
    # filter measure_interleaved applies to the closed-loop numbers: a
    # single OS scheduling spike lands directly in a p99 of ~350 gap
    # samples. The baseline keeps its BEST goodput pass and its FEWEST
    # ITL violations (conservative on both gates it feeds); the chunked
    # engine keeps its best-tail pass. Token identity is checked on
    # every pass.
    open_mismatch = 0
    base_rep = chunk_rep = None
    base_viol = None
    for _ in range(args.reps):
        b, base_out = measure(base_eng)
        c, chunk_out = measure(chunk_eng)
        open_mismatch += sum(
            not np.array_equal(x.generated, y.generated)
            for x, y in zip(base_out, chunk_out))
        if base_rep is None or b["goodput_tokens_per_s"] \
                > base_rep["goodput_tokens_per_s"]:
            base_rep = b
        base_viol = b["itl_violations"] if base_viol is None \
            else min(base_viol, b["itl_violations"])
        if chunk_rep is None or tail(c) < tail(chunk_rep):
            chunk_rep = c
    for name, rep in (("unchunked", base_rep), ("chunked", chunk_rep)):
        print(f"{name:>10}: goodput {rep['goodput_tokens_per_s']:7.1f} "
              f"tok/s (raw {rep['tokens_per_s']:7.1f}) | attainment "
              f"{rep['slo_attainment']:.2f} | itl p50 "
              f"{rep['itl_p50_ms']:6.2f} ms p99 {rep['itl_p99_ms']:7.2f} "
              f"ms | ttft viol {rep['ttft_violations']} itl viol "
              f"{rep['itl_violations']}")

    gates = {
        "token_mismatches": gate(mismatch, 0, op="<="),
        "bf16_token_mismatches": gate(bf_mismatch, 0, op="<="),
        "open_loop_token_mismatches": gate(open_mismatch, 0, op="<="),
        # ratio capped at 100: an unchunked baseline with ~zero goodput
        # would otherwise print a meaningless astronomical number
        "goodput_ratio": gate(
            min(chunk_rep["goodput_tokens_per_s"]
                / max(base_rep["goodput_tokens_per_s"], 1e-9), 100.0),
            args.goodput_ratio),
        "baseline_itl_violations": gate(base_viol, 1),
        "chunked_itl_tail": gate(tail(chunk_rep), args.tail_ratio,
                                 op="<="),
    }
    results["open_unchunked"] = base_rep
    results["open_chunked"] = {**chunk_rep,
                               **{k: chunk_eng.report(1.0)[k] for k in
                                  ("chunk_steps", "chunk_tokens",
                                   "chunk_budget")}}
    return results, gates


def run_multi_tenant_routed(arch, params, args, max_len):
    """Prefix-affinity vs round-robin routing over IDENTICAL replica
    fleets (see module docstring, PASS (multi-tenant-routed)).

    The sizing makes the routing decision the only difference that
    matters: slots_budget < max_batch per replica, so arena blocks —
    not decode slots — bound concurrency, and whoever dedups prefixes
    admits more requests per step; retain_blocks holds ~tenants/replicas
    prefix working sets, so the affinity partition revives across
    passes while round-robin's all-tenant stream cyclically thrashes
    its LRUs."""
    T = args.tenants
    prefix_blocks = args.prefix_len // args.block_size
    retain = max(1, T // args.replicas) * prefix_blocks

    tenant_rng = np.random.default_rng(args.seed + 100)
    prefixes = [tenant_rng.integers(5, arch.cfg.vocab,
                                    size=args.prefix_len).astype(np.int32)
                for _ in range(T)]

    def mk_reqs(seed):
        # waves of all T tenants, tenant order SHUFFLED per wave: a
        # fixed interleave would stride-align tenants onto round-robin
        # replicas and hand the baseline affinity for free
        rng = np.random.default_rng(seed)
        reqs = []
        for _ in range(args.requests // T):
            for t in rng.permutation(T):
                tail = rng.integers(5, arch.cfg.vocab,
                                    size=args.prompt_len).astype(np.int32)
                reqs.append(Request(
                    prompt=np.concatenate([prefixes[t], tail]),
                    max_new_tokens=args.new_tokens))
        return reqs

    routers = {}

    def make_fleet(name, policy):
        fleet = [
            ContinuousEngine(
                arch, params, max_batch=args.max_batch, max_len=max_len,
                policy=args.precision, prefill_bucket=args.prefill_bucket,
                cache="paged", block_size=args.block_size,
                slots_budget=T // args.replicas + 1, growth="eager",
                retain_blocks=retain, sampler=args.sampler)
            for _ in range(args.replicas)]
        router = ReplicaRouter(fleet, policy=policy)
        routers[name] = router

        def one():
            reqs = mk_reqs(args.seed)
            steps0 = sum(e.steps_run for e in fleet)
            t0 = time.perf_counter()
            router.run(reqs)
            dt = time.perf_counter() - t0
            stats = aggregate([r.trace for r in reqs], dt,
                              sum(len(r.generated) for r in reqs))
            stats["decode_steps"] = sum(e.steps_run for e in fleet) - steps0
            stats["max_concurrent"] = sum(e.max_concurrent for e in fleet)
            return stats, reqs

        return one

    runners = {"rr": make_fleet("rr", "rr"),
               "routed": make_fleet("routed", "prefix")}
    results, rep_outputs = measure_interleaved(runners, args.reps)
    mismatch = sum(check_tokens(outs, "rr") for outs in rep_outputs)
    print_stats(results)

    reports = {name: routers[name].report(1.0) for name in routers}
    for name, rep in reports.items():
        done = [len(e.scheduler.completed) for e in routers[name].replicas]
        print(f"{name:>10}: retained hit rate "
              f"{rep['retained_hit_rate']:.3f} | affinity hits "
              f"{rep['routed_affinity_hits']} | depth fallbacks "
              f"{rep['routed_fallback']} | completed per replica {done}")

    gates = {
        "token_mismatches": gate(mismatch, 0, op="<="),
        "routed_tokens_ratio": gate(
            results["routed"]["tokens_per_s"]
            / max(results["rr"]["tokens_per_s"], 1e-9), args.routed_ratio),
        # the mechanism behind the wall-clock ratio, gated exactly:
        # dedup admits more concurrent requests, so the routed fleet
        # finishes the same workload in fewer decode steps
        "routed_steps_vs_rr": gate(results["routed"]["decode_steps"],
                                   results["rr"]["decode_steps"], op="<="),
        "routed_hit_rate_gain": gate(
            reports["routed"]["retained_hit_rate"],
            reports["rr"]["retained_hit_rate"], op=">"),
    }
    for name, rep in reports.items():
        results[f"router_{name}"] = {
            k: v for k, v in rep.items() if k != "per_replica"}
    return results, gates


def run_bert_scoring(arch, params, args, max_len):
    """Batched masked-LM scoring vs the batch-1 latency path on ONE
    engine (see module docstring, PASS (bert-scoring)). Scoring
    requests complete at admission, so the batched path's cost is
    ceil(n / max_batch) score calls against run_one's n serial
    (1, score_len) calls — the gate is the dispatch amortization,
    measured on the same warm engine with identical seeded requests."""
    engine = ContinuousEngine(
        arch, params, max_batch=args.max_batch, max_len=max_len,
        policy=args.precision, sampler=args.sampler, task="score")

    def mk_reqs():
        return synthetic_scoring_requests(
            args.requests, arch.cfg.vocab, prompt_len=args.prompt_len,
            seed=args.seed)

    def batched():
        reqs = mk_reqs()
        steps0 = engine.steps_run
        t0 = time.perf_counter()
        engine.run(reqs)
        dt = time.perf_counter() - t0
        stats = aggregate([r.trace for r in reqs], dt,
                          sum(len(r.generated) for r in reqs))
        stats["decode_steps"] = engine.steps_run - steps0
        return stats, reqs

    def batch1():
        reqs = mk_reqs()
        t0 = time.perf_counter()
        for r in reqs:
            engine.run_one(r)
        dt = time.perf_counter() - t0
        stats = aggregate([r.trace for r in reqs], dt,
                          sum(len(r.generated) for r in reqs))
        stats["decode_steps"] = len(reqs)   # one score call per request
        return stats, reqs

    runners = {"batched": batched, "batch1": batch1}
    results, rep_outputs = measure_interleaved(runners, args.reps)
    mismatch = sum(check_tokens(outs, "batched") for outs in rep_outputs)
    # the pooled embedding rides the same score call; pin it bitwise too
    emb_mismatch = sum(
        not np.array_equal(x.embedding, y.embedding)
        for outs in rep_outputs
        for x, y in zip(outs["batched"], outs["batch1"]))
    print_stats(results)

    ratio = (results["batched"]["tokens_per_s"]
             / max(results["batch1"]["tokens_per_s"], 1e-9))
    gates = {
        "token_mismatches": gate(mismatch, 0, op="<="),
        "embedding_mismatches": gate(emb_mismatch, 0, op="<="),
        "batched_vs_batch1": gate(ratio, args.score_batch_ratio),
        # admission/finish churn and short final batches must never
        # retrace either path: both shapes are fixed per engine lifetime
        "score_compiles": gate(engine._score._cache_size(), 1, op="<="),
        "batch1_compiles": gate(
            engine._lat_score._cache_size(), 1, op="<="),
    }
    return results, gates


def run_encdec(arch, params, args, max_len):
    """Pooled encoder-decoder serving (shared cross-attention arena)
    vs the batch-1 latency path on ONE engine (see module docstring,
    PASS (encdec))."""
    cfg = arch.cfg
    n_inputs = args.shared_inputs or max(1, args.requests // 4)
    engine = ContinuousEngine(
        arch, params, max_batch=args.max_batch, max_len=max_len,
        policy=args.precision, prefill_bucket=args.prefill_bucket,
        cache="paged", block_size=args.block_size,
        sampler=args.sampler)

    def mk_reqs():
        return synthetic_encdec_requests(
            args.requests, cfg.vocab, n_frames=cfg.n_frames,
            d_model=cfg.d_model, prompt_len=args.prompt_len,
            new_tokens=args.new_tokens, n_inputs=n_inputs,
            seed=args.seed)

    def pooled():
        reqs = mk_reqs()
        steps0 = engine.steps_run
        hits0 = engine.pool.shared_hits
        t0 = time.perf_counter()
        engine.run(reqs)
        dt = time.perf_counter() - t0
        stats = aggregate([r.trace for r in reqs], dt,
                          sum(len(r.generated) for r in reqs))
        stats["decode_steps"] = engine.steps_run - steps0
        stats["max_concurrent"] = engine.max_concurrent
        stats["shared_block_hits"] = engine.pool.shared_hits - hits0
        stats["retained_block_hits"] = engine.pool.retained_hits
        return stats, reqs

    def batch1():
        reqs = mk_reqs()
        t0 = time.perf_counter()
        for r in reqs:
            engine.run_one(r)
        dt = time.perf_counter() - t0
        stats = aggregate([r.trace for r in reqs], dt,
                          sum(len(r.generated) for r in reqs))
        stats["decode_steps"] = sum(
            max(len(r.generated) - 1, 0) for r in reqs)
        return stats, reqs

    runners = {"pooled": pooled, "batch1": batch1}
    results, rep_outputs = measure_interleaved(runners, args.reps)
    mismatch = sum(check_tokens(outs, "pooled") for outs in rep_outputs)
    print_stats(results)

    gates = {
        "token_mismatches": gate(mismatch, 0, op="<="),
        # the tentpole mechanism: same-input requests reuse registered
        # encoder blocks instead of re-storing them (measured passes
        # only — each pass admits n_requests over n_inputs inputs)
        "shared_block_hits": gate(
            results["pooled"]["shared_block_hits"], 1),
        "step_compiles": gate(engine._step._cache_size(), 1, op="<="),
        "batch1_compiles": gate(
            engine._lat_step._cache_size(), 1, op="<="),
    }
    results["pool"] = {
        "shared_block_hits_total": engine.pool.shared_hits,
        "retained_block_hits": engine.pool.retained_hits,
        "prefix_misses": engine.pool.prefix_misses,
        "retained_hit_rate": engine.pool.retained_hit_rate,
    }
    return results, gates


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload",
                    choices=["mixed", "shared-prefix", "bursty-long",
                             "open-loop", "low-entropy",
                             "multi-tenant-routed", "bert-scoring",
                             "encdec"],
                    default="mixed")
    ap.add_argument("--arch", default=None,
                    help="default: gemma2-2b (mixed) / qwen2.5-14b "
                         "(shared-prefix, bursty-long: full attention, so "
                         "every layer type dedups — sliding-window rings "
                         "stop sharing once decode wraps them) / "
                         "bert-large (bert-scoring) / whisper-large-v3 "
                         "(encdec)")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--prefix-len", type=int, default=32,
                    help="shared system-prompt tokens (shared-prefix / "
                         "bursty-long wave phase)")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--prefill-bucket", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--paged-tol", type=float, default=0.75,
                    help="mixed PASS gate: paged tokens/s >= tol x dense "
                         "(block-table gather + arena inserts cost ~10-20% "
                         "against per-slot rows when nothing is shared; "
                         "the pool buys memory/concurrency, not raw step "
                         "latency — a real regression like a per-step "
                         "recompile shows up as 0.1-0.3x)")
    ap.add_argument("--static-tol", type=float, default=0.7,
                    help="mixed PASS gate: continuous tokens/s >= tol x "
                         "static (at reduced scale admission dispatch "
                         "costs ~ the decode steps it saves; the exact "
                         "scheduling win is gated on decode-step counts "
                         "instead)")
    ap.add_argument("--lazy-ratio", type=float, default=1.5,
                    help="bursty-long PASS gate: lazy-growth admitted "
                         "concurrency >= ratio x eager whole-chain "
                         "reservation at equal arena memory")
    ap.add_argument("--reps", type=int, default=5,
                    help="measured passes per engine (after warmup); the "
                         "fastest is reported")
    ap.add_argument("--chunk-budget", type=int, default=12,
                    help="open-loop: per-step token budget for the "
                         "chunked-prefill engine (chunk + active decodes "
                         "<= budget)")
    ap.add_argument("--arrival-rate", type=float, default=10.0,
                    help="open-loop: Poisson arrival rate in requests/s")
    ap.add_argument("--long-len", type=int, default=512,
                    help="open-loop: long-prompt mode of the bimodal mix "
                         "(the admissions that stall unchunked decodes)")
    ap.add_argument("--long-frac", type=float, default=0.5,
                    help="open-loop: fraction of long-prompt requests")
    ap.add_argument("--ttft-slo-ms", type=float, default=None,
                    help="open-loop TTFT bound (default: auto, loose)")
    ap.add_argument("--itl-slo-ms", type=float, default=None,
                    help="open-loop ITL bound on every inter-token gap "
                         "(default: --itl-slo-mult x warm unchunked p50)")
    ap.add_argument("--itl-slo-mult", type=float, default=4.0,
                    help="auto ITL SLO multiplier over the warm "
                         "unchunked closed-loop ITL p50")
    ap.add_argument("--goodput-ratio", type=float, default=1.1,
                    help="open-loop PASS gate: chunked goodput >= ratio "
                         "x unchunked goodput at the same arrival rate")
    ap.add_argument("--tail-ratio", type=float, default=2.0,
                    help="open-loop PASS gate: chunked ITL p99 <= ratio "
                         "x chunked ITL p50 (metered prefill keeps the "
                         "tail near the median)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="low-entropy: draft tokens proposed/verified "
                         "per speculative round")
    ap.add_argument("--spec-draft", default="truncated",
                    choices=["truncated", "self"],
                    help="low-entropy draft source: 'truncated' = "
                         "make_spec_pair's one-period draft under an "
                         "inert-upper target (the latency demo); "
                         "'self' = the target drafts for itself "
                         "(correctness soak, no compute saving)")
    ap.add_argument("--spec-itl-ratio", type=float, default=2.0,
                    help="low-entropy PASS gate: non-spec ITL p50 >= "
                         "ratio x spec ITL p50 at every batch size 1-4 "
                         "(a full-acceptance round commits spec_k "
                         "tokens per verify step)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="multi-tenant-routed: engine replicas per fleet")
    ap.add_argument("--tenants", type=int, default=4,
                    help="multi-tenant-routed: distinct system prompts")
    ap.add_argument("--routed-ratio", type=float, default=1.2,
                    help="multi-tenant-routed PASS gate: prefix-affinity "
                         "aggregate tokens/s >= ratio x the round-robin "
                         "fleet on the same workload")
    ap.add_argument("--score-batch-ratio", type=float, default=2.0,
                    help="bert-scoring PASS gate: batched scoring "
                         "tokens/s >= ratio x the batch-1 run_one path "
                         "on the same engine (one score call per "
                         "max_batch requests vs one per request)")
    ap.add_argument("--shared-inputs", type=int, default=None,
                    help="encdec: distinct encoder inputs reused "
                         "round-robin across --requests requests "
                         "(default requests//4) — the cross-arena "
                         "sharing knob")
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "bf16_compute", "fp16"])
    ap.add_argument("--sampler", default=None,
                    help="optional sampler spec (default greedy)")
    ap.add_argument("--json-out", default=None,
                    help="also write the JSON summary blob to this path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    args.sampler = Sampler.parse(args.sampler)
    if args.sampler is None and args.precision.startswith("bf16"):
        # identity gates under bf16 default to the tie-stable greedy
        # argmax: cross-layout one-ulp logit ties no longer require
        # pinning the benchmark to fp32
        args.sampler = Sampler.parse("temperature=0,stable=1")

    shared = args.workload == "shared-prefix"
    bursty = args.workload == "bursty-long"
    open_loop = args.workload == "open-loop"
    low_entropy = args.workload == "low-entropy"
    routed = args.workload == "multi-tenant-routed"
    scoring = args.workload == "bert-scoring"
    encdec = args.workload == "encdec"
    arch_name = args.arch or (
        "gemma2-2b" if args.workload in ("mixed", "open-loop")
        else "bert-large" if scoring
        else "whisper-large-v3" if encdec
        else "qwen2.5-14b")
    arch = reduced_arch(arch_name)
    want_kind = "bert" if scoring else "encdec" if encdec else "decoder"
    if arch.kind != want_kind:
        raise SystemExit(f"--workload {args.workload} needs a "
                         f"{want_kind} arch, got {arch_name} "
                         f"({arch.kind})")
    params = arch.init(jax.random.PRNGKey(args.seed))

    if shared:
        args.prompt_len, args.new_tokens = 8, 8
    elif open_loop:
        # mostly-short decode traffic + long-prompt stalls; modest
        # request count keeps the open-loop replay to a few seconds,
        # and >= 8 decode slots keep the decode half of a chunked step
        # heavy enough that the chunk's extra dispatch stays inside the
        # --tail-ratio envelope
        args.requests = min(args.requests, 32)
        args.max_batch = max(args.max_batch, 8)
        args.prompt_len, args.new_tokens = 8, 12
    elif bursty:
        # budgets dwarf prompts: whole-chain reservation strands rows
        args.requests = min(args.requests, 16)
        args.prompt_len, args.new_tokens, args.prefix_len = 8, 32, 24
    elif low_entropy:
        # small request count: the gate sweeps batch sizes 1..4 and the
        # batch-1 engine decodes every request serially
        args.requests = min(args.requests, 8)
        args.prompt_len, args.new_tokens = 8, 16
    elif routed:
        # short tails/budgets keep the per-tenant prefix the dominant
        # arena cost; max_batch above the arena's admitting capacity so
        # blocks, not slots, bound concurrency; enough waves that the
        # retained LRUs see repeated tenant revisits
        args.requests = min(args.requests, 24)
        args.max_batch = max(args.max_batch, 8)
        args.prompt_len, args.new_tokens = 8, 8
    elif scoring:
        # one batched score call serves max_batch requests; batch-1
        # pays one call per request — bigger batches widen the gap
        args.max_batch = max(args.max_batch, 8)
    elif encdec:
        # modest decode budgets: the cross arena (encoder blocks) is
        # the sharing surface; batch-1 replays every request serially
        args.requests = min(args.requests, 24)
        args.prompt_len, args.new_tokens = 8, 12
    prefix = args.prefix_len if shared else 0
    max_len = prefix + args.prompt_len + args.new_tokens \
        + args.prefill_bucket
    if bursty:
        max_len += args.prefix_len     # wave phase prepends the prefix
    if routed:
        max_len += args.prefix_len     # tenant prefix on every prompt
    if open_loop:                      # must hold the long-prompt mode
        max_len = args.long_len + args.new_tokens + args.prefill_bucket
    if scoring:                        # score_len: no KV growth at all
        max_len = min(args.prompt_len, arch.cfg.max_pos)
    if encdec:                         # decoder budget <= max_target
        max_len = min(max_len, arch.cfg.max_target)
    max_len = -(-max_len // args.block_size) * args.block_size

    # bursty-long keeps budgets uniformly LONG (that is the stranding
    # shape); the other workloads mix budgets down to 25%
    min_new_frac = 0.75 if bursty else 0.25

    def mk_workload(seed):
        def workload():
            return synthetic_requests(
                args.requests, arch.cfg.vocab, prompt_len=args.prompt_len,
                new_tokens=args.new_tokens, seed=seed,
                min_new_frac=min_new_frac, shared_prefix=prefix)
        return workload

    summary = {"workload": args.workload, "arch": arch_name}
    if bursty:
        results, gates = run_bursty_long(arch, params, args, mk_workload,
                                         max_len)
    elif open_loop:
        results, gates = run_open_loop(arch, params, args, max_len)
    elif low_entropy:
        results, gates = run_low_entropy(arch, params, args, max_len)
    elif routed:
        results, gates = run_multi_tenant_routed(arch, params, args,
                                                 max_len)
    elif scoring:
        results, gates = run_bert_scoring(arch, params, args, max_len)
    elif encdec:
        results, gates = run_encdec(arch, params, args, max_len)
    else:
        mk = (arch, params, mk_workload(args.seed), args, max_len)
        if shared:
            runners = {
                "dense": make_continuous(*mk, cache="dense"),
                "paged": make_continuous(*mk, cache="paged", slot_factor=4),
            }
        else:
            runners = {
                "static": make_static(*mk),
                "dense": make_continuous(*mk, cache="dense"),
                "paged": make_continuous(*mk, cache="paged"),
            }
        results, rep_outputs = measure_interleaved(runners, args.reps)

        # identical tokens from every engine on EVERY measured pass (same
        # seeded workload) — scheduling and cache layout must not change
        # output, including intermittently on reused warm engines
        mismatch = sum(check_tokens(outs, "dense") for outs in rep_outputs)
        print_stats(results)
        gates = {"token_mismatches": gate(mismatch, 0, op="<=")}
        if shared:
            gates["concurrency_ratio"] = gate(
                results["paged"]["max_concurrent"]
                / max(results["dense"]["max_concurrent"], 1), 2.0)
        else:
            gates["speedup_vs_static"] = gate(
                results["paged"]["tokens_per_s"]
                / max(results["static"]["tokens_per_s"], 1e-9),
                args.static_tol)
            gates["paged_vs_dense"] = gate(
                results["paged"]["tokens_per_s"]
                / max(results["dense"]["tokens_per_s"], 1e-9),
                args.paged_tol)
            gates["continuous_steps_vs_static"] = gate(
                results["paged"]["decode_steps"],
                results["static"]["decode_steps"], op="<=")

    ok = all(g["pass"] for g in gates.values())
    summary["gates"] = gates
    summary["engines"] = {
        name: {k: round(v, 3) if isinstance(v, float) else v
               for k, v in s.items()}
        for name, s in results.items()}
    summary["pass"] = ok
    blob = json.dumps(summary)
    print(blob)
    if args.json_out:
        with open(args.json_out, "w") as f:
            f.write(blob + "\n")
    print("PASS" if ok else "FAIL")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

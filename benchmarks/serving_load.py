"""Serving load generator: continuous batching vs. the static baseline.

Builds a heterogeneous request workload (mixed prompt lengths and
generation budgets — the traffic shape a real endpoint sees), then drives
it through both engines at the same slot/batch size:

  static      ServeEngine: requests grouped into waves of --max-batch,
              each wave padded to its longest prompt and decoded lockstep
              for the wave's LONGEST generation budget — short requests
              burn decode steps they don't need, and wave k+1 waits for
              all of wave k.
  continuous  ContinuousEngine: a slot frees the moment its request
              finishes and is refilled from the queue between decode
              steps, so the pool stays full and total decode steps track
              sum(tokens)/slots instead of waves * max(budget).

Both engines share one jitted decode step, precision policy and exact
left-pad masking, so the comparison is pure scheduling. Reports tokens/s
and p50/p99 time-to-first-token / inter-token latency per engine (after a
compile warmup pass), plus the decode-step counts that explain the gap.

  PYTHONPATH=src python -m benchmarks.serving_load \\
      [--arch gemma2-2b] [--requests 24] [--max-batch 4] [--precision bf16]

Runs on CPU in under a minute at the defaults. PASS: the continuous
engine's throughput >= the static baseline's on the same workload.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import reduced_arch
from repro.serving import ContinuousEngine, ServeEngine, synthetic_requests
from repro.serving.metrics import aggregate


def run_static(arch, params, reqs, args, max_len):
    engine = ServeEngine(arch, params, max_len=max_len,
                         policy=args.precision)
    steps = 0
    t0 = time.perf_counter()
    for r in reqs:             # the whole workload is waiting from t0:
        r.trace.mark_submit()  # TTFT must include the inter-wave queue wait
    for i in range(0, len(reqs), args.max_batch):
        wave = reqs[i:i + args.max_batch]
        engine.run_batch(wave)
        steps += max(r.max_new_tokens for r in wave)
    dt = time.perf_counter() - t0
    stats = aggregate([r.trace for r in reqs], dt,
                      sum(len(r.generated) for r in reqs))
    stats["decode_steps"] = steps
    return stats, reqs


def run_continuous(arch, params, reqs, args, max_len):
    engine = ContinuousEngine(
        arch, params, max_batch=args.max_batch, max_len=max_len,
        policy=args.precision, prefill_bucket=args.prefill_bucket)
    t0 = time.perf_counter()
    engine.run(reqs)
    return engine.report(time.perf_counter() - t0), reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--prefill-bucket", type=int, default=8)
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "bf16_compute", "fp16"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = reduced_arch(args.arch)
    if arch.kind != "decoder":
        raise SystemExit(f"{args.arch} is {arch.kind}: no decode step")
    params = arch.init(jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.new_tokens + args.prefill_bucket

    def workload():
        return synthetic_requests(
            args.requests, arch.cfg.vocab, prompt_len=args.prompt_len,
            new_tokens=args.new_tokens, seed=args.seed, min_new_frac=0.25)

    results, outputs = {}, {}
    for name, runner in [("static", run_static),
                         ("continuous", run_continuous)]:
        runner(arch, params, workload(), args, max_len)   # compile warmup
        results[name], outputs[name] = runner(
            arch, params, workload(), args, max_len)

    # identical tokens from both engines (same seeded workload) —
    # scheduling must not change output
    mismatch = sum(not np.array_equal(x.generated, y.generated)
                   for x, y in zip(outputs["static"], outputs["continuous"]))

    for name, s in results.items():
        print(f"{name:>10}: {s['tokens_per_s']:8.1f} tok/s | "
              f"ttft p50 {s['ttft_p50_ms']:7.2f} ms p99 "
              f"{s['ttft_p99_ms']:7.2f} ms | itl p50 "
              f"{s['itl_p50_ms']:6.2f} ms p99 {s['itl_p99_ms']:6.2f} ms | "
              f"decode steps {s['decode_steps']}")
    speedup = (results["continuous"]["tokens_per_s"]
               / max(results["static"]["tokens_per_s"], 1e-9))
    ok = speedup >= 1.0 and mismatch == 0
    print(json.dumps({
        "speedup": round(speedup, 3), "token_mismatches": mismatch,
        "static": {k: round(v, 3) for k, v in results["static"].items()},
        "continuous": {k: round(v, 3)
                       for k, v in results["continuous"].items()},
        "pass": ok,
    }))
    print("PASS" if ok else "FAIL")


if __name__ == "__main__":
    main()

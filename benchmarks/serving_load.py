"""Serving load generator: paged vs dense pools, continuous vs static.

Two workloads:

  mixed          (default) heterogeneous prompt lengths and generation
                 budgets with NO common prefix — the traffic shape where
                 paging buys nothing, used as the regression gate: the
                 paged pool must not cost throughput against the dense
                 pool (>= --paged-tol x dense tokens/s), and the
                 continuous engine must beat the static waves baseline.
  shared-prefix  every request carries the same --prefix-len system
                 prompt plus a short unique tail — the "millions of users,
                 one system prompt" shape. The paged pool is given the
                 SAME arena memory as the dense pool (slots_budget =
                 --max-batch) but 4x the decode slots, and must sustain
                 >= 2x the dense pool's peak concurrency by storing the
                 shared prefix blocks once (refcounted, copy-free).

Every engine pair runs the byte-identical seeded workload and must emit
identical tokens per request — scheduling and cache layout must never
change output (the differential property tests/test_serving_engine.py
locks down; the benchmark re-checks it end to end). Reports tokens/s,
p50/p99 TTFT / inter-token latency, decode-step counts, peak concurrency
and shared-block hits, all measured on WARM engines (compiles cached)
with interleaved best-of passes — see measure_interleaved.

  PYTHONPATH=src python -m benchmarks.serving_load                # mixed
  PYTHONPATH=src python -m benchmarks.serving_load --workload shared-prefix

Runs on CPU in a few minutes at the defaults. PASS (mixed): zero token
mismatches, paged >= --paged-tol x dense tokens/s, continuous >=
--static-tol x static tokens/s, AND the deterministic scheduling claim —
the continuous engine finishes the workload in no more decode steps than
the static waves burn (slots refill instead of idling until the wave's
longest budget). At the reduced CPU scale a decode step costs ~1 ms, so
wall-clock ratios are dispatch-overhead-bound and carry wide error bars
(hence the tolerances); the step-count gate is exact. PASS
(shared-prefix): paged peak concurrency >= 2x dense at equal arena
memory, zero mismatches.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import reduced_arch
from repro.serving import (ContinuousEngine, ServeEngine, Sampler,
                           synthetic_requests)
from repro.serving.metrics import aggregate


def make_static(arch, params, workload, args, max_len):
    """Returns a measured-pass closure over ONE persistent engine, so jit
    tracing and XLA compiles never land inside the measured wall clock
    (each engine instance owns its jit caches — a fresh engine would
    recompile)."""
    engine = ServeEngine(arch, params, max_len=max_len,
                         policy=args.precision, sampler=args.sampler)

    def one():
        reqs = workload()
        steps = 0
        t0 = time.perf_counter()
        for r in reqs:         # the whole workload is waiting from t0:
            r.trace.mark_submit()  # TTFT includes the inter-wave queue wait
        for i in range(0, len(reqs), args.max_batch):
            wave = reqs[i:i + args.max_batch]
            engine.run_batch(wave)
            # decode-step INVOCATIONS, comparable to ContinuousEngine's
            # steps_run: the wave's first token comes from prefill
            steps += max(r.max_new_tokens for r in wave) - 1
        dt = time.perf_counter() - t0
        stats = aggregate([r.trace for r in reqs], dt,
                          sum(len(r.generated) for r in reqs))
        stats["decode_steps"] = steps
        return stats, reqs

    return one


def make_continuous(arch, params, workload, args, max_len, *, cache,
                    slot_factor=1):
    engine = ContinuousEngine(
        arch, params, max_batch=slot_factor * args.max_batch,
        max_len=max_len, policy=args.precision,
        prefill_bucket=args.prefill_bucket, cache=cache,
        block_size=args.block_size, slots_budget=args.max_batch,
        sampler=args.sampler)

    def one():
        reqs = workload()
        steps0 = engine.steps_run
        t0 = time.perf_counter()
        engine.run(reqs)
        dt = time.perf_counter() - t0
        stats = aggregate([r.trace for r in reqs], dt,
                          sum(len(r.generated) for r in reqs))
        stats["decode_steps"] = engine.steps_run - steps0
        stats["max_concurrent"] = engine.max_concurrent
        if engine.paged:
            stats["shared_block_hits"] = engine.pool.shared_hits
        return stats, reqs

    return one


def measure_interleaved(runners: dict, reps: int):
    """Warm every engine first, then INTERLEAVE the measured passes
    (rep 0 of every engine, then rep 1, ...), keeping each engine's
    fastest stats. Warm passes at this reduced scale take a few hundred
    ms — the same order as container CPU noise and thermal drift — so
    measuring engines in sequential blocks systematically biases against
    whichever runs last; interleaving spreads the drift evenly and
    best-of filters the spikes. Returns every rep's outputs so the
    caller can gate token identity on ALL passes, not just the fastest.
    """
    for one in runners.values():
        one()                  # warmup: compiles cached per engine
    best = {}
    rep_outputs = []
    for _ in range(reps):
        outs = {}
        for name, one in runners.items():
            stats, reqs = one()
            outs[name] = reqs
            if (name not in best
                    or stats["tokens_per_s"] > best[name]["tokens_per_s"]):
                best[name] = stats
        rep_outputs.append(outs)
    return best, rep_outputs


def check_tokens(outputs: dict, baseline: str) -> int:
    base = outputs[baseline]
    return sum(not np.array_equal(x.generated, y.generated)
               for name, out in outputs.items() if name != baseline
               for x, y in zip(base, out))


def print_stats(results: dict):
    for name, s in results.items():
        extra = ""
        if "max_concurrent" in s:
            extra = f" | peak slots {s['max_concurrent']:3d}"
        if "shared_block_hits" in s:
            extra += f" | shared hits {s['shared_block_hits']}"
        print(f"{name:>10}: {s['tokens_per_s']:8.1f} tok/s | "
              f"ttft p50 {s['ttft_p50_ms']:7.2f} ms p99 "
              f"{s['ttft_p99_ms']:7.2f} ms | itl p50 "
              f"{s['itl_p50_ms']:6.2f} ms p99 {s['itl_p99_ms']:6.2f} ms | "
              f"decode steps {s['decode_steps']}{extra}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=["mixed", "shared-prefix"],
                    default="mixed")
    ap.add_argument("--arch", default=None,
                    help="default: gemma2-2b (mixed) / qwen2.5-14b "
                         "(shared-prefix: full attention, so every layer "
                         "type dedups — sliding-window rings stop sharing "
                         "once decode wraps them)")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--prefix-len", type=int, default=32,
                    help="shared system-prompt tokens (shared-prefix)")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--prefill-bucket", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--paged-tol", type=float, default=0.75,
                    help="mixed PASS gate: paged tokens/s >= tol x dense "
                         "(block-table gather + arena inserts cost ~10-20% "
                         "against per-slot rows when nothing is shared; "
                         "the pool buys memory/concurrency, not raw step "
                         "latency — a real regression like a per-step "
                         "recompile shows up as 0.1-0.3x)")
    ap.add_argument("--static-tol", type=float, default=0.7,
                    help="mixed PASS gate: continuous tokens/s >= tol x "
                         "static (at reduced scale admission dispatch "
                         "costs ~ the decode steps it saves; the exact "
                         "scheduling win is gated on decode-step counts "
                         "instead)")
    ap.add_argument("--reps", type=int, default=5,
                    help="measured passes per engine (after warmup); the "
                         "fastest is reported")
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "bf16_compute", "fp16"])
    ap.add_argument("--sampler", default=None,
                    help="optional sampler spec (default greedy)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    args.sampler = Sampler.parse(args.sampler)

    shared = args.workload == "shared-prefix"
    arch_name = args.arch or ("qwen2.5-14b" if shared else "gemma2-2b")
    arch = reduced_arch(arch_name)
    if arch.kind != "decoder":
        raise SystemExit(f"{arch_name} is {arch.kind}: no decode step")
    params = arch.init(jax.random.PRNGKey(args.seed))

    if shared:
        prompt_len, prefix, new_tokens = 8, args.prefix_len, 8
    else:
        prompt_len, prefix, new_tokens = args.prompt_len, 0, args.new_tokens
    max_len = prefix + prompt_len + new_tokens + args.prefill_bucket
    max_len = -(-max_len // args.block_size) * args.block_size

    def workload():
        return synthetic_requests(
            args.requests, arch.cfg.vocab, prompt_len=prompt_len,
            new_tokens=new_tokens, seed=args.seed, min_new_frac=0.25,
            shared_prefix=prefix)

    mk = (arch, params, workload, args, max_len)
    if shared:
        runners = {
            "dense": make_continuous(*mk, cache="dense"),
            "paged": make_continuous(*mk, cache="paged", slot_factor=4),
        }
    else:
        runners = {
            "static": make_static(*mk),
            "dense": make_continuous(*mk, cache="dense"),
            "paged": make_continuous(*mk, cache="paged"),
        }
    results, rep_outputs = measure_interleaved(runners, args.reps)

    # identical tokens from every engine on EVERY measured pass (same
    # seeded workload) — scheduling and cache layout must not change
    # output, including intermittently on reused warm engines
    mismatch = sum(check_tokens(outs, "dense") for outs in rep_outputs)
    print_stats(results)

    summary = {"workload": args.workload, "arch": arch_name,
               "token_mismatches": mismatch}
    if shared:
        ratio = (results["paged"]["max_concurrent"]
                 / max(results["dense"]["max_concurrent"], 1))
        ok = ratio >= 2.0 and mismatch == 0
        summary["concurrency_ratio"] = round(ratio, 3)
    else:
        speedup = (results["paged"]["tokens_per_s"]
                   / max(results["static"]["tokens_per_s"], 1e-9))
        paged_vs_dense = (results["paged"]["tokens_per_s"]
                          / max(results["dense"]["tokens_per_s"], 1e-9))
        fewer_steps = (results["paged"]["decode_steps"]
                       <= results["static"]["decode_steps"])
        ok = (speedup >= args.static_tol
              and paged_vs_dense >= args.paged_tol
              and fewer_steps and mismatch == 0)
        summary["speedup_vs_static"] = round(speedup, 3)
        summary["paged_vs_dense"] = round(paged_vs_dense, 3)
        summary["continuous_fewer_steps"] = fewer_steps
    summary.update({name: {k: round(v, 3) for k, v in s.items()}
                    for name, s in results.items()})
    summary["pass"] = ok
    print(json.dumps(summary))
    print("PASS" if ok else "FAIL")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Table 2 reproduction (CPU scale): LANS converges at a large-batch
learning rate where LAMB degrades/diverges.

The paper's Table 2: at batch 96K/33K (4301 steps), LAMB diverges while
LANS reaches F1 90.60. The scale-faithful analogue here: a reduced BERT
on the synthetic MLM corpus with an aggressive eta — we report final
losses for LANS vs LAMB under the identical schedule and data stream.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_arch
from repro.core.optim import apply_updates, lamb, lans
from repro.core.schedules import warmup_hold_decay
from repro.data.corpus import SyntheticCorpus, mlm_batch_iterator
from repro.data.sharding import ShardSpec

STEPS = 25
ETA = 0.2  # hostile: far above the stable LR for this toy setup


def _run(tx, seed=0):
    arch = reduced_arch("bert-large")
    corpus = SyntheticCorpus(vocab=arch.cfg.vocab, num_docs=512, doc_len=256,
                             seed=seed)
    spec = ShardSpec(num_samples=512, num_workers=1, worker=0, seed=seed)
    data = mlm_batch_iterator(corpus, spec, per_worker_batch=8, seq_len=64,
                              seed=seed)
    params = arch.init(jax.random.PRNGKey(seed))
    st = tx.init(params)

    @jax.jit
    def step(params, st, batch):
        (l, _), g = jax.value_and_grad(arch.loss_fn, has_aux=True)(params, batch)
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        upd, st = tx.update(g, st, params)
        return apply_updates(params, upd), st, l

    losses = []
    for _ in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, st, l = step(params, st, batch)
        losses.append(float(l))
    return losses


def run():
    """Directional claim, seed-averaged: at a hostile eta LANS stays finite
    and accumulates no more loss than LAMB (10% tolerance). A 2-layer CPU
    BERT cannot reproduce the paper's outright LAMB divergence, and single
    seeds are noisy at this scale — hence 2 seeds + summed-loss ordering."""
    sched = warmup_hold_decay(ETA, STEPS + 1, max(1, STEPS // 4),
                              STEPS // 3)
    t0 = time.perf_counter()
    sums = {"lans": [], "lamb": []}
    finite = {"lans": True, "lamb": True}
    for seed in (0, 1):
        for name, txf in (("lans", lans), ("lamb", lamb)):
            losses = _run(txf(sched), seed=seed)
            finite[name] &= bool(np.isfinite(losses).all())
            sums[name].append(float(np.sum(np.minimum(losses, 1e4))))
    dt = (time.perf_counter() - t0) * 1e6
    lans_total = float(np.mean(sums["lans"]))
    lamb_total = float(np.mean(sums["lamb"]))

    rows = [
        ("table2/lans_loss_sum", dt / 4,
         f"{lans_total:.1f} over {STEPS} steps x 2 seeds @ eta={ETA} "
         f"(finite={finite['lans']})"),
        ("table2/lamb_loss_sum", dt / 4,
         f"{lamb_total:.1f} over {STEPS} steps x 2 seeds @ eta={ETA} "
         f"(finite={finite['lamb']})"),
        ("table2/verdict", 0.0,
         "LANS finite and no worse than LAMB under hostile LR"
         if finite["lans"] and lans_total <= lamb_total * 1.10
         else "UNEXPECTED"),
    ]
    ok = finite["lans"] and lans_total <= lamb_total * 1.10
    return rows, ok

"""§3.4 reproduction: gradient-estimate variance of sharded
without-replacement sampling vs with-replacement sampling.

Theory: with replacement Var ~ sigma^2/k; without replacement
Var ~ (n-k)/(k(n-1)) sigma^2 — strictly smaller, reaching 0 at k = n.
We measure the variance of the mini-batch MEAN of a fixed population
(the scalar proxy for the gradient) across many resamples.
"""
import time

import numpy as np

from repro.data.sharding import (ShardSpec, minibatches,
                                 with_replacement_batch)


def run():
    t0 = time.perf_counter()
    rng = np.random.default_rng(0)
    n = 4096
    population = rng.normal(size=n)
    sigma2 = population.var()
    rows = []
    ok = True
    for k in (64, 1024, 4096):
        # with replacement
        wr = [population[with_replacement_batch(rng, n, k)].mean()
              for _ in range(400)]
        var_wr = np.var(wr)
        # sharded without replacement: one global batch = union of worker
        # batches; vary epoch to resample.
        workers = 8
        per = k // workers
        wo = []
        for epoch in range(400):
            idx = []
            for w in range(workers):
                spec = ShardSpec(num_samples=n, num_workers=workers,
                                 worker=w, seed=epoch)
                it = minibatches(spec, per_worker_batch=per)
                idx.extend(next(it).tolist())
            wo.append(population[idx].mean())
        var_wo = np.var(wo)

        bound_wr = sigma2 / k
        bound_wo = (n - k) / (k * (n - 1)) * sigma2
        rows.append((
            f"sharding_variance/k={k}", (time.perf_counter() - t0) * 1e6 / 3,
            f"with-repl {var_wr:.2e} (bound {bound_wr:.2e})  "
            f"sharded {var_wo:.2e} (bound {bound_wo:.2e})",
        ))
        # sharded variance must respect its (smaller) bound scale; at k=n
        # it must collapse to ~0.
        ok &= var_wo <= 3.0 * max(bound_wo, 1e-12)
    ok &= rows and True
    return rows, bool(ok)

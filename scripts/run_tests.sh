#!/usr/bin/env bash
# Tier-1 verify — the exact command ROADMAP.md pins:
#   PYTHONPATH=src python -m pytest -x -q
# (pytest.ini deselects tests marked `slow` by default.)
#
#   scripts/run_tests.sh --all      # include the slow serving matrices
#   scripts/run_tests.sh --paged    # only the paged-cache/allocator suite
#   scripts/run_tests.sh --sched    # scheduler/lazy-growth/preemption suite
#   scripts/run_tests.sh --chunked  # chunked-prefill admission + open-loop
#   scripts/run_tests.sh --spec     # speculative decode / rollback / wrap-COW
#   scripts/run_tests.sh --sharded  # mesh serving differentials on 2
#                                   # simulated host devices (sets XLA_FLAGS)
#   scripts/run_tests.sh --bert     # BERT scoring/embedding family suite
#   scripts/run_tests.sh --encdec   # encoder-decoder family / cross-arena
#   scripts/run_tests.sh --kernels  # Pallas kernel suite + bench smoke
#                                   # (kernel_throughput --iters 1), so a
#                                   # kernel regression fails fast
#   scripts/run_tests.sh --docs     # smoke-check docs/README code fences
#   scripts/run_tests.sh --durations-report [out.json]
#                                   # tier-1 run that also writes per-suite
#                                   # wall-clock timings as JSON (default
#                                   # test_durations.json) via the conftest
#                                   # REPRO_DURATIONS_JSON plugin
#
# Optional test extras (requirements.txt): `hypothesis` enables
# tests/test_properties.py and tests/test_serving_properties.py, which
# otherwise skip cleanly at collection. The core library itself needs only
# jax + numpy (baked into the image).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--all" ]]; then
  shift
  exec python -m pytest -x -q -m "" "$@"
fi
if [[ "${1:-}" == "--paged" ]]; then
  shift
  exec python -m pytest -x -q -m "paged" "$@"
fi
if [[ "${1:-}" == "--sched" ]]; then
  shift
  exec python -m pytest -x -q -m "sched" "$@"
fi
if [[ "${1:-}" == "--chunked" ]]; then
  shift
  exec python -m pytest -x -q -m "chunked" "$@"
fi
if [[ "${1:-}" == "--spec" ]]; then
  shift
  exec python -m pytest -x -q -m "spec" "$@"
fi
if [[ "${1:-}" == "--sharded" ]]; then
  shift
  # two simulated host CPU devices; must be set before jax initializes
  export XLA_FLAGS="--xla_force_host_platform_device_count=2${XLA_FLAGS:+ $XLA_FLAGS}"
  exec python -m pytest -x -q -m "sharded" "$@"
fi
if [[ "${1:-}" == "--bert" ]]; then
  shift
  exec python -m pytest -x -q -m "bert" "$@"
fi
if [[ "${1:-}" == "--encdec" ]]; then
  shift
  exec python -m pytest -x -q -m "encdec" "$@"
fi
if [[ "${1:-}" == "--kernels" ]]; then
  shift
  python -m pytest -x -q -m "kernels" "$@"
  exec python -m benchmarks.kernel_throughput --iters 1
fi
if [[ "${1:-}" == "--docs" ]]; then
  shift
  exec python -m pytest -x -q tests/test_docs.py "$@"
fi
if [[ "${1:-}" == "--durations-report" ]]; then
  shift
  out="${1:-test_durations.json}"
  [[ $# -gt 0 ]] && shift
  export REPRO_DURATIONS_JSON="$out"
  status=0
  python -m pytest -x -q "$@" || status=$?
  echo "per-suite durations written to $out"
  exit "$status"
fi
exec python -m pytest -x -q "$@"

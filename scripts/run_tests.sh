#!/usr/bin/env bash
# Tier-1 verify — the exact command ROADMAP.md pins:
#   PYTHONPATH=src python -m pytest -x -q
#
# Optional test extras (requirements.txt): `hypothesis` enables
# tests/test_properties.py, which otherwise skips cleanly at collection.
# The core library itself needs only jax + numpy (baked into the image).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"

"""Render the §Roofline and fit tables into EXPERIMENTS.md from the
dry-run JSONs. Idempotent: replaces the <!-- ROOFLINE_TABLE --> and
<!-- FIT_TABLE --> markers (or previously rendered blocks)."""
import re
import sys

sys.path.insert(0, "src")

from benchmarks.roofline_report import load_records  # noqa: E402
from repro.launch.mesh import HBM_BYTES  # noqa: E402

BEGIN_R, END_R = "<!-- roofline:begin -->", "<!-- roofline:end -->"
BEGIN_F, END_F = "<!-- fit:begin -->", "<!-- fit:end -->"


def roofline_md(recs, mesh="pod1"):
    lines = ["| arch | shape | compute_s | memory_s | coll_s | bound | useful | temp_GB |",
             "|---|---|---:|---:|---:|---|---:|---:|"]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip: {r['reason'][:40]} | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        t = r["roofline"]
        temp = r["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{t['dominant'].replace('_s','')} | "
            f"{r.get('useful_flops_ratio', 0):.2f} | {temp:.1f} |")
    return "\n".join(lines)


def fit_md(recs, mesh="pod1"):
    lines = ["| arch/shape | args+temp GB | fits 16 GiB? |",
             "|---|---:|---|"]
    for r in recs:
        if r.get("mesh") != mesh or r["status"] != "ok":
            continue
        m = r["memory_analysis"]
        tot = (m.get("temp_size_in_bytes", 0)
               + m.get("argument_size_in_bytes", 0)) / 2**30
        fits = "yes" if tot * 2**30 <= HBM_BYTES else "**no**"
        lines.append(f"| {r['arch']}/{r['shape']} | {tot:.1f} | {fits} |")
    return "\n".join(lines)


def splice(text, begin, end, marker, block):
    block = f"{begin}\n{block}\n{end}"
    if begin in text:
        return re.sub(re.escape(begin) + r".*?" + re.escape(end), block,
                      text, flags=re.S)
    return text.replace(marker, block)


def main():
    recs = load_records()
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = splice(text, BEGIN_R, END_R, "<!-- ROOFLINE_TABLE -->",
                  roofline_md(recs))
    text = splice(text, BEGIN_F, END_F, "<!-- FIT_TABLE -->", fit_md(recs))
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()

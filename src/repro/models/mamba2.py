"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm, the TPU-friendly form: the sequence is split into
chunks of length ``chunk``; within a chunk the recurrence is computed in its
dual "attention-like" quadratic form (dense matmuls -> MXU), and a
`lax.scan` over chunks carries the (heads, dstate, headdim) state — the same
decomposition the paper uses to get matmul-dominated FLOPs.

Decode (S == 1) takes the pure recurrent path with an explicit SSM + conv
state cache: O(1) per token, which is what makes long_500k tractable for the
SSM/hybrid archs. Under the paged serving pool
(serving/cache_pool.PagedCachePool) this state stays SLOT-RESIDENT: unlike
attention KV it does not grow with sequence length — one (H, N, P) state
plus a (W-1, C) conv tail per slot regardless of prompt size — so block
paging would add table indirection for zero memory win, and a shared
prompt prefix cannot be shared anyway (the recurrent state after the
prefix is numerically folded into one tensor, not addressable rows).
Hybrid archs therefore page their attention slots and scatter/gather
mamba state by batch row exactly as the dense pool does.

Layer anatomy (faithful to Mamba-2):
  in_proj -> [z (gate), x, B, C, dt]; causal depthwise conv over (x, B, C);
  dt = softplus(dt + dt_bias); a_t = exp(dt * -exp(A_log));
  h_t = a_t h_{t-1} + dt_t * (B_t ⊗ x_t); y_t = C_t · h_t + D * x_t;
  out = out_proj(RMSNorm(y * silu(z))).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import (dense_apply, dense_init, maybe_constrain,
                                 rmsnorm_apply, rmsnorm_init)


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_inner: int          # typically 2 * d_model
    headdim: int = 64
    dstate: int = 128
    ngroups: int = 1
    conv_width: int = 4
    chunk: int = 64

    @property
    def nheads(self) -> int:
        assert self.d_inner % self.headdim == 0
        return self.d_inner // self.headdim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.ngroups * self.dstate


def mamba_init(rng, cfg: MambaConfig, dtype=jnp.float32):
    ks = jax.random.split(rng, 5)
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.ngroups * cfg.dstate + cfg.nheads
    p = {
        "in_proj": dense_init(ks[0], cfg.d_model, d_in_proj, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, cfg.conv_channels))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((cfg.conv_channels,), dtype),
        "A_log": jnp.log(jnp.arange(1, cfg.nheads + 1, dtype=jnp.float32)).astype(dtype),
        "dt_bias": jnp.zeros((cfg.nheads,), dtype),
        "D": jnp.ones((cfg.nheads,), dtype),
        "norm": rmsnorm_init(cfg.d_inner, dtype),
        "out_proj": dense_init(ks[2], cfg.d_inner, cfg.d_model, dtype=dtype),
    }
    return p


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv, x: (B, S, C), w: (W, C). Returns (y, new_state).

    conv_state: (B, W-1, C) trailing inputs from the previous call (decode).
    """
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    y = jax.nn.silu(y + b[None, None, :])
    new_state = xp[:, -(W - 1):, :]
    return y, new_state


def _ssd_chunked(xh, dt, a_log_t, Bm, Cm, cfg: MambaConfig, h0=None):
    """Chunked SSD.

    xh:    (B, S, H, P)   inputs per head (P = headdim)
    dt:    (B, S, H)      positive step sizes
    a_log_t: (B, S, H)    log decay = dt * A  (negative)
    Bm,Cm: (B, S, G, N)   input/output projections (N = dstate)
    h0:    (B, H, N, P)   initial state or None
    Returns (y: (B,S,H,P), h_final).
    """
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    L = cfg.chunk
    assert S % L == 0, (S, L)
    nc = S // L
    rep = H // G

    # reshape to chunks: (B, nc, L, ...)
    xc = xh.reshape(B, nc, L, H, P)
    dtc = dt.reshape(B, nc, L, H)
    alc = a_log_t.reshape(B, nc, L, H)
    Bc = Bm.reshape(B, nc, L, G, N)
    Cc = Cm.reshape(B, nc, L, G, N)

    cum = jnp.cumsum(alc, axis=2)                       # (B, nc, L, H) inclusive
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,L(t),L(s),H)
    causal = jnp.tril(jnp.ones((L, L), bool))
    # mask BEFORE exp: masked (t < s) entries have seg > 0 and can overflow
    # to inf; exp-then-where leaks NaN into the BACKWARD pass (0 * inf).
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)

    Bg = jnp.repeat(Bc, rep, axis=3)  # (B,nc,L,H,N)
    Cg = jnp.repeat(Cc, rep, axis=3)

    # Intra-chunk (dual quadratic form): scores[t,s] = (C_t.B_s) decay dt_s
    scores = jnp.einsum("bclhn,bcshn->bclsh", Cg, Bg) * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", scores, xc)

    # Per-chunk aggregated state contribution and total decay.
    chunk_decay = jnp.exp(cum[:, :, -1:, :] - cum)       # exp(sum_after_s)
    states = jnp.einsum("bclh,bclhn,bclhp->bchnp",
                        chunk_decay * dtc, Bg, xc)       # (B,nc,H,N,P)
    total_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)

    h_init = (jnp.zeros((B, H, N, P), xh.dtype) if h0 is None
              else h0.astype(xh.dtype))

    def chunk_step(h, inp):
        st, td = inp  # (B,H,N,P), (B,H)
        h_new = h * td[..., None, None] + st
        return h_new, h  # emit PRE-chunk state for inter-chunk output

    xs = (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total_decay, 1, 0))
    h_final, h_prevs = jax.lax.scan(chunk_step, h_init, xs)
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # (B,nc,H,N,P)

    # Inter-chunk: y_t += C_t · (exp(cum_t) * h_prev_chunk)
    in_decay = jnp.exp(cum)                              # (B,nc,L,H)
    y_inter = jnp.einsum("bclhn,bchnp->bclhp", Cg * in_decay[..., None], h_prevs)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y, h_final


def mamba_apply(p, cfg: MambaConfig, x, *, cache=None, valid=None,
                compute_dtype=jnp.bfloat16):
    """x: (B, S, d_model). cache: dict(ssm, conv, index) for decode.

    valid: optional (B, S) bool — False positions (left-padding in a batched
    prefill) are neutralized so they cannot leak into the recurrent state:
    their conv inputs are zeroed (matching the zero history a pad-free run
    sees) and their dt is forced to 0, which makes the SSM update an exact
    identity (decay exp(0)=1, input contribution dt*B*x = 0). Outputs at
    invalid positions are garbage and must be masked downstream.

    Returns (out, new_cache_or_None).
    """
    B, S, _ = x.shape
    H, P, G, N = cfg.nheads, cfg.headdim, cfg.ngroups, cfg.dstate

    proj = dense_apply(p["in_proj"], x, compute_dtype)
    z, xr, Bm, Cm, dt = jnp.split(
        proj,
        [cfg.d_inner, 2 * cfg.d_inner, 2 * cfg.d_inner + G * N,
         2 * cfg.d_inner + 2 * G * N],
        axis=-1)

    conv_in = jnp.concatenate([xr, Bm, Cm], axis=-1)
    if valid is not None:
        conv_in = conv_in * valid[..., None].astype(conv_in.dtype)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(
        conv_in, p["conv_w"].astype(compute_dtype), p["conv_b"].astype(compute_dtype),
        conv_state)
    xr, Bm, Cm = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    if valid is not None:
        dt = dt * valid[..., None].astype(dt.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))         # (H,) negative
    a_log_t = dt * A[None, None, :]                      # (B,S,H)

    xh = xr.reshape(B, S, H, P)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)

    new_cache = None
    if cache is not None and S == 1:
        # Recurrent single-token update: h = a h + dt (B ⊗ x); y = C·h.
        h = cache["ssm"].astype(jnp.float32)             # (B,H,N,P)
        a = jnp.exp(a_log_t[:, 0, :])                    # (B,H)
        Bg = jnp.repeat(Bm[:, 0], H // G, axis=1)        # (B,H,N)
        Cg = jnp.repeat(Cm[:, 0], H // G, axis=1)
        xt = xh[:, 0].astype(jnp.float32)                # (B,H,P)
        h_new = (h * a[..., None, None]
                 + dt[:, 0, :, None, None] * Bg.astype(jnp.float32)[..., None]
                 * xt[:, :, None, :])
        # pin to the cache layout (batch over data, headdim over model) so
        # GSPMD doesn't reshard the state every token (EXPERIMENTS.md iter 4)
        h_new = maybe_constrain(h_new, "data", None, None, "model")
        y = jnp.einsum("bhn,bhnp->bhp", Cg.astype(jnp.float32), h_new)
        y = y[:, None].astype(compute_dtype)             # (B,1,H,P)
        # keep cache dtypes stable across steps (exact upcast): a bf16
        # conv tail stored into an fp32 cache would flip the cache pytree
        # dtype and force the serving decode step to recompile.
        new_cache = {"ssm": h_new,
                     "conv": new_conv.astype(cache["conv"].dtype),
                     "index": cache["index"] + 1}
    else:
        h0 = cache["ssm"] if cache is not None else None
        y, h_final = _ssd_chunked(
            xh.astype(jnp.float32), dt, a_log_t,
            Bm.astype(jnp.float32), Cm.astype(jnp.float32), cfg, h0)
        y = y.astype(compute_dtype)
        if cache is not None:
            new_cache = {"ssm": h_final,
                         "conv": new_conv.astype(cache["conv"].dtype),
                         "index": cache["index"] + S}

    y = y + p["D"].astype(compute_dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, cfg.d_inner)
    y = y * jax.nn.silu(z)
    y = rmsnorm_apply(p["norm"], y)
    out = dense_apply(p["out_proj"], y, compute_dtype)
    return out.astype(x.dtype), new_cache


def init_mamba_cache(batch: int, cfg: MambaConfig, dtype=jnp.float32):
    return {
        "ssm": jnp.zeros((batch, cfg.nheads, cfg.dstate, cfg.headdim), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_channels), dtype),
        "index": jnp.zeros((), jnp.int32),
    }

"""BERT — the paper's own pretraining workload (Devlin et al.).

Faithful to the original: post-LN encoder, learned positional + token-type
embeddings, GELU MLP with biases, MLM head (transform -> tied decoder +
output bias) and NSP head over the [CLS] pooler. The pretraining loss is
MLM cross-entropy + NSP cross-entropy, exactly what LAMB/LANS optimize.

bert_large: 24L / 1024d / 16H / ff 4096 / vocab 30522 / max_pos 512.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.attention import AttnConfig, attn_apply, attn_init
from repro.models.common import (
    dense_apply,
    dense_init,
    embed_apply,
    embed_init,
    gelu,
    layernorm_apply,
    layernorm_init,
    mlp_init,
)


@dataclasses.dataclass(frozen=True)
class BertConfig:
    name: str = "bert_large"
    n_layers: int = 24
    d_model: int = 1024
    n_heads: int = 16
    d_ff: int = 4096
    vocab: int = 30522
    max_pos: int = 512
    type_vocab: int = 2
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_heads, head_dim=self.head_dim,
            qkv_bias=True, rope=False, causal=False)


def _layer_init(rng, cfg: BertConfig):
    ks = jax.random.split(rng, 2)
    return {
        "attn": attn_init(ks[0], cfg.attn_cfg(), dtype=cfg.param_dtype),
        "attn_ln": layernorm_init(cfg.d_model, cfg.param_dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=False,
                        use_bias=True, dtype=cfg.param_dtype),
        "mlp_ln": layernorm_init(cfg.d_model, cfg.param_dtype),
    }


def bert_init(rng, cfg: BertConfig):
    ks = jax.random.split(rng, 7)
    layer_rngs = jax.random.split(ks[0], cfg.n_layers)
    return {
        "tok_embed": embed_init(ks[1], cfg.vocab, cfg.d_model, cfg.param_dtype),
        "pos_embed": (jax.random.normal(ks[2], (cfg.max_pos, cfg.d_model))
                      * 0.02).astype(cfg.param_dtype),
        "type_embed": (jax.random.normal(ks[3], (cfg.type_vocab, cfg.d_model))
                       * 0.02).astype(cfg.param_dtype),
        "embed_ln": layernorm_init(cfg.d_model, cfg.param_dtype),
        "layers": jax.vmap(lambda r: _layer_init(r, cfg))(layer_rngs),
        "mlm_transform": dense_init(ks[4], cfg.d_model, cfg.d_model,
                                    use_bias=True, dtype=cfg.param_dtype),
        "mlm_ln": layernorm_init(cfg.d_model, cfg.param_dtype),
        "mlm_bias": jnp.zeros((cfg.vocab,), cfg.param_dtype),
        "pooler": dense_init(ks[5], cfg.d_model, cfg.d_model,
                             use_bias=True, dtype=cfg.param_dtype),
        "nsp_head": dense_init(ks[6], cfg.d_model, 2, use_bias=True,
                               dtype=cfg.param_dtype),
    }


def bert_encode(params, cfg: BertConfig, tokens, token_types=None,
                attn_valid_len=None, positions=None):
    """tokens (B, S) -> hidden states (B, S, d). Post-LN residual stack.

    positions (B, S) gives each token its LOCAL position (left-padded
    serving batches: pads carry pos < 0) — the position embedding is
    looked up per token and padded columns are masked out of the
    bidirectional attention, so a left-padded row's valid columns are
    bitwise the unpadded run of the same tokens at the same S. The
    default (None) keeps the training path's contiguous 0..S-1 layout.
    """
    B, S = tokens.shape
    x = embed_apply(params["tok_embed"], tokens, cfg.compute_dtype)
    if positions is None:
        x = x + params["pos_embed"].astype(cfg.compute_dtype)[None, :S]
    else:
        pos_ids = jnp.clip(positions, 0, cfg.max_pos - 1)
        x = x + jnp.take(params["pos_embed"].astype(cfg.compute_dtype),
                         pos_ids, axis=0)
    if token_types is None:
        token_types = jnp.zeros_like(tokens)
    x = x + jnp.take(params["type_embed"].astype(cfg.compute_dtype),
                     token_types, axis=0)
    x = layernorm_apply(params["embed_ln"], x)

    def layer(x, lp):
        h, _ = attn_apply(lp["attn"], cfg.attn_cfg(), x,
                          positions=positions,
                          kv_valid_len=None, compute_dtype=cfg.compute_dtype)
        x = layernorm_apply(lp["attn_ln"], x + h)
        up = dense_apply(lp["mlp"]["up"], x, cfg.compute_dtype)
        h = dense_apply(lp["mlp"]["down"], gelu(up), cfg.compute_dtype)
        x = layernorm_apply(lp["mlp_ln"], x + h)
        return x, None

    layer = jax.checkpoint(layer,
                           policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(layer, x, params["layers"])
    return x


def bert_pretrain_logits(params, cfg: BertConfig, tokens, token_types=None):
    """Returns (mlm_logits (B,S,V), nsp_logits (B,2))."""
    h = bert_encode(params, cfg, tokens, token_types)
    t = dense_apply(params["mlm_transform"], h, cfg.compute_dtype)
    t = layernorm_apply(params["mlm_ln"], gelu(t))
    mlm = jnp.einsum("bsd,vd->bsv", t.astype(cfg.compute_dtype),
                     params["tok_embed"]["embedding"].astype(cfg.compute_dtype))
    mlm = mlm.astype(jnp.float32) + params["mlm_bias"].astype(jnp.float32)
    cls = jnp.tanh(dense_apply(params["pooler"], h[:, 0], cfg.compute_dtype))
    nsp = dense_apply(params["nsp_head"], cls, cfg.compute_dtype).astype(jnp.float32)
    return mlm, nsp


def bert_serve_outputs(params, cfg: BertConfig, tokens, positions):
    """Scoring/embedding forward for the serving engine.

    tokens/positions (B, S) LEFT-padded (pads carry pos < 0, the same
    convention as decoder serving prefill). Returns
      mlm_ids (B, S) int32 — greedy masked-LM argmax per column (pad
        columns produce garbage ids; the engine slices the valid tail),
      pooled (B, d) float32 — tanh-pooled [CLS] embedding, where [CLS]
        is each row's FIRST valid column (position 0).
    One fixed-shape forward, no KV cache: a scoring slot's only state is
    its output, freed at completion.
    """
    B, S = tokens.shape
    h = bert_encode(params, cfg, tokens, positions=positions)
    t = dense_apply(params["mlm_transform"], h, cfg.compute_dtype)
    t = layernorm_apply(params["mlm_ln"], gelu(t))
    mlm = jnp.einsum("bsd,vd->bsv", t.astype(cfg.compute_dtype),
                     params["tok_embed"]["embedding"].astype(cfg.compute_dtype))
    mlm = mlm.astype(jnp.float32) + params["mlm_bias"].astype(jnp.float32)
    mlm_ids = jnp.argmax(mlm, axis=-1).astype(jnp.int32)
    # first valid column per row: argmax of the (pos >= 0) indicator
    cls_col = jnp.argmax((positions >= 0).astype(jnp.int32), axis=1)
    cls_h = h[jnp.arange(B), cls_col]
    pooled = jnp.tanh(dense_apply(params["pooler"], cls_h,
                                  cfg.compute_dtype)).astype(jnp.float32)
    return mlm_ids, pooled


def bert_pretrain_loss(params, cfg: BertConfig, batch):
    """batch: tokens, token_types, mlm_labels (-100 = unmasked), nsp_labels."""
    mlm_logits, nsp_logits = bert_pretrain_logits(
        params, cfg, batch["tokens"], batch.get("token_types"))
    labels = batch["mlm_labels"]
    valid = labels != -100
    safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(mlm_logits, axis=-1)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    mlm_loss = -jnp.sum(ll * valid) / jnp.maximum(jnp.sum(valid), 1)

    nsp_logp = jax.nn.log_softmax(nsp_logits, axis=-1)
    nsp_loss = -jnp.mean(
        jnp.take_along_axis(nsp_logp, batch["nsp_labels"][:, None], axis=-1))
    return mlm_loss + nsp_loss, {"mlm_loss": mlm_loss, "nsp_loss": nsp_loss}

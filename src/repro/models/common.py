"""Functional NN building blocks (no flax in this environment).

Params are nested dicts of jnp arrays. Every module is a pair of pure
functions: ``init_*(rng, ...) -> params`` and an apply function. Models store
master params in ``param_dtype`` (fp32 by default) and cast to
``compute_dtype`` (bf16 on TPU) at use — the mixed-precision policy the paper
trains BERT with.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def maybe_constrain(x, *spec_axes):
    """with_sharding_constraint against the AMBIENT mesh, if any.

    Models stay mesh-agnostic: under the production mesh context the
    constraint pins activation sharding (e.g. MoE expert capacity over
    "data"); in local/unmeshed runs it is a no-op. Axes missing from the
    mesh or non-divisible dims degrade to None for that dim.
    """
    from jax._src.mesh import thread_resources
    from jax.sharding import PartitionSpec

    mesh = thread_resources.env.physical_mesh
    if mesh.empty:
        return x
    names = set(mesh.axis_names)

    def ok(dim, axis):
        if axis is None:
            return None
        axes = axis if isinstance(axis, tuple) else (axis,)
        if not all(a in names for a in axes):
            return None
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        return axis if dim % size == 0 else None

    fixed = PartitionSpec(*(ok(d, a) for d, a in zip(x.shape, spec_axes)))
    return jax.lax.with_sharding_constraint(x, fixed)


def ambient_axis_size(name: str) -> int:
    """Size of a named axis in the ambient mesh (1 if absent/unmeshed)."""
    from jax._src.mesh import thread_resources

    mesh = thread_resources.env.physical_mesh
    if mesh.empty or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def dense_init(rng, in_dim: int, out_dim: int, *, use_bias: bool = False,
               dtype=jnp.float32, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    p = {"kernel": (jax.random.normal(rng, (in_dim, out_dim)) * scale).astype(dtype)}
    if use_bias:
        p["bias"] = jnp.zeros((out_dim,), dtype)
    return p


def dense_apply(p, x, compute_dtype=jnp.bfloat16):
    y = jnp.einsum("...i,io->...o", x.astype(compute_dtype),
                   p["kernel"].astype(compute_dtype))
    if "bias" in p:
        y = y + p["bias"].astype(compute_dtype)
    return y


def embed_init(rng, vocab: int, dim: int, dtype=jnp.float32):
    return {"embedding": (jax.random.normal(rng, (vocab, dim)) * 0.02).astype(dtype)}


def embed_apply(p, ids, compute_dtype=jnp.bfloat16):
    return jnp.take(p["embedding"].astype(compute_dtype), ids, axis=0)


def embed_attend(p, x, compute_dtype=jnp.bfloat16):
    """Tied-readout logits: x @ E^T."""
    return jnp.einsum("...d,vd->...v", x.astype(compute_dtype),
                      p["embedding"].astype(compute_dtype))


def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((dim,), dtype)}  # gemma-style (1+scale)


def rmsnorm_apply(p, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dtype)


def layernorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(p, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dtype)


def softcap(x, cap: Optional[float]):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# --- rotary position embeddings -------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    exps = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exps)  # (head_dim/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- activations ------------------------------------------------------------

def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


ACTIVATIONS = {"gelu": gelu, "silu": silu, "relu": jax.nn.relu}


# --- gated / plain MLP ------------------------------------------------------

def mlp_init(rng, d_model: int, d_ff: int, *, gated: bool = True,
             use_bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    p = {"up": dense_init(ks[0], d_model, d_ff, use_bias=use_bias, dtype=dtype),
         "down": dense_init(ks[1], d_ff, d_model, use_bias=use_bias, dtype=dtype)}
    if gated:
        p["gate"] = dense_init(ks[2], d_model, d_ff, use_bias=use_bias, dtype=dtype)
    return p


def mlp_apply(p, x, *, activation: str = "silu", compute_dtype=jnp.bfloat16):
    act = ACTIVATIONS[activation]
    up = dense_apply(p["up"], x, compute_dtype)
    if "gate" in p:
        up = act(dense_apply(p["gate"], x, compute_dtype)) * up
    else:
        up = act(up)
    return dense_apply(p["down"], up, compute_dtype)

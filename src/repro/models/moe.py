"""Mixture-of-Experts FFN with top-k routing (grok-1, granite-moe, jamba).

GShard/Mesh-TF style dense dispatch: tokens are routed to experts through a
capacity-bounded one-hot dispatch tensor and combined back with router
probabilities. On the production mesh the expert dimension is sharded over
the "model" axis (expert parallelism) so the two dispatch einsums lower to
all-to-all-like collectives — exactly the communication pattern MoE papers
optimize, and the place LANS's per-block trust ratios matter most (router
blocks see very different gradient scales than expert FFN blocks).

Includes the standard auxiliary load-balancing loss (Shazeer et al.) exposed
to the training loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import (ACTIVATIONS, ambient_axis_size, dense_apply,
                                 dense_init, maybe_constrain)


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    activation: str = "silu"
    gated: bool = True


def moe_init(rng, cfg: MoeConfig, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff

    def expert_stack(k, din, dout):
        # (E, din, dout) — one slab per expert, sharded over E on the mesh.
        scale = 1.0 / jnp.sqrt(din)
        return (jax.random.normal(k, (e, din, dout)) * scale).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, e, use_bias=False, dtype=jnp.float32),
        "up": expert_stack(ks[1], d, f),
        "down": expert_stack(ks[2], f, d),
    }
    if cfg.gated:
        p["gate"] = expert_stack(ks[3], d, f)
    return p


def _top_k_mask(probs: jnp.ndarray, k: int):
    """(T, E) probs -> (T, E) bool mask of the per-token top-k experts."""
    _, idx = jax.lax.top_k(probs, k)  # (T, k)
    return jax.nn.one_hot(idx, probs.shape[-1], dtype=bool).any(axis=-2)


def moe_apply(p, cfg: MoeConfig, x, *, valid=None, compute_dtype=jnp.bfloat16):
    """x: (B, S, d). Returns (out, aux) with aux = load-balance loss terms.

    valid: optional (B, S) bool — False tokens (left-padding in a batched
    serving prefill) are excluded from routing: they consume no expert
    capacity (their dispatch one-hots are zeroed before the position
    cumsum), produce zero output, and drop out of the load-balance stats,
    so real tokens route identically to a pad-free run.

    GROUP-LOCAL SCATTER DISPATCH. The classic GShard one-hot dispatch
    materializes a (T, E, C) tensor — O(T^2 K / E) memory/FLOPs, which blew
    the granite-40e configs to 5.5 TB at prefill_32k (EXPERIMENTS.md §Perf
    iteration 1). Instead:
      1. tokens are split into G groups (G = ambient "data" axis size) and
         routed group-locally — each group enforces its own capacity, which
         is exactly what per-device routing does in production MoE systems;
      2. dispatch is a scatter-add into (G, E, C_local, d) expert buffers
         and combine is a gather — O(T*K*d + G*E*C_local*d), no TEC tensor.
    Expert compute stays dense einsum (MXU): experts over "model" when
    divisible (jamba 16e), otherwise the ff dim (grok 8e, granite 40e).
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    # Groups span the FULL data-parallel extent (pod x data): using "data"
    # alone replicated all expert compute across pods (pod2 dry-run showed
    # identical per-chip FLOPs to pod1 for every MoE arch — §Perf iter 5).
    dp_axes = tuple(a for a in ("pod", "data") if ambient_axis_size(a) > 1)
    G = max(1, ambient_axis_size("pod") * ambient_axis_size("data"))
    while T % G != 0:  # tiny test shapes: fall back to fewer groups
        G //= 2
    G = max(G, 1)
    Tl = T // G
    cap = max(1, int(cfg.capacity_factor * Tl * K / E))

    xg = x.reshape(G, Tl, d)
    xg = maybe_constrain(xg, dp_axes or None, None, None)

    router_logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), p["router"]["kernel"])
    probs = jax.nn.softmax(router_logits, axis=-1)           # (G, Tl, E)

    gates_k, idx_k = jax.lax.top_k(probs, K)                 # (G, Tl, K)
    gates_k = gates_k / jnp.maximum(
        gates_k.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, k) within its expert's buffer, per group.
    sel = jax.nn.one_hot(idx_k, E, dtype=jnp.int32)          # (G, Tl, K, E)
    vflat = None
    if valid is not None:
        vg = valid.reshape(G, Tl)
        sel = sel * vg[..., None, None].astype(sel.dtype)    # pads route nowhere
        vflat = jnp.repeat(vg, K, axis=1)                    # (G, TlK)
    sel_flat = sel.reshape(G, Tl * K, E)
    position = jnp.cumsum(sel_flat, axis=1) - 1              # (G, TlK, E)
    pos_k = jnp.take_along_axis(
        position, idx_k.reshape(G, Tl * K)[..., None], axis=-1)[..., 0]
    keep = pos_k < cap                                       # (G, TlK)
    if vflat is not None:
        keep = keep & vflat
    pos_clipped = jnp.where(keep, pos_k, cap)                # overflow bucket

    # Scatter dispatch: (G, E, cap+1, d), drop the overflow bucket after.
    flat_e = idx_k.reshape(G, Tl * K)
    x_rep = jnp.repeat(xg.astype(compute_dtype), K, axis=1)  # (G, TlK, d)

    def scatter_group(xr, e_idx, p_idx):
        buf = jnp.zeros((E, cap + 1, d), compute_dtype)
        return buf.at[e_idx, p_idx].add(xr)

    xin = jax.vmap(scatter_group)(x_rep, flat_e, pos_clipped)[:, :, :cap]
    ep = E % max(ambient_axis_size("model"), 1) == 0 \
        and ambient_axis_size("model") > 1
    e_ax = "model" if ep else None
    ff_ax = None if ep else "model"
    xin = maybe_constrain(xin, dp_axes or None, e_ax, None, None)  # (G,E,cap,d)

    act = ACTIVATIONS[cfg.activation]
    up = jnp.einsum("gecd,edf->gecf", xin, p["up"].astype(compute_dtype))
    up = maybe_constrain(up, dp_axes or None, e_ax, None, ff_ax)
    if cfg.gated:
        g = jnp.einsum("gecd,edf->gecf", xin, p["gate"].astype(compute_dtype))
        g = maybe_constrain(g, dp_axes or None, e_ax, None, ff_ax)
        up = act(g) * up
    else:
        up = act(up)
    yout = jnp.einsum("gecf,efd->gecd", up, p["down"].astype(compute_dtype))
    yout = maybe_constrain(yout, dp_axes or None, e_ax, None, None)

    # Combine: gather each (token, k)'s expert output, weight, sum over K.
    yflat = yout.reshape(G, E * cap, d)
    gather_idx = jnp.minimum(flat_e * cap + jnp.minimum(pos_clipped, cap - 1),
                             E * cap - 1)
    y_tk = jnp.take_along_axis(yflat, gather_idx[..., None], axis=1)
    w = (gates_k.reshape(G, Tl * K).astype(compute_dtype)
         * keep.astype(compute_dtype))
    out = (y_tk * w[..., None]).reshape(G, Tl, K, d).sum(axis=2)
    out = out.reshape(B, S, d)

    # Aux load-balancing loss (mean gate fraction * mean dispatch fraction).
    topk_mask = sel.sum(axis=2) > 0                          # (G, Tl, E)
    density = topk_mask.astype(jnp.float32).mean(axis=(0, 1))
    density_proxy = probs.mean(axis=(0, 1))
    aux_loss = jnp.sum(density * density_proxy) * (E / K)
    return out.astype(x.dtype), {"moe_aux_loss": aux_loss,
                                 "router_entropy": -(probs * jnp.log(probs + 1e-9)).sum(-1).mean()}

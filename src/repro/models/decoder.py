"""Unified decoder-only LM covering the dense, MoE, VLM and hybrid archs.

The layer stack is described as a repeating **superblock**: a short, fixed
list of (mixer, ffn) slots. Examples:

  dense / moe     : [("attn", "mlp"|"moe")]                       x n_layers
  gemma2          : [("attn_local", "mlp"), ("attn", "mlp")]      x n_layers/2
  jamba (hybrid)  : [("attn", "moe"), ("mamba", "mlp"), ...]      x n_layers/8

Parameters for each slot are stacked over the repeat dimension and the
forward pass is a single `jax.lax.scan` over periods — one trace per slot
type regardless of depth, which keeps the HLO small enough to compile 72-layer
398B configs in the dry-run. Caches (attention KV / SSM state) are likewise
stacked per slot and threaded through the scan as xs/ys.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models.attention import AttnConfig, attn_apply, attn_init
from repro.models.common import (
    dense_apply,
    dense_init,
    embed_apply,
    embed_attend,
    embed_init,
    mlp_apply,
    mlp_init,
    rmsnorm_apply,
    rmsnorm_init,
    softcap,
)


@dataclasses.dataclass(frozen=True)
class DecoderConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # attention variants
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None       # window for "attn_local" slots
    attn_softcap: Optional[float] = None       # gemma2: 50.0
    final_softcap: Optional[float] = None      # gemma2: 30.0
    post_block_norm: bool = False              # gemma2 pre+post norms
    attn_kernel: str = "xla"                   # paged decode: "xla" | "paged"
    kernel_interpret: Optional[bool] = None    # Pallas interpret override

    # ffn
    activation: str = "silu"
    gated_mlp: bool = True

    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # mamba slots (hybrid archs)
    mamba_d_inner: Optional[int] = None
    mamba_headdim: int = 64
    mamba_dstate: int = 128
    mamba_chunk: int = 64

    # superblock: sequence of (mixer, ffn) slot descriptors.
    #   mixer in {"attn", "attn_local", "mamba"}; ffn in {"mlp", "moe"}
    superblock: Tuple[Tuple[str, str], ...] = (("attn", "mlp"),)

    tie_embeddings: bool = True
    scale_embeds: bool = False                 # gemma2: x *= sqrt(d_model)
    remat: bool = True                         # checkpoint each period in bwd
    max_seq: int = 8192
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.superblock) == 0, (
            self.n_layers, self.superblock)
        return self.n_layers // len(self.superblock)

    def attn_cfg(self, local: bool) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.resolved_head_dim,
            qkv_bias=self.qkv_bias, qk_norm=self.qk_norm, rope=True,
            rope_theta=self.rope_theta, causal=True,
            sliding_window=self.sliding_window if local else None,
            logit_softcap=self.attn_softcap,
            decode_kernel=self.attn_kernel,
            kernel_interpret=self.kernel_interpret)

    def moe_cfg(self) -> moe_lib.MoeConfig:
        return moe_lib.MoeConfig(
            d_model=self.d_model, d_ff=self.d_ff, n_experts=self.n_experts,
            top_k=self.top_k, capacity_factor=self.capacity_factor,
            activation=self.activation, gated=self.gated_mlp)

    def mamba_cfg(self) -> mamba_lib.MambaConfig:
        return mamba_lib.MambaConfig(
            d_model=self.d_model,
            d_inner=self.mamba_d_inner or 2 * self.d_model,
            headdim=self.mamba_headdim, dstate=self.mamba_dstate,
            chunk=self.mamba_chunk)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _slot_init(rng, cfg: DecoderConfig, mixer: str, ffn: str):
    ks = jax.random.split(rng, 6)
    p = {"pre_mixer_norm": rmsnorm_init(cfg.d_model, cfg.param_dtype),
         "pre_ffn_norm": rmsnorm_init(cfg.d_model, cfg.param_dtype)}
    if cfg.post_block_norm:
        p["post_mixer_norm"] = rmsnorm_init(cfg.d_model, cfg.param_dtype)
        p["post_ffn_norm"] = rmsnorm_init(cfg.d_model, cfg.param_dtype)
    if mixer in ("attn", "attn_local"):
        p["mixer"] = attn_init(ks[0], cfg.attn_cfg(mixer == "attn_local"),
                               dtype=cfg.param_dtype)
    elif mixer == "mamba":
        p["mixer"] = mamba_lib.mamba_init(ks[0], cfg.mamba_cfg(), cfg.param_dtype)
    else:
        raise ValueError(mixer)
    if ffn == "mlp":
        p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
                            dtype=cfg.param_dtype)
    elif ffn == "moe":
        p["ffn"] = moe_lib.moe_init(ks[1], cfg.moe_cfg(), cfg.param_dtype)
    elif ffn == "none":    # pure-SSM archs (mamba2): mixer-only blocks
        p.pop("pre_ffn_norm")
        if cfg.post_block_norm:
            p.pop("post_ffn_norm")
    else:
        raise ValueError(ffn)
    return p


def decoder_init(rng, cfg: DecoderConfig):
    ks = jax.random.split(rng, 2 + len(cfg.superblock))
    params = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, cfg.param_dtype),
        "final_norm": rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab,
                                       dtype=cfg.param_dtype)
    # stacked slot params: vmap init over the period dimension
    for si, (mixer, ffn) in enumerate(cfg.superblock):
        slot_rngs = jax.random.split(ks[2 + si], cfg.n_periods)
        params[f"slot{si}"] = jax.vmap(
            lambda r: _slot_init(r, cfg, mixer, ffn))(slot_rngs)
    return params


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _run_slot(slot_params, cfg: DecoderConfig, mixer: str, ffn: str, x,
              positions, cache, kv_valid_len, valid=None):
    """One (mixer, ffn) slot. cache may be None. Returns (x, new_cache, aux).

    valid: optional (B, S) bool — False marks left-padding whose state
    contributions must be suppressed (attention masks pads by their
    negative positions; mamba/moe need the explicit mask)."""
    aux = {}
    h = rmsnorm_apply(slot_params["pre_mixer_norm"], x)
    if mixer in ("attn", "attn_local"):
        out, new_cache = attn_apply(
            slot_params["mixer"], cfg.attn_cfg(mixer == "attn_local"), h,
            positions=positions, cache=cache, kv_valid_len=kv_valid_len,
            compute_dtype=cfg.compute_dtype)
    else:
        out, new_cache = mamba_lib.mamba_apply(
            slot_params["mixer"], cfg.mamba_cfg(), h, cache=cache,
            valid=valid, compute_dtype=cfg.compute_dtype)
    if cfg.post_block_norm:
        out = rmsnorm_apply(slot_params["post_mixer_norm"], out)
    x = x + out

    if ffn == "none":
        return x, new_cache, aux

    h = rmsnorm_apply(slot_params["pre_ffn_norm"], x)
    if ffn == "mlp":
        out = mlp_apply(slot_params["ffn"], h, activation=cfg.activation,
                        compute_dtype=cfg.compute_dtype)
    else:
        out, moe_aux = moe_lib.moe_apply(slot_params["ffn"], cfg.moe_cfg(), h,
                                         valid=valid,
                                         compute_dtype=cfg.compute_dtype)
        aux.update(moe_aux)
    if cfg.post_block_norm:
        out = rmsnorm_apply(slot_params["post_ffn_norm"], out)
    x = x + out
    return x, new_cache, aux


def decoder_apply(params, cfg: DecoderConfig, tokens=None, *, embeds=None,
                  positions=None, caches=None, kv_valid_len=None,
                  return_hidden=False):
    """Forward pass.

    tokens: (B, S) int32, or embeds: (B, S, d) precomputed (VLM/audio stubs).
    caches: model cache from init_decoder_cache (decode) or None (train).
    Returns (logits, new_caches, aux_dict); with return_hidden=True the
    first element is the final-norm hidden states instead (big-vocab loss
    path computes logits chunkwise — see chunked_lm_loss).
    """
    assert (tokens is None) != (embeds is None)
    if embeds is None:
        x = embed_apply(params["embed"], tokens, cfg.compute_dtype)
        if cfg.scale_embeds:
            x = x * jnp.asarray(jnp.sqrt(float(cfg.d_model)), cfg.compute_dtype)
    else:
        x = embeds.astype(cfg.compute_dtype)
    B, S = x.shape[:2]
    if positions is None:
        base = caches["index"] if caches is not None else 0
        if caches is not None and jnp.ndim(caches["index"]) == 1:
            positions = base[:, None] + jnp.arange(S)  # per-slot cursors (B, S)
        else:
            positions = base + jnp.arange(S)
    # Per-batch positions mark left-padding with negative values: attention
    # masks those keys structurally (k_pos >= 0); mamba/moe need the mask.
    valid = (positions >= 0) if jnp.ndim(positions) == 2 else None

    aux_acc = {"moe_aux_loss": jnp.zeros((), jnp.float32),
               "router_entropy": jnp.zeros((), jnp.float32)}

    # Paged serving cache: block tables are read-only in the model (the
    # host-side allocator owns them) and identical across periods, so they
    # ride into the scan as captured constants rather than scanned leaves.
    tables = caches.get("tables") if isinstance(caches, dict) else None

    def period_step(carry, xs):
        x = carry
        slot_params, slot_caches = xs
        new_caches = []
        aux_out = dict(aux_acc)
        for si, (mixer, ffn) in enumerate(cfg.superblock):
            cache_i = None
            if slot_caches is not None:
                cache_i = dict(slot_caches[si])
                cache_i["index"] = caches["index"]
                if tables is not None and tables[si] is not None:
                    cache_i["table"] = tables[si]
            x, nc, aux = _run_slot(
                slot_params[si], cfg, mixer, ffn, x, positions,
                cache_i, kv_valid_len, valid)
            if nc is not None:
                nc.pop("index")
                new_caches.append(nc)
            for k, v in aux.items():
                aux_out[k] = aux_out[k] + v
        ys = (tuple(new_caches) if new_caches else None, aux_out)
        return x, ys

    slot_param_stacks = tuple(params[f"slot{si}"]
                              for si in range(len(cfg.superblock)))
    slot_cache_stacks = None
    if caches is not None:
        slot_cache_stacks = tuple(caches["slots"][si]
                                  for si in range(len(cfg.superblock)))

    step = period_step
    if cfg.remat and caches is None:
        # full per-period rematerialization: only the carried activations
        # survive to the backward pass (the config every >10B framework uses)
        step = jax.checkpoint(period_step,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, (new_cache_stacks, aux_stacks) = jax.lax.scan(
        step, x, (slot_param_stacks, slot_cache_stacks))

    new_caches = None
    if caches is not None and new_cache_stacks is not None:
        new_caches = {"slots": tuple(new_cache_stacks),
                      "index": caches["index"] + S}
        if tables is not None:
            new_caches["tables"] = tables    # pass-through: host-owned

    aux = {k: jnp.sum(v) for k, v in aux_stacks.items()}

    x = rmsnorm_apply(params["final_norm"], x)
    if return_hidden:
        return x, new_caches, aux
    logits = _head_logits(params, cfg, x)
    return logits, new_caches, aux


def _head_logits(params, cfg: DecoderConfig, x):
    if cfg.tie_embeddings:
        logits = embed_attend(params["embed"], x, cfg.compute_dtype)
    else:
        logits = dense_apply(params["lm_head"], x, cfg.compute_dtype)
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


def init_decoder_cache(cfg: DecoderConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16, *, per_slot: bool = False,
                       clamp_window: bool = True):
    """Stacked per-slot caches. attn_local slots get ring buffers of the
    window size — the memory win that makes long_500k viable for gemma2.

    per_slot=True builds the pooled continuous-batching layout: the write
    cursor becomes (batch,) and KV positions (batch, L), so each batch slot
    carries its own local timeline (see serving/cache_pool.py).

    clamp_window=False gives attn_local slots the FULL max_len rows too —
    the chunk-resumable prefill cache: every prompt chunk then lands in
    attention's incremental write path (never the roll-on-overflow branch,
    which assumes a from-scratch prefill and cannot resume), window
    locality is enforced by the mask instead of the ring, and the
    serving pool's insert picks the window tail out of the full-length
    rows (see serving/admission.py)."""
    slots = []
    for mixer, _ in cfg.superblock:
        if mixer == "mamba":
            one = mamba_lib.init_mamba_cache(batch, cfg.mamba_cfg())
        else:
            L = max_len
            if clamp_window and mixer == "attn_local" and cfg.sliding_window:
                L = min(max_len, cfg.sliding_window)
            one = attn_lib.init_kv_cache(batch, L, cfg.n_kv_heads,
                                         cfg.resolved_head_dim, dtype,
                                         per_slot=per_slot)
        one.pop("index")
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape).copy(), one)
        slots.append(stacked)
    index = (jnp.zeros((batch,), jnp.int32) if per_slot
             else jnp.zeros((), jnp.int32))
    return {"slots": tuple(slots), "index": index}


def paged_layout(cfg: DecoderConfig, max_len: int, block_size: int,
                 row_margin: int = 0):
    """Per-superblock-slot paged layout: [(slot_idx, ring_len) | None].

    Attention slots page their KV through a block arena; the entry gives
    the slot's logical ring length (max_len, or the sliding window for
    "attn_local" slots). Mamba slots return None: their state is O(1) per
    slot (a fixed SSM tensor + conv tail), so paging buys nothing and
    they stay slot-resident (see init_paged_decoder_cache).

    row_margin > 0 widens EVERY attention ring by that many rows
    (rounded up to whole blocks): the speculative verify step scatters
    its K rows BEFORE attention runs, so the ring must hold K - 1 rows
    beyond what any live query still attends to. Sliding-window rings
    need window + K - 1 or the burst overwrites in-window keys of
    earlier query rows; full rings need max_len + K - 1 because a
    budget-truncated final round still scatters (position -1) rows up to
    cursor + K - 1, which a bare max_len ring would wrap onto the
    slot's first prompt blocks mid-verify.
    """
    margin = -(-row_margin // block_size) * block_size if row_margin else 0
    out = []
    for si, (mixer, _) in enumerate(cfg.superblock):
        if mixer == "mamba":
            out.append(None)
            continue
        L = max_len + margin
        if mixer == "attn_local" and cfg.sliding_window:
            L = min(max_len, cfg.sliding_window) + margin
        if L % block_size != 0:
            raise ValueError(
                f"slot {si} ({mixer}): cache length {L} not a multiple of "
                f"block_size {block_size}")
        out.append((si, L))
    return out


def init_paged_decoder_cache(cfg: DecoderConfig, batch: int, max_len: int,
                             *, block_size: int, n_blocks,
                             dtype=jnp.bfloat16, row_margin: int = 0):
    """Paged continuous-batching cache: block arenas + per-slot tables.

    Layout (vs the dense per_slot layout of init_decoder_cache):
      attention slots: k/v/pos become (n_periods, n_blocks, block_size,
        ...) ARENAS with no batch dim; a (batch, ring_len // block_size)
        int32 block table per slot-type (under "tables", index 0 = the
        reserved null block) maps each decode slot's logical rows onto
        arena blocks, so identical prompt prefixes are stored once and
        shared across slots.
      mamba slots: unchanged (n_periods, batch, ...) slot-resident state.
      index: (batch,) per-slot LOCAL write cursors (== tokens seen, with
        no left-pad offset — the paged chain is position-aligned).

    n_blocks: data blocks per attention arena — an int (same for every
    attention slot-type) or a dict {slot_idx: int}. One extra null block
    is always added.
    """
    layouts = paged_layout(cfg, max_len, block_size, row_margin)
    slots, tables = [], []
    for si, (mixer, _) in enumerate(cfg.superblock):
        layout = layouts[si]
        if layout is None:
            one = mamba_lib.init_mamba_cache(batch, cfg.mamba_cfg())
            one.pop("index")
            stacked = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (cfg.n_periods,) + a.shape).copy(), one)
            slots.append(stacked)
            tables.append(None)
            continue
        _, ring_len = layout
        nb = n_blocks[si] if isinstance(n_blocks, dict) else n_blocks
        one = attn_lib.init_paged_kv_cache(
            nb + 1, block_size, cfg.n_kv_heads, cfg.resolved_head_dim,
            dtype)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (cfg.n_periods,) + a.shape).copy(), one)
        slots.append(stacked)
        tables.append(jnp.zeros((batch, ring_len // block_size), jnp.int32))
    return {"slots": tuple(slots), "tables": tuple(tables),
            "index": jnp.zeros((batch,), jnp.int32)}


# --------------------------------------------------------------------------
# losses / steps
# --------------------------------------------------------------------------

def lm_loss(logits, labels, *, ignore_id: int = -100,
            moe_aux: Optional[jnp.ndarray] = None, aux_weight: float = 0.01):
    """Next-token cross entropy; labels already shifted by the data pipeline."""
    valid = labels != ignore_id
    labels_safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    loss = -jnp.sum(ll * valid) / jnp.maximum(jnp.sum(valid), 1)
    if moe_aux is not None:
        loss = loss + aux_weight * moe_aux
    return loss


def chunked_lm_loss(params, cfg: DecoderConfig, hidden, labels, *,
                    ignore_id: int = -100, chunk: int = 512,
                    moe_aux: Optional[jnp.ndarray] = None,
                    aux_weight: float = 0.01):
    """Cross entropy without materializing (B, S, V) logits.

    Scans remat'd sequence chunks: per-chunk logits peak at (B, chunk, V)
    and are recomputed in the backward pass — the memory fix that lets the
    152k/256k-vocab archs fit HBM at train_4k (see EXPERIMENTS.md §Perf).
    """
    B, S, _ = hidden.shape
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    h_chunks = hidden.reshape(B, n, chunk, -1).swapaxes(0, 1)
    l_chunks = labels.reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def one(h, lab):
        logits = _head_logits(params, cfg, h)
        valid = lab != ignore_id
        safe = jnp.where(valid, lab, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return -jnp.sum(ll * valid), jnp.sum(valid)

    def body(carry, xs):
        tot, cnt = carry
        h, lab = xs
        s, c = one(h, lab)
        return (tot + s, cnt + c), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (h_chunks, l_chunks))
    loss = total / jnp.maximum(count, 1)
    if moe_aux is not None:
        loss = loss + aux_weight * moe_aux
    return loss

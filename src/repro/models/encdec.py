"""Encoder-decoder transformer backbone (whisper-large-v3 assignment).

Whisper conventions: pre-LN LayerNorm (not RMSNorm), GELU MLP (not gated),
learned positions (no RoPE), MHA (n_kv == n_heads), QKV bias. The
mel-spectrogram + conv frontend is the allowed STUB: the model consumes
precomputed frame embeddings (B, n_frames, d_model) from input_specs().

serve_step decodes one token with (a) a self-attention KV cache and (b)
cross-attention K/V precomputed once from the encoder output.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import AttnConfig, attn_apply, attn_init, init_kv_cache
from repro.models.common import (
    dense_apply,
    dense_init,
    embed_apply,
    embed_attend,
    embed_init,
    layernorm_apply,
    layernorm_init,
    mlp_apply,
    mlp_init,
)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_layers: int           # per stack (encoder and decoder each)
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_frames: int = 1500    # whisper encoder positions
    max_target: int = 448
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def attn_cfg(self, causal: bool) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_heads, head_dim=self.head_dim,
            qkv_bias=True, rope=False, causal=causal)


def _enc_layer_init(rng, cfg: EncDecConfig):
    ks = jax.random.split(rng, 2)
    return {
        "ln1": layernorm_init(cfg.d_model, cfg.param_dtype),
        "attn": attn_init(ks[0], cfg.attn_cfg(False), dtype=cfg.param_dtype),
        "ln2": layernorm_init(cfg.d_model, cfg.param_dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=False,
                        use_bias=True, dtype=cfg.param_dtype),
    }


def _dec_layer_init(rng, cfg: EncDecConfig):
    ks = jax.random.split(rng, 3)
    return {
        "ln1": layernorm_init(cfg.d_model, cfg.param_dtype),
        "self_attn": attn_init(ks[0], cfg.attn_cfg(True), dtype=cfg.param_dtype),
        "ln_x": layernorm_init(cfg.d_model, cfg.param_dtype),
        "cross_attn": attn_init(ks[1], cfg.attn_cfg(False), cross=True,
                                dtype=cfg.param_dtype),
        "ln2": layernorm_init(cfg.d_model, cfg.param_dtype),
        "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, gated=False,
                        use_bias=True, dtype=cfg.param_dtype),
    }


def encdec_init(rng, cfg: EncDecConfig):
    ks = jax.random.split(rng, 5)
    enc_rngs = jax.random.split(ks[0], cfg.n_layers)
    dec_rngs = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_pos": (jax.random.normal(ks[2], (cfg.n_frames, cfg.d_model))
                    * 0.02).astype(cfg.param_dtype),
        "dec_embed": embed_init(ks[3], cfg.vocab, cfg.d_model, cfg.param_dtype),
        "dec_pos": (jax.random.normal(ks[4], (cfg.max_target, cfg.d_model))
                    * 0.02).astype(cfg.param_dtype),
        "enc_layers": jax.vmap(lambda r: _enc_layer_init(r, cfg))(enc_rngs),
        "dec_layers": jax.vmap(lambda r: _dec_layer_init(r, cfg))(dec_rngs),
        "enc_ln_post": layernorm_init(cfg.d_model, cfg.param_dtype),
        "dec_ln_post": layernorm_init(cfg.d_model, cfg.param_dtype),
    }


def encode(params, cfg: EncDecConfig, frame_embeds):
    """frame_embeds: (B, n_frames, d_model) from the stub frontend."""
    x = frame_embeds.astype(cfg.compute_dtype)
    x = x + params["enc_pos"].astype(cfg.compute_dtype)[None, :x.shape[1]]

    def layer(x, lp):
        h, _ = attn_apply(lp["attn"], cfg.attn_cfg(False),
                          layernorm_apply(lp["ln1"], x),
                          compute_dtype=cfg.compute_dtype)
        x = x + h
        h = mlp_apply(lp["mlp"], layernorm_apply(lp["ln2"], x),
                      activation="gelu", compute_dtype=cfg.compute_dtype)
        return x + h, None

    layer = jax.checkpoint(layer,
                           policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(layer, x, params["enc_layers"])
    return layernorm_apply(params["enc_ln_post"], x)


def decode(params, cfg: EncDecConfig, tokens, memory, *, caches=None,
           positions=None):
    """tokens (B, S); memory (B, n_frames, d) encoder output.

    caches: {"self": stacked kv caches (L, ...), "index": scalar} or None.
    Returns (logits, new_caches).
    """
    B, S = tokens.shape
    base = caches["index"] if caches is not None else 0
    if positions is None:
        positions = base + jnp.arange(S)
    x = embed_apply(params["dec_embed"], tokens, cfg.compute_dtype)
    pos_table = params["dec_pos"].astype(cfg.compute_dtype)
    # allow decode positions past max_target by clamping the table lookup
    pos_ids = jnp.minimum(positions, pos_table.shape[0] - 1)
    x = x + jnp.take(pos_table, pos_ids, axis=0)[None]

    def layer(carry, xs):
        x = carry
        lp, self_cache = xs
        cache_i = None
        if self_cache is not None:
            cache_i = dict(self_cache)
            cache_i["index"] = caches["index"]
        h, nc = attn_apply(lp["self_attn"], cfg.attn_cfg(True),
                           layernorm_apply(lp["ln1"], x),
                           positions=positions, cache=cache_i,
                           compute_dtype=cfg.compute_dtype)
        x = x + h
        h, _ = attn_apply(lp["cross_attn"], cfg.attn_cfg(False),
                          layernorm_apply(lp["ln_x"], x), kv_x=memory,
                          compute_dtype=cfg.compute_dtype)
        x = x + h
        h = mlp_apply(lp["mlp"], layernorm_apply(lp["ln2"], x),
                      activation="gelu", compute_dtype=cfg.compute_dtype)
        x = x + h
        if nc is not None:
            nc.pop("index")
        return x, nc

    self_caches = caches["self"] if caches is not None else None
    if caches is None:  # training path: full per-layer remat
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)
    x, new_self = jax.lax.scan(layer, x, (params["dec_layers"], self_caches))

    new_caches = None
    if caches is not None:
        new_caches = {"self": new_self, "index": caches["index"] + S}

    x = layernorm_apply(params["dec_ln_post"], x)
    logits = embed_attend(params["dec_embed"], x, cfg.compute_dtype)
    return logits.astype(jnp.float32), new_caches


def encdec_apply(params, cfg: EncDecConfig, frame_embeds, tokens):
    """Training forward: encode + teacher-forced decode."""
    memory = encode(params, cfg, frame_embeds)
    logits, _ = decode(params, cfg, tokens, memory)
    return logits


def init_encdec_cache(cfg: EncDecConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, *, per_slot: bool = False):
    """Self-attention decoder caches, stacked (n_layers, ...).

    per_slot=True is the pooled continuous-batching layout the serving
    engine slices per slot: {"slots": {"self": stacked}, "index": (B,)}
    with per-slot position rows — the same shape contract CachePool's
    `_insert_row` scatters into (leaf axis 1 is the slot axis). The
    legacy scalar-cursor layout stays for the single-stream decode path.
    """
    one = init_kv_cache(batch, max_len, cfg.n_heads, cfg.head_dim, dtype,
                        per_slot=per_slot)
    idx = one.pop("index")
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), one)
    if per_slot:
        return {"slots": {"self": stacked}, "index": idx}
    return {"self": stacked, "index": jnp.zeros((), jnp.int32)}


def precompute_cross_kv(params, cfg: EncDecConfig, memory):
    """Per-decoder-layer cross-attention K/V of one encoder output.

    memory (B, Sm, d) -> k, v each (L, B, Sm, n_heads, head_dim), in
    compute_dtype — bitwise the projections attn_apply computes inline
    from kv_x=memory, so serving decode against these (attn_apply's
    kv_cache path) matches the training-style decode() token for token.
    """
    h, hd = cfg.n_heads, cfg.head_dim

    def one(lp):
        ca = lp["cross_attn"]
        k = dense_apply(ca["wk"], memory, cfg.compute_dtype)
        v = dense_apply(ca["wv"], memory, cfg.compute_dtype)
        return (k.reshape(*k.shape[:-1], h, hd),
                v.reshape(*v.shape[:-1], h, hd))

    return jax.lax.map(one, params["dec_layers"])


def decode_serve(params, cfg: EncDecConfig, tokens, positions, cache):
    """Pooled (continuous-batching) decode step for the encdec family.

    cache: {"slots": {"self": stacked (L, B, rows, ...) KV}, "index":
    (B,) per-slot cursors, "cross": read-only cross-attention K/V —
    dense {"k","v","pos"} with k/v (L, B, Sm, H, hd), or the paged
    arena {"k","v","pos","table"} with k/v (L, n_blocks, bs, H, hd)
    (pos/table carry no layer dim: frame positions are layer-invariant).
    The cross tree is passed through new_cache UNCHANGED so the donated
    serve step aliases it in place — arenas never round-trip the host.
    positions: (B, S) per-slot LOCAL decode positions (pads < 0).
    """
    B, S = tokens.shape
    x = embed_apply(params["dec_embed"], tokens, cfg.compute_dtype)
    pos_table = params["dec_pos"].astype(cfg.compute_dtype)
    pos_ids = jnp.clip(positions, 0, pos_table.shape[0] - 1)
    x = x + jnp.take(pos_table, pos_ids, axis=0)

    cross = cache["cross"]
    cross_ro = {n: cross[n] for n in cross if n not in ("k", "v")}

    def layer(x, xs):
        lp, self_cache, ck, cv = xs
        cache_i = dict(self_cache)
        cache_i["index"] = cache["index"]
        h, nc = attn_apply(lp["self_attn"], cfg.attn_cfg(True),
                           layernorm_apply(lp["ln1"], x),
                           positions=positions, cache=cache_i,
                           compute_dtype=cfg.compute_dtype)
        x = x + h
        h, _ = attn_apply(lp["cross_attn"], cfg.attn_cfg(False),
                          layernorm_apply(lp["ln_x"], x),
                          positions=positions,
                          kv_cache={"k": ck, "v": cv, **cross_ro},
                          compute_dtype=cfg.compute_dtype)
        x = x + h
        h = mlp_apply(lp["mlp"], layernorm_apply(lp["ln2"], x),
                      activation="gelu", compute_dtype=cfg.compute_dtype)
        x = x + h
        nc.pop("index")
        return x, nc

    x, new_self = jax.lax.scan(
        layer, x,
        (params["dec_layers"], cache["slots"]["self"], cross["k"], cross["v"]))
    x = layernorm_apply(params["dec_ln_post"], x)
    logits = embed_attend(params["dec_embed"], x, cfg.compute_dtype)
    new_cache = {"slots": {"self": new_self}, "index": cache["index"] + S,
                 "cross": cross}
    return logits.astype(jnp.float32), new_cache


def prefill_serve(params, cfg: EncDecConfig, tokens, positions, frames,
                  cache_len: int):
    """Batched encdec admission: encode, project cross K/V once, run the
    decoder prompt into fresh per-slot caches.

    tokens/positions (B, S) left-padded prompts (pads < 0); frames
    (B, n_frames, d_model). Returns (last-position fp32 logits (B, 1, V),
    pooled cache whose "cross" is the DENSE per-request form — axis 1 is
    the batch axis on every cross leaf, so the engine slices one
    request's cross K/V out for arena registration the same way it
    slices self-cache rows).
    """
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]),
                                     tokens.shape)
    memory = encode(params, cfg, frames)
    ck, cv = precompute_cross_kv(params, cfg, memory)
    cache = init_encdec_cache(cfg, tokens.shape[0], cache_len,
                              dtype=cfg.compute_dtype, per_slot=True)
    cache["cross"] = {"k": ck, "v": cv,
                      "pos": jnp.arange(memory.shape[1], dtype=jnp.int32)}
    logits, cache = decode_serve(params, cfg, tokens, positions, cache)
    return logits[:, -1:].astype(jnp.float32), cache

"""Grouped-query attention with the variants the assigned archs need:

  - GQA / MHA / MQA (n_kv_heads),
  - optional QKV bias (qwen2.5),
  - optional QK-norm (chameleon),
  - attention-logit soft-capping (gemma2),
  - sliding-window masking (gemma2 local layers; mistral long-ctx variant),
  - RoPE or no positional op (whisper uses learned pos embs upstream),
  - bidirectional (whisper encoder, BERT) or causal,
  - cross-attention (whisper decoder),
  - incremental decoding against a KV cache.

Shapes: x (B, S, d_model); cache k/v (B, max_len, n_kv, head_dim).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import NEG_INF  # single-sourced masked-logit value
from repro.models.common import (apply_rope, dense_apply, dense_init,
                                 maybe_constrain, rmsnorm_apply,
                                 rmsnorm_init, softcap)


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    causal: bool = True
    sliding_window: Optional[int] = None
    logit_softcap: Optional[float] = None
    query_scale: Optional[float] = None  # default 1/sqrt(head_dim)
    # Query-block chunking bound: sequences >= this use the remat-chunked
    # attention path (bounds the live S x S logits to q_block x S — the
    # XLA-level flash-attention analogue that makes prefill_32k fit).
    q_chunk_threshold: int = 4096
    q_block: int = 1024
    # Paged decode implementation: "xla" gathers arena[table] into a dense
    # (B, ring_len) K/V copy; "paged" streams the table's blocks from HBM
    # inside the fused Pallas kernel (kernels/paged_attention_kernel.py).
    # Only the paged serving branch reads this; token output is identical.
    decode_kernel: str = "xla"
    # Pallas interpret-mode override for the paged kernel: None = auto
    # (interpret off-TPU, compiled on TPU); True forces interpret — the
    # escape hatch (serve.py --interpret) for arena layouts that fail
    # TPU tile alignment (kernels/paged_attention_kernel.py).
    kernel_interpret: Optional[bool] = None


def attn_init(rng, cfg: AttnConfig, *, cross: bool = False, dtype=jnp.float32):
    ks = jax.random.split(rng, 6)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], d, h * hd, use_bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(ks[1], d, kv * hd, use_bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(ks[2], d, kv * hd, use_bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ks[3], h * hd, d, use_bias=False, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    del cross
    return p


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _mask_logits(logits, q_pos, k_pos, *, causal, window, kv_valid_len=None):
    """logits: (B, H, Sq, Sk); q_pos (Sq,) or (B, Sq), k_pos (Sk,) or (B, Sk)
    absolute positions. Negative k_pos marks invalid rows (unwritten ring
    slots, left-padding, evicted serving slots) and is always masked."""
    kp = k_pos[None, None, :] if k_pos.ndim == 1 else k_pos[:, None, :]
    qp = q_pos[None, :, None] if q_pos.ndim == 1 else q_pos[:, :, None]
    ok = kp >= 0
    if causal:
        ok = ok & (kp <= qp)
    if window is not None:
        ok = ok & ((qp - kp) < window)
    ok = jnp.broadcast_to(ok, (ok.shape[0],) + logits.shape[-2:])
    mask = ok[:, None]  # (B or 1, 1, Sq, Sk)
    if kv_valid_len is not None:  # (B,) number of valid cache slots
        valid = kp < kv_valid_len[:, None, None]  # (B, 1|Sq, Sk)
        mask = mask & valid[:, None]
    return jnp.where(mask, logits, NEG_INF)


def attn_apply(
    p,
    cfg: AttnConfig,
    x,
    *,
    kv_x=None,                 # cross-attention memory (B, Sm, d)
    positions=None,            # (B, S) or (S,) absolute positions of x
    cache=None,                # dict(k, v, index) for incremental decode
    kv_cache=None,             # READ-ONLY precomputed cross K/V (serving)
    kv_valid_len=None,         # (B,) valid cache length (incl. new tokens)
    compute_dtype=jnp.bfloat16,
):
    """Returns (out, new_cache). new_cache is None unless cache is given.

    kv_cache is the serving twin of kv_x: the cross-attention K/V were
    projected ONCE (at encdec admission) and are attended read-only every
    decode step — wk/wv never run here and nothing is written back. Two
    forms: dense {"k","v"[,"pos"]} with k/v (B, Sm, n_kv, hd), or paged
    {"k","v","pos","table"} where k/v are (n_blocks, block_size, n_kv,
    hd) arenas gathered through a (B, max_blocks) table exactly like the
    paged self-attention read path. Pad rows carry pos -1 and mask out,
    so the gathered padded attention is bitwise the dense one (exp of a
    masked logit is exactly 0.0 in fp32). Mutually exclusive with kv_x
    and cache.
    """
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    scale = cfg.query_scale if cfg.query_scale is not None else 1.0 / math.sqrt(hd)

    q = _split_heads(dense_apply(p["wq"], x, compute_dtype), h, hd)
    if kv_cache is None:
        src = x if kv_x is None else kv_x
        k = _split_heads(dense_apply(p["wk"], src, compute_dtype), kv, hd)
        v = _split_heads(dense_apply(p["wv"], src, compute_dtype), kv, hd)

    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        if kv_cache is None:
            k = rmsnorm_apply(p["k_norm"], k)

    if positions is None:
        positions = jnp.arange(S)
    positions = jnp.broadcast_to(positions, (S,) if positions.ndim <= 1 else positions.shape)

    if cfg.rope and kv_x is None and kv_cache is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    attend_cached = cache is not None
    # Pooled (continuous-batching) caches carry a per-slot write cursor
    # index: (B,) and per-slot positions pos: (B, cache_len); the classic
    # single-stream cache keeps the scalar index / shared (cache_len,) pos.
    # Paged pooled caches additionally carry a block table: k/v/pos are
    # block ARENAS shared by every slot, and "table" maps each slot's
    # logical rows onto arena blocks.
    pooled = cache is not None and jnp.ndim(cache["index"]) == 1
    paged = cache is not None and "table" in cache
    if kv_cache is not None:
        if cache is not None or kv_x is not None:
            raise ValueError("kv_cache is exclusive with cache/kv_x")
        if "table" in kv_cache:
            # Paged cross arena (serving/cache_pool.EncDecCachePool): the
            # same fixed-shape gather the paged self-attention read path
            # uses — blocks churn, the jitted step never recompiles.
            tbl = kv_cache["table"]                    # (B, max_blocks)
            bsz = kv_cache["k"].shape[1]
            mem_len = tbl.shape[1] * bsz
            k = kv_cache["k"][tbl].reshape(B, mem_len, kv, hd)
            v = kv_cache["v"][tbl].reshape(B, mem_len, kv, hd)
            k_pos = kv_cache["pos"][tbl].reshape(B, mem_len)
        else:
            k, v = kv_cache["k"], kv_cache["v"]
            k_pos = kv_cache.get("pos")
            if k_pos is None:
                k_pos = jnp.arange(k.shape[1])
        k = k.astype(compute_dtype)
        v = v.astype(compute_dtype)
        q_pos = positions
    elif paged:
        # Paged decode (serving/cache_pool.PagedCachePool): cache k/v are
        # (n_blocks, block_size, kv, hd) arenas, pos is (n_blocks,
        # block_size), table is (B, max_blocks) int32 arena indices with 0
        # pointing at the reserved null block (pos -1 everywhere, so its
        # rows are structurally masked). Logical row r of slot b lives at
        # arena[table[b, r // bsz], r % bsz]; r = cursor % ring_len gives
        # the sliding-window layers their ring semantics for free. The
        # host-side allocator guarantees the block being written is
        # exclusively owned (shared prefix blocks are never in the write
        # path), so the scatter below cannot race between slots —
        # inactive slots all write the null block with position -1, which
        # keeps it invalid. Everything is a fixed-shape gather/scatter:
        # the jitted step never recompiles as blocks churn. S > 1 is the
        # speculative-verify step: the S draft tokens of slot b land at
        # logical rows cursor..cursor+S-1 (lazy growth backs them before
        # the step; rejected rows are invalidated by a pos scatter after).
        idx = cache["index"]                       # (B,) local cursors
        tbl = cache["table"]                       # (B, max_blocks)
        bsz = cache["k"].shape[1]
        ring_len = tbl.shape[1] * bsz
        k_new = maybe_constrain(k.astype(cache["k"].dtype),
                                "data", None, None, "model")
        v_new = maybe_constrain(v.astype(cache["v"].dtype),
                                "data", None, None, "model")
        q_pos = (positions if positions.ndim == 2
                 else jnp.broadcast_to(positions, (B, S))).astype(jnp.int32)
        q = maybe_constrain(q, "data", None, None, "model")
        if cfg.decode_kernel == "paged":
            # Fused Pallas path: the block table rides into the kernel as
            # a scalar-prefetch operand, K/V blocks stream HBM->VMEM
            # directly — no (B, ring_len, kv, hd) materialization — and
            # the K/V/pos scatter happens in the kernel EPILOGUE: arenas
            # are aliased in/out and come back updated, so the three XLA
            # arena round-trips below never exist on this path. Token
            # output matches the XLA gather below to fp32 summation-order
            # tolerance (both accumulate in fp32); the returned arenas
            # match the XLA scatter bit-for-bit on every data block (the
            # fused kernel never writes the null block — invalid rows
            # write NOTHING instead of null row 0; both keep the null
            # block's positions -1, so attention cannot see the
            # difference). See kernels/paged_attention_kernel.py.
            if kv_valid_len is not None:
                raise NotImplementedError(
                    "kv_valid_len is unsupported on the paged kernel path")
            from repro.kernels.paged_attention_kernel import (
                paged_attention_fused)
            out, k_arena, v_arena, pos_arena = paged_attention_fused(
                q, k_new, v_new, cache["k"], cache["v"], cache["pos"],
                tbl, q_pos, idx,
                scale=scale, causal=cfg.causal, window=cfg.sliding_window,
                softcap=cfg.logit_softcap, interpret=cfg.kernel_interpret)
            new_cache = {"k": k_arena, "v": v_arena, "pos": pos_arena,
                         "index": idx + S}
            out = out.astype(compute_dtype)
            out = maybe_constrain(out, "data", None, None, "model")
            out = out.reshape(B, S, h * hd)
            return dense_apply(p["wo"], out, compute_dtype), new_cache
        if cfg.decode_kernel != "xla":
            raise ValueError(f"unknown decode_kernel {cfg.decode_kernel!r}")
        r = jax.lax.rem(idx[:, None] + jnp.arange(S, dtype=jnp.int32),
                        ring_len)                  # (B, S) logical rows
        blk = jnp.take_along_axis(tbl, r // bsz, axis=1)
        off = jax.lax.rem(r, bsz)
        # Rows with a negative feed position (inactive slots; the padding
        # rows of a budget-truncated verify block) are routed to the null
        # block BY THE SCATTER, not just by their table being empty: a
        # truncated verify block's pad rows sit past the slot's live
        # chain, where the ring may map them onto real blocks — shared
        # prompt blocks included — that growth never COWed because no
        # real write ever reaches them. The null block's row 0 takes all
        # such writes, value -1, and stays invalid.
        blk = jnp.where(q_pos >= 0, blk, 0)
        off = jnp.where(q_pos >= 0, off, 0)
        k_arena = cache["k"].at[blk, off].set(k_new)
        v_arena = cache["v"].at[blk, off].set(v_new)
        pos_arena = cache["pos"].at[blk, off].set(q_pos)
        new_cache = {"k": k_arena, "v": v_arena, "pos": pos_arena,
                     "index": idx + S}
        # block-table gather: (B, max_blocks, bsz, ...) -> (B, ring_len, ...)
        k = k_arena[tbl].reshape(B, ring_len, kv, hd).astype(compute_dtype)
        v = v_arena[tbl].reshape(B, ring_len, kv, hd).astype(compute_dtype)
        k_pos = pos_arena[tbl].reshape(B, ring_len)
    elif cache is not None and S > 1 and S >= cache["k"].shape[1]:
        attend_cached = False  # attend in-flight; cache write is tail-only
        # Prefill longer than a ring cache (sliding-window layer): attend
        # the in-flight k/v (standard masking below) and write only the
        # LAST cache_len rows, rolled so that slot == write_cursor %
        # cache_len — the invariant later decode steps rely on. Assumes
        # idx == 0 (prefill from scratch), which is the only way the
        # engine uses it.
        idx = cache["index"]
        cache_len = cache["k"].shape[1]
        W = cache_len
        shift = (S - W) % cache_len
        k_tail = jnp.roll(k[:, S - W:S].astype(cache["k"].dtype), shift, axis=1)
        v_tail = jnp.roll(v[:, S - W:S].astype(cache["v"].dtype), shift, axis=1)
        # positions may be per-batch (left-padded prefill: pads carry pos<0
        # and stay masked for the lifetime of the cache entry)
        pos_src = (positions if positions.ndim == 2
                   else jnp.broadcast_to(positions, (B, S))).astype(jnp.int32)
        pos_tail = jnp.roll(pos_src[:, S - W:S], shift, axis=1)
        if not pooled:
            pos_tail = pos_tail[0]
        new_cache = {"k": k_tail, "v": v_tail, "pos": pos_tail,
                     "index": idx + S}
        k_pos = positions
        q_pos = positions
    elif cache is not None:
        # Incremental decode / prefill-into-cache: write the S new k/v rows
        # at the write cursor. Ring-buffer caches (cache_len < model max_len;
        # sliding-window layers) wrap the write slot and track absolute
        # positions in cache["pos"].
        idx = cache["index"]  # scalar int32, or (B,) per-slot cursors
        cache_len = cache["k"].shape[1]
        # Pin the incoming rows to the cache layout (batch over data, head_dim
        # over model) BEFORE the update: otherwise GSPMD reshards the whole
        # cache through collectives every decode step (EXPERIMENTS.md iter 4).
        k_new = maybe_constrain(k.astype(cache["k"].dtype),
                                "data", None, None, "model")
        v_new = maybe_constrain(v.astype(cache["v"].dtype),
                                "data", None, None, "model")
        if pooled:
            # Per-slot scatter: slot b writes rows idx[b]..idx[b]+S-1 (mod
            # cache_len). RoPE/mask positions come from `positions`, which
            # the serving engine sets to each slot's LOCAL time — rows of
            # evicted/previous occupants are wiped by cache-pool insertion,
            # so `pos >= 0 and causal` is the complete validity rule.
            rows = jax.lax.rem(idx[:, None]
                               + jnp.arange(S, dtype=jnp.int32), cache_len)
            brow = jnp.arange(B)[:, None]
            k_cache = cache["k"].at[brow, rows].set(k_new)
            v_cache = cache["v"].at[brow, rows].set(v_new)
            q_pos = (positions if positions.ndim == 2
                     else jnp.broadcast_to(positions, (B, S))).astype(jnp.int32)
            pos_new = cache["pos"].at[brow, rows].set(q_pos)
        else:
            slot = jax.lax.rem(idx, cache_len)
            k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new,
                                                   (0, slot, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new,
                                                   (0, slot, 0, 0))
            pos_new = jax.lax.dynamic_update_slice(
                cache["pos"], (idx + jnp.arange(S, dtype=jnp.int32)), (slot,))
            q_pos = idx + jnp.arange(S)
            if kv_valid_len is None:
                kv_valid_len = jnp.full((B,), idx + S, jnp.int32)
        # Decode attention stays head_dim-sharded end to end: q must match,
        # else GSPMD all-gathers the whole cached K/V per layer per token
        # (measured 31 GB/chip/token on gemma2 decode_32k — iter 4).
        q = maybe_constrain(q, "data", None, None, "model")
        new_cache = {"k": k_cache, "v": v_cache, "pos": pos_new, "index": idx + S}
        k, v = k_cache.astype(compute_dtype), v_cache.astype(compute_dtype)
        k_pos = pos_new
    else:
        k_pos = jnp.arange(k.shape[1]) if kv_x is not None else positions
        q_pos = positions

    # GQA: repeat kv heads up to h.
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)

    causal = cfg.causal and kv_x is None and kv_cache is None
    # Single-token cached decode runs its logit/PV contractions with fp32
    # accumulation and keeps probs fp32: the (B, H, 1, K) intermediates are
    # tiny, and it makes the Pallas paged kernel (fp32 in VREGs throughout)
    # token-comparable to every XLA decode path — the property the
    # paged-pallas == paged-xla differential tests pin. The OUTPUT still
    # rounds to compute_dtype: the pools lay the same keys out at
    # different cache rows, and that single rounding is what absorbs the
    # sub-ulp fp32 summation-order differences so static == dense ==
    # paged stays token-exact across layouts. The paged branch gets fp32
    # at ANY S: its S > 1 case is the speculative-verify block, which must
    # stay token-comparable to the Pallas kernel exactly like S == 1
    # (other S > 1 paths are prefill, where bf16 probs are the contract).
    decode = attend_cached and (S == 1 or paged)
    acc_dtype = jnp.float32 if decode else None
    probs_dtype = jnp.float32 if decode else compute_dtype

    def _attend_block(qb, q_pos_b, kv_len):
        logits = jnp.einsum("bqhd,bkhd->bhqk", qb, k,
                            preferred_element_type=acc_dtype) * scale
        logits = softcap(logits, cfg.logit_softcap)
        logits = _mask_logits(
            logits.astype(jnp.float32), q_pos_b, k_pos,
            causal=causal, window=cfg.sliding_window,
            kv_valid_len=kv_len)
        probs = jax.nn.softmax(logits, axis=-1).astype(probs_dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", probs, v,
                       preferred_element_type=acc_dtype).astype(compute_dtype)
        if decode:
            # keep decode attention head_dim-sharded (see cache note above)
            o = maybe_constrain(o, "data", None, None, "model")
        return o

    kv_len = kv_valid_len if attend_cached else None
    qb = cfg.q_block
    if not attend_cached and S >= cfg.q_chunk_threshold and S % qb == 0:
        # remat-chunked query blocks: live logits bounded to (B,H,qb,S) and
        # the backward pass recomputes per-block probs instead of saving them.
        q_blocks = q.reshape(B, S // qb, qb, h, hd).swapaxes(0, 1)
        if q_pos.ndim == 2:   # per-batch positions (left-padded serving prefill)
            qpos_blocks = q_pos.reshape(B, S // qb, qb).swapaxes(0, 1)
        else:
            qpos_blocks = q_pos.reshape(S // qb, qb)
        blk = jax.checkpoint(lambda qq, pp: _attend_block(qq, pp, kv_len))
        out = jax.lax.map(lambda args: blk(*args), (q_blocks, qpos_blocks))
        out = out.swapaxes(0, 1).reshape(B, S, h, hd)
    else:
        out = _attend_block(q, q_pos, kv_len)
    out = out.reshape(B, S, h * hd)
    out = dense_apply(p["wo"], out, compute_dtype)
    return out, new_cache


def init_paged_kv_cache(n_blocks: int, block_size: int, n_kv: int,
                        head_dim: int, dtype=jnp.bfloat16):
    """Block arena for the paged serving cache (one attention slot-type).

    Unlike `init_kv_cache` there is no batch dim: slots reference blocks
    through a (max_batch, max_blocks) int32 table kept NEXT to the cache
    (see serving/cache_pool.PagedCachePool). Block 0 is the reserved null
    block — its positions stay -1 so unbacked table entries gather rows
    that are structurally masked.
    """
    return {
        "k": jnp.zeros((n_blocks, block_size, n_kv, head_dim), dtype),
        "v": jnp.zeros((n_blocks, block_size, n_kv, head_dim), dtype),
        "pos": jnp.full((n_blocks, block_size), -1, jnp.int32),
    }


def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16, *, per_slot: bool = False):
    """Contiguous cache; pass max_len = sliding_window for ring-buffer layers.

    per_slot=True builds the pooled (continuous-batching) layout: one write
    cursor and one position row per batch slot, so slots admitted at
    different times decode through a single fixed-shape jitted step.
    """
    pos_shape = (batch, max_len) if per_slot else (max_len,)
    idx_shape = (batch,) if per_slot else ()
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "pos": jnp.full(pos_shape, -1, jnp.int32),
        "index": jnp.zeros(idx_shape, jnp.int32),
    }

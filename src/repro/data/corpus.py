"""Synthetic token corpus + BERT-style MLM/NSP example construction.

The container has no Wikipedia/BooksCorpus; the *pipeline semantics* are
what the paper contributes (§3.4), so the corpus is a deterministic
synthetic token stream with a power-law unigram distribution (to make MLM
learnable) while sharding/shuffling/masking match the real pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

# Special ids follow the BERT convention.
PAD_ID, CLS_ID, SEP_ID, MASK_ID = 0, 101, 102, 103
FIRST_NORMAL_ID = 110


@dataclasses.dataclass(frozen=True)
class SyntheticCorpus:
    """num_docs documents of doc_len tokens, materialized lazily per doc."""

    vocab: int
    num_docs: int
    doc_len: int
    seed: int = 0
    zipf_a: float = 1.2

    def doc(self, i: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, i]))
        # 75% global zipf tokens (a learnable unigram head for MLM) + 25%
        # doc-"topic" tokens (shifted zipf) so NSP and in-context prediction
        # carry signal too.
        n_normal = self.vocab - FIRST_NORMAL_ID
        z = rng.zipf(self.zipf_a, size=self.doc_len)
        global_tok = FIRST_NORMAL_ID + (z - 1) % n_normal
        shift = rng.integers(0, n_normal)
        topic_tok = FIRST_NORMAL_ID + (z - 1 + shift) % n_normal
        is_topic = rng.random(self.doc_len) < 0.25
        return np.where(is_topic, topic_tok, global_tok).astype(np.int32)


def build_mlm_example(
    corpus: SyntheticCorpus,
    doc_idx: int,
    rng: np.random.Generator,
    *,
    seq_len: int,
    mask_prob: float = 0.15,
) -> Dict[str, np.ndarray]:
    """One BERT pretraining example: [CLS] A [SEP] B [SEP] with 50% random-B
    (NSP negative) and standard 80/10/10 MLM masking."""
    doc = corpus.doc(doc_idx)
    seg = (seq_len - 3) // 2
    a_start = rng.integers(0, max(1, len(doc) - 2 * seg))
    seg_a = doc[a_start:a_start + seg]

    is_next = rng.random() < 0.5
    if is_next:
        seg_b = doc[a_start + seg:a_start + 2 * seg]
    else:
        other = corpus.doc(int(rng.integers(0, corpus.num_docs)))
        b_start = rng.integers(0, max(1, len(other) - seg))
        seg_b = other[b_start:b_start + seg]

    tokens = np.full((seq_len,), PAD_ID, np.int32)
    types = np.zeros((seq_len,), np.int32)
    tokens[0] = CLS_ID
    tokens[1:1 + len(seg_a)] = seg_a
    tokens[1 + len(seg_a)] = SEP_ID
    b0 = 2 + len(seg_a)
    tokens[b0:b0 + len(seg_b)] = seg_b
    tokens[b0 + len(seg_b)] = SEP_ID
    types[b0:b0 + len(seg_b) + 1] = 1

    # MLM masking: 15% of non-special positions; 80% [MASK], 10% random, 10% keep.
    labels = np.full((seq_len,), -100, np.int32)
    maskable = (tokens >= FIRST_NORMAL_ID)
    pick = maskable & (rng.random(seq_len) < mask_prob)
    labels[pick] = tokens[pick]
    r = rng.random(seq_len)
    tokens = np.where(pick & (r < 0.8), MASK_ID, tokens)
    rand_ids = rng.integers(FIRST_NORMAL_ID, corpus.vocab, size=seq_len)
    tokens = np.where(pick & (r >= 0.8) & (r < 0.9), rand_ids, tokens)

    return {
        "tokens": tokens.astype(np.int32),
        "token_types": types,
        "mlm_labels": labels,
        "nsp_labels": np.int32(0 if is_next else 1),
    }


def mlm_batch_iterator(corpus: SyntheticCorpus, spec, *, per_worker_batch: int,
                       seq_len: int, seed: int = 0):
    """Shard-without-replacement batches of BERT pretraining examples.

    ``spec`` is a repro.data.sharding.ShardSpec over corpus.num_docs.
    """
    from repro.data.sharding import minibatches

    rng = np.random.default_rng(np.random.SeedSequence([seed, spec.worker]))
    for idx_batch in minibatches(spec, per_worker_batch):
        exs = [build_mlm_example(corpus, int(i), rng, seq_len=seq_len)
               for i in idx_batch]
        yield {k: np.stack([e[k] for e in exs]) for k in exs[0]}


def lm_batch_iterator(corpus: SyntheticCorpus, spec, *, per_worker_batch: int,
                      seq_len: int):
    """Causal-LM batches (tokens, labels=shift-by-one) for the decoder archs."""
    from repro.data.sharding import minibatches

    for idx_batch in minibatches(spec, per_worker_batch):
        toks = np.stack([corpus.doc(int(i))[:seq_len + 1] for i in idx_batch])
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}

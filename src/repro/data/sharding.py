"""Data sharding — paper §3.4.

"To make sure that the mini-batch does not have redundant samples, we only
grant each worker access to a shard of the dataset. Within each shard,
random shuffling is used to construct the mini-batch samples."

This is sampling WITHOUT replacement across the global batch, giving the
variance bound O((n-k)/(k(n-1)) sigma^2) instead of O(sigma^2 / k) for
with-replacement sampling. `benchmarks/sharding_variance.py` verifies the
two bounds empirically.

The sampler is deterministic given (seed, epoch, worker): shard assignment
is a static partition; the in-shard order is a per-epoch PRNG permutation —
so every worker can compute its own indices with no coordination, exactly
like the paper's 1536-shard setup.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    num_samples: int     # n: dataset size
    num_workers: int     # number of data-parallel workers (paper: 1536)
    worker: int          # this worker's index
    seed: int = 0

    def __post_init__(self):
        assert 0 <= self.worker < self.num_workers


def shard_bounds(spec: ShardSpec) -> tuple:
    """Contiguous disjoint shard [lo, hi) for this worker."""
    per = spec.num_samples // spec.num_workers
    lo = spec.worker * per
    hi = lo + per if spec.worker < spec.num_workers - 1 else spec.num_samples
    return lo, hi


def epoch_indices(spec: ShardSpec, epoch: int) -> np.ndarray:
    """Shuffled in-shard sample indices for one epoch (without replacement)."""
    lo, hi = shard_bounds(spec)
    rng = np.random.default_rng(
        np.random.SeedSequence([spec.seed, epoch, spec.worker]))
    idx = np.arange(lo, hi)
    rng.shuffle(idx)
    return idx


def minibatches(spec: ShardSpec, per_worker_batch: int,
                start_epoch: int = 0) -> Iterator[np.ndarray]:
    """Infinite stream of per-worker index batches; epoch boundary reshuffles.

    Drops the tail remainder of each epoch (standard practice) so every
    global batch is exactly num_workers * per_worker_batch unique samples.
    """
    epoch = start_epoch
    while True:
        idx = epoch_indices(spec, epoch)
        usable = (len(idx) // per_worker_batch) * per_worker_batch
        for i in range(0, usable, per_worker_batch):
            yield idx[i:i + per_worker_batch]
        epoch += 1


def with_replacement_batch(rng: np.random.Generator, num_samples: int,
                           batch: int) -> np.ndarray:
    """Baseline sampler for the variance comparison benchmark."""
    return rng.integers(0, num_samples, size=batch)

"""JSONL metrics logging for training/serving runs.

One line per step: {"step": n, "wall_s": t, **scalars}. Values are
converted with float() so jnp scalars are accepted. A rolling window
provides smoothed console summaries (loss EMA, steps/s).
"""
from __future__ import annotations

import collections
import json
import os
import time
from typing import Dict, Iterator, Optional


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, *, window: int = 20):
        self.path = path
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "a", buffering=1)
        self._t0 = time.time()
        self._last = self._t0
        self._window = collections.deque(maxlen=window)

    def log(self, step: int, **scalars) -> Dict[str, float]:
        now = time.time()
        rec = {"step": int(step), "wall_s": round(now - self._t0, 3),
               "step_s": round(now - self._last, 4)}
        self._last = now
        for k, v in scalars.items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                rec[k] = str(v)
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
        if "loss" in rec:
            self._window.append(rec["loss"])
        return rec

    @property
    def smoothed_loss(self) -> Optional[float]:
        if not self._window:
            return None
        return sum(self._window) / len(self._window)

    def close(self):
        if self._fh:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_metrics(path: str) -> Iterator[dict]:
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                yield json.loads(line)

from repro.metrics.logger import MetricsLogger, read_metrics

__all__ = ["MetricsLogger", "read_metrics"]

"""Mixed-precision training subsystem.

Precision policies (param/compute/output dtypes with per-block fp32
overrides), apex-style loss scaling (dynamic skip-and-halve / static), fp32
master weights as a composable GradientTransformation wrapper, and a fused
Pallas cast-and-apply LANS path.

    policy = get_policy("fp16_mixed")
    tx = mixed_precision(lans(sched, mu_dtype=policy.moment_dtype), policy)
    params = policy.cast_params(arch.init(rng))
    state = tx.init(params)
    # each step: scale loss by loss_scale_value(state), grads flow scaled,
    # tx.update unscales in fp32, skips + halves on overflow.
"""
from repro.precision.loss_scale import (
    DynamicLossScale,
    LossScaleState,
    StaticLossScale,
    all_finite,
)
from repro.precision.mixed import (
    MixedPrecisionState,
    find_loss_scale,
    loss_scale_value,
    mixed_precision,
    overflow_count,
)
from repro.precision.fused import FusedMixedState, fused_mixed_lans
from repro.precision.policy import KEEP_FP32, Policy, get_policy, tree_cast

__all__ = [
    "DynamicLossScale",
    "FusedMixedState",
    "KEEP_FP32",
    "LossScaleState",
    "MixedPrecisionState",
    "Policy",
    "StaticLossScale",
    "all_finite",
    "find_loss_scale",
    "fused_mixed_lans",
    "get_policy",
    "loss_scale_value",
    "mixed_precision",
    "overflow_count",
    "tree_cast",
]

"""mixed_precision(): fp32 master weights around any GradientTransformation.

The model holds low-precision params (policy.cast_params of the master); the
wrapper owns the fp32 master copy and runs the inner optimizer on it, so
`lans`/`lamb`/`adamw`/`fused_lans` compose unchanged:

    tx = mixed_precision(lans(sched, mu_dtype=policy.moment_dtype), policy)
    state = tx.init(lp_params)                  # builds master + inner state
    updates, state = tx.update(scaled_grads, state, lp_params)
    lp_params = apply_updates(lp_params, updates)

Semantics per update (apex O2):
  1. unscale grads to fp32 (divide by the carried loss scale),
  2. check finiteness; on overflow lax.cond skips the inner optimizer
     entirely — master, moments and (exactly) the low-precision params are
     unchanged, the scale is halved,
  3. otherwise the inner tx steps the MASTER weights in fp32 and the new
     low-precision copy is re-cast from the master.

Master storage is sparse: leaves the policy keeps fp32 (LayerNorm/bias) ARE
their own master, so the wrapper stores a zero-size placeholder for them —
optimizer state for a low-precision policy is strictly smaller than fp32
training despite the extra master copy (see benchmarks/precision_sweep.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.optim.base import GradientTransformation, apply_updates
from repro.precision.loss_scale import LossScaleState, all_finite
from repro.precision.policy import Policy, _is_float

PyTree = Any


class MixedPrecisionState(NamedTuple):
    loss_scale: LossScaleState
    master: PyTree  # fp32 masters; zero-size placeholder where params are fp32
    inner: Any      # inner optimizer state, built over the fp32 master tree


def _placeholder():
    return jnp.zeros((0,), jnp.float32)


def _needs_master(p) -> bool:
    return _is_float(p) and jnp.dtype(p.dtype) != jnp.dtype(jnp.float32)


def _stash_master(master: PyTree, params: PyTree) -> PyTree:
    """Keep master only where the model copy is low precision."""
    return jax.tree.map(
        lambda m, p: m if _needs_master(p) else _placeholder(), master, params)


def _merge_master(stored: PyTree, params: PyTree) -> PyTree:
    """Rebuild the full master from sparse storage + the fp32 leaves of
    params (which are bit-identical to their master by construction)."""
    def merge(s, p):
        if s.size != 0:
            return s
        return p.astype(jnp.float32) if _is_float(p) else p

    return jax.tree.map(merge, stored, params)


def mixed_precision(
    tx: GradientTransformation,
    policy: Policy,
    loss_scale=None,
) -> GradientTransformation:
    """Wrap `tx` with master weights + loss scaling per `policy`.

    `loss_scale` defaults to the policy's scaler (dynamic for fp16_mixed,
    static 1.0 for bf16). Incoming grads are expected SCALED (the train step
    multiplies the loss by the carried scale); the wrapper unscales in fp32.
    """
    ls = loss_scale if loss_scale is not None else policy.make_loss_scale()

    def init_fn(params):
        master = jax.tree.map(
            lambda p: p.astype(jnp.float32) if _is_float(p) else p, params)
        return MixedPrecisionState(
            loss_scale=ls.init(),
            master=_stash_master(master, params),
            inner=tx.init(master),
        )

    def update_fn(updates, state, params=None):
        if params is None:
            raise ValueError("mixed_precision requires params "
                             "(the low-precision model copy).")
        master = _merge_master(state.master, params)
        grads32 = ls.unscale(updates, state.loss_scale)
        finite = all_finite(grads32)

        def _step(operand):
            mst, inner = operand
            u32, inner2 = tx.update(grads32, inner, mst)
            return apply_updates(mst, u32), inner2

        # Overflow => skip: master/moments pass through untouched, so the
        # re-cast lp params are exactly unchanged and updates are exact zeros.
        new_master, new_inner = jax.lax.cond(
            finite, _step, lambda operand: operand, (master, state.inner))

        new_lp = policy.cast_params(new_master)
        updates_out = jax.tree.map(lambda n, p: n - p, new_lp, params)

        new_state = MixedPrecisionState(
            loss_scale=ls.adjust(state.loss_scale, finite),
            master=_stash_master(new_master, params),
            inner=new_inner,
        )
        return updates_out, new_state

    return GradientTransformation(init_fn, update_fn)


# ---------------------------------------------------------------------------
# State introspection — the train step reads the carried scale from inside
# the (possibly nested) optimizer state to scale the loss BEFORE the grads
# exist, and logs overflow_count from the post-update state.
# ---------------------------------------------------------------------------

def find_loss_scale(opt_state) -> Optional[LossScaleState]:
    """First LossScaleState anywhere in an optimizer-state pytree, else None."""
    hits = [
        l for l in jax.tree.leaves(
            opt_state, is_leaf=lambda x: isinstance(x, LossScaleState))
        if isinstance(l, LossScaleState)
    ]
    return hits[0] if hits else None


def loss_scale_value(opt_state) -> jnp.ndarray:
    s = find_loss_scale(opt_state)
    return s.scale if s is not None else jnp.asarray(1.0, jnp.float32)


def overflow_count(opt_state) -> jnp.ndarray:
    s = find_loss_scale(opt_state)
    return s.overflow_count if s is not None else jnp.zeros([], jnp.int32)

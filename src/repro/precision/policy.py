"""Precision policies: which dtype each part of the training state lives in.

A `Policy` names four dtypes plus the per-block override list:

  param_dtype    dtype of the model's parameter copy (what loss_fn sees)
  compute_dtype  dtype matmuls/attention run in (models cast at use)
  output_dtype   dtype step outputs (logits/loss) are returned in
  moment_dtype   storage dtype of optimizer first/second moments (math is
                 always fp32 inside the optimizers; see lans mu_dtype)

Per-block overrides (`keep_fp32`): parameter leaves whose path matches any
substring stay fp32 regardless of param_dtype — LayerNorm scales/biases and
other 1-D stabilizer params, matching apex O2 practice (the paper trained
with fp16 compute + fp32 LN/master weights on V100s).

The named policies:

  fp32        everything fp32 (the seed behaviour; no wrapper needed)
  bf16        bf16 params/compute, fp32 master weights, static scale 1
              (bf16's fp32-sized exponent needs no loss scaling)
  fp16_mixed  fp16 params/compute, fp32 master weights, dynamic loss
              scaling with skip-and-halve on overflow (apex semantics)

Casting utilities only touch floating leaves; integer leaves (token ids,
counters) pass through untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.optim.base import tree_paths

PyTree = Any

# LayerNorm/RMSNorm scales, every bias, and SSM stabilizers stay fp32.
KEEP_FP32 = ("bias", "scale", "layernorm", "ln_", "norm", "a_log")


def _is_float(x) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)


def tree_cast(tree: PyTree, dtype) -> PyTree:
    """Cast every floating leaf to `dtype`; non-float leaves untouched."""
    return jax.tree.map(
        lambda x: x.astype(dtype) if _is_float(x) else x, tree)


@dataclasses.dataclass(frozen=True)
class Policy:
    name: str
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    output_dtype: Any = jnp.float32
    moment_dtype: Any = jnp.float32
    keep_fp32: Tuple[str, ...] = KEEP_FP32
    loss_scaling: str = "none"  # "none" | "static" | "dynamic"

    # ---------------- per-leaf dtype resolution ----------------

    def leaf_dtype(self, path: str):
        low = path.lower()
        if any(s in low for s in self.keep_fp32):
            return jnp.float32
        return self.param_dtype

    @property
    def needs_master(self) -> bool:
        """True when the model copy loses bits vs fp32 master weights."""
        return jnp.dtype(self.param_dtype) != jnp.dtype(jnp.float32)

    @property
    def wants_wrapper(self) -> bool:
        """True when training needs mixed_precision() around the optimizer."""
        return self.needs_master or self.loss_scaling != "none"

    # ---------------- tree casting ----------------

    def cast_params(self, params: PyTree) -> PyTree:
        """Model-copy cast with per-block overrides (LN/bias stay fp32)."""
        paths = tree_paths(params)
        return jax.tree.map(
            lambda x, pth: x.astype(self.leaf_dtype(pth)) if _is_float(x)
            else x, params, paths)

    def cast_to_compute(self, tree: PyTree) -> PyTree:
        return tree_cast(tree, self.compute_dtype)

    def cast_output(self, x):
        return jax.tree.map(
            lambda v: v.astype(self.output_dtype) if _is_float(v) else v, x)

    def make_loss_scale(self):
        from repro.precision.loss_scale import DynamicLossScale, StaticLossScale
        if self.loss_scaling == "dynamic":
            return DynamicLossScale()
        return StaticLossScale()

    def apply_to_cfg(self, cfg):
        """dataclasses.replace a model config's dtype fields, if it has them."""
        kw = {}
        if hasattr(cfg, "compute_dtype"):
            kw["compute_dtype"] = self.compute_dtype
        if hasattr(cfg, "param_dtype"):
            kw["param_dtype"] = self.param_dtype
        return dataclasses.replace(cfg, **kw) if kw else cfg


_POLICIES = {
    "fp32": Policy("fp32"),
    "bf16": Policy(
        "bf16",
        param_dtype=jnp.bfloat16,
        compute_dtype=jnp.bfloat16,
        output_dtype=jnp.float32,
        moment_dtype=jnp.bfloat16,
        loss_scaling="static",
    ),
    "fp16_mixed": Policy(
        "fp16_mixed",
        param_dtype=jnp.float16,
        compute_dtype=jnp.float16,
        output_dtype=jnp.float32,
        moment_dtype=jnp.bfloat16,
        loss_scaling="dynamic",
    ),
    # compute-only cast: fp32 params, bf16 matmuls — no wrapper needed.
    "bf16_compute": Policy(
        "bf16_compute",
        compute_dtype=jnp.bfloat16,
    ),
}
_POLICIES["fp16"] = _POLICIES["fp16_mixed"]


def get_policy(name) -> Policy:
    if isinstance(name, Policy):
        return name
    if name not in _POLICIES:
        raise KeyError(f"unknown policy {name!r}; known: {sorted(_POLICIES)}")
    return _POLICIES[name]

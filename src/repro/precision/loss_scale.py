"""Loss scaling — the paper-era apex state machine, jit-native.

fp16 gradients underflow (min normal 6e-5); scaling the loss by S shifts the
gradient distribution into representable range, and the optimizer divides it
back out in fp32. Overflow is the failure mode: any inf/nan gradient means S
was too large, so the step is SKIPPED (params/moments untouched) and S is
halved. After `growth_interval` consecutive good steps S doubles back.

State is a flat NamedTuple of scalars so it rides inside the optimizer state
through jit/pjit/lax.cond without special casing. Both scalers are frozen
dataclasses (static under jit); all decisions are jnp.where on traced
scalars — no host sync anywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class LossScaleState(NamedTuple):
    scale: jnp.ndarray           # fp32 scalar, current multiplier S
    good_steps: jnp.ndarray      # int32, consecutive finite steps since last change
    overflow_count: jnp.ndarray  # int32, total skipped steps (monotonic)


def all_finite(tree: PyTree) -> jnp.ndarray:
    """Scalar bool: every element of every floating leaf is finite."""
    leaves = [l for l in jax.tree.leaves(tree)
              if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating)]
    if not leaves:
        return jnp.bool_(True)
    finite = jnp.bool_(True)
    for l in leaves:
        finite = jnp.logical_and(finite, jnp.all(jnp.isfinite(l)))
    return finite


def unscale_grads(grads: PyTree, state: LossScaleState) -> PyTree:
    """grads / S in fp32 (float leaves only) — shared by both scalers."""
    inv = 1.0 / state.scale
    return jax.tree.map(
        lambda g: g.astype(jnp.float32) * inv
        if jnp.issubdtype(jnp.asarray(g).dtype, jnp.floating) else g,
        grads)


@dataclasses.dataclass(frozen=True)
class DynamicLossScale:
    """apex.amp dynamic scaling: start high, halve on overflow, double after
    `growth_interval` clean steps. Defaults match apex's DynamicLossScaler
    (init 2^16, window 2000, x2 growth / x0.5 backoff)."""

    init_scale: float = 2.0 ** 16
    growth_factor: float = 2.0
    backoff_factor: float = 0.5
    growth_interval: int = 2000
    min_scale: float = 1.0
    max_scale: float = 2.0 ** 24

    def init(self) -> LossScaleState:
        return LossScaleState(
            scale=jnp.asarray(self.init_scale, jnp.float32),
            good_steps=jnp.zeros([], jnp.int32),
            overflow_count=jnp.zeros([], jnp.int32),
        )

    unscale = staticmethod(unscale_grads)

    def adjust(self, state: LossScaleState, grads_finite) -> LossScaleState:
        good = state.good_steps + 1
        grow = good >= self.growth_interval
        grown = jnp.minimum(state.scale * self.growth_factor, self.max_scale)
        scale_ok = jnp.where(grow, grown, state.scale)
        good_ok = jnp.where(grow, 0, good).astype(jnp.int32)
        scale_bad = jnp.maximum(state.scale * self.backoff_factor,
                                self.min_scale)
        return LossScaleState(
            scale=jnp.where(grads_finite, scale_ok, scale_bad),
            good_steps=jnp.where(grads_finite, good_ok, 0).astype(jnp.int32),
            overflow_count=state.overflow_count
            + (1 - grads_finite.astype(jnp.int32)),
        )


@dataclasses.dataclass(frozen=True)
class StaticLossScale:
    """Fixed multiplier (1.0 == no scaling, the bf16 case). Overflow still
    skips the step and is counted, but the scale never moves."""

    scale_value: float = 1.0

    def init(self) -> LossScaleState:
        return LossScaleState(
            scale=jnp.asarray(self.scale_value, jnp.float32),
            good_steps=jnp.zeros([], jnp.int32),
            overflow_count=jnp.zeros([], jnp.int32),
        )

    unscale = staticmethod(unscale_grads)

    def adjust(self, state: LossScaleState, grads_finite) -> LossScaleState:
        return LossScaleState(
            scale=state.scale,
            good_steps=state.good_steps + grads_finite.astype(jnp.int32),
            overflow_count=state.overflow_count
            + (1 - grads_finite.astype(jnp.int32)),
        )

"""fused_mixed_lans: the Pallas cast-and-apply path as a transform.

The generic `mixed_precision(fused_lans(...), policy)` composition works, but
it re-casts the whole master tree to low precision OUTSIDE the kernel — an
extra full read+write of the parameters per step. This transform instead
routes every block through `ops.fused_lans_mixed_step`, whose phase-2 kernel
writes the fp32 master update AND its low-precision cast in one pass: per
step that saves 4+P bytes/param of HBM traffic (4 re-read of x_new, P write
merged into the pass that already owns the tile).

State layout matches mixed_precision's sparse-master convention so the
sharding rules (distributed/sharding.py) and byte accounting agree; moments
are fp32 because the kernels accumulate into them directly.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.optim.base import (
    GradientTransformation,
    WeightDecayMask,
    tree_paths,
)
from repro.kernels import ops
from repro.precision.loss_scale import LossScaleState, all_finite
from repro.precision.mixed import _merge_master, _stash_master
from repro.precision.policy import Policy, _is_float

PyTree = Any


class FusedMixedState(NamedTuple):
    loss_scale: LossScaleState
    count: jnp.ndarray  # int32 completed steps
    master: PyTree      # sparse fp32 masters (placeholder where params fp32)
    mu: PyTree          # fp32 (kernel contract)
    nu: PyTree          # fp32


def fused_mixed_lans(
    learning_rate,
    policy: Policy,
    loss_scale=None,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    decay_mask: Optional[Callable[[str], bool]] = None,
    interpret: bool = True,
) -> GradientTransformation:
    """Kernel-fused LANS + master weights + loss scaling in one transform."""
    ls = loss_scale if loss_scale is not None else policy.make_loss_scale()
    mask_fn = decay_mask or WeightDecayMask()
    sched = learning_rate if callable(learning_rate) else (
        lambda _: jnp.asarray(learning_rate, jnp.float32))

    def init_fn(params):
        master = jax.tree.map(
            lambda p: p.astype(jnp.float32) if _is_float(p) else p, params)
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return FusedMixedState(
            loss_scale=ls.init(),
            count=jnp.zeros([], jnp.int32),
            master=_stash_master(master, params),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update_fn(updates, state, params):
        if params is None:
            raise ValueError("fused_mixed_lans requires params.")
        master = _merge_master(state.master, params)
        grads32 = ls.unscale(updates, state.loss_scale)
        finite = all_finite(grads32)

        paths = tree_paths(params)
        masks = jax.tree.map(lambda pth: bool(mask_fn(pth)), paths)
        lp_dtypes = jax.tree.map(policy.leaf_dtype, paths)
        t = state.count + 1
        eta = sched(state.count)

        treedef = jax.tree_util.tree_structure(params)
        flat = lambda tree: treedef.flatten_up_to(tree)

        def _one(g, m, v, x, ld, dm):
            if not _is_float(x):  # non-float leaves pass through untouched
                return ops.MixedStepOut(x, m, v, x)
            return ops.fused_lans_mixed_step(
                g, m, v, x, eta=eta, step=t, lp_dtype=ld,
                beta1=beta1, beta2=beta2, eps=eps,
                lam=weight_decay if dm else 0.0,
                apply_trust=bool(dm), interpret=interpret)

        def _step(operand):
            mst, mu, nu = operand
            outs = [
                _one(g, m, v, x, ld, dm)
                for g, m, v, x, ld, dm in zip(
                    flat(grads32), flat(mu), flat(nu), flat(mst),
                    flat(lp_dtypes), flat(masks))
            ]
            unflat = jax.tree_util.tree_unflatten
            return (unflat(treedef, [o.x for o in outs]),
                    unflat(treedef, [o.m for o in outs]),
                    unflat(treedef, [o.v for o in outs]),
                    unflat(treedef, [o.x_lp for o in outs]))

        def _skip(operand):
            mst, mu, nu = operand
            # lp params already equal cast(master): re-emit them unchanged.
            return mst, mu, nu, params

        new_master, new_mu, new_nu, new_lp = jax.lax.cond(
            finite, _step, _skip, (master, state.mu, state.nu))

        updates_out = jax.tree.map(lambda n_, p: n_ - p, new_lp, params)
        new_state = FusedMixedState(
            loss_scale=ls.adjust(state.loss_scale, finite),
            # count only advances on applied steps, matching the generic
            # wrapper: bias correction must track the number of moment
            # updates, and a skipped step must not consume a schedule tick.
            count=state.count + finite.astype(jnp.int32),
            master=_stash_master(new_master, params),
            mu=new_mu,
            nu=new_nu,
        )
        return updates_out, new_state

    return GradientTransformation(init_fn, update_fn)

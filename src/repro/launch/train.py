"""Training launcher — runs real steps on local devices.

On this CPU container it trains the REDUCED configs (or bert-large at a
small size) end-to-end with the paper's full recipe: LANS + warmup-hold-
decay schedule + sharded-without-replacement data. On TPU the same entry
point scales to the production mesh (--mesh production).

  PYTHONPATH=src python -m repro.launch.train --arch bert-large --steps 50 \
      --batch 32 --seq 128 --optimizer lans

Mixed precision (--precision {fp32,bf16,fp16}): fp16/bf16 hold the model
copy in half precision with fp32 master weights in the optimizer state;
fp16 adds apex-style dynamic loss scaling (skip the step + halve the scale
on overflow, grow it back after clean steps). The live `loss_scale` and
`overflow_count` appear in the console line and the JSONL metrics:

  PYTHONPATH=src python -m repro.launch.train --arch bert-large --steps 30 \
      --precision fp16 --metrics /tmp/fp16.jsonl
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save as ckpt_save
from repro.configs import get_arch, reduced_arch
from repro.core.optim import adamw, lamb, lans
from repro.core.schedules import warmup_hold_decay, warmup_linear_decay
from repro.data.corpus import SyntheticCorpus, lm_batch_iterator, mlm_batch_iterator
from repro.data.sharding import ShardSpec
from repro import precision as prec


def make_optimizer(name: str, schedule, *, policy=None, **kw):
    if name == "lans" and policy is not None:
        # moments store in the policy's dtype (math stays fp32 in-kernel).
        kw.setdefault("mu_dtype", policy.moment_dtype)
    return {"lans": lans, "lamb": lamb, "adamw": adamw}[name](schedule, **kw)


def make_data(arch, *, batch: int, seq: int, num_workers: int = 1, seed: int = 0):
    """Sharded-without-replacement stream (paper §3.4), worker 0 view."""
    corpus = SyntheticCorpus(vocab=arch.cfg.vocab, num_docs=4096,
                             doc_len=max(2 * seq + 2, 256), seed=seed)
    spec = ShardSpec(num_samples=corpus.num_docs, num_workers=num_workers,
                     worker=0, seed=seed)
    if arch.kind == "bert":
        return mlm_batch_iterator(corpus, spec, per_worker_batch=batch,
                                  seq_len=seq, seed=seed)
    if arch.kind == "encdec":
        rng = np.random.default_rng(seed)
        def gen():
            it = lm_batch_iterator(corpus, spec, per_worker_batch=batch,
                                   seq_len=seq)
            for b in it:
                yield {"frames": rng.normal(
                           size=(batch, arch.cfg.n_frames, arch.cfg.d_model)
                       ).astype(np.float32),
                       "tokens": b["tokens"], "labels": b["labels"]}
        return gen()
    if arch.embeds_input:
        rng = np.random.default_rng(seed)
        def gen():
            it = lm_batch_iterator(corpus, spec, per_worker_batch=batch,
                                   seq_len=seq)
            for b in it:
                yield {"embeds": rng.normal(
                           size=(batch, seq, arch.cfg.d_model)
                       ).astype(np.float32) * 0.02,
                       "labels": b["labels"]}
        return gen()
    return lm_batch_iterator(corpus, spec, per_worker_batch=batch, seq_len=seq)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert-large")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", default="lans",
                    choices=["lans", "lamb", "adamw"])
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "fp16"],
                    help="fp16/bf16: low-precision model copy + fp32 master "
                         "weights; fp16 adds dynamic loss scaling")
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--schedule", default="hold",
                    choices=["hold", "linear", "const"])
    ap.add_argument("--warmup-frac", type=float, default=0.2)
    ap.add_argument("--hold-frac", type=float, default=0.3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--metrics", default="", help="JSONL metrics path")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = reduced_arch(args.arch) if args.reduced else get_arch(args.arch)
    if args.reduced:
        args.seq = min(args.seq, arch.cfg.max_pos if arch.kind == "bert"
                       else getattr(arch.cfg, "max_seq", args.seq))

    warm = max(1, int(args.steps * args.warmup_frac))
    hold = int(args.steps * args.hold_frac)
    if args.schedule == "hold":
        sched = warmup_hold_decay(args.lr, args.steps + 1, warm, hold)
    elif args.schedule == "linear":
        sched = warmup_linear_decay(args.lr, args.steps + 1, warm)
    else:
        sched = lambda _: jnp.asarray(args.lr, jnp.float32)
    policy = prec.get_policy(args.precision)
    tx = make_optimizer(args.optimizer, sched, policy=policy)
    if policy.wants_wrapper:
        arch = dataclasses.replace(arch, cfg=policy.apply_to_cfg(arch.cfg))

    # One step builder for every entry point: build_train_step owns the
    # mixed-precision wiring (master-weight wrapper, loss scaling, metrics).
    from repro.distributed.steps import build_train_step, jit_train_step
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(data=1, model=1)
    step_fn, init_fn, specs_for = build_train_step(
        arch.loss_fn, tx, mesh, param_init_fn=arch.init, policy=policy)
    params, opt_state = init_fn(jax.random.PRNGKey(args.seed))
    pspec, ospec = specs_for(params, opt_state)

    from repro.metrics import MetricsLogger

    data = make_data(arch, batch=args.batch, seq=args.seq, seed=args.seed)
    t0 = time.time()
    losses = []
    logger = MetricsLogger(args.metrics or None)
    step = None
    with mesh:
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            if step is None:
                step = jit_train_step(step_fn, mesh, pspec, ospec, batch)
            params, opt_state, metrics = step(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            extra = {}
            if policy.wants_wrapper:
                extra = {"loss_scale": metrics["loss_scale"],
                         "overflow_count": metrics["overflow_count"]}
            logger.log(i + 1, loss=metrics["loss"],
                       lr=sched(jnp.asarray(i)), **extra)
            if (i + 1) % args.log_every == 0 or i == 0:
                ls_txt = (f"  scale {float(extra['loss_scale']):.0f}"
                          f"  ovf {int(extra['overflow_count'])}"
                          if extra else "")
                print(f"step {i+1:5d}  loss {losses[-1]:.4f}  "
                      f"(ema {logger.smoothed_loss:.4f})  "
                      f"lr {float(sched(jnp.asarray(i))):.2e}  "
                      f"{(time.time()-t0)/(i+1):.2f}s/step{ls_txt}",
                      flush=True)
    logger.close()

    if args.ckpt_dir:
        ckpt_save(args.ckpt_dir, args.steps, params,
                  metadata={"arch": args.arch, "optimizer": args.optimizer,
                            "final_loss": losses[-1]})
        print("checkpoint saved to", args.ckpt_dir)
    print(json.dumps({"first_loss": losses[0], "final_loss": losses[-1],
                      "steps": args.steps}))


if __name__ == "__main__":
    main()

"""Serving launcher: continuous-batching decode against an arch.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b \\
      --requests 16 --max-batch 4 --precision bf16 --metrics serve.jsonl \\
      --sampler temperature=0.8,top_k=40 --cache paged --shared-prefix 24

Generates a synthetic request stream (randomized prompt lengths and
generation budgets around --prompt-len / --new-tokens; --shared-prefix N
prepends a common N-token system prompt the paged cache deduplicates),
drives the requested engine and prints a JSON report: tokens/s,
time-to-first-token and inter-token latency percentiles, slot
utilization, peak concurrency, queue depth, preemption count and
shared/retained prefix block hits. --cache dense keeps the PR 2
per-slot-rows pool; --sampler greedy (default) or
"temperature=...,top_k=...,top_p=...,seed=..." samples with per-slot
PRNG keys (temperature=0 is bit-exact greedy). Scheduling is
policy-driven: --sched-policy picks the admission/preemption policy,
--growth lazy (default) allocates decode blocks on demand (preempting a
victim when the arena exhausts; --no-preempt turns that into an error),
--retain-blocks keeps evicted prefix blocks warm on a bounded LRU, and
--slo-ms evicts slots that blow their SLO. --chunk-budget N admits
prompts chunk by chunk within a per-step token budget (chunked prefill;
continuous+paged only). --spec-draft arms speculative draft-verify
decode: --spec-k draft tokens are proposed per slot and verified in one
batched step ('self' drafts with the target itself, 'truncated' builds
the make_spec_pair one-period draft whose proposals the doctored target
always accepts); the report gains acceptance-rate telemetry. --arrival-rate R replays the request stream as
seeded open-loop Poisson traffic at R req/s instead of submitting
everything up front, and reports goodput against the --ttft-slo-ms /
--itl-slo-ms bounds. --engine static runs the padded lockstep baseline
instead. --task picks the workload family: generate (default) decodes
with decoder or encoder-decoder archs — encdec archs synthesize
framed requests whose encoder output lands in the shared cross-
attention block arena (--shared-inputs N reuses N distinct inputs
round-robin, exercising encoder-block sharing) — while score / embed
need a bert arch and run batched masked-LM scoring / [CLS] embedding
through one fixed-shape forward (no KV cache; requests complete at
admission). --mesh DxM (e.g. 2x1, 1x2; a bare N means 1xN tensor
parallel) runs the continuous engine live-sharded over a local device
mesh — params per the distributed param rules, KV arenas blocks-over-
data / head_dim-over-model — with token output identical to the
unsharded engine (fp32 greedy, or bf16 with the stable-argmax
sampler). --replicas N serves the stream through N engine replicas
behind the prefix-affinity router (serving/router.py); --route-policy
picks prefix (content-addressed sticky routing, the default), depth
(least outstanding work) or rr (round-robin). --metrics writes one
JSONL record per decode step (active slots, queue depth, preemptions,
step latency) plus a final summary record — the serving analogue of
train.py's loss curve.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import get_arch, reduced_arch
from repro.metrics import MetricsLogger
from repro.serving import (ContinuousEngine, ReplicaRouter, ServeEngine,
                           synthetic_encdec_requests, synthetic_requests,
                           synthetic_scoring_requests)

# Flags that configure the continuous engine's PAGED pool (or features
# built on it): each entry is (flag, fn(args) -> requested?). They must
# fail fast — uniformly — under --engine static or --cache dense, where
# the subsystem they configure does not exist and the printed numbers
# would never have exercised the requested setting.
PAGED_ONLY_FLAGS = (
    ("--growth", lambda a: a.growth is not None),
    ("--slots-budget", lambda a: a.slots_budget != 0),
    ("--retain-blocks", lambda a: a.retain_blocks is not None),
    ("--watermark", lambda a: a.watermark != 0),
    ("--chunk-budget", lambda a: a.chunk_budget is not None),
    ("--spec-draft", lambda a: a.spec_draft != "none"),
    ("--spec-k", lambda a: a.spec_k is not None),
    ("--replicas", lambda a: a.replicas != 1),
    ("--route-policy", lambda a: a.route_policy is not None),
    ("--attn-kernel paged", lambda a: a.attn_kernel == "paged"),
    ("--interpret", lambda a: a.interpret),
)

# Flags of the continuous engine's scheduler/traffic loop: valid with
# either cache, invalid under --engine static (no scheduler there).
CONTINUOUS_ONLY_FLAGS = (
    ("--sched-policy", lambda a: a.sched_policy != "fifo"),
    ("--slo-ms", lambda a: a.slo_ms is not None),
    ("--no-preempt", lambda a: not a.preempt),
    ("--arrival-rate", lambda a: a.arrival_rate is not None),
    ("--mesh", lambda a: a.mesh is not None),
)


def flag_errors(args) -> list:
    """Every flag-compatibility error for this parse, uniform wording —
    one SystemExit lists them all (unit-tested in-process over the full
    flag matrix in tests/test_metrics_and_launchers.py)."""
    errs = []
    paged = args.engine == "continuous" and args.cache == "paged"
    bad = [f for f, req in PAGED_ONLY_FLAGS if req(args) and not paged]
    if bad:
        errs.append(
            f"{' '.join(bad)}: only apply to the continuous engine's "
            f"paged pool (--engine continuous --cache paged)")
    if args.engine != "continuous":
        bad = [f for f, req in CONTINUOUS_ONLY_FLAGS if req(args)]
        if bad:
            errs.append(
                f"{' '.join(bad)}: only apply to the continuous "
                f"engine's scheduler (--engine continuous)")
    if paged and args.interpret and args.attn_kernel != "paged":
        errs.append(
            "--interpret: only applies to the Pallas kernel path "
            "(--attn-kernel paged)")
    return errs


def parse_mesh(spec):
    """'DxM' (data x model) or a bare 'N' (= 1xN tensor parallel) ->
    local mesh; None stays None (unsharded)."""
    if spec is None:
        return None
    from repro.launch.mesh import make_local_mesh
    low = str(spec).lower()
    data, model = low.split("x") if "x" in low else (1, low)
    return make_local_mesh(data=int(data), model=int(model))


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--engine", choices=["continuous", "static"],
                    default="continuous")
    ap.add_argument("--task", choices=["generate", "score", "embed"],
                    default="generate",
                    help="workload family: generate (decoder/encdec "
                         "autoregressive decode), score (bert batched "
                         "masked-LM scoring) or embed (bert pooled "
                         "[CLS] embeddings). score/embed need a bert "
                         "arch and hold no KV cache")
    ap.add_argument("--shared-inputs", type=int, default=0,
                    help="encdec only: number of DISTINCT encoder "
                         "inputs reused round-robin across --requests "
                         "(0: all distinct). Same-input requests share "
                         "cross-attention arena blocks copy-free")
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "bf16_compute", "fp16"],
                    help="inference precision policy (greedy always fp32)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", "--max-batch", dest="max_batch", type=int,
                    default=4, help="decode slot-pool size")
    ap.add_argument("--max-len", type=int, default=0,
                    help="KV pool length (0: prompt-len + new-tokens)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--prefill-bucket", type=int, default=8,
                    help="round prompt lengths up to this multiple "
                         "(fewer prefill compiles; token-exact — one "
                         "batched prefill per bucket at admission)")
    ap.add_argument("--cache", choices=["paged", "dense"], default="paged",
                    help="paged: block arena + shared prompt prefixes; "
                         "dense: per-slot rows (PR 2 baseline)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged cache block granularity (must divide "
                         "max-len and any sliding window)")
    ap.add_argument("--slots-budget", type=int, default=0,
                    help="size the paged arena for this many dense-"
                         "equivalent slots (0: max-batch). Under lazy "
                         "growth this is a HIGH-WATERMARK on blocks in "
                         "use, not a per-request reservation — max-batch "
                         "can exceed it whenever budgets outrun typical "
                         "outputs or prefixes are shared")
    ap.add_argument("--growth", choices=["lazy", "eager"], default=None,
                    help="lazy (default): allocate decode blocks on "
                         "demand, preempt a victim when the arena "
                         "exhausts; eager: reserve the whole chain at "
                         "admission (PR 3 contract)")
    ap.add_argument("--sched-policy", default="fifo",
                    choices=["fifo", "arrival-deadline", "prefix-affinity"],
                    help="admission order + preemption victim selection "
                         "(see serving/scheduler.py)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="finish any slot active longer than this early "
                         "(SLO eviction of stuck slots; default off)")
    ap.add_argument("--no-preempt", dest="preempt", action="store_false",
                    help="turn lazy-growth arena exhaustion into an error "
                         "instead of preempting a victim")
    ap.add_argument("--retain-blocks", type=int, default=None,
                    help="LRU bound on warm prefix blocks kept alive after "
                         "their last holder evicts, per attention slot-"
                         "type (default: one batch's worth — covers a "
                         "multi-tenant prefix working set; 0 disables)")
    ap.add_argument("--watermark", type=int, default=0,
                    help="free blocks admission holds back per slot-type "
                         "so in-flight slots can grow without preempting")
    ap.add_argument("--attn-kernel", choices=["xla", "paged"], default=None,
                    help="paged decode attention: 'xla' gathers the block "
                         "arenas into a dense (B, ring) K/V copy per step; "
                         "'paged' streams blocks inside the fused Pallas "
                         "kernel (token-identical; interpret mode off-TPU; "
                         "requires --cache paged). Default: adopt the "
                         "arch config (usually 'xla')")
    ap.add_argument("--interpret", action="store_true",
                    help="force Pallas interpret mode for --attn-kernel "
                         "paged: the escape hatch for arena layouts that "
                         "fail real-TPU tile alignment (block_size / "
                         "head_dim off the 8/16 x 128 tile grid). Off-TPU "
                         "interpret is already the default")
    ap.add_argument("--chunk-budget", type=int, default=None,
                    help="per-step token budget for chunked-prefill "
                         "admission: prompts prefill chunk by chunk in "
                         "the decode loop's spare capacity instead of "
                         "one whole-prompt stall (continuous engine + "
                         "paged cache only; token-identical to whole-"
                         "prompt prefill)")
    ap.add_argument("--spec-draft", default="none",
                    choices=["none", "self", "truncated"],
                    help="speculative draft-verify decode: 'self' drafts "
                         "with the target model itself (exact-match "
                         "greedy proposals, acceptance ~1.0); "
                         "'truncated' doctors the target's upper "
                         "periods inert and drafts with its first "
                         "period (make_spec_pair; acceptance exactly "
                         "1.0). Continuous engine + paged cache only")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="draft tokens proposed and verified per "
                         "speculative round (>= 2; default 4)")
    ap.add_argument("--mesh", default=None,
                    help="serve live-sharded over a local device mesh: "
                         "'DxM' = data x model (e.g. 2x1, 1x2), bare N "
                         "= 1xN tensor parallel. Token-identical to "
                         "unsharded (fp32 greedy / bf16 stable argmax); "
                         "continuous engine only")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the prefix-affinity "
                         "router (serving/router.py); each replica owns "
                         "max-batch slots and its own paged arena")
    ap.add_argument("--route-policy", default=None,
                    choices=["prefix", "depth", "rr"],
                    help="router policy with --replicas: prefix "
                         "(content-addressed sticky affinity, default), "
                         "depth (least outstanding work), rr "
                         "(round-robin)")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="open-loop Poisson arrival rate in requests/s: "
                         "submit on the arrival clock instead of all up "
                         "front, and report goodput/SLO attainment "
                         "(continuous engine only; default closed-loop)")
    ap.add_argument("--ttft-slo-ms", type=float, default=1000.0,
                    help="open-loop TTFT bound (submit -> first token) "
                         "a request must meet to count toward goodput")
    ap.add_argument("--itl-slo-ms", type=float, default=200.0,
                    help="open-loop bound on EVERY inter-token gap; one "
                         "violation disqualifies the whole stream")
    ap.add_argument("--sampler", default="greedy",
                    help="'greedy' or 'temperature=0.8,top_k=40,"
                         "top_p=0.95,seed=0' (temperature=0 == greedy; "
                         "add stable=1 for the bf16 tie-stable argmax)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="common system-prompt tokens prepended to every "
                         "request (exercises prefix sharing)")
    ap.add_argument("--metrics", default=None,
                    help="JSONL path for per-step latency/throughput")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main():
    args = build_parser().parse_args()

    errs = flag_errors(args)
    if errs:
        raise SystemExit("; ".join(errs))

    arch = reduced_arch(args.arch) if args.reduced else get_arch(args.arch)
    if arch.kind not in ("decoder", "encdec", "bert"):
        raise SystemExit(f"{args.arch} is {arch.kind}: cannot serve")
    if args.engine == "static" and arch.kind != "decoder":
        raise SystemExit(
            f"--engine static is decoder-only, got {arch.kind}")
    if arch.kind == "bert" and args.task == "generate":
        raise SystemExit(f"{args.arch} is a bert arch: pass --task score "
                         f"or --task embed")
    if arch.kind != "bert" and args.task != "generate":
        raise SystemExit(f"--task {args.task} needs a bert arch, "
                         f"got {arch.kind}")
    for flag, wrong in (("--shared-prefix", args.shared_prefix
                         and arch.kind != "decoder"),
                        ("--shared-inputs", args.shared_inputs
                         and arch.kind != "encdec")):
        if wrong:
            raise SystemExit(f"{flag} does not apply to {arch.kind} archs")
    params = arch.init(jax.random.PRNGKey(args.seed))
    if arch.kind == "bert":     # scoring holds no decode budget
        max_len = args.max_len or args.prompt_len
    else:
        max_len = args.max_len or (args.prompt_len + args.new_tokens)

    if arch.kind == "encdec":
        reqs = synthetic_encdec_requests(
            args.requests, arch.cfg.vocab, n_frames=arch.cfg.n_frames,
            d_model=arch.cfg.d_model, prompt_len=args.prompt_len,
            new_tokens=args.new_tokens,
            n_inputs=args.shared_inputs or None, seed=args.seed)
    elif arch.kind == "bert":
        reqs = synthetic_scoring_requests(
            args.requests, arch.cfg.vocab, prompt_len=args.prompt_len,
            seed=args.seed)
    else:
        reqs = synthetic_requests(args.requests, arch.cfg.vocab,
                                  prompt_len=args.prompt_len,
                                  new_tokens=args.new_tokens,
                                  seed=args.seed,
                                  shared_prefix=args.shared_prefix)
    if args.shared_prefix:
        max_len += args.shared_prefix
    if args.cache == "paged" and arch.kind == "decoder":
        # arena rows come in whole blocks
        max_len = -(-max_len // args.block_size) * args.block_size
    log = MetricsLogger(args.metrics)

    spec_kw = {}
    spec_k = args.spec_k if args.spec_k is not None else 4
    if args.spec_draft == "self":
        spec_kw = dict(spec_draft=(arch, params), spec_k=spec_k)
    elif args.spec_draft == "truncated":
        from repro.serving import make_spec_pair
        params, draft_arch, draft_params = make_spec_pair(arch, params)
        spec_kw = dict(spec_draft=(draft_arch, draft_params),
                       spec_k=spec_k)

    mesh = parse_mesh(args.mesh)
    t0 = time.perf_counter()
    if args.engine == "continuous":
        last = {"t": t0}

        def make_on_step(replica):
            def on_step(rec):
                now = time.perf_counter()
                log.log(rec["step"], active=rec["active"],
                        queued=rec["queued"],
                        preemptions=rec["preemptions"],
                        step_latency_ms=(now - last["t"]) * 1e3,
                        replica=replica)
                last["t"] = now
            return on_step

        def make_engine(replica):
            return ContinuousEngine(
                arch, params, max_batch=args.max_batch, max_len=max_len,
                policy=args.precision, prefill_bucket=args.prefill_bucket,
                on_step=make_on_step(replica), cache=args.cache,
                block_size=args.block_size,
                slots_budget=args.slots_budget or None,
                sampler=args.sampler, attn_kernel=args.attn_kernel,
                kernel_interpret=True if args.interpret else None,
                growth=args.growth or "lazy",
                sched_policy=args.sched_policy,
                slo_ms=args.slo_ms, preempt=args.preempt,
                retain_blocks=args.retain_blocks,
                watermark=args.watermark,
                chunk_budget=args.chunk_budget, mesh=mesh,
                task=args.task, **spec_kw)

        if args.replicas > 1:
            engine = ReplicaRouter(
                [make_engine(i) for i in range(args.replicas)],
                policy=args.route_policy or "prefix")
        else:
            engine = make_engine(0)
        if args.arrival_rate is not None:
            from repro.serving import (OpenLoopDriver, SLO, poisson_arrivals,
                                       slo_report)
            arrivals = poisson_arrivals(len(reqs), args.arrival_rate,
                                        seed=args.seed)
            t0 = time.perf_counter()
            wall = OpenLoopDriver(engine, reqs, arrivals).run()
            stats = engine.report(wall)
            stats.update(slo_report(
                reqs, SLO(args.ttft_slo_ms, args.itl_slo_ms), wall))
        else:
            engine.run(reqs)
            stats = engine.report(time.perf_counter() - t0)
        pools = (engine.replicas[0].pool if args.replicas > 1
                 else engine.pool)
        attn_kernel = (pools.attn_kernel
                       if args.cache == "paged" and arch.kind == "decoder"
                       else "xla")
    else:
        attn_kernel = "xla"
        engine = ServeEngine(arch, params, max_len=max_len,
                             policy=args.precision, sampler=args.sampler)
        from repro.serving.metrics import aggregate
        for r in reqs:              # TTFT includes the inter-wave queue wait
            r.trace.mark_submit()
        for i in range(0, len(reqs), args.max_batch):
            engine.run_batch(reqs[i:i + args.max_batch])
        dt = time.perf_counter() - t0
        stats = aggregate([r.trace for r in reqs], dt,
                          sum(len(r.generated) for r in reqs))

    stats["engine"] = args.engine
    stats["task"] = args.task
    stats["precision"] = args.precision
    stats["cache"] = args.cache if args.engine == "continuous" else "static"
    stats["attn_kernel"] = attn_kernel
    stats["sampler"] = args.sampler
    stats["mesh"] = args.mesh or "1x1"
    log.log(-1, **{k: v for k, v in stats.items()
                   if isinstance(v, (int, float))})
    log.close()
    print(json.dumps({k: round(v, 3) if isinstance(v, float) else v
                      for k, v in stats.items()}))


if __name__ == "__main__":
    main()

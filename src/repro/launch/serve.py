"""Serving launcher: batched greedy decoding against a reduced arch.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --batch 4
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch, reduced_arch
from repro.serving.engine import Request, ServeEngine, throughput_probe


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = reduced_arch(args.arch) if args.reduced else get_arch(args.arch)
    if arch.kind == "bert":
        raise SystemExit("bert-large is encoder-only: no decode step")
    params = arch.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(arch, params,
                         max_len=args.prompt_len + args.new_tokens)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(
                5, arch.cfg.vocab, size=args.prompt_len).astype(np.int32),
                max_new_tokens=args.new_tokens)
            for _ in range(args.batch)]
    stats = throughput_probe(engine, reqs)
    print(stats)


if __name__ == "__main__":
    main()

"""Loop-aware HLO cost model.

XLA's built-in `compiled.cost_analysis()` counts each `while` body ONCE —
but every model here scans over layer periods (and chunked attention /
SSD chunks), so FLOPs, bytes and collective traffic inside loops are
undercounted by the trip count (13-72x). This module re-derives costs from
the optimized HLO text with loop awareness:

  cost(while) = cost(body) * trip_count(condition)
  cost(fusion/call) = cost(called computation)
  cost(conditional) = max over branches

FLOPs: dot ops dominate — 2 * prod(result dims) * prod(contracting dims),
with elementwise ops charged 1 FLOP/element. Bytes: per op, result bytes +
operand bytes (symbol-table lookup). Collectives: result-shape bytes, by
kind, scaled by enclosing trip counts.

Trip counts are extracted from scan-style conditions (`compare(counter,
constant)` — the largest integer literal in the condition computation).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\](?:\{[^}]*\})?")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "compare", "select", "and", "or", "not", "xor", "convert", "floor",
    "ceil", "round-nearest-even", "cosine", "sine", "logistic",
    "exponential-minus-one", "log-plus-one", "atan2", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "clamp", "reduce", "reduce-window",
}


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: Dict[str, float] = field(default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * times
            self.coll_counts[k] += other.coll_counts[k] * times


def _shapes_in(text: str):
    return [( _DTYPE_BYTES[dt], dims) for dt, dims in _SHAPE_RE.findall(text)]


def _nbytes(dt_bytes: int, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return float(n * dt_bytes)


def _nelems(dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return float(n)


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._cost_cache: Dict[str, Cost] = {}

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            s = line.strip()
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$", s)
            if m and not s.startswith("ROOT"):
                cur = m.group(2)
                self.computations[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if s == "}" or s == "})":
                cur = None
                continue
            if cur is not None and "=" in s:
                self.computations[cur].append(s)
        if self.entry is None and self.computations:
            # fall back: computation named like 'main...'
            for name in self.computations:
                if name.startswith("main"):
                    self.entry = name
                    break
            if self.entry is None:
                self.entry = max(self.computations,
                                 key=lambda c: len(self.computations[c]))

    # ---------------- trip counts ----------------

    def trip_count(self, cond_name: str) -> float:
        """Largest integer literal in the condition computation."""
        best = 1
        for line in self.computations.get(cond_name, []):
            for m in re.finditer(r"constant\((-?\d+)\)", line):
                best = max(best, int(m.group(1)))
        return float(best)

    # ---------------- per-computation cost ----------------

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._cost_cache:
            return self._cost_cache[comp_name]
        total = Cost()
        # pre-insert to break recursion on pathological graphs
        self._cost_cache[comp_name] = total
        symtab: Dict[str, float] = {}  # op name -> result bytes
        for line in self.computations.get(comp_name, []):
            m = re.match(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$", line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            # result shape(s): everything before the op name token
            op_m = re.search(r"\)?\s*([\w\-]+)\(", rhs)
            opname = op_m.group(1) if op_m else ""
            result_shapes = _shapes_in(rhs.split(opname + "(")[0]) if opname \
                else _shapes_in(rhs)
            result_bytes = sum(_nbytes(b, d) for b, d in result_shapes)
            symtab[name] = result_bytes

            # operand bytes via symbol table
            operand_names = re.findall(r"%([\w.\-]+)", rhs)
            operand_bytes = sum(symtab.get(o, 0.0) for o in operand_names)

            if opname == "while":
                cond = self._called(rhs, "condition")
                body = self._called(rhs, "body")
                # prefer XLA's own annotation: backend_config known_trip_count
                tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rhs)
                if tm:
                    trips = float(tm.group(1))
                else:
                    trips = self.trip_count(cond) if cond else 1.0
                if body:
                    total.add(self.cost_of(body), times=trips)
                continue
            if opname == "fusion":
                called = self._called(rhs, "calls")
                if called:
                    c = self.cost_of(called)
                    # fused internals never touch HBM: charge flops and any
                    # collectives, but bytes only at the fusion boundary.
                    total.flops += c.flops
                    for k in _COLLECTIVES:
                        total.coll[k] += c.coll[k]
                        total.coll_counts[k] += c.coll_counts[k]
                total.bytes += result_bytes + operand_bytes
                continue
            if opname in ("call", "custom-call"):
                called = self._called(rhs, "to_apply") or self._called(rhs, "called_computations")
                if called:
                    c = self.cost_of(called)
                    total.flops += c.flops
                    for k in _COLLECTIVES:
                        total.coll[k] += c.coll[k]
                        total.coll_counts[k] += c.coll_counts[k]
                total.bytes += result_bytes + operand_bytes
                continue
            if opname == "conditional":
                branches = re.findall(r"%([\w.\-]+)", rhs.split("branch")[-1]) \
                    if "branch" in rhs else []
                if branches:
                    costs = [self.cost_of(b) for b in branches
                             if b in self.computations]
                    if costs:
                        best = max(costs, key=lambda c: c.flops)
                        total.add(best)
                continue
            if opname in ("parameter", "constant", "get-tuple-element",
                          "tuple", "bitcast", "copy-start", "copy-done",
                          "after-all", "partition-id", "replica-id"):
                continue

            is_coll = None
            for k in _COLLECTIVES:
                if opname in (k, k + "-start", k + "-done"):
                    is_coll = k
                    break
            if is_coll:
                if opname.endswith("-done"):
                    continue
                total.coll[is_coll] += result_bytes
                total.coll_counts[is_coll] += 1
                total.bytes += result_bytes + operand_bytes
                continue

            if opname == "dot":
                flops = self._dot_flops(rhs, symtab, result_shapes)
                total.flops += flops
                total.bytes += result_bytes + operand_bytes
                continue
            if opname == "convolution":
                # rough: 2 * result elems * (window elems * in-channels)
                total.flops += 2.0 * sum(_nelems(d) for _, d in result_shapes)
                total.bytes += result_bytes + operand_bytes
                continue

            # elementwise & everything else: 1 flop per result element
            if opname in _ELEMENTWISE:
                total.flops += sum(_nelems(d) for _, d in result_shapes)
            total.bytes += result_bytes + operand_bytes
        return total

    def _called(self, rhs: str, key: str) -> Optional[str]:
        m = re.search(rf"{key}=%?([\w.\-]+)", rhs)
        if m and m.group(1) in self.computations:
            return m.group(1)
        # calls={%a, %b} form
        m = re.search(rf"{key}=\{{([^}}]*)\}}", rhs)
        if m:
            names = re.findall(r"%?([\w.\-]+)", m.group(1))
            for n in names:
                if n in self.computations:
                    return n
        return None

    def _dot_flops(self, rhs: str, symtab: Dict[str, float],
                   result_shapes) -> float:
        """2 * result_elems * prod(contracting dim sizes of lhs)."""
        result_elems = 0.0
        for _, dims in result_shapes:
            result_elems += _nelems(dims)
        lhs_dims: Optional[List[int]] = None
        m = re.search(r"dot\(([^)]*)\)", rhs)
        if m:
            inner = m.group(1)
            # newer jax prints operands with inline shapes:
            #   dot(f32[128,256]{1,0} %Arg_0.1, f32[256,64]{1,0} %Arg_1.2)
            inline = _SHAPE_RE.findall(inner)
            if inline:
                d = inline[0][1]
                lhs_dims = [int(x) for x in d.split(",")] if d else []
            else:
                # older style: dot(%Arg_0.1, %Arg_1.2) — symbol-table lookup
                names = re.findall(r"%([\w.\-]+)", inner)
                if names:
                    lhs_dims = self._shape_dims.get(names[0])
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
        contract = 1.0
        if cm and lhs_dims:
            for ax in cm.group(1).split(","):
                if ax != "":
                    ax = int(ax)
                    if ax < len(lhs_dims):
                        contract *= lhs_dims[ax]
        elif lhs_dims:
            contract = lhs_dims[-1] if lhs_dims else 1.0
        return 2.0 * result_elems * max(contract, 1.0)

    # symbol-table of dims per op name (filled lazily for dot lookups)
    @property
    def _shape_dims(self) -> Dict[str, List[int]]:
        if not hasattr(self, "_dims_cache"):
            dims: Dict[str, List[int]] = {}
            for lines in self.computations.values():
                for line in lines:
                    m = re.match(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$", line)
                    if not m:
                        continue
                    shapes = _SHAPE_RE.findall(m.group(2))
                    if shapes:
                        d = shapes[0][1]
                        dims[m.group(1)] = [int(x) for x in d.split(",")] if d else []
            self._dims_cache = dims
        return self._dims_cache


def analyze_hlo_text(text: str) -> Cost:
    mod = HloModule(text)
    return mod.cost_of(mod.entry)

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination
with ShapeDtypeStruct inputs — no allocation — and record memory / cost /
collective analysis for the roofline report.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all            # everything
Flags: --mesh {pod1,pod2,both}  --out experiments/dryrun  --microbatches N
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, SHAPES, get_arch
from repro.core.optim import lans
from repro.core.schedules import warmup_hold_decay
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh


def _shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def make_tx(arch):
    """The paper's optimizer + schedule, as lowered into the train step."""
    sched = warmup_hold_decay(0.00675, 3519, 1501, 962)  # paper stage-1 shape
    mu_dtype = arch.cfg.param_dtype if arch.zero3 else jnp.float32
    return lans(sched, mu_dtype=mu_dtype)


def lower_one(arch_name: str, shape_name: str, multi_pod: bool,
              microbatches: int = 1, attn_kernel: str = "xla") -> dict:
    arch = get_arch(arch_name)
    if attn_kernel != "xla" and arch.kind == "decoder":
        # Lower the decode shapes with the fused Pallas paged-attention
        # step instead of the XLA gather. Off-TPU this lowers the
        # interpret-mode kernel (practical only for reduced shapes — the
        # interpreter unrolls the (B, blocks) grid); on TPU it lowers
        # the compiled Mosaic kernel the production mesh would run.
        import dataclasses as _dc
        arch = _dc.replace(arch, cfg=_dc.replace(arch.cfg,
                                                 attn_kernel=attn_kernel))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    record = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "pod2" if multi_pod else "pod1", "n_chips": n_chips,
        "kind": shape.kind, "params": arch.param_count(),
        "zero3": arch.zero3,
    }
    if not arch.supports(shape_name):
        record["status"] = "skipped"
        record["reason"] = ("long_500k requires sub-quadratic attention"
                            if shape_name == "long_500k"
                            else f"{arch.kind} has no {shape.kind} step")
        return record

    t0 = time.time()
    params_abs = arch.abstract_params()
    pspec = shd.params_pspec(params_abs, mesh, zero3=arch.zero3)
    batch_abs = arch.input_specs(shape_name)
    bspec = shd.batch_pspec(batch_abs, mesh)

    if shape.kind == "train":
        tx = make_tx(arch)
        opt_abs = jax.eval_shape(tx.init, params_abs)
        mspec = None
        if arch.zero1 and not arch.zero3:
            # ZeRO-1: moments additionally sharded over "data"
            mspec = shd.params_pspec(params_abs, mesh, zero3=True)
        ospec = shd.opt_state_pspec(opt_abs, pspec, moments_spec=mspec)

        # Microbatch rows must stay divisible by the FULL data-parallel
        # extent (pod x data) or batch_pspec degrades to replicated and
        # every chip computes the whole microbatch (qwen32 pod2 showed 32x
        # FLOP replication at mb=16 — EXPERIMENTS.md iter 5).
        dp_total = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        mb = max(1, min(arch.train_microbatches,
                        shape.global_batch // dp_total))

        def train_step(params, opt_state, batch):
            def loss_fn(p, b):
                loss, aux = arch.loss_fn(p, b)
                return loss, aux

            # fp32 grad accumulation for fp32-master archs; bf16 for the
            # bf16-weights archs (documented memory/precision trade).
            acc_dtype = arch.cfg.param_dtype

            if mb <= 1:
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
                grads = jax.tree.map(
                    lambda g: g.astype(acc_dtype), grads)
            else:
                # gradient accumulation over microbatch slices (paper setup:
                # 96K global batch through a fixed device footprint)
                def body(carry, i):
                    acc, loss_acc = carry
                    sl = jax.tree.map(
                        lambda x: jax.lax.dynamic_slice_in_dim(
                            x, i * (x.shape[0] // mb), x.shape[0] // mb, 0)
                        if getattr(x, "ndim", 0) >= 1 else x, batch)
                    # re-pin batch sharding: GSPMD loses it on dynamic-slice
                    # along the sharded dim and would replicate the compute
                    sl = shd.constrain(sl, mesh, shd.batch_pspec(sl, mesh))
                    (loss, _), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, sl)
                    acc = jax.tree.map(
                        lambda a, g: a + g.astype(acc_dtype), acc, grads)
                    return (acc, loss_acc + loss), None

                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, acc_dtype), params)
                (grads, loss_sum), _ = jax.lax.scan(
                    body, (zero, jnp.zeros((), jnp.float32)),
                    jnp.arange(mb))
                grads = jax.tree.map(lambda g: g / mb, grads)
                loss = loss_sum / mb

            updates, new_opt = tx.update(grads, opt_state, params)
            from repro.core.optim.base import apply_updates
            new_params = apply_updates(params, updates)
            return new_params, new_opt, loss

        jitted = jax.jit(
            train_step,
            in_shardings=(_shardings(mesh, pspec), _shardings(mesh, ospec),
                          _shardings(mesh, bspec)),
            out_shardings=(_shardings(mesh, pspec), _shardings(mesh, ospec),
                           None),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
            compiled = lowered.compile()

    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            return arch.prefill(params, batch)

        cache_abs = jax.eval_shape(
            lambda p, b: arch.prefill(p, b)[1], params_abs, batch_abs)
        cspec = shd.cache_pspec(cache_abs, mesh)
        jitted = jax.jit(
            prefill_step,
            in_shardings=(_shardings(mesh, pspec), _shardings(mesh, bspec)),
            out_shardings=(None, _shardings(mesh, cspec)),
        )
        with mesh:
            lowered = jitted.lower(params_abs, batch_abs)
            compiled = lowered.compile()

    elif shape.kind == "decode" and arch.kind == "decoder":
        # Pooled PAGED decode: lower the exact serving step the
        # continuous-batching engine runs — block arenas sharded blocks-
        # over-data / head_dim-over-model, block-table gather included —
        # so the production-mesh sharding of the paged pool gets HLO
        # coverage (the engine-side no-recompile property is asserted in
        # tests/test_paged_cache.py).
        from repro.distributed.steps import build_serve_step

        cache_abs = arch.paged_cache_specs(shape_name)
        B = shape.global_batch
        tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        jitted = build_serve_step(arch.decode_step, mesh,
                                  params_like=params_abs,
                                  cache_like=cache_abs)
        record["cache"] = "paged"
        record["attn_kernel"] = attn_kernel
        with mesh:
            lowered = jitted.lower(params_abs, tok_abs, pos_abs, cache_abs)
            compiled = lowered.compile()

    else:  # decode, enc-dec archs (whisper): dense cross-attention cache
        cache_abs = arch.cache_specs(shape_name)
        cspec = shd.cache_pspec(cache_abs, mesh)

        def serve_step(params, batch, cache):
            return arch.decode_step(params, batch, cache)

        jitted = jax.jit(
            serve_step,
            in_shardings=(_shardings(mesh, pspec), _shardings(mesh, bspec),
                          _shardings(mesh, cspec)),
            out_shardings=(None, _shardings(mesh, cspec)),
            donate_argnums=(2,),
        )
        with mesh:
            lowered = jitted.lower(params_abs, batch_abs, cache_abs)
            compiled = lowered.compile()

    analysis = hlo_analysis.analyze_compiled(lowered, compiled, n_chips)

    # useful-FLOPs ratio: MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy)
    n_active = active_params(arch)
    n_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                     else 1)
    if shape.kind == "train":
        model_flops = hlo_analysis.model_flops_training(n_active, n_tokens)
    else:
        model_flops = hlo_analysis.model_flops_inference(n_active, n_tokens)
    analysis["model_flops"] = model_flops
    analysis["useful_flops_ratio"] = (
        model_flops / analysis["flops_global"]
        if analysis.get("flops_global") else 0.0)

    record.update(analysis)
    record["status"] = "ok"
    record["lower_compile_s"] = round(time.time() - t0, 1)
    return record


def active_params(arch) -> int:
    """Parameters touched per token (MoE: top_k of n_experts)."""
    total = arch.param_count()
    cfg = arch.cfg
    if getattr(cfg, "n_experts", 0) and cfg.n_experts > cfg.top_k:
        import math
        expert_leaf = 0
        params = arch.abstract_params()
        from repro.core.optim.base import tree_paths
        paths = jax.tree.leaves(tree_paths(params))
        leaves = jax.tree.leaves(params)
        for pth, leaf in zip(paths, leaves):
            if leaf.ndim == 4 and leaf.shape[1] == cfg.n_experts:
                expert_leaf += math.prod(leaf.shape)
        total = total - expert_leaf + expert_leaf * cfg.top_k // cfg.n_experts
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (the 10 assigned)")
    ap.add_argument("--shape", default="all", choices=["all", *SHAPES])
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--attn-kernel", default="xla", choices=["xla", "paged"],
                    help="decode shapes: lower the XLA arena gather or the "
                         "fused Pallas paged-attention step (see lower_one)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch_name in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                tag = f"{arch_name}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
                try:
                    rec = lower_one(arch_name, shape_name, multi_pod,
                                    attn_kernel=args.attn_kernel)
                except Exception as e:
                    rec = {"arch": arch_name, "shape": shape_name,
                           "mesh": "pod2" if multi_pod else "pod1",
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=2, default=str)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bound={r['dominant']} "
                             f"c={r['compute_s']:.3f}s m={r['memory_s']:.3f}s "
                             f"x={r['collective_s']:.3f}s "
                             f"useful={rec['useful_flops_ratio']:.2f} "
                             f"[{rec['lower_compile_s']}s]")
                elif status == "skipped":
                    extra = f" ({rec['reason']})"
                else:
                    extra = f" {rec['error'][:160]}"
                print(f"{tag:60s} {status}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} combinations failed")


if __name__ == "__main__":
    main()

"""Roofline-term extraction from compiled dry-run artifacts.

compiled.cost_analysis() provides HLO FLOPs and bytes-accessed; collective
traffic is NOT in cost_analysis, so we parse the (optimized) HLO text and
sum the operand bytes of every collective op:

  all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute

Roofline terms per §Roofline (v5e constants from launch/mesh.py):

  compute   = HLO_FLOPs / (chips * 197e12)
  memory    = HLO_bytes / (chips * 819e9)
  collective= collective_bytes / (chips * 50e9)
"""
from __future__ import annotations

import re
from typing import Dict

import numpy as np

from repro.launch import mesh as mesh_lib

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# e.g. "bf16[16,4096,384]{2,1,0}" inside an HLO op line
_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of OUTPUT shape bytes of every collective op, by kind.

    HLO lines look like:
      %ag = bf16[16,512]{...} all-gather(%x), replica_groups=...
    The leading shape is the op result; for collectives this is the traffic
    unit we charge (all-gather: gathered bytes; all-reduce: reduced tensor).
    """
    out = {k: 0 for k in _COLLECTIVE_KINDS}
    counts = {k: 0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^(%?[\w.\-]+)\s*=\s*(.*)$", s)
        if not m:
            continue
        rhs = m.group(2)
        kind = None
        for k in _COLLECTIVE_KINDS:
            # op name appears right after the result shape(s)
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rhs:
            continue  # -done pairs with -start; count once
        shapes = _SHAPE_RE.findall(rhs.split("(")[0])
        if not shapes:
            shapes = _SHAPE_RE.findall(rhs)
        total = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind] += total
        counts[kind] += 1
    out["_counts"] = counts
    return out


def op_counts(hlo_text: str, kinds=("scatter",)) -> Dict[str, int]:
    """Count ops of the named kinds in an HLO or StableHLO dump.

    Matches both spellings — HLO `scatter(...)` and StableHLO
    `"stablehlo.scatter"(...)` — while the kind must START the op name,
    so "scatter" does NOT match reduce-scatter / reduce_scatter and
    "gather" does not match all-gather. Used to pin fusion claims
    structurally: the fused paged-attention decode step must lower with
    ZERO arena scatters where the XLA branch lowers three
    (tests/test_paged_cache.py). NB count on the PRE-optimization
    lowering for backend-portable results: the CPU backend's scatter
    expander rewrites scatter into while loops during optimization.
    """
    counts = {k: 0 for k in kinds}
    for line in hlo_text.splitlines():
        m = re.match(r"^(%?[\w.\-\"]+)\s*=\s*(.*)$", line.strip())
        if not m:
            continue
        rhs = m.group(2)
        for k in kinds:
            if re.search(rf'(?:^|[^\w.\-])(?:\w+\.)?{k}"?\(', rhs):
                counts[k] += 1
    return counts


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes_total: float, n_chips: int) -> Dict[str, float]:
    """Roofline seconds. Inputs are GLOBAL totals; divide by chip count.

    NB: when costs come from the partitioned (per-chip) HLO program, pass
    n_chips=1 — the program is already one chip's share.
    """
    compute_s = flops / (n_chips * mesh_lib.PEAK_FLOPS_BF16)
    memory_s = bytes_accessed / (n_chips * mesh_lib.HBM_BW)
    collective_s = coll_bytes_total / (n_chips * mesh_lib.ICI_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms


def model_flops_training(n_params_active: int, n_tokens: int) -> float:
    """6*N*D — the standard training-FLOPs estimate (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params_active * n_tokens


def model_flops_inference(n_params_active: int, n_tokens: int) -> float:
    return 2.0 * n_params_active * n_tokens


def analyze_compiled(lowered, compiled, n_chips: int) -> dict:
    from repro.launch import hlo_cost

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()

    # Loop-aware cost model: XLA's cost_analysis counts while bodies once,
    # which undercounts scanned-layer models by the layer count.
    loop_cost = hlo_cost.analyze_hlo_text(hlo)
    flops = loop_cost.flops
    byts = loop_cost.bytes
    coll = dict(loop_cost.coll)
    coll["_counts"] = {k: int(v) for k, v in loop_cost.coll_counts.items()}
    coll_total = sum(v for k, v in coll.items() if not k.startswith("_"))

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # CPU backend may not expose memory analysis
        mem["error"] = str(e)

    # The SPMD-partitioned HLO is the per-chip program: costs are per chip.
    terms = roofline_terms(flops, byts, coll_total, n_chips=1)
    return {
        "flops": flops,                      # per chip
        "flops_global": flops * n_chips,
        "bytes_accessed": byts,              # per chip
        "xla_flops_loop_blind": xla_flops,
        "xla_bytes_loop_blind": xla_bytes,
        "collective_bytes": {k: v for k, v in coll.items()
                             if not k.startswith("_")},
        "collective_counts": coll.get("_counts", {}),
        "collective_bytes_total": coll_total,
        "memory_analysis": mem,
        "roofline": terms,
        "n_chips": n_chips,
    }

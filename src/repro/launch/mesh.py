"""Production mesh builders.

Single pod:  (data=16, model=16)           = 256 chips  (TPU v5e pod slice)
Multi-pod:   (pod=2, data=16, model=16)    = 512 chips; "pod" is a pure
data-parallel axis whose gradient all-reduce crosses DCN — the paper's
scale-out pattern (192 instances x 8 GPUs ~ outer DP axis over EFA).

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and only
dryrun.py forces 512 host devices).
"""
from __future__ import annotations

import jax


def _mk(shape, axes):
    # AxisType (and make_mesh's axis_types kwarg) only exist on newer jax;
    # older versions treat every axis as Auto already, so plain make_mesh is
    # semantically identical there.
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(*, data: int = 1, model: int = 1):
    """Mesh over whatever devices exist locally (tests / CPU examples)."""
    return _mk((data, model), ("data", "model"))


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link (~ per-chip usable)
HBM_BYTES = 16 * 1024**3       # 16 GiB

"""Pytree checkpointing (npz-based; orbax is not available offline).

Layout: <dir>/step_<N>/arrays.npz + manifest.json holding the treedef and
dtypes. Arrays are gathered to host before writing (works under pjit: the
caller is expected to pass addressable arrays or fully-replicated ones).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "::"


def _flatten_with_paths(tree: PyTree):
    from repro.core.optim.base import tree_paths

    paths = tree_paths(tree)
    flat_paths = jax.tree_util.tree_leaves(paths)
    flat_vals = jax.tree_util.tree_leaves(tree)
    return flat_paths, flat_vals


def save(ckpt_dir: str, step: int, tree: PyTree, *, metadata: Optional[dict] = None):
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(out, exist_ok=True)
    paths, vals = _flatten_with_paths(tree)
    assert len(set(paths)) == len(paths), "duplicate param paths"
    arrays = {p: np.asarray(v) for p, v in zip(paths, vals)}
    np.savez(os.path.join(out, "arrays.npz"), **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "paths": paths,
        "metadata": metadata or {},
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (names must match)."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(src, "arrays.npz"))
    paths, vals = _flatten_with_paths(like)
    loaded = []
    for p, v in zip(paths, vals):
        if p not in data:
            raise KeyError(f"checkpoint missing {p}")
        arr = data[p]
        if arr.shape != tuple(v.shape):
            raise ValueError(f"{p}: shape {arr.shape} != {tuple(v.shape)}")
        loaded.append(jax.numpy.asarray(arr, dtype=v.dtype))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, loaded)

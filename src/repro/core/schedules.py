"""Learning-rate schedules from the paper.

eq. (8): LAMB's linear warmup -> linear decay.
eq. (9): the paper's contribution — linear warmup -> CONSTANT HOLD -> linear
decay. The hold phase lets training spend longer at the (Lipschitz-bounded)
maximum learning rate when eta can no longer scale with sqrt(batch).

Also includes:
  - sqrt_scaling_rule: eta = sqrt(k) * eta_ref (LAMB's batch-size scaling),
  - schedule_auc: area under the schedule curve — reproduces the Fig. 1
    analysis (gap 5.28 vs 1.91),
  - paper_stage_schedules(): the exact Table 1 hyper-parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def warmup_linear_decay(eta: float, total_steps: int, warmup_steps: int) -> Schedule:
    """eq. (8). t is the 0-indexed step count (internally shifted to 1-indexed)."""
    if not 0 < warmup_steps < total_steps:
        raise ValueError(f"need 0 < warmup({warmup_steps}) < total({total_steps})")

    def sched(count):
        t = count.astype(jnp.float32) + 1.0
        warm = eta * t / warmup_steps
        decay = eta * (total_steps - t) / (total_steps - warmup_steps)
        return jnp.maximum(jnp.where(t <= warmup_steps, warm, decay), 0.0)

    return sched


def warmup_hold_decay(
    eta: float, total_steps: int, warmup_steps: int, hold_steps: int
) -> Schedule:
    """eq. (9): warmup -> constant hold of ``hold_steps`` -> linear decay."""
    if not 0 < warmup_steps < total_steps:
        raise ValueError(f"need 0 < warmup({warmup_steps}) < total({total_steps})")
    if warmup_steps + hold_steps >= total_steps:
        raise ValueError("warmup + hold must leave room for decay")

    def sched(count):
        t = count.astype(jnp.float32) + 1.0
        warm = eta * t / warmup_steps
        decay = eta * (total_steps - t) / (total_steps - warmup_steps - hold_steps)
        out = jnp.where(
            t <= warmup_steps,
            warm,
            jnp.where(t <= warmup_steps + hold_steps, eta, decay),
        )
        return jnp.maximum(out, 0.0)

    return sched


def constant(eta: float) -> Schedule:
    return lambda count: jnp.full([], eta, jnp.float32)


def sqrt_scaling_rule(eta_ref: float, batch_ref: int, batch: int) -> float:
    """LAMB's square-root LR scaling: eta = sqrt(batch/batch_ref) * eta_ref.

    The paper's point: this BREAKS past ~32-64K because eta exceeds the
    Lipschitz bound 1/L; eq. (9)'s hold phase is the fix.
    """
    return float(eta_ref * np.sqrt(batch / batch_ref))


def schedule_auc(sched: Schedule, total_steps: int) -> float:
    """Sum of eta_t over the schedule — the 'area under curve' of Fig. 1."""
    import jax

    ts = jnp.arange(total_steps, dtype=jnp.int32)
    vals = jax.vmap(sched)(ts)  # schedules are elementwise in t
    return float(jnp.sum(vals))


@dataclasses.dataclass(frozen=True)
class StageSchedule:
    """One pretraining stage (paper §4 / Table 1)."""

    name: str
    batch_size: int
    seq_len: int
    total_steps: int
    eta: float
    ratio_warmup: float
    ratio_const: float

    @property
    def warmup_steps(self) -> int:
        return max(1, round(self.total_steps * self.ratio_warmup))

    @property
    def hold_steps(self) -> int:
        return max(0, round(self.total_steps * self.ratio_const))

    def schedule(self) -> Schedule:
        return warmup_hold_decay(
            self.eta, self.total_steps, self.warmup_steps, self.hold_steps
        )


def paper_stage_schedules() -> tuple:
    """Exact Table 1 / §4 settings: batches 96K/33K, 3519 + 782 steps."""
    stage1 = StageSchedule(
        name="phase1_seq128",
        batch_size=96 * 1024,
        seq_len=128,
        total_steps=3519,
        eta=0.00675,
        ratio_warmup=0.4265,
        ratio_const=0.2735,   # warmup + const = 70%
    )
    stage2 = StageSchedule(
        name="phase2_seq512",
        batch_size=33 * 1024,
        seq_len=512,
        total_steps=782,
        eta=0.005,
        ratio_warmup=0.192,
        ratio_const=0.108,    # warmup + const = 30%
    )
    return stage1, stage2


def figure1_settings() -> dict:
    """The exact Fig. 1 configuration for the AUC-gap reproduction."""
    return dict(total_steps=3519, warmup_steps=1500, hold_steps=963,
                eta_feasible=0.007, eta_ideal=0.01)

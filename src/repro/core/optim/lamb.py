"""LAMB — Algorithm 1 (You et al., ICLR 2020), the paper's primary baseline.

Kept faithful to the listing reproduced in the LANS paper:

    m_t = b1*m + (1-b1)*g          v_t = b2*v + (1-b2)*g^2
    r_t = m~_t / (sqrt(v~_t) + eps)
    x  <- x - eta_t * phi(||x||) / ||r_t + lam*x|| * (r_t + lam*x)

Shares the block conventions of lans.py (block == parameter tensor; bias /
norm blocks get phi == 1, no decay, no trust normalization).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.optim.base import (
    GradientTransformation,
    WeightDecayMask,
    bias_correction,
    safe_norm,
    tree_paths,
)


class LambState(NamedTuple):
    count: jnp.ndarray
    mu: jnp.ndarray
    nu: jnp.ndarray


def _lamb_block_update(
    g, m, v, x, *, count, beta1, beta2, eps, weight_decay, decay_this_block,
    phi_clip=None, grad_clip_norm=None, global_grad_norm=None,
):
    g = g.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    lam = weight_decay if decay_this_block else 0.0

    # LAMB (unlike LANS) needs global gradient clipping for stability.
    if grad_clip_norm is not None and global_grad_norm is not None:
        clip = jnp.minimum(1.0, grad_clip_norm / jnp.maximum(global_grad_norm, 1e-12))
        g = g * clip

    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * jnp.square(g)

    t = count + 1
    m_hat = m_new / bias_correction(beta1, t)
    v_hat = v_new / bias_correction(beta2, t)

    r = m_hat / (jnp.sqrt(v_hat) + eps)
    u = r + lam * x32

    x_norm = safe_norm(x32)
    phi = x_norm if phi_clip is None else jnp.clip(x_norm, phi_clip[0], phi_clip[1])
    u_norm = safe_norm(u)
    trust = jnp.where(u_norm > 0, phi / jnp.maximum(u_norm, 1e-38), 1.0)
    if not decay_this_block:
        trust = jnp.ones_like(trust)

    d = trust * u
    return d.astype(x.dtype), m_new, v_new


def scale_by_lamb(
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    decay_mask: Optional[Callable[[str], bool]] = None,
    phi_clip: Optional[tuple] = None,
    grad_clip_norm: Optional[float] = 1.0,
) -> GradientTransformation:
    mask_fn = decay_mask or WeightDecayMask()

    def init_fn(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return LambState(
            count=jnp.zeros([], jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update_fn(updates, state, params):
        if params is None:
            raise ValueError("LAMB requires params.")
        paths = tree_paths(params)
        masks = jax.tree.map(lambda pth: bool(mask_fn(pth)), paths)

        global_norm = None
        if grad_clip_norm is not None:
            sq = sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(updates)
            )
            global_norm = jnp.sqrt(sq)

        flat_g, treedef = jax.tree_util.tree_flatten(updates)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_x = treedef.flatten_up_to(params)
        flat_mask = treedef.flatten_up_to(masks)

        outs = [
            _lamb_block_update(
                g, m, v, x,
                count=state.count, beta1=beta1, beta2=beta2, eps=eps,
                weight_decay=weight_decay, decay_this_block=dm,
                phi_clip=phi_clip, grad_clip_norm=grad_clip_norm,
                global_grad_norm=global_norm,
            )
            for g, m, v, x, dm in zip(flat_g, flat_m, flat_v, flat_x, flat_mask)
        ]
        new_d = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
        return new_d, LambState(count=state.count + 1, mu=new_m, nu=new_v)

    return GradientTransformation(init_fn, update_fn)


def lamb(
    learning_rate,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    decay_mask: Optional[Callable[[str], bool]] = None,
    phi_clip: Optional[tuple] = None,
    grad_clip_norm: Optional[float] = 1.0,
) -> GradientTransformation:
    from repro.core.optim.base import chain, scale_by_schedule

    sched = learning_rate if callable(learning_rate) else (
        lambda _: jnp.asarray(learning_rate, jnp.float32))
    return chain(
        scale_by_lamb(beta1, beta2, eps, weight_decay, decay_mask, phi_clip,
                      grad_clip_norm),
        scale_by_schedule(sched),
    )

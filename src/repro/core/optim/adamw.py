"""AdamW (Loshchilov & Hutter) and the paper's finetuning variant:
AdamW + per-block gradient normalization (eq. 4) — "BN-AdamW".

The paper uses plain AdamW with eq. (4) applied first for SQuAD finetuning.
Also provides SGD with classic / Nesterov momentum (paper §2.2 eqs. 2-3),
used in tests to verify the NAG identity that motivates LANS' momentum form.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.optim.base import (
    GradientTransformation,
    WeightDecayMask,
    bias_correction,
    chain,
    safe_div,
    safe_norm,
    scale_by_schedule,
    tree_paths,
)


class AdamWState(NamedTuple):
    count: jnp.ndarray
    mu: jnp.ndarray
    nu: jnp.ndarray


def scale_by_adamw(
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    decay_mask: Optional[Callable[[str], bool]] = None,
    block_normalize: bool = False,
) -> GradientTransformation:
    """AdamW direction; block_normalize=True applies paper eq. (4) first."""
    mask_fn = decay_mask or WeightDecayMask()

    def init_fn(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            count=jnp.zeros([], jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update_fn(updates, state, params):
        if params is None:
            raise ValueError("AdamW (decoupled decay) requires params.")
        paths = tree_paths(params)
        masks = jax.tree.map(lambda pth: bool(mask_fn(pth)), paths)
        t = state.count + 1

        def block(g, m, v, x, dm):
            g = g.astype(jnp.float32)
            if block_normalize:
                g = safe_div(g, safe_norm(g))
            m_new = beta1 * m + (1.0 - beta1) * g
            v_new = beta2 * v + (1.0 - beta2) * jnp.square(g)
            m_hat = m_new / bias_correction(beta1, t)
            v_hat = v_new / bias_correction(beta2, t)
            d = m_hat / (jnp.sqrt(v_hat) + eps)
            if dm:
                d = d + weight_decay * x.astype(jnp.float32)
            return d.astype(x.dtype), m_new, v_new

        flat_g, treedef = jax.tree_util.tree_flatten(updates)
        outs = [
            block(g, m, v, x, dm)
            for g, m, v, x, dm in zip(
                flat_g,
                treedef.flatten_up_to(state.mu),
                treedef.flatten_up_to(state.nu),
                treedef.flatten_up_to(params),
                treedef.flatten_up_to(masks),
            )
        ]
        new_d = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
        return new_d, AdamWState(count=t, mu=new_m, nu=new_v)

    return GradientTransformation(init_fn, update_fn)


def adamw(learning_rate, **kw) -> GradientTransformation:
    sched = learning_rate if callable(learning_rate) else (
        lambda _: jnp.asarray(learning_rate, jnp.float32))
    return chain(scale_by_adamw(**kw), scale_by_schedule(sched))


def bn_adamw(learning_rate, **kw) -> GradientTransformation:
    """The paper's finetuning optimizer: AdamW + blockwise grad normalization."""
    kw.setdefault("block_normalize", True)
    return adamw(learning_rate, **kw)


# ---------------------------------------------------------------------------
# SGD with classic momentum (eqs. 2-3) and Nesterov momentum (paper §2.2).
# ---------------------------------------------------------------------------

class MomentumState(NamedTuple):
    momentum: jnp.ndarray


def scale_by_momentum(mu: float = 0.9, nesterov: bool = False) -> GradientTransformation:
    def init_fn(params):
        return MomentumState(jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params))

    def update_fn(updates, state, params=None):
        del params
        m_new = jax.tree.map(
            lambda m, g: mu * m + g.astype(jnp.float32), state.momentum, updates)
        if nesterov:
            # x_{t+1} = x_t - eta (mu * m_t + g_t): the "future momentum" form.
            d = jax.tree.map(lambda m, g: mu * m + g.astype(jnp.float32), m_new, updates)
        else:
            d = m_new
        d = jax.tree.map(lambda dd, g: dd.astype(g.dtype), d, updates)
        return d, MomentumState(m_new)

    return GradientTransformation(init_fn, update_fn)


def sgd(learning_rate, mu: float = 0.0, nesterov: bool = False) -> GradientTransformation:
    sched = learning_rate if callable(learning_rate) else (
        lambda _: jnp.asarray(learning_rate, jnp.float32))
    if mu == 0.0:
        from repro.core.optim.base import identity
        return chain(identity(), scale_by_schedule(sched))
    return chain(scale_by_momentum(mu, nesterov), scale_by_schedule(sched))

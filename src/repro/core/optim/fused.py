"""Kernel-backed LANS/LAMB: the Pallas fused step as a GradientTransformation.

Drop-in replacement for `lans(...)` / `lamb(...)` that routes every block
through the 3-phase Pallas pipeline (repro.kernels.ops). This is the TPU
analogue of the paper's `fused_lans` apex optimizer. On this CPU container
the kernels run in interpret mode; on TPU pass interpret=False.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.optim.base import (
    GradientTransformation,
    WeightDecayMask,
    tree_paths,
)
from repro.kernels import ops


class FusedState(NamedTuple):
    count: jnp.ndarray
    mu: jnp.ndarray
    nu: jnp.ndarray


def _make_fused(step_fn, needs_clip: bool):
    def factory(
        learning_rate,
        beta1: float = 0.9,
        beta2: float = 0.999,
        eps: float = 1e-6,
        weight_decay: float = 0.01,
        decay_mask: Optional[Callable[[str], bool]] = None,
        grad_clip_norm: Optional[float] = 1.0,
        interpret: bool = True,
    ) -> GradientTransformation:
        mask_fn = decay_mask or WeightDecayMask()
        sched = learning_rate if callable(learning_rate) else (
            lambda _: jnp.asarray(learning_rate, jnp.float32))

        def init_fn(params):
            zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
            return FusedState(
                count=jnp.zeros([], jnp.int32),
                mu=jax.tree.map(zeros, params),
                nu=jax.tree.map(zeros, params),
            )

        def update_fn(updates, state, params):
            if params is None:
                raise ValueError("fused optimizers require params")
            paths = tree_paths(params)
            masks = jax.tree.map(lambda pth: bool(mask_fn(pth)), paths)
            t = state.count + 1
            eta = sched(state.count)

            clip_kw = {}
            if needs_clip:
                if grad_clip_norm is not None:
                    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(updates))
                    gnorm = jnp.sqrt(sq)
                    clip_kw["clip"] = jnp.minimum(
                        1.0, grad_clip_norm / jnp.maximum(gnorm, 1e-12))
                else:
                    clip_kw["clip"] = jnp.float32(1.0)

            flat_g, treedef = jax.tree_util.tree_flatten(updates)
            outs = []
            for g, m, v, x, dm in zip(
                flat_g,
                treedef.flatten_up_to(state.mu),
                treedef.flatten_up_to(state.nu),
                treedef.flatten_up_to(params),
                treedef.flatten_up_to(masks),
            ):
                o = step_fn(
                    g, m, v, x, eta=eta, step=t,
                    beta1=beta1, beta2=beta2, eps=eps,
                    lam=weight_decay if dm else 0.0,
                    apply_trust=bool(dm),
                    interpret=interpret, **clip_kw)
                # Express as an additive update: delta = x_new - x.
                outs.append(((o.x - x).astype(x.dtype), o.m, o.v))
            new_d = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
            new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
            new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
            return new_d, FusedState(count=t, mu=new_m, nu=new_v)

        return GradientTransformation(init_fn, update_fn)

    return factory


fused_lans = _make_fused(ops.fused_lans_step, needs_clip=False)
fused_lamb = _make_fused(ops.fused_lamb_step, needs_clip=True)

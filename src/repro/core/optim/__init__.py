from repro.core.optim.base import (
    GradientTransformation,
    WeightDecayMask,
    apply_updates,
    chain,
    identity,
    scale,
    scale_by_schedule,
    tree_paths,
)
from repro.core.optim.adamw import adamw, bn_adamw, scale_by_adamw, sgd
from repro.core.optim.lamb import LambState, lamb, scale_by_lamb
from repro.core.optim.lans import LansState, lans, scale_by_lans

__all__ = [
    "GradientTransformation", "WeightDecayMask", "apply_updates", "chain",
    "identity", "scale", "scale_by_schedule", "tree_paths",
    "adamw", "bn_adamw", "scale_by_adamw", "sgd",
    "LambState", "lamb", "scale_by_lamb",
    "LansState", "lans", "scale_by_lans",
]

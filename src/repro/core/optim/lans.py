"""LANS — the paper's Algorithm 2.

Differences from LAMB (Algorithm 1), per paper §3:

  1. Per-block gradient normalization (eq. 4):
         g~_b = g_b / ||g_b||_2
     applied BEFORE the Adam moment updates. Gradient clipping becomes
     unnecessary (the update direction is invariant to the gradient scale of
     each block).

  2. Nesterov-style update (eq. 7): convex combination of two separately
     normalized directions,
         d_b = phi(||x_b||) * [ beta1   * (r_b + lam*x_b)/||r_b + lam*x_b||
                              + (1-b1)  * (c_b + lam*x_b)/||c_b + lam*x_b|| ]
     with r_b = m~_b / (sqrt(v~_b) + eps) the bias-corrected trust direction
     and  c_b = g~_b / (sqrt(v~_b) + eps) the momentum-free direction.
     The 1/(1-beta1^t) bias-correction is deliberately NOT applied to c_b
     (paper drops it to avoid a bias toward g when lam > 0).

A "block" follows the paper's definition: one parameter tensor (leaf of the
pytree). Under pjit/SPMD, the per-block sums-of-squares lower to partial
reductions + all-reduce automatically, so this implementation is correct for
sharded parameters (ZeRO/FSDP) with no special casing.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.optim.base import (
    GradientTransformation,
    Schedule,
    WeightDecayMask,
    bias_correction,
    safe_div,
    safe_norm,
    tree_paths,
)


class LansState(NamedTuple):
    count: jnp.ndarray  # int32, number of completed steps
    mu: jnp.ndarray  # first moment pytree (fp32)
    nu: jnp.ndarray  # second moment pytree (fp32)


def _lans_block_update(
    g: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    x: jnp.ndarray,
    *,
    count: jnp.ndarray,
    beta1: float,
    beta2: float,
    eps: float,
    weight_decay: float,
    decay_this_block: bool,
    phi_clip: Optional[tuple] = None,
    normalize_grads: bool = True,
    nesterov: bool = True,
):
    """One LANS step for a single block. Returns (direction, new_m, new_v).

    ``direction`` is the positive step d_t; caller applies x <- x - eta*d.
    All math in fp32 regardless of input dtypes.
    """
    g = g.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    mu_dtype = m.dtype
    m = m.astype(jnp.float32)
    v = v.astype(jnp.float32)
    lam = weight_decay if decay_this_block else 0.0

    # eq. (4): blockwise gradient normalization.
    if normalize_grads:
        g_norm = safe_norm(g)
        g_tilde = safe_div(g, g_norm)
    else:
        g_tilde = g

    # Adam moments on the normalized gradient.
    m_new = beta1 * m + (1.0 - beta1) * g_tilde
    v_new = beta2 * v + (1.0 - beta2) * jnp.square(g_tilde)

    # Bias corrections (count is the completed-steps counter; this step is t=count+1).
    t = count + 1
    m_hat = m_new / bias_correction(beta1, t)
    v_hat = v_new / bias_correction(beta2, t)

    denom = jnp.sqrt(v_hat) + eps
    r = m_hat / denom                     # trust direction (with momentum)
    c = g_tilde / denom                   # momentum-free direction (no 1/(1-b1^t))

    r_full = r + lam * x32
    c_full = c + lam * x32

    # phi(||x||): identity, optionally clipped (LAMB practice allows clamping).
    x_norm = safe_norm(x32)
    phi = x_norm
    if phi_clip is not None:
        phi = jnp.clip(phi, phi_clip[0], phi_clip[1])
    # For blocks excluded from trust scaling (biases / norms), phi -> 1 and the
    # normalization is skipped: fall back to the inner Adam-style direction.
    r_n = safe_norm(r_full)
    c_n = safe_norm(c_full)
    scale_r = jnp.where(r_n > 0, phi / jnp.maximum(r_n, 1e-38), 1.0)
    scale_c = jnp.where(c_n > 0, phi / jnp.maximum(c_n, 1e-38), 1.0)
    if not decay_this_block:
        # paper/LAMB practice: phi==1 and no trust normalization for bias/LN blocks.
        scale_r = jnp.ones_like(scale_r)
        scale_c = jnp.ones_like(scale_c)

    if nesterov:
        d = beta1 * scale_r * r_full + (1.0 - beta1) * scale_c * c_full
    else:
        d = scale_r * r_full   # classic-momentum LAMB-style update
    return d.astype(x.dtype), m_new.astype(mu_dtype), v_new.astype(mu_dtype)


def scale_by_lans(
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    decay_mask: Optional[Callable[[str], bool]] = None,
    phi_clip: Optional[tuple] = None,
    mu_dtype=jnp.float32,
    normalize_grads: bool = True,
    nesterov: bool = True,
) -> GradientTransformation:
    """LANS direction transform (Algorithm 2, without the -eta_t factor).

    mu_dtype: storage dtype of the moments (bf16 halves optimizer memory for
    the 314B/398B archs; math is always fp32 — documented deviation).
    normalize_grads / nesterov: ablation switches for the paper's two
    components (eq. 4 blockwise normalization; eq. 7 Nesterov-style
    convex-combination update). Both True == Algorithm 2; both False is
    LAMB-without-clipping (benchmarks/ablation_lans.py).
    """
    mask_fn = decay_mask or WeightDecayMask()

    def init_fn(params):
        zeros = lambda p: jnp.zeros(p.shape, mu_dtype)
        return LansState(
            count=jnp.zeros([], jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update_fn(updates, state, params):
        if params is None:
            raise ValueError("LANS requires params (trust-ratio + weight decay).")
        paths = tree_paths(params)
        masks = jax.tree.map(lambda pth: bool(mask_fn(pth)), paths)

        flat_g, treedef = jax.tree_util.tree_flatten(updates)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_x = treedef.flatten_up_to(params)
        flat_mask = treedef.flatten_up_to(masks)

        outs = [
            _lans_block_update(
                g, m, v, x,
                count=state.count,
                beta1=beta1, beta2=beta2, eps=eps,
                weight_decay=weight_decay,
                decay_this_block=dm,
                phi_clip=phi_clip,
                normalize_grads=normalize_grads,
                nesterov=nesterov,
            )
            for g, m, v, x, dm in zip(flat_g, flat_m, flat_v, flat_x, flat_mask)
        ]
        new_d = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
        return new_d, LansState(count=state.count + 1, mu=new_m, nu=new_v)

    return GradientTransformation(init_fn, update_fn)


def lans(
    learning_rate,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    decay_mask: Optional[Callable[[str], bool]] = None,
    phi_clip: Optional[tuple] = None,
    mu_dtype=jnp.float32,
    normalize_grads: bool = True,
    nesterov: bool = True,
) -> GradientTransformation:
    """Full LANS optimizer: direction transform x (-eta_t)."""
    from repro.core.optim.base import chain, scale, scale_by_schedule

    sched: Schedule
    if callable(learning_rate):
        sched = learning_rate
    else:
        sched = lambda _: jnp.asarray(learning_rate, jnp.float32)
    return chain(
        scale_by_lans(beta1, beta2, eps, weight_decay, decay_mask, phi_clip,
                      mu_dtype, normalize_grads, nesterov),
        scale_by_schedule(sched),
    )

"""Minimal optax-style gradient-transformation API.

optax is not available in this environment, so the framework ships its own
composable transform layer with the same shape:

    tx = chain(scale_by_lans(...), scale_by_schedule(sched))
    state = tx.init(params)
    updates, state = tx.update(grads, state, params)
    params = apply_updates(params, updates)

Transforms are pure pytree->pytree functions so they compose with jit/pjit
and shard_map without special casing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


class GradientTransformation(NamedTuple):
    """A pair of pure functions (init, update)."""

    init: Callable[[PyTree], PyTree]
    # update(grads, state, params) -> (updates, new_state)
    update: Callable[[PyTree, PyTree, Optional[PyTree]], tuple]


class EmptyState(NamedTuple):
    pass


class ScaleState(NamedTuple):
    pass


class ScaleByScheduleState(NamedTuple):
    count: jnp.ndarray  # int32 scalar


def identity() -> GradientTransformation:
    def init_fn(params):
        del params
        return EmptyState()

    def update_fn(updates, state, params=None):
        del params
        return updates, state

    return GradientTransformation(init_fn, update_fn)


def scale(step_size: float) -> GradientTransformation:
    def init_fn(params):
        del params
        return ScaleState()

    def update_fn(updates, state, params=None):
        del params
        updates = jax.tree.map(lambda u: step_size * u, updates)
        return updates, state

    return GradientTransformation(init_fn, update_fn)


def scale_by_schedule(schedule: Schedule) -> GradientTransformation:
    """Multiply updates by -schedule(count); increments count each step."""

    def init_fn(params):
        del params
        return ScaleByScheduleState(count=jnp.zeros([], jnp.int32))

    def update_fn(updates, state, params=None):
        del params
        step = state.count
        lr = schedule(step)
        updates = jax.tree.map(lambda u: -lr * u, updates)
        return updates, ScaleByScheduleState(count=step + 1)

    return GradientTransformation(init_fn, update_fn)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init_fn(params):
        return tuple(t.init(params) for t in transforms)

    def update_fn(updates, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            updates, s = t.update(updates, s, params)
            new_state.append(s)
        return updates, tuple(new_state)

    return GradientTransformation(init_fn, update_fn)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """params + updates, preserving param dtype (master-weight safe)."""
    return jax.tree.map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params,
        updates,
    )


# ---------------------------------------------------------------------------
# Shared numeric helpers used by the concrete optimizers.
# ---------------------------------------------------------------------------

def tree_zeros_like(params: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def safe_norm(x: jnp.ndarray, eps: float = 0.0) -> jnp.ndarray:
    """l2 norm in fp32; returns max(norm, eps)."""
    n = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))
    return jnp.maximum(n, eps)


def safe_div(num: jnp.ndarray, den: jnp.ndarray) -> jnp.ndarray:
    """num/den with den==0 -> 0 (blockwise normalization of a zero block)."""
    return jnp.where(den > 0.0, num / jnp.maximum(den, 1e-38), jnp.zeros_like(num))


def bias_correction(decay: float, count: jnp.ndarray) -> jnp.ndarray:
    """1 - decay**t computed in fp32 for a (1-indexed) step count."""
    return 1.0 - jnp.power(jnp.asarray(decay, jnp.float32), count.astype(jnp.float32))


@dataclasses.dataclass(frozen=True)
class WeightDecayMask:
    """Predicate over pytree paths selecting params that receive weight decay.

    The paper (following BERT/LAMB practice) excludes LayerNorm scales and
    biases from decay and from the trust-ratio rescaling (phi == 1 for them).
    """

    exclude_substrings: Sequence[str] = ("bias", "layernorm", "ln_", "norm", "scale_param")

    def __call__(self, path: str) -> bool:
        lowered = path.lower()
        return not any(s in lowered for s in self.exclude_substrings)


def tree_paths(params: PyTree) -> PyTree:
    """Pytree of '/'-joined key paths, same structure as params."""

    def _name(entry) -> str:
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return entry.name
        if isinstance(entry, jax.tree_util.SequenceKey):
            return str(entry.idx)
        return str(entry)

    paths_and_vals, treedef = jax.tree_util.tree_flatten_with_path(params)
    paths = ["/".join(_name(k) for k in path) for path, _ in paths_and_vals]
    return jax.tree_util.tree_unflatten(treedef, paths)

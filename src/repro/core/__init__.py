"""Core contribution of the paper: LANS optimizer + large-batch LR schedules."""
from repro.core import optim, schedules  # noqa: F401

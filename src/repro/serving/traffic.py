"""Open-loop traffic: seeded Poisson arrivals, TTFT/ITL SLOs, goodput.

Closed-loop load (run_batch over a pre-built list) measures peak
tokens/s: the generator waits for the system, so the system never
falls behind. Production traffic does not wait — requests arrive on
their own clock, queues build when the server stalls, and the metric
that models millions-of-users capacity is GOODPUT: tokens/s delivered
by requests that met their latency SLOs, at a fixed arrival rate
(PAPERS.md: cost-efficient multi-node serving argues goodput per fixed
hardware, not peak throughput, is the capacity number).

This module is the open-loop side of that measurement:

  poisson_arrivals  seeded exponential inter-arrival times — the
                    memoryless process whose bursts expose prefill
                    stalls that uniform pacing hides;
  SLO               per-request TTFT (submit -> first token) and ITL
                    (every inter-token gap) bounds, in milliseconds;
  meets_slo         a request is GOOD iff its TTFT met the bound AND
                    no single inter-token gap exceeded the ITL bound —
                    one whole-prompt prefill stalling a stream past
                    the ITL SLO disqualifies the entire stream;
  slo_report        goodput + attainment + violation counts, JSON-able;
  bimodal_requests  the mixed workload: mostly short prompts (decode
                    traffic) + a long-prompt minority whose admissions
                    stall everyone else unless prefill is chunked;
  OpenLoopDriver    submits requests at their arrival offsets while
                    stepping a ContinuousEngine — the harness behind
                    benchmarks/serving_load.py --workload open-loop.

Host-side only (numpy + wall clock); the clock and sleep are injectable
so scheduling tests can drive the loop deterministically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.metrics import RequestTrace, percentile


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request latency bounds, milliseconds."""
    ttft_ms: float
    itl_ms: float

    def __post_init__(self):
        if self.ttft_ms <= 0 or self.itl_ms <= 0:
            raise ValueError(f"SLO bounds must be positive, got {self}")


def poisson_arrivals(n: int, rate_per_s: float, seed: int = 0,
                     start: float = 0.0) -> np.ndarray:
    """(n,) arrival offsets in seconds: a seeded Poisson process of
    `rate_per_s` requests/s (exponential inter-arrival gaps)."""
    if rate_per_s <= 0:
        raise ValueError(f"rate_per_s must be positive, got {rate_per_s}")
    rng = np.random.default_rng(seed)
    return start + np.cumsum(rng.exponential(1.0 / rate_per_s, size=n))


def bimodal_requests(n: int, vocab: int, *, short_len: int, long_len: int,
                     new_tokens: int, long_frac: float = 0.25,
                     seed: int = 0) -> List:
    """Mixed open-loop workload: ~(1 - long_frac) short prompts and a
    long-prompt minority. The short streams are the ITL victims; each
    long admission is the stall. Pure function of the arguments, so the
    chunked and unchunked engines see byte-identical requests."""
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        base = long_len if rng.random() < long_frac else short_len
        plen = int(rng.integers(max(1, base * 3 // 4), base + 1))
        reqs.append(Request(
            prompt=rng.integers(5, vocab, size=plen).astype(np.int32),
            max_new_tokens=new_tokens))
    return reqs


def ttft_violated(trace: RequestTrace, slo: SLO) -> bool:
    ttft = trace.ttft_s
    return ttft is None or ttft * 1e3 > slo.ttft_ms


def itl_violated(trace: RequestTrace, slo: SLO) -> bool:
    return any(gap * 1e3 > slo.itl_ms for gap in trace.inter_token_s)


def meets_slo(trace: RequestTrace, slo: SLO) -> bool:
    return not ttft_violated(trace, slo) and not itl_violated(trace, slo)


def slo_report(requests: Sequence, slo: SLO, wall_s: float) -> Dict:
    """Goodput + SLO attainment over a finished open-loop run.

    goodput_tokens_per_s counts ONLY tokens of requests that met both
    bounds; tokens_per_s counts everything (the closed-loop number).
    """
    done = [r for r in requests if r.generated is not None]
    good = [r for r in done if meets_slo(r.trace, slo)]
    ttfts = [r.trace.ttft_s for r in done if r.trace.ttft_s is not None]
    itls = [g for r in done for g in r.trace.inter_token_s]
    good_tokens = sum(len(r.generated) for r in good)
    all_tokens = sum(len(r.generated) for r in done)
    return {
        "requests": len(requests),
        "completed": len(done),
        "wall_s": wall_s,
        "slo_ttft_ms": slo.ttft_ms,
        "slo_itl_ms": slo.itl_ms,
        "goodput_tokens_per_s": good_tokens / wall_s if wall_s > 0 else 0.0,
        "tokens_per_s": all_tokens / wall_s if wall_s > 0 else 0.0,
        "slo_attainment": len(good) / len(done) if done else 0.0,
        "ttft_violations": sum(ttft_violated(r.trace, slo) for r in done),
        "itl_violations": sum(itl_violated(r.trace, slo) for r in done),
        "ttft_p50_ms": percentile(ttfts, 50) * 1e3,
        "ttft_p99_ms": percentile(ttfts, 99) * 1e3,
        "itl_p50_ms": percentile(itls, 50) * 1e3,
        "itl_p99_ms": percentile(itls, 99) * 1e3,
    }


class OpenLoopDriver:
    """Submit requests at their arrival offsets while stepping the
    engine — the generator does not wait for the server.

    Each loop iteration submits every request whose arrival time has
    passed, then runs one engine step if there is work; when the engine
    is idle and the next arrival is in the future, it sleeps until that
    arrival. time_fn/sleep_fn are injectable so tests can drive the
    loop on a fake clock (tests/test_admission.py)."""

    def __init__(self, engine, requests: Sequence,
                 arrivals: Sequence[float], *,
                 time_fn: Callable[[], float] = time.perf_counter,
                 sleep_fn: Callable[[float], None] = time.sleep):
        if len(requests) != len(arrivals):
            raise ValueError(
                f"{len(requests)} requests but {len(arrivals)} arrivals")
        order = np.argsort(np.asarray(arrivals, float), kind="stable")
        self.engine = engine
        self.requests = [requests[i] for i in order]
        self.arrivals = [float(arrivals[i]) for i in order]
        self.time_fn = time_fn
        self.sleep_fn = sleep_fn
        self.submitted = 0

    def run(self) -> float:
        """Drive to completion; returns the measured wall seconds."""
        base = self.time_fn()
        n = len(self.requests)
        while self.submitted < n or self.engine.scheduler.has_work:
            now = self.time_fn() - base
            while self.submitted < n and \
                    self.arrivals[self.submitted] <= now:
                self.engine.submit(self.requests[self.submitted])
                self.submitted += 1
            if self.engine.scheduler.has_work:
                self.engine.step()
            elif self.submitted < n:
                wait = self.arrivals[self.submitted] - (self.time_fn() - base)
                if wait > 0:
                    self.sleep_fn(wait)
        return self.time_fn() - base

"""Prefix-affinity front-end over N engine replicas.

One `ContinuousEngine` owns one cache pool — its shared-prefix registry
and retained-prefix LRU are REPLICA-LOCAL. A fleet of replicas behind a
prefix-blind balancer therefore stores every hot system prompt N times
(once per replica its tenants land on) and splits each tenant's request
stream across N independent LRUs, so per-replica reuse frequency drops
by ~N and the retained working set thrashes. `ReplicaRouter` fixes both
with CONTENT-ADDRESSED routing: requests are keyed by their leading
prompt block — the same first-`block_size`-tokens granularity
`BlockTableMap.prefix_warm` registers, so the router's notion of "same
prefix" is exactly the pool's notion of "shareable block" — and a
sticky key -> replica map sends every request that could share blocks
to the replica that already holds them. Distinct-prefix traffic still
balances: an unseen key binds to the replica with the least outstanding
work (queue + active slots), and the `depth`/`rr` policies disable
affinity entirely (the benchmark baselines).

Routing is EXACT, not heuristic, in the token sense: a request's output
never depends on which replica serves it (every replica runs the same
params/step; pool block churn never changes tokens — the PR 3
differential), so the router changes throughput and hit rates only.
Affinity wins on two mechanisms, both measured by
benchmarks/serving_load.py --workload multi-tenant-routed:

  * arena dedup: a tenant's shared prefix is written to ONE replica's
    arena instead of all N, so each arena admits more concurrent
    requests at fixed block budget (fewer admission waits, fewer decode
    steps per token of goodput);
  * LRU partitioning: each replica's retained LRU holds its OWN
    tenants' prefixes (T/N working set instead of all T), so revival
    hits (`retained_hit_rate`) rise instead of thrashing.

The router presents the OpenLoopDriver engine surface (`submit`,
`step`, `scheduler.has_work`), so open-loop traffic drives a fleet
exactly like a single engine.
"""
from __future__ import annotations

import collections
import hashlib
from typing import List, Optional, Sequence

from repro.serving.metrics import hit_rate

ROUTE_POLICIES = ("prefix", "depth", "rr")


def prefix_route_key(prompt, block_size: int) -> Optional[bytes]:
    """Content key of the request's leading prompt block, or None when
    the prompt cannot fill one block (sub-block prompts are never
    registered for sharing — see BlockTableMap — so affinity has
    nothing to win; such requests route by depth).

    Keyed on (block_size, first block_size tokens): the same content
    the pool's prefix registry hashes for its leading block. The pool
    additionally keys on padded_len (bucketed prompts of different pads
    shard differently past block one), which the router deliberately
    omits — grouping by content only can at worst co-locate two
    requests that share fewer blocks than hoped, never miss a shareable
    pair."""
    if len(prompt) < block_size:
        return None
    h = hashlib.sha256(str(block_size).encode())
    h.update(bytes(memoryview(prompt[:block_size])))
    return h.digest()


class _FleetScheduler:
    """The `engine.scheduler` duck-type surface OpenLoopDriver and the
    benchmarks read, aggregated over the fleet."""

    def __init__(self, router: "ReplicaRouter"):
        self._router = router

    @property
    def has_work(self) -> bool:
        return any(e.scheduler.has_work for e in self._router.replicas)

    @property
    def completed(self) -> list:
        return [r for e in self._router.replicas
                for r in e.scheduler.completed]


class ReplicaRouter:
    """Route requests across engine replicas; step whichever have work.

    policy:
      prefix  sticky content-addressed affinity (leading prompt block
              -> replica), least-depth fallback for unseen/sub-block
              prefixes — the production policy;
      depth   always least outstanding work (prefix-blind baseline);
      rr      round-robin (the fully blind baseline the benchmark
              gates against).

    max_keys bounds the sticky map (LRU on use): a stale binding only
    costs a warm start on some other replica, so a small bound is safe.
    """

    def __init__(self, replicas: Sequence, *, policy: str = "prefix",
                 block_size: Optional[int] = None, max_keys: int = 4096):
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        if policy not in ROUTE_POLICIES:
            raise ValueError(
                f"route policy must be one of {ROUTE_POLICIES}, "
                f"got {policy!r}")
        self.replicas = list(replicas)
        self.policy = policy
        if block_size is None:
            pools = [p for p in (getattr(e, "pool", None)
                                 for e in self.replicas)
                     if hasattr(p, "block_size")]
            if policy == "prefix" and not pools:
                raise ValueError(
                    "prefix routing needs paged replicas (their "
                    "block_size defines the affinity key) or an "
                    "explicit block_size")
            block_size = pools[0].block_size if pools else 16
        self.block_size = block_size
        self.scheduler = _FleetScheduler(self)
        self._affinity: "collections.OrderedDict[bytes, int]" = \
            collections.OrderedDict()
        self._max_keys = max_keys
        self._rr_next = 0
        self.routed_submits = 0
        self.routed_affinity_hits = 0   # sticky map sends (prefix policy)
        self.routed_fallback = 0        # prefix policy fell back to depth

    # ---------------- routing ----------------

    def _depth(self, i: int) -> int:
        e = self.replicas[i]
        return e.scheduler.queued + len(e.scheduler.active)

    def _least_depth(self) -> int:
        return min(range(len(self.replicas)), key=self._depth)

    def route(self, request) -> int:
        """Replica index for a request (no submission) — the policy
        decision, exposed separately for tests."""
        if self.policy == "rr":
            i = self._rr_next
            self._rr_next = (i + 1) % len(self.replicas)
            return i
        if self.policy == "depth":
            return self._least_depth()
        key = prefix_route_key(request.prompt, self.block_size)
        if key is None:
            self.routed_fallback += 1
            return self._least_depth()
        i = self._affinity.get(key)
        if i is None:
            i = self._least_depth()
            self._affinity[key] = i
            if len(self._affinity) > self._max_keys:
                self._affinity.popitem(last=False)
        else:
            self._affinity.move_to_end(key)
            self.routed_affinity_hits += 1
        return i

    def submit(self, request):
        """Route and enqueue on the chosen replica."""
        self.routed_submits += 1
        self.replicas[self.route(request)].submit(request)

    # ---------------- stepping ----------------

    def step(self) -> bool:
        """One step on every replica that has work (idle replicas cost
        nothing). Returns True while any replica still has work — the
        same contract as ContinuousEngine.step()."""
        progressed = False
        for e in self.replicas:
            if e.scheduler.has_work:
                progressed = e.step() or progressed
        return progressed

    def run(self, requests: Optional[List] = None) -> list:
        """Submit `requests` (optional) and drive the fleet to drain;
        returns every completed request across replicas."""
        for r in requests or ():
            self.submit(r)
        while self.step():
            pass
        return self.scheduler.completed

    # ---------------- reporting ----------------

    def report(self, wall_s: float) -> dict:
        """Fleet aggregate + per-replica engine reports. Aggregate
        tokens/s sums replica throughput over the SHARED wall clock
        (the replicas step interleaved in one loop); the aggregate
        retained_hit_rate pools hits/misses across replicas — the
        router gate's two numbers."""
        per = []
        hits = misses = 0
        tokens = 0
        for idx, e in enumerate(self.replicas):
            r = e.report(wall_s)
            r["replica"] = idx
            per.append(r)
            if "retained_block_hits" in r:
                hits += r["retained_block_hits"]
                misses += r["prefix_misses"]
            tokens += sum(len(q.generated) for q in e.scheduler.completed)
        return {
            "replicas": len(self.replicas),
            "route_policy": self.policy,
            "routed_submits": self.routed_submits,
            "routed_affinity_hits": self.routed_affinity_hits,
            "routed_fallback": self.routed_fallback,
            "completed": sum(len(e.scheduler.completed)
                             for e in self.replicas),
            "tokens_per_s": tokens / wall_s if wall_s > 0 else 0.0,
            "retained_hit_rate": hit_rate(hits, misses),
            "per_replica": per,
        }

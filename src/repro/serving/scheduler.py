"""Slot scheduler for the continuous-batching engine.

Host-side FIFO admission control over a fixed pool of decode slots. The
scheduler owns the slot <-> request mapping and nothing else: no device
state, no timing — which keeps its invariants (the ones the property tests
check) easy to state:

  * a slot is either free or bound to exactly one in-flight request;
  * a request is queued, active in exactly one slot, or completed;
  * admissions are FIFO: requests enter slots in submission order;
  * completion frees the slot for the next queued request.
"""
from __future__ import annotations

import collections
import itertools
from typing import Any, Deque, Dict, List, Optional, Tuple


class SchedulerError(RuntimeError):
    pass


class Scheduler:
    """Fixed-capacity slot assignment with a FIFO admission queue."""

    def __init__(self, n_slots: int):
        if n_slots <= 0:
            raise ValueError(f"n_slots must be positive, got {n_slots}")
        self.n_slots = n_slots
        self._free: Deque[int] = collections.deque(range(n_slots))
        self._queue: Deque[Any] = collections.deque()
        self.active: Dict[int, Any] = {}
        self.completed: List[Any] = []
        self._seq = itertools.count()

    # ---------------- queue side ----------------

    def submit(self, request) -> int:
        """Enqueue a request; returns its admission ticket (FIFO order)."""
        ticket = next(self._seq)
        self._queue.append(request)
        return ticket

    @property
    def queued(self) -> int:
        return len(self._queue)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def has_work(self) -> bool:
        return bool(self._queue or self.active)

    # ---------------- slot side ----------------

    def peek(self):
        """Head of the admission queue (None when empty) — lets the
        engine gate admission on cache-pool capacity without breaking
        FIFO order."""
        return self._queue[0] if self._queue else None

    def assign_one(self) -> Optional[Tuple[int, Any]]:
        """Bind the queue head to one free slot, or None if either side
        is empty."""
        if not (self._free and self._queue):
            return None
        slot = self._free.popleft()
        if slot in self.active:  # corrupted free list — refuse to reuse
            raise SchedulerError(f"slot {slot} free but active")
        req = self._queue.popleft()
        self.active[slot] = req
        return slot, req

    def assign(self) -> List[Tuple[int, Any]]:
        """Bind queued requests to free slots (FIFO). Returns the new
        (slot, request) pairs; caller prefills and inserts their caches."""
        pairs: List[Tuple[int, Any]] = []
        while True:
            pair = self.assign_one()
            if pair is None:
                return pairs
            pairs.append(pair)

    def requeue(self, slot: int):
        """Undo an assignment (admission failed downstream, e.g. the
        paged pool ran out of blocks): the request returns to the FRONT
        of the queue — FIFO order is preserved — and the slot frees."""
        if slot not in self.active:
            raise SchedulerError(f"requeue() on inactive slot {slot}")
        req = self.active.pop(slot)
        self._free.append(slot)
        self._queue.appendleft(req)
        return req

    def complete(self, slot: int):
        """Release a slot whose request finished; returns the request."""
        if slot not in self.active:
            raise SchedulerError(f"complete() on inactive slot {slot}")
        req = self.active.pop(slot)
        self._free.append(slot)
        self.completed.append(req)
        return req

    # ---------------- invariants (used by tests) ----------------

    def check_invariants(self):
        free = list(self._free)
        assert len(set(free)) == len(free), "duplicate free slots"
        assert not (set(free) & set(self.active)), "slot both free and active"
        assert len(free) + len(self.active) == self.n_slots, (
            "slots leaked", free, list(self.active))
        assert all(0 <= s < self.n_slots for s in free + list(self.active))

"""Slot scheduler + scheduling policies for the continuous-batching engine.

Host-side admission control over a fixed pool of decode slots, split in
two layers:

`Scheduler` owns the MECHANISM: the slot <-> request mapping, a ticketed
admission queue, and the preempt/requeue path. No device state, no
timing — which keeps its invariants (the ones the property tests check)
easy to state:

  * a slot is either free or bound to exactly one in-flight request;
  * a request is queued, active in exactly one slot, or completed;
  * every queued request keeps its original arrival ticket; preemption
    and requeue re-insert BY TICKET, so arrival order is never lost no
    matter how admission reorders departures from the queue;
  * completion frees the slot for the next admitted request.

`SchedulingPolicy` owns the POLICY: which queued request to admit next,
which active slot to preempt when lazy growth exhausts the arena, and
when an active slot has blown its SLO and should be evicted early.
Policies see an immutable snapshot (the queue, plus a `PolicyContext` of
admission times/order and a warm-prefix probe) and return indices — they
never mutate scheduler state, so any policy composes with the same
engine invariants:

  fifo             admit in arrival order; preempt the youngest
                   admission (it has the least work to redo).
  arrival-deadline admit by earliest deadline (arrival + SLO); preempt
                   the slot with the latest deadline. With a uniform SLO
                   this is arrival-time-aware FIFO that also ranks
                   preemption victims by arrival.
  prefix-affinity  admit the first queued request whose leading prompt
                   block is already resident (live or retained) in the
                   paged pool — maximizing copy-free prefix reuse —
                   falling back to arrival order; preempt the youngest.

SLO eviction (`slo_s`) is orthogonal to the admission order: any policy
evicts a slot whose request has been running longer than the SLO since
admission (the engine finishes it early with the tokens it has, flagging
`trace.evicted_slo`).
"""
from __future__ import annotations

import bisect
import collections
import copy
import dataclasses
import itertools
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple)


class SchedulerError(RuntimeError):
    pass


class Scheduler:
    """Fixed-capacity slot assignment with a ticketed admission queue.

    The queue holds (ticket, request) pairs; tickets are assigned once at
    submit() and travel with the request through any number of
    preempt()/requeue() round-trips, so "arrival order" stays a stable,
    policy-independent notion."""

    def __init__(self, n_slots: int):
        if n_slots <= 0:
            raise ValueError(f"n_slots must be positive, got {n_slots}")
        self.n_slots = n_slots
        self._free: Deque[int] = collections.deque(range(n_slots))
        self._queue: List[Tuple[int, Any]] = []   # sorted by ticket
        self.active: Dict[int, Any] = {}
        self.completed: List[Any] = []
        self._seq = itertools.count()
        self._slot_ticket: Dict[int, int] = {}    # slot -> arrival ticket

    # ---------------- queue side ----------------

    def submit(self, request) -> int:
        """Enqueue a request; returns its arrival ticket (FIFO order)."""
        ticket = next(self._seq)
        self._queue.append((ticket, request))
        return ticket

    @property
    def queued(self) -> int:
        return len(self._queue)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def has_work(self) -> bool:
        return bool(self._queue or self.active)

    def queue_items(self) -> Sequence[Tuple[int, Any]]:
        """Immutable snapshot of (ticket, request) pairs in arrival
        order — what a SchedulingPolicy ranks for admission."""
        return tuple(self._queue)

    # ---------------- slot side ----------------

    def peek(self, i: int = 0):
        """The i-th queued request in arrival order (None when out of
        range) — lets the engine gate admission on cache-pool capacity
        without dequeuing."""
        return self._queue[i][1] if 0 <= i < len(self._queue) else None

    def assign_at(self, i: int) -> Optional[Tuple[int, Any]]:
        """Bind the i-th queued request (arrival order; a policy's pick)
        to one free slot, or None if either side is empty."""
        if not self._free or not (0 <= i < len(self._queue)):
            return None
        slot = self._free.popleft()
        if slot in self.active:  # corrupted free list — refuse to reuse
            raise SchedulerError(f"slot {slot} free but active")
        ticket, req = self._queue.pop(i)
        self.active[slot] = req
        self._slot_ticket[slot] = ticket
        return slot, req

    def assign_one(self) -> Optional[Tuple[int, Any]]:
        """Bind the queue head to one free slot (FIFO), or None if
        either side is empty."""
        return self.assign_at(0)

    def assign(self) -> List[Tuple[int, Any]]:
        """Bind queued requests to free slots (FIFO). Returns the new
        (slot, request) pairs; caller prefills and inserts their caches."""
        pairs: List[Tuple[int, Any]] = []
        while True:
            pair = self.assign_one()
            if pair is None:
                return pairs
            pairs.append(pair)

    def _reinsert(self, slot: int) -> Any:
        if slot not in self.active:
            raise SchedulerError(f"requeue() on inactive slot {slot}")
        req = self.active.pop(slot)
        ticket = self._slot_ticket.pop(slot)
        self._free.append(slot)
        bisect.insort(self._queue, (ticket, req))
        return req

    def requeue(self, slot: int):
        """Undo an assignment (admission failed downstream, e.g. the
        paged pool ran out of blocks): the request re-enters the queue
        at its ARRIVAL-TICKET position — arrival order is preserved —
        and the slot frees."""
        return self._reinsert(slot)

    def preempt(self, slot: int):
        """Evict a mid-decode victim so its blocks can serve someone
        else: same mechanics as requeue() (ticket-ordered re-entry), a
        distinct name so call sites read as what they are. The ENGINE
        owns the continuation state (generated-so-far tokens)."""
        return self._reinsert(slot)

    def admitted_order(self, slot: int) -> int:
        """The active slot's arrival ticket (stable tie-break for
        victim selection)."""
        return self._slot_ticket[slot]

    def complete(self, slot: int):
        """Release a slot whose request finished; returns the request.
        Same-step assign -> complete is a legal lifecycle: the scoring
        family finishes requests AT admission (one batched score call,
        no decode), so a slot may bind and free inside one engine step."""
        if slot not in self.active:
            raise SchedulerError(f"complete() on inactive slot {slot}")
        req = self.active.pop(slot)
        self._slot_ticket.pop(slot, None)
        self._free.append(slot)
        self.completed.append(req)
        return req

    # ---------------- invariants (used by tests) ----------------

    def check_invariants(self):
        free = list(self._free)
        assert len(set(free)) == len(free), "duplicate free slots"
        assert not (set(free) & set(self.active)), "slot both free and active"
        assert len(free) + len(self.active) == self.n_slots, (
            "slots leaked", free, list(self.active))
        assert all(0 <= s < self.n_slots for s in free + list(self.active))
        assert set(self._slot_ticket) == set(self.active), (
            "slot tickets out of sync with active slots")
        tickets = [t for t, _ in self._queue]
        assert tickets == sorted(tickets), "queue not in arrival order"
        assert len(set(tickets)) == len(tickets), "duplicate tickets"
        live = [r for _, r in self._queue] + list(self.active.values())
        assert not set(map(id, self.completed)) & set(map(id, live)), (
            "request both completed and live (queued/active)")


# ---------------------------------------------------------------------------
# scheduling policies
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PolicyContext:
    """Immutable view the engine hands a policy each decision point.

    now: wall-clock seconds (time.perf_counter domain).
    admit_seq: slot -> monotone admission sequence number (higher =
        admitted later; survives slot reuse).
    admit_t: slot -> admission wall-clock time (RESETS on every
        re-admission of a preempted request — use submit_t for
        arrival/deadline ranking, which a continuation keeps).
    active: slot -> in-flight request (victim selection ranks these).
    submit_t: callable(request) -> submission wall-clock time.
    prefix_warm: callable(request) -> bool, True when the request's
        leading prompt block is already resident in the paged pool
        (None when the pool cannot answer, e.g. the dense pool).
    resume_cost: callable(slot) -> tokens a preemption of that slot
        would have to re-prefill (prompt + generated so far). Set only
        by the CHUNKED admission controller, where re-prefilling is
        metered chunk work competing with decodes for the step budget —
        the base victim rule then minimizes it. None keeps the classic
        youngest-admission victim (the PR 5 behaviour, which the
        non-chunked differential tests pin).
    """
    now: float = 0.0
    admit_seq: Dict[int, int] = dataclasses.field(default_factory=dict)
    admit_t: Dict[int, float] = dataclasses.field(default_factory=dict)
    active: Dict[int, Any] = dataclasses.field(default_factory=dict)
    submit_t: Callable[[Any], float] = lambda req: 0.0
    prefix_warm: Optional[Callable[[Any], bool]] = None
    resume_cost: Optional[Callable[[int], int]] = None


class SchedulingPolicy:
    """Base policy: FIFO admission, youngest-admission victim, SLO
    eviction when `slo_s` is set. Subclasses override `pick` and/or
    `victim`; `parse` maps the CLI spec strings."""

    name = "fifo"

    def __init__(self, slo_s: Optional[float] = None):
        if slo_s is not None and slo_s <= 0:
            raise ValueError(f"slo_s must be positive, got {slo_s}")
        self.slo_s = slo_s

    # -- admission: index into queue_items() to admit next ------------
    def pick(self, queue: Sequence[Tuple[int, Any]],
             ctx: PolicyContext) -> int:
        return 0

    # -- preemption: which active slot to sacrifice -------------------
    def victim(self, slots: Sequence[int], ctx: PolicyContext) -> int:
        """Default: the youngest admission — it has generated the least
        (its continuation prefill redoes the least work) and preempting
        it keeps arrival order intact when it re-enters the queue.

        When the context carries a resume_cost (chunked admission), the
        proxy becomes exact: pick the slot whose continuation prefill
        re-chunks the FEWEST tokens (prompt + generated), tie-broken by
        youngest admission. A short-prompt late arrival no longer beats
        a long-prompt one purely on admission order."""
        if ctx.resume_cost is not None:
            return min(slots, key=lambda s: (ctx.resume_cost(s),
                                             -ctx.admit_seq.get(s, -1)))
        return max(slots, key=lambda s: ctx.admit_seq.get(s, -1))

    # -- SLO: should this active slot be evicted early? ---------------
    def overdue(self, slot: int, ctx: PolicyContext) -> bool:
        if self.slo_s is None:
            return False
        return ctx.now - ctx.admit_t.get(slot, ctx.now) > self.slo_s

    @classmethod
    def parse(cls, spec, slo_s: Optional[float] = None
              ) -> "SchedulingPolicy":
        """Policy instance from a spec: an existing policy passes
        through — COPIED if an slo_s must be attached, so one policy
        object shared across engines never inherits another engine's
        SLO; a name in {fifo, arrival-deadline, prefix-affinity}
        constructs one."""
        if isinstance(spec, SchedulingPolicy):
            if slo_s is not None and spec.slo_s is None:
                spec = copy.copy(spec)
                spec.slo_s = slo_s
            return spec
        if spec is None:
            spec = "fifo"
        policies = {p.name: p for p in
                    (SchedulingPolicy, ArrivalDeadlinePolicy,
                     PrefixAffinityPolicy)}
        if spec not in policies:
            raise ValueError(
                f"unknown scheduling policy {spec!r}: "
                f"expected one of {sorted(policies)}")
        return policies[spec](slo_s=slo_s)


class ArrivalDeadlinePolicy(SchedulingPolicy):
    """Earliest-deadline-first admission over deadline = submit + SLO.

    With one global SLO this equals arrival-time order — but unlike raw
    FIFO it stays arrival-aware through preemption churn (a continuation
    keeps its original submit time, hence its original deadline) and
    ranks preemption victims by SLACK: the latest SUBMIT time (= latest
    deadline) has the most room to absorb a requeue. Ranking by
    admission time would invert this under churn — a re-admitted
    continuation always carries the newest admit_t and would be
    re-preempted forever."""

    name = "arrival-deadline"

    def pick(self, queue, ctx):
        return min(range(len(queue)),
                   key=lambda i: (ctx.submit_t(queue[i][1]), queue[i][0]))

    def victim(self, slots, ctx):
        def deadline(s):
            req = ctx.active.get(s)
            return (ctx.submit_t(req) if req is not None else 0.0,
                    ctx.admit_seq.get(s, -1))
        return max(slots, key=deadline)


class PrefixAffinityPolicy(SchedulingPolicy):
    """Admit the first queued request whose leading prompt block is
    already resident in the paged pool (live shared or retained) —
    turning warm prefixes into copy-free admissions while they are
    still warm — falling back to arrival order when nothing is warm or
    the pool cannot answer."""

    name = "prefix-affinity"

    def pick(self, queue, ctx):
        if ctx.prefix_warm is not None:
            for i, (_, req) in enumerate(queue):
                if ctx.prefix_warm(req):
                    return i
        return 0

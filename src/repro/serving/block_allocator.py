"""Refcounted block allocator + block-table bookkeeping for the paged cache.

All state here is host-side (numpy / python): the device side of the paged
pool is just two kinds of arrays — block arenas `(n_blocks, block_size, ...)`
and block tables `(max_batch, max_blocks)` of int32 arena indices — and this
module decides what those tables contain. Splitting the bookkeeping from the
device scatters keeps the allocator a pure state machine, which is what the
hypothesis property tests in tests/test_serving_properties.py drive:

  * refcounts are never negative; free blocks always have refcount 0;
  * the free list and the live (ref > 0) blocks partition the arena
    (minus the reserved null block);
  * a block referenced by two slot tables is always a registered shared
    block (refcount == number of table references);
  * any sequence of insert/evict ops returns every block: no leaks.

Block 0 is the reserved NULL block: unoccupied table entries point at it,
so the fixed-shape gather in the decode step always has a valid index to
read. Its position rows stay -1 forever (inserts route skipped chain
positions' writes there with invalid source rows, and evicted slots'
decode writes carry position -1), which masks it out of attention.

Prefix sharing: a chain block whose `block_size` rows are entirely prompt
tokens is content-addressed by (padded prefill length, the prompt tokens
up to the end of the block), realised as an INCREMENTAL sha256 chain —
digest_j = sha256(block_size, padded_len, tokens[0:(j+1)*bs]) built one
block at a time — so registry keys are O(1) bytes each instead of O(plen)
token tuples and a 32k-token system prompt does not hold megabytes of
boxed ints live. The padded length is part of the key because the
prefill's reduction shapes depend on it — two requests only share blocks
their own prefill would have filled with identical values. Blocks that
decode will later overwrite (ring-buffer wrap on sliding-window layers)
are never shared, so copy-on-write is not needed: every block a slot
writes is exclusively owned from admission.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Tuple

import numpy as np


class NoBlocksError(RuntimeError):
    """Arena exhausted: the caller should keep the request queued."""


NULL_BLOCK = 0


class BlockAllocator:
    """Free-list allocator with refcounts over blocks 1..n_blocks-1."""

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 data + null), got {n_blocks}")
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))
        self.ref = np.zeros(n_blocks, np.int32)

    @property
    def n_free(self) -> int:
        """Blocks available for allocation (excludes the null block)."""
        return len(self._free)

    @property
    def n_live(self) -> int:
        """Blocks currently referenced by at least one table entry."""
        return int((self.ref[1:] > 0).sum())

    def alloc(self) -> int:
        """Take a free block (refcount 1); NoBlocksError when exhausted."""
        if not self._free:
            raise NoBlocksError(f"all {self.n_blocks - 1} blocks in use")
        b = self._free.pop()
        self.ref[b] = 1
        return b

    def retain(self, block: int):
        """Add a reference to a live block (a shared-prefix hit)."""
        if not (0 < block < self.n_blocks) or self.ref[block] < 1:
            raise ValueError(f"retain of non-live block {block}")
        self.ref[block] += 1

    def release(self, block: int) -> bool:
        """Drop one reference; returns True when the block went free."""
        if not (0 < block < self.n_blocks) or self.ref[block] < 1:
            raise ValueError(f"release of non-live block {block}")
        self.ref[block] -= 1
        if self.ref[block] == 0:
            self._free.append(block)
            return True
        return False

    def check_invariants(self):
        """Assert the free/live partition and refcount sanity (test hook;
        also driven by the hypothesis state machine)."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate free blocks"
        assert NULL_BLOCK not in free, "null block on the free list"
        assert (self.ref >= 0).all(), "negative refcount"
        assert all(self.ref[b] == 0 for b in free), "free block with refs"
        live = {b for b in range(1, self.n_blocks) if self.ref[b] > 0}
        assert not (free & live)
        assert free | live == set(range(1, self.n_blocks)), (
            "free + live blocks do not partition the arena")


@dataclasses.dataclass(frozen=True)
class Placement:
    """One chain position of an insert plan."""
    chain_pos: int     # index into the slot's block table row
    block: int         # arena block id
    shared: bool       # True: reused an existing prefix block (no write)


class BlockTableMap:
    """Block tables + allocator + prefix registry for ONE attention
    slot-type (full-attention and sliding-window layer types have
    different ring lengths, hence separate arenas and maps).

    `table` is the host mirror of the device block table handed to the
    jitted decode step: row `slot` lists the arena blocks backing that
    slot's logical rows [j*block_size, (j+1)*block_size), 0 = unbacked.
    """

    def __init__(self, max_batch: int, ring_len: int, block_size: int,
                 n_blocks: int):
        if ring_len % block_size != 0:
            raise ValueError(
                f"cache length {ring_len} not a multiple of block_size "
                f"{block_size}")
        self.block_size = block_size
        self.ring_len = ring_len
        self.max_blocks = ring_len // block_size
        self.table = np.zeros((max_batch, self.max_blocks), np.int32)
        self.alloc = BlockAllocator(n_blocks)
        self._registry: Dict[tuple, int] = {}   # prefix key -> block
        self._block_key: Dict[int, tuple] = {}  # block -> prefix key

    # ---------------- planning ----------------

    def _chain(self, prompt_key, plen: int, padded_len: int, budget: int,
               share: bool) -> List[Tuple[int, bytes]]:
        """(chain_pos, sharing key | None) for every block the slot needs.

        Rows the slot touches: prompt rows 0..plen-1 plus decode writes at
        rows plen..plen+budget-2 (the final sampled token is never fed
        back). Ring wrap maps row r to r % ring_len; chain positions that
        decode will overwrite are excluded from sharing, as is the whole
        insert when the prefill stored a rolled ring layout
        (padded_len > ring_len) whose rows are not content-addressable.
        Keys are snapshots of one sha256 chain over (block_size,
        padded_len, prompt tokens so far) — O(1) bytes per block.
        """
        bs, L = self.block_size, self.ring_len
        total_rows = plen + max(budget - 1, 0)
        wrap = total_rows > L
        chain_len = self.max_blocks if wrap else -(-total_rows // bs)
        overwritten = {(r % L) // bs for r in range(plen, total_rows)}
        rolled = padded_len > L
        toks = np.asarray(prompt_key, np.int64)
        h = hashlib.sha256(np.array([bs, padded_len], np.int64).tobytes())
        out = []
        for j in range(chain_len):
            key = None
            if (j + 1) * bs <= plen:          # entirely prompt-backed
                h.update(toks[j * bs:(j + 1) * bs].tobytes())
                if share and not rolled and j not in overwritten:
                    key = h.digest()
            out.append((j, key))
        return out

    def blocks_needed(self, prompt_key, plen: int, padded_len: int,
                      budget: int, share: bool = True) -> int:
        """Fresh blocks an insert would consume (registry hits are free)."""
        return sum(1 for _, key in self._chain(prompt_key, plen, padded_len,
                                               budget, share)
                   if key is None or key not in self._registry)

    # ---------------- mutation ----------------

    def insert(self, slot: int, prompt_key, plen: int,
               padded_len: int, budget: int,
               share: bool = True) -> List[Placement]:
        """Allocate/retain the slot's whole chain up front. Atomic: on
        NoBlocksError every block this call touched is released and the
        table row is left empty, so the caller can requeue the request."""
        assert not self.table[slot].any(), f"slot {slot} table not empty"
        placed: List[Placement] = []
        try:
            for j, key in self._chain(prompt_key, plen, padded_len, budget,
                                      share):
                if key is not None and key in self._registry:
                    b = self._registry[key]
                    self.alloc.retain(b)
                    placed.append(Placement(j, b, True))
                else:
                    b = self.alloc.alloc()
                    placed.append(Placement(j, b, False))
                    if key is not None:
                        self._registry[key] = b
                        self._block_key[b] = key
        except NoBlocksError:
            for p in placed:
                self._release(p.block)
            raise
        for p in placed:
            self.table[slot, p.chain_pos] = p.block
        return placed

    def _release(self, block: int) -> bool:
        freed = self.alloc.release(block)
        if freed and block in self._block_key:
            del self._registry[self._block_key.pop(block)]
        return freed

    def evict(self, slot: int) -> List[int]:
        """Return the slot's blocks to the pool; yields the freed ids."""
        freed = []
        for j in range(self.max_blocks):
            b = int(self.table[slot, j])
            if b != NULL_BLOCK and self._release(b):
                freed.append(b)
            self.table[slot, j] = NULL_BLOCK
        return freed

    # ---------------- introspection ----------------

    @property
    def n_shared(self) -> int:
        """Prefix blocks currently registered for content-address reuse."""
        return len(self._registry)

    def check_invariants(self):
        """Assert table/refcount/registry consistency: every table
        reference holds exactly one refcount, multiply-referenced blocks
        are registered shared prefixes, registered blocks are live."""
        self.alloc.check_invariants()
        counts = np.bincount(self.table.ravel(),
                             minlength=self.alloc.n_blocks)
        # every table reference holds exactly one refcount
        np.testing.assert_array_equal(counts[1:], self.alloc.ref[1:])
        # a block in two tables must be a registered shared block
        multi = {b for b in np.nonzero(counts > 1)[0] if b != NULL_BLOCK}
        assert multi <= set(self._block_key), (
            "unshared block referenced by multiple table entries", multi)
        # registry consistency: every registered block is live
        for key, b in self._registry.items():
            assert self.alloc.ref[b] > 0 and self._block_key.get(b) == key

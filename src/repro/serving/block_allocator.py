"""Refcounted block allocator + block-table bookkeeping for the paged cache.

All state here is host-side (numpy / python): the device side of the paged
pool is just two kinds of arrays — block arenas `(n_blocks, block_size, ...)`
and block tables `(max_batch, max_blocks)` of int32 arena indices — and this
module decides what those tables contain. Splitting the bookkeeping from the
device scatters keeps the allocator a pure state machine, which is what the
hypothesis property tests in tests/test_serving_properties.py drive:

  * refcounts are never negative; free blocks always have refcount 0;
  * the free list, the live (ref > 0) blocks and the RETAINED (ref 0,
    content kept warm) blocks partition the arena (minus the reserved
    null block);
  * a block referenced by two slot tables is always a registered shared
    block (refcount == number of table references);
  * a retained block is never referenced by any table — live writes can
    therefore never alias retained content;
  * any sequence of insert/grow/evict ops returns every block: no leaks.

Block 0 is the reserved NULL block: unoccupied table entries point at it,
so the fixed-shape gather in the decode step always has a valid index to
read. Its position rows stay -1 forever (inserts route skipped chain
positions' writes there with invalid source rows, and evicted slots'
decode writes carry position -1), which masks it out of attention.

Prefix sharing: a chain block whose `block_size` rows are entirely prompt
tokens is content-addressed by (padded prefill length, the prompt tokens
up to the end of the block), realised as an INCREMENTAL sha256 chain —
digest_j = sha256(block_size, padded_len, tokens[0:(j+1)*bs]) built one
block at a time — so registry keys are O(1) bytes each instead of O(plen)
token tuples and a 32k-token system prompt does not hold megabytes of
boxed ints live. The padded length is part of the key because the
prefill's reduction shapes depend on it — two requests only share blocks
their own prefill would have filled with identical values.

Ring wrap vs sharing (sliding-window layers): under EAGER inserts,
blocks that decode will later overwrite are simply never shared — every
block a slot writes is exclusively owned from admission, no
copy-on-write needed. Under LAZY growth the same rule used to turn the
whole prompt prefix unshareable the moment any slot's budget could wrap
the ring, permanently disabling prefix sharing for long generations.
Lazy inserts therefore DO share fully-prompt blocks that decode may
later overwrite, and `grow()` copy-on-writes at wrap time: when the
cursor crosses into a chain position backed by a REGISTERED block, the
slot gets a fresh private block, the (src, dst) pair is queued on
`_pending_cow` for the pool to copy arena content device-side, and the
slot's reference on the shared block is released — the pre-wrap prefix
stays registered (live for other holders, or parked on the retained
LRU) and later waves keep hitting it. Unregistered private blocks
still wrap in place, copy-free.

Retained prefixes (`retain_limit > 0`): when the LAST holder of a
registered prefix block evicts, the block moves to a bounded LRU
"retained" list instead of the free list — its arena content stays
bitwise valid (no table references it, so nothing can write it), and a
later request with the same (padded_len, tokens) prefix REVIVES it
copy-free instead of re-prefilling its KV into a fresh block. Retained
blocks are reclaimed lazily: allocation pressure pops the LRU tail
(unregister + free) before ever failing, so retention can delay reuse
but never causes an allocation failure the free list alone would not
have had.

Chain growth (`lazy=True` inserts + `grow()`): admission allocates only
the chain positions the PROMPT occupies; decode-budget positions stay
NULL in the table and are allocated one block at a time as the write
cursor crosses block boundaries — or copy-on-written when the ring
wraps onto a shared prompt block (see above).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np


class NoBlocksError(RuntimeError):
    """Arena exhausted: the caller should keep the request queued (at
    admission) or preempt a victim slot (mid-decode growth)."""


NULL_BLOCK = 0


class BlockAllocator:
    """Free-list allocator with refcounts over blocks 1..n_blocks-1.

    Three disjoint states per data block: FREE (on the free list, ref 0),
    LIVE (ref > 0, referenced by tables) and RETAINED (ref 0, off the
    free list — a warm prefix block parked by release(keep=True) until
    revive()/reclaim() moves it back). `watermark` is advisory headroom
    the ADMISSION gate subtracts from the allocatable count so mid-decode
    growth rarely has to preempt; alloc() itself ignores it (growth is
    exactly what the watermark reserves blocks for).
    """

    def __init__(self, n_blocks: int, watermark: int = 0):
        if n_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 data + null), got {n_blocks}")
        if watermark < 0 or watermark >= n_blocks - 1:
            raise ValueError(
                f"watermark {watermark} must be in [0, {n_blocks - 1})")
        self.n_blocks = n_blocks
        self.watermark = watermark
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))
        self._limbo: set = set()        # retained: ref 0, off the free list
        self.ref = np.zeros(n_blocks, np.int32)

    @property
    def n_free(self) -> int:
        """Blocks available for allocation (excludes null + retained)."""
        return len(self._free)

    @property
    def n_live(self) -> int:
        """Blocks currently referenced by at least one table entry."""
        return int((self.ref[1:] > 0).sum())

    @property
    def n_retained(self) -> int:
        """Warm ref-0 blocks parked off the free list (reclaimable)."""
        return len(self._limbo)

    def alloc(self) -> int:
        """Take a free block (refcount 1); NoBlocksError when exhausted.
        Never touches retained blocks — the table map reclaims those
        explicitly (LRU order) before retrying."""
        if not self._free:
            raise NoBlocksError(f"all {self.n_blocks - 1} blocks in use")
        b = self._free.pop()
        self.ref[b] = 1
        return b

    def retain(self, block: int):
        """Add a reference to a live block (a shared-prefix hit)."""
        if not (0 < block < self.n_blocks) or self.ref[block] < 1:
            raise ValueError(f"retain of non-live block {block}")
        self.ref[block] += 1

    def release(self, block: int, keep: bool = False) -> bool:
        """Drop one reference; returns True when the block went FREE.
        keep=True parks a block whose refcount hits 0 in the retained
        set instead (returns False: the block is warm, not allocatable
        until reclaim())."""
        if not (0 < block < self.n_blocks) or self.ref[block] < 1:
            raise ValueError(f"release of non-live block {block}")
        self.ref[block] -= 1
        if self.ref[block] == 0:
            if keep:
                self._limbo.add(block)
                return False
            self._free.append(block)
            return True
        return False

    def revive(self, block: int):
        """Retained -> live (ref 1): a warm-prefix hit, content reused
        copy-free."""
        if block not in self._limbo:
            raise ValueError(f"revive of non-retained block {block}")
        self._limbo.discard(block)
        self.ref[block] = 1

    def reclaim(self, block: int):
        """Retained -> free list: the content is given up (LRU pressure
        or retain_limit shrink)."""
        if block not in self._limbo:
            raise ValueError(f"reclaim of non-retained block {block}")
        self._limbo.discard(block)
        self._free.append(block)

    def check_invariants(self):
        """Assert the free/live/retained partition and refcount sanity
        (test hook; also driven by the hypothesis state machine)."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate free blocks"
        assert NULL_BLOCK not in free, "null block on the free list"
        assert NULL_BLOCK not in self._limbo, "null block retained"
        assert (self.ref >= 0).all(), "negative refcount"
        assert all(self.ref[b] == 0 for b in free), "free block with refs"
        assert all(self.ref[b] == 0 for b in self._limbo), (
            "retained block with refs")
        live = {b for b in range(1, self.n_blocks) if self.ref[b] > 0}
        assert not (free & live) and not (free & self._limbo)
        assert not (live & self._limbo)
        assert free | live | self._limbo == set(range(1, self.n_blocks)), (
            "free + live + retained blocks do not partition the arena")


@dataclasses.dataclass(frozen=True)
class Placement:
    """One chain position of an insert plan."""
    chain_pos: int     # index into the slot's block table row
    block: int         # arena block id
    shared: bool       # True: reused an existing prefix block (no write)
    revived: bool = False   # True: the reuse hit the RETAINED list (the
    #                         block survived with zero holders in between)
    registered: bool = False  # True: THIS insert registered the block's
    #                           prefix key and counted the prefix_misses
    #                           increment — rollback's decrement keys off
    #                           this record, never off registry state
    #                           (which a same-admission LRU reclaim can
    #                           have churned since)


class BlockTableMap:
    """Block tables + allocator + prefix registry for ONE attention
    slot-type (full-attention and sliding-window layer types have
    different ring lengths, hence separate arenas and maps).

    `table` is the host mirror of the device block table handed to the
    jitted decode step: row `slot` lists the arena blocks backing that
    slot's logical rows [j*block_size, (j+1)*block_size), 0 = unbacked.

    `retain_limit` bounds the retained-LRU list (0 disables retention:
    the PR 3 free-on-last-release behaviour). `watermark` is forwarded
    to the allocator and only affects `admissible()`.

    `src_len` is the PREFILL window this map's inserts are backed from
    (defaults to ring_len). The speculative row_margin widens ring_len
    past the attention window while prefill caches stay window-sized, so
    the rolled-layout sharing exclusion keys off src_len — "can the
    prefill cache still back every prompt row of a full block" — not the
    widened ring.
    """

    def __init__(self, max_batch: int, ring_len: int, block_size: int,
                 n_blocks: int, *, retain_limit: int = 0,
                 watermark: int = 0, src_len: Optional[int] = None):
        if ring_len % block_size != 0:
            raise ValueError(
                f"cache length {ring_len} not a multiple of block_size "
                f"{block_size}")
        if retain_limit < 0:
            raise ValueError(f"retain_limit must be >= 0, got {retain_limit}")
        self.block_size = block_size
        self.ring_len = ring_len
        self.src_len = src_len if src_len is not None else ring_len
        self.max_blocks = ring_len // block_size
        self.retain_limit = retain_limit
        self.table = np.zeros((max_batch, self.max_blocks), np.int32)
        self.alloc = BlockAllocator(n_blocks, watermark=watermark)
        self._registry: Dict[bytes, int] = {}   # prefix key -> block
        self._block_key: Dict[int, bytes] = {}  # block -> prefix key
        # retained LRU: key -> block, oldest first (ref 0, warm content)
        self._retained: "collections.OrderedDict[bytes, int]" = \
            collections.OrderedDict()
        self.retained_hits = 0     # revived warm blocks (survived ref 0)
        self.prefix_misses = 0     # registered prefix blocks written fresh
        # wrap-time copy-on-write: (src, dst) arena copies grow() queued;
        # the pool drains this and copies block content device-side
        # BEFORE the next decode write lands in dst.
        self._pending_cow: List[Tuple[int, int]] = []

    # ---------------- planning ----------------

    def _chain(self, prompt_key, plen: int, padded_len: int, budget: int,
               share: bool,
               lazy: bool = False) -> List[Tuple[int, Optional[bytes], bool]]:
        """(chain_pos, sharing key | None, prompt_backed) for every block
        the slot's full chain covers.

        Rows the slot touches: prompt rows 0..plen-1 plus decode writes at
        rows plen..plen+budget-2 (the final sampled token is never fed
        back). Ring wrap maps row r to r % ring_len. Under EAGER inserts
        chain positions that decode will overwrite are excluded from
        sharing (the slot writes them in place, so they must be
        exclusively owned); under LAZY inserts they stay shareable —
        grow() copy-on-writes the position at wrap time, so the shared
        content is never clobbered. A rolled prefill layout
        (padded_len > src_len: the prefill cache no longer backs every
        prompt row) is never content-addressable and excludes the whole
        insert either way. `prompt_backed` marks positions
        holding at least one prompt row — the ones a LAZY insert must
        allocate at admission (the rest grow on demand as the write
        cursor reaches them). Keys are snapshots of one sha256 chain over
        (block_size, padded_len, prompt tokens so far) — O(1) bytes per
        block.
        """
        bs, L = self.block_size, self.ring_len
        total_rows = plen + max(budget - 1, 0)
        wrap = total_rows > L
        chain_len = self.max_blocks if wrap else -(-total_rows // bs)
        overwritten = {(r % L) // bs for r in range(plen, total_rows)}
        prompt_backed = {(r % L) // bs for r in range(plen)}
        rolled = padded_len > self.src_len
        toks = np.asarray(prompt_key, np.int64)
        h = hashlib.sha256(np.array([bs, padded_len], np.int64).tobytes())
        out = []
        for j in range(chain_len):
            key = None
            if (j + 1) * bs <= plen:          # entirely prompt-backed
                h.update(toks[j * bs:(j + 1) * bs].tobytes())
                if share and not rolled and (lazy or j not in overwritten):
                    key = h.digest()
            out.append((j, key, j in prompt_backed))
        return out

    def admission_plan(self, prompt_key, plen: int, padded_len: int,
                       budget: int, share: bool = True,
                       lazy: bool = False) -> Tuple[int, int]:
        """(fresh blocks, warm retained hits) an insert would consume.

        Fresh blocks come off the free list (possibly via LRU reclaim);
        retained hits revive warm blocks. Their SUM is what admission
        subtracts from `admissible()` — a retained hit that pressure
        converts to a miss mid-insert costs one block either way, so the
        count is conversion-invariant. lazy=True restricts the plan to
        prompt-backed chain positions (decode positions grow on demand).
        """
        fresh = hits = 0
        for _, key, prompt_backed in self._chain(prompt_key, plen,
                                                 padded_len, budget, share,
                                                 lazy):
            if lazy and not prompt_backed:
                continue
            if key is not None and key in self._registry:
                if key in self._retained:
                    hits += 1
            else:
                fresh += 1
        return fresh, hits

    def blocks_needed(self, prompt_key, plen: int, padded_len: int,
                      budget: int, share: bool = True,
                      lazy: bool = False) -> int:
        """Fresh blocks an insert would consume (registry hits are free,
        whether live-shared or retained)."""
        return self.admission_plan(prompt_key, plen, padded_len, budget,
                                   share, lazy)[0]

    def admissible(self) -> int:
        """Blocks the ADMISSION gate may plan against: free + reclaimable
        retained, minus the growth watermark. Growth itself ignores the
        watermark — reserving headroom for it is the watermark's job."""
        return (self.alloc.n_free + self.alloc.n_retained
                - self.alloc.watermark)

    # ---------------- mutation ----------------

    def _alloc_block(self) -> int:
        """Allocate a fresh block, reclaiming the LRU-oldest retained
        block (unregister + free) under pressure before failing."""
        try:
            return self.alloc.alloc()
        except NoBlocksError:
            if not self._retained:
                raise
            key, b = self._retained.popitem(last=False)   # LRU oldest
            del self._registry[key]
            del self._block_key[b]
            self.alloc.reclaim(b)
            return self.alloc.alloc()

    def insert(self, slot: int, prompt_key, plen: int,
               padded_len: int, budget: int,
               share: bool = True, lazy: bool = False) -> List[Placement]:
        """Allocate/retain the slot's chain. Atomic: on NoBlocksError
        every block this call touched is released and the table row is
        left empty, so the caller can requeue the request.

        lazy=False reserves the WHOLE chain (prompt + decode budget) up
        front — a decoding slot can then never fail. lazy=True allocates
        only the prompt-backed positions; the caller must grow() the
        chain before each decode write (and preempt on NoBlocksError).
        """
        assert not self.table[slot].any(), f"slot {slot} table not empty"
        placed: List[Placement] = []
        try:
            for j, key, prompt_backed in self._chain(prompt_key, plen,
                                                     padded_len, budget,
                                                     share, lazy):
                if lazy and not prompt_backed:
                    continue
                if key is not None and key in self._registry:
                    b = self._registry[key]
                    if key in self._retained:       # warm ref-0 block
                        del self._retained[key]
                        self.alloc.revive(b)
                        self.retained_hits += 1
                        placed.append(Placement(j, b, True, revived=True))
                    else:
                        self.alloc.retain(b)
                        placed.append(Placement(j, b, True))
                else:
                    b = self._alloc_block()
                    placed.append(Placement(j, b, False,
                                            registered=key is not None))
                    if key is not None:
                        self._registry[key] = b
                        self._block_key[b] = key
                        self.prefix_misses += 1
        except NoBlocksError:
            self._rollback(placed)
            raise
        for p in placed:
            self.table[slot, p.chain_pos] = p.block
        return placed

    def _rollback(self, placed: List[Placement]):
        """Undo an insert's placements exactly. NOT plain _release(): a
        fresh block registered by THIS insert has no content yet and
        must never be parked warm — unregister + free it. Revived
        blocks (content still valid) go back to the retained list they
        came from, with the hit counter corrected; plain shared retains
        just drop the extra reference.

        Counter accounting pairs with the placement RECORD, not with
        registry state at rollback time: prefix_misses decrements only
        for placements flagged `registered` (the ones whose insert
        counted the matching increment). An LRU reclaim later in the
        same admission can unregister blocks between the increment and
        this rollback, so deriving the decrement from a _block_key
        lookup could double-count a miss that was already undone —
        driving the counter negative and retained_hit_rate above 1.0.
        The non-negative counter invariant is asserted by
        check_invariants and the hypothesis state machines."""
        for p in placed:
            if p.revived:
                self.alloc.release(p.block, keep=True)
                self._retained[self._block_key[p.block]] = p.block
                self.retained_hits -= 1
            elif p.shared:
                self.alloc.release(p.block)
            else:
                if p.registered:
                    key = self._block_key.pop(p.block, None)
                    if key is not None:
                        del self._registry[key]
                    self.prefix_misses -= 1   # never materialized
                self.alloc.release(p.block)
        assert self.prefix_misses >= 0 and self.retained_hits >= 0, (
            "rollback drove a hit/miss counter negative",
            self.prefix_misses, self.retained_hits)

    def rollback_insert(self, slot: int, placed: List[Placement]):
        """Undo a COMPLETED insert whose sibling slot-type failed (the
        pool's cross-map rollback): clear the table entries this insert
        wrote, then apply the same exact per-placement rollback the
        intra-map failure path uses — fresh registrations are freed and
        unregistered (their device content was never written), revived
        blocks are re-parked warm, shared retains are dropped."""
        for p in placed:
            self.table[slot, p.chain_pos] = NULL_BLOCK
        self._rollback(placed)

    def grow(self, slot: int, row: int) -> Optional[int]:
        """Back the chain position covering logical `row` (the next
        decode write) with an exclusively-owned block.

        Three cases:
          * position unbacked -> allocate a fresh block (plain growth);
          * position backed by an unregistered private block -> None
            (a whole-chain insert, a previous grow, or a ring wrap onto
            content nobody else can reference: write in place);
          * position backed by a REGISTERED prefix block (lazy sharing
            + ring wrap) -> copy-on-write: allocate a private dst,
            queue (src, dst) on `_pending_cow` for the pool's arena
            copy, and release this slot's reference on src — the prefix
            stays registered (live for other holders or parked on the
            retained LRU) and later waves keep sharing it. A sole
            holder with retention off skips the copy: the block is
            simply unregistered and written in place.

        Returns the newly allocated block id (ref 1, exclusively owned)
        or None when the slot writes in place. Raises NoBlocksError when
        free list AND retained LRU are both empty — the engine's
        preemption path; no state is mutated in that case. Grown/COW'd
        blocks hold decode writes only: never registered, shared, or
        retained."""
        j = (row % self.ring_len) // self.block_size
        src = int(self.table[slot, j])
        if src != NULL_BLOCK:
            key = self._block_key.get(src)
            if key is None:
                return None                    # private block: wrap in place
            if self.alloc.ref[src] == 1 and self.retain_limit == 0:
                # sole holder, no retention: nobody can ever hit the
                # registration again once we write — drop it, skip the copy
                del self._registry[key]
                del self._block_key[src]
                return None
            dst = self._alloc_block()
            self.table[slot, j] = dst
            self._pending_cow.append((src, dst))
            self._release(src)
            return dst
        b = self._alloc_block()
        self.table[slot, j] = b
        return b

    def _release(self, block: int) -> bool:
        """Drop one table reference. A registered prefix block whose last
        holder leaves is RETAINED (LRU, bounded) instead of freed when
        retention is on; anything else frees normally. Returns True when
        the block landed on the free list. (Rollback paths do NOT come
        through here — see _rollback: a block whose device content was
        never written must not be parked warm.)"""
        key = self._block_key.get(block)
        if (self.retain_limit > 0 and key is not None
                and self.alloc.ref[block] == 1):
            self.alloc.release(block, keep=True)
            self._retained[key] = block         # newest at the end
            while len(self._retained) > self.retain_limit:
                k, b = self._retained.popitem(last=False)
                del self._registry[k]
                del self._block_key[b]
                self.alloc.reclaim(b)
            return False
        freed = self.alloc.release(block)
        if freed and key is not None:
            del self._registry[self._block_key.pop(block)]
        return freed

    def evict(self, slot: int) -> List[int]:
        """Return the slot's blocks to the pool; yields the freed ids
        (retained blocks are parked warm, not freed, and not listed).
        Only for slots whose insert COMPLETED — an insert that failed
        midway in a sibling slot-type rolls back via rollback_insert."""
        freed = []
        for j in range(self.max_blocks):
            b = int(self.table[slot, j])
            if b != NULL_BLOCK and self._release(b):
                freed.append(b)
            self.table[slot, j] = NULL_BLOCK
        return freed

    # ---------------- introspection ----------------

    @property
    def n_shared(self) -> int:
        """Prefix blocks currently registered for content-address reuse
        (live shared blocks + warm retained blocks)."""
        return len(self._registry)

    @property
    def n_retained(self) -> int:
        """Warm ref-0 prefix blocks on the retained LRU."""
        return len(self._retained)

    def prefix_warm(self, prompt_key, plen: int, padded_len: int) -> bool:
        """Does the request's FIRST full prompt block hit the registry
        (live or retained)? The prefix-affinity scheduling policy's
        admission signal — cheap: one sha256 over block_size tokens."""
        bs = self.block_size
        if plen < bs or padded_len > self.src_len:
            return False
        h = hashlib.sha256(np.array([bs, padded_len], np.int64).tobytes())
        h.update(np.asarray(prompt_key, np.int64)[:bs].tobytes())
        return h.digest() in self._registry

    def check_invariants(self):
        """Assert table/refcount/registry/retained consistency: every
        table reference holds exactly one refcount, multiply-referenced
        blocks are registered shared prefixes, registered blocks are
        live or retained, retained blocks are never table-referenced
        (so live writes cannot alias them) and respect the LRU bound.
        Hit/miss telemetry counters are never negative — the rollback
        accounting contract that keeps retained_hit_rate <= 1.0."""
        self.alloc.check_invariants()
        assert self.prefix_misses >= 0, (
            "negative prefix_misses (rollback over-decremented)")
        assert self.retained_hits >= 0, (
            "negative retained_hits (rollback over-decremented)")
        counts = np.bincount(self.table.ravel(),
                             minlength=self.alloc.n_blocks)
        # every table reference holds exactly one refcount
        np.testing.assert_array_equal(counts[1:], self.alloc.ref[1:])
        # a block in two tables must be a registered shared block
        multi = {b for b in np.nonzero(counts > 1)[0] if b != NULL_BLOCK}
        assert multi <= set(self._block_key), (
            "unshared block referenced by multiple table entries", multi)
        # retained list: bounded, ref 0, registered, never in a table
        assert len(self._retained) <= max(self.retain_limit, 0), (
            "retained LRU exceeds its bound")
        assert len(self._retained) == self.alloc.n_retained
        for key, b in self._retained.items():
            assert self._registry.get(key) == b, "retained but unregistered"
            assert counts[b] == 0, f"retained block {b} aliased by a table"
            assert self.alloc.ref[b] == 0
        # registry consistency: every registered block is live or retained
        for key, b in self._registry.items():
            assert self._block_key.get(b) == key
            assert self.alloc.ref[b] > 0 or key in self._retained, (
                "registered block neither live nor retained", b)

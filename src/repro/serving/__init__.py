"""Serving subsystem: continuous-batching decode over a fixed slot pool.

See serving/engine.py for the architecture overview. Public surface:

  ContinuousEngine   slot-pool continuous batching (paged cache default;
                     spec_draft=(arch, params) enables draft-verify
                     speculative decoding, spec_k tokens per round)
  make_spec_pair     acceptance-1.0 speculative fixture: inert upper
                     periods + one-period draft sharing embed/head
  ServeEngine        static-batch baseline (padded lockstep decode)
  Request            one prompt + generation budget (+ latency trace)
  Sampler            temperature/top-k/top-p decode (per-slot PRNG keys;
                     greedy stable_tiebreak for bf16 differentials)
  throughput_probe   warmup-aware timed run -> tokens/s + percentiles
  Scheduler          ticketed slot admission (host-side, property-tested)
  SchedulingPolicy   admission/victim/SLO policy (fifo | arrival-deadline
                     | prefix-affinity; see serving/scheduler.py)
  CachePool          dense pooled KV/SSM cache + insert/evict (baseline)
  PagedCachePool     block-paged KV arena with shared prompt prefixes,
                     lazy chain growth and a retained-prefix LRU
  EncDecCachePool    encdec family pool: dense per-slot self-attention
                     rows + a refcounted, content-addressed cross-
                     attention block arena keyed by the raw encoder
                     input (frames_key) — same-input requests share
                     encoder blocks like shared prompt prefixes
  BlockAllocator     refcounted free-list over arena blocks
  BlockTableMap      per-slot-type tables + prefix registry (host-side)
  AdmissionController  chunked-prefill admission: one resumable prompt
                     chunk per step, fused into the decode token budget
  plan_chunk         the budget partition (size + active <= budget)
  SLO / OpenLoopDriver / poisson_arrivals / slo_report
                     open-loop traffic: seeded Poisson arrivals with
                     TTFT/ITL SLOs and goodput accounting (traffic.py)
  ReplicaRouter      prefix-affinity front-end over N engine replicas
                     (content-addressed sticky routing, least-depth
                     fallback; router.py) — drives like one engine
  prefix_route_key   the router's leading-prompt-block content key
"""
from repro.serving.admission import (AdmissionController, PrefillTask,
                                     chunk_granularity, plan_chunk)
from repro.serving.block_allocator import (BlockAllocator, BlockTableMap,
                                           NoBlocksError)
from repro.serving.cache_pool import (CachePool, EncDecCachePool,
                                      PagedCachePool, frames_key)
from repro.serving.engine import (ContinuousEngine, Request, ServeEngine,
                                  apply_serving_policy,
                                  build_encdec_prefill_fn,
                                  build_first_token_fn,
                                  build_prefill_fn, make_spec_pair,
                                  pad_prompts, prompt_granularity,
                                  synthetic_encdec_requests,
                                  synthetic_requests,
                                  synthetic_scoring_requests,
                                  throughput_probe)
from repro.serving.metrics import (DepthTracker, RequestTrace, aggregate,
                                   hit_rate, percentile)
from repro.serving.router import (ROUTE_POLICIES, ReplicaRouter,
                                  prefix_route_key)
from repro.serving.sampler import Sampler, fold_keys, stable_argmax
from repro.serving.scheduler import (ArrivalDeadlinePolicy, PolicyContext,
                                     PrefixAffinityPolicy, Scheduler,
                                     SchedulerError, SchedulingPolicy)
from repro.serving.traffic import (SLO, OpenLoopDriver, bimodal_requests,
                                   meets_slo, poisson_arrivals, slo_report)

__all__ = [
    "AdmissionController", "ArrivalDeadlinePolicy", "BlockAllocator",
    "BlockTableMap", "CachePool", "ContinuousEngine", "DepthTracker",
    "EncDecCachePool",
    "NoBlocksError", "OpenLoopDriver", "PagedCachePool", "PolicyContext",
    "PrefillTask", "PrefixAffinityPolicy", "ROUTE_POLICIES", "ReplicaRouter",
    "Request", "RequestTrace", "SLO",
    "Sampler", "Scheduler", "SchedulerError", "SchedulingPolicy",
    "ServeEngine", "aggregate", "apply_serving_policy", "bimodal_requests",
    "build_encdec_prefill_fn",
    "build_first_token_fn", "build_prefill_fn", "chunk_granularity",
    "fold_keys", "frames_key", "hit_rate", "make_spec_pair", "meets_slo",
    "pad_prompts", "percentile",
    "plan_chunk", "poisson_arrivals", "prefix_route_key",
    "prompt_granularity", "slo_report",
    "stable_argmax", "synthetic_encdec_requests", "synthetic_requests",
    "synthetic_scoring_requests", "throughput_probe",
]

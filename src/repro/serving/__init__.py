"""Serving subsystem: continuous-batching decode over a fixed slot pool.

See serving/engine.py for the architecture overview. Public surface:

  ContinuousEngine   slot-pool continuous batching (paged cache default)
  ServeEngine        static-batch baseline (padded lockstep decode)
  Request            one prompt + generation budget (+ latency trace)
  Sampler            temperature/top-k/top-p decode (per-slot PRNG keys)
  throughput_probe   warmup-aware timed run -> tokens/s + percentiles
  Scheduler          FIFO slot admission (host-side, property-tested)
  CachePool          dense pooled KV/SSM cache + insert/evict (baseline)
  PagedCachePool     block-paged KV arena with shared prompt prefixes
  BlockAllocator     refcounted free-list over arena blocks
  BlockTableMap      per-slot-type tables + prefix registry (host-side)
"""
from repro.serving.block_allocator import (BlockAllocator, BlockTableMap,
                                           NoBlocksError)
from repro.serving.cache_pool import CachePool, PagedCachePool
from repro.serving.engine import (ContinuousEngine, Request, ServeEngine,
                                  apply_serving_policy, build_first_token_fn,
                                  build_prefill_fn, pad_prompts,
                                  prompt_granularity, synthetic_requests,
                                  throughput_probe)
from repro.serving.metrics import RequestTrace, aggregate, percentile
from repro.serving.sampler import Sampler, fold_keys
from repro.serving.scheduler import Scheduler, SchedulerError

__all__ = [
    "BlockAllocator", "BlockTableMap", "CachePool", "ContinuousEngine",
    "NoBlocksError", "PagedCachePool", "Request", "RequestTrace", "Sampler",
    "Scheduler", "SchedulerError", "ServeEngine", "aggregate",
    "apply_serving_policy", "build_first_token_fn", "build_prefill_fn",
    "fold_keys", "pad_prompts", "percentile", "prompt_granularity",
    "synthetic_requests", "throughput_probe",
]

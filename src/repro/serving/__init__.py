"""Serving subsystem: continuous-batching decode over a fixed slot pool.

See serving/engine.py for the architecture overview. Public surface:

  ContinuousEngine   slot-pool continuous batching (paged cache default)
  ServeEngine        static-batch baseline (padded lockstep decode)
  Request            one prompt + generation budget (+ latency trace)
  Sampler            temperature/top-k/top-p decode (per-slot PRNG keys)
  throughput_probe   warmup-aware timed run -> tokens/s + percentiles
  Scheduler          ticketed slot admission (host-side, property-tested)
  SchedulingPolicy   admission/victim/SLO policy (fifo | arrival-deadline
                     | prefix-affinity; see serving/scheduler.py)
  CachePool          dense pooled KV/SSM cache + insert/evict (baseline)
  PagedCachePool     block-paged KV arena with shared prompt prefixes,
                     lazy chain growth and a retained-prefix LRU
  BlockAllocator     refcounted free-list over arena blocks
  BlockTableMap      per-slot-type tables + prefix registry (host-side)
"""
from repro.serving.block_allocator import (BlockAllocator, BlockTableMap,
                                           NoBlocksError)
from repro.serving.cache_pool import CachePool, PagedCachePool
from repro.serving.engine import (ContinuousEngine, Request, ServeEngine,
                                  apply_serving_policy, build_first_token_fn,
                                  build_prefill_fn, pad_prompts,
                                  prompt_granularity, synthetic_requests,
                                  throughput_probe)
from repro.serving.metrics import (DepthTracker, RequestTrace, aggregate,
                                   percentile)
from repro.serving.sampler import Sampler, fold_keys
from repro.serving.scheduler import (ArrivalDeadlinePolicy, PolicyContext,
                                     PrefixAffinityPolicy, Scheduler,
                                     SchedulerError, SchedulingPolicy)

__all__ = [
    "ArrivalDeadlinePolicy", "BlockAllocator", "BlockTableMap", "CachePool",
    "ContinuousEngine", "DepthTracker", "NoBlocksError", "PagedCachePool",
    "PolicyContext", "PrefixAffinityPolicy", "Request", "RequestTrace",
    "Sampler", "Scheduler", "SchedulerError", "SchedulingPolicy",
    "ServeEngine", "aggregate", "apply_serving_policy",
    "build_first_token_fn", "build_prefill_fn", "fold_keys", "pad_prompts",
    "percentile", "prompt_granularity", "synthetic_requests",
    "throughput_probe",
]

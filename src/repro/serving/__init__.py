"""Serving subsystem: continuous-batching decode over a fixed slot pool.

See serving/engine.py for the architecture overview. Public surface:

  ContinuousEngine   slot-pool continuous batching (production shape)
  ServeEngine        static-batch baseline (padded lockstep decode)
  Request            one prompt + generation budget (+ latency trace)
  throughput_probe   warmup-aware timed run -> tokens/s + percentiles
  Scheduler          FIFO slot admission (host-side, property-tested)
  CachePool          preallocated pooled KV/SSM cache + insert/evict
"""
from repro.serving.cache_pool import CachePool
from repro.serving.engine import (ContinuousEngine, Request, ServeEngine,
                                  apply_serving_policy, build_prefill_fn,
                                  pad_prompts, prompt_granularity,
                                  synthetic_requests, throughput_probe)
from repro.serving.metrics import RequestTrace, aggregate, percentile
from repro.serving.scheduler import Scheduler, SchedulerError

__all__ = [
    "CachePool", "ContinuousEngine", "Request", "RequestTrace",
    "Scheduler", "SchedulerError", "ServeEngine", "aggregate",
    "apply_serving_policy", "build_prefill_fn", "pad_prompts",
    "percentile", "prompt_granularity", "synthetic_requests",
    "throughput_probe",
]

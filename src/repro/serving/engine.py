"""Batched serving engine: continuous greedy decoding over a request queue.

Serving semantics match the decode dry-run shapes: prefill once per request
batch, then step one token per iteration against the shared KV/SSM cache.
The engine is deliberately simple (static batch, greedy) — the point is
that `serve_step` is the exact function the decode_32k / long_500k shapes
lower on the production mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int = 16
    generated: Optional[np.ndarray] = None


class ServeEngine:
    def __init__(self, arch, params, *, max_len: int = 512):
        self.arch = arch
        self.params = params
        self.max_len = max_len
        self._decode = jax.jit(
            lambda p, b, c: arch.decode_step(p, b, c))

    def run_batch(self, requests: List[Request]) -> List[Request]:
        assert requests
        B = len(requests)
        plen = max(len(r.prompt) for r in requests)
        prompts = np.full((B, plen), 0, np.int32)
        for i, r in enumerate(requests):
            prompts[i, -len(r.prompt):] = r.prompt  # left-pad

        batch = {"tokens": jnp.asarray(prompts)}
        # decode cache must be long enough for prompt + generation
        steps = max(r.max_new_tokens for r in requests)
        logits, cache = self.arch.prefill(self.params, batch,
                                          cache_len=plen + steps)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        out = [tok]
        for _ in range(steps - 1):
            step_batch = {"tokens": tok[:, None]}
            logits, cache = self._decode(self.params, step_batch, cache)
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            out.append(tok)
        gen = np.stack([np.asarray(t) for t in out], axis=1)  # (B, steps)
        for i, r in enumerate(requests):
            r.generated = gen[i, :r.max_new_tokens]
        return requests


def throughput_probe(engine: ServeEngine, requests: List[Request]) -> dict:
    t0 = time.time()
    done = engine.run_batch(requests)
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    return {"requests": len(done), "tokens": toks,
            "tokens_per_s": toks / dt, "wall_s": dt}

"""Serving engines: continuous batching (slot pool) + the static baseline.

Two engines share one decode step (`build_serve_step` over
`Arch.decode_step`), one precision path and one prompt handling scheme:

`ContinuousEngine` — the production shape. A fixed pool of `max_batch`
decode slots backed by a preallocated pooled KV/SSM cache
(serving/cache_pool.py). Each request is prefilled alone (batch 1, prompt
left-padded to the arch's granularity with pad positions < 0, so padding
is exactly masked out of attention/SSM/MoE state), its cache row is
inserted into a free slot between decode steps, and one fixed-shape
jitted decode step then advances every active slot per iteration — no
recompiles for the lifetime of the engine, and freed slots are refilled
from the admission queue while other requests keep decoding.

`ServeEngine` — the static baseline (kept for comparison + older
callers): pads the whole request batch to a common length, prefills once,
decodes lockstep for max(max_new_tokens) steps. Requests admitted
together must finish together; the padded prefill is still exact (local
positions, pads masked) so both engines emit token-identical greedy
output for the same request set — asserted in tests/test_serving_engine.py
under fp32 and bf16 policies.

Precision: pass `policy` (name or `repro.precision.Policy`) — parameters
are cast once at engine construction (bf16/fp16 model copy with fp32
LN/bias overrides, matching training's inference-side policy) and matmuls
run in the policy compute dtype, while greedy sampling always reads fp32
logits (see `build_serve_step`). MoE archs serve with dropless dispatch
(capacity = tokens * top_k) so a token's output never depends on its
batch-mates — the property that makes continuous batching and the static
path byte-comparable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.steps import build_serve_step, greedy_next
from repro.serving.cache_pool import CachePool
from repro.serving.metrics import RequestTrace, aggregate
from repro.serving.scheduler import Scheduler


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int = 16
    generated: Optional[np.ndarray] = None
    rid: Optional[int] = None
    trace: RequestTrace = dataclasses.field(default_factory=RequestTrace)


def apply_serving_policy(arch, params, policy=None):
    """Inference-side precision + MoE policy for an (arch, params) pair.

    * policy (optional name/Policy): cast the parameter copy per the policy
      (keep_fp32 overrides intact) and run compute in its compute_dtype.
    * MoE archs: serve dropless — capacity_factor = n_experts makes
      cap = tokens * top_k, so no token is ever dropped and routing is
      independent of batch composition (continuous == static, padded ==
      unpadded). Serving never trains, so the load-balance aux is unused.
    """
    cfg = arch.cfg
    if policy is not None:
        from repro.precision import get_policy
        policy = get_policy(policy)
        cfg = policy.apply_to_cfg(cfg)
        params = policy.cast_params(params)
    if getattr(cfg, "n_experts", 0):
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    if cfg is not arch.cfg:
        arch = dataclasses.replace(arch, cfg=cfg)
    return arch, params


def prompt_granularity(cfg) -> int:
    """Smallest prefill length multiple the arch supports: mamba's chunked
    SSD scan needs S % chunk == 0; attention/MoE take any length."""
    if any(m == "mamba" for m, _ in getattr(cfg, "superblock", ())):
        return int(cfg.mamba_chunk)
    return 1


def build_prefill_fn(arch, max_len: int):
    """Jitted masked prefill shared by both engines: (params, tokens,
    positions) -> (first greedy token fp32, pooled cache of max_len rows).
    Retraces per padded prompt length — bucket lengths to bound that."""
    def prefill(params, tokens, positions):
        logits, cache = arch.prefill(
            params, {"tokens": tokens}, cache_len=max_len,
            per_slot=True, positions=positions)
        return greedy_next(logits.astype(jnp.float32)), cache
    return jax.jit(prefill)


def synthetic_requests(n: int, vocab: int, *, prompt_len: int,
                       new_tokens: int, seed: int = 0,
                       min_new_frac: float = 0.5):
    """Load-generator workload: mixed prompt lengths in
    [prompt_len/2, prompt_len] and budgets in [new_tokens*min_new_frac,
    new_tokens]. Pure function of the arguments, so two engines handed the
    same seed see byte-identical requests."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        plen = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        new = int(rng.integers(max(1, int(new_tokens * min_new_frac)),
                               new_tokens + 1))
        reqs.append(Request(
            prompt=rng.integers(5, vocab, size=plen).astype(np.int32),
            max_new_tokens=new))
    return reqs


def pad_prompts(prompts: List[np.ndarray], granularity: int = 1,
                pad_len: Optional[int] = None):
    """Left-pad to a common length; returns (tokens, positions, lengths).

    Positions are per-request LOCAL timelines (0..len-1 for real tokens,
    negative for padding) — the contract the masked prefill relies on.
    """
    lens = np.array([len(p) for p in prompts], np.int32)
    plen = pad_len if pad_len is not None else int(lens.max())
    plen = -(-plen // granularity) * granularity
    if plen < int(lens.max()):
        raise ValueError(f"pad_len {plen} < longest prompt {lens.max()}")
    B = len(prompts)
    tokens = np.zeros((B, plen), np.int32)
    positions = np.empty((B, plen), np.int32)
    for i, p in enumerate(prompts):
        tokens[i, plen - len(p):] = p
        positions[i] = np.arange(plen) - (plen - len(p))
    return tokens, positions, lens


class ContinuousEngine:
    """Continuous-batching greedy decode over a fixed slot pool."""

    def __init__(self, arch, params, *, max_batch: int = 8,
                 max_len: int = 256, policy=None, mesh=None,
                 prefill_bucket: int = 1, on_step=None):
        if arch.kind != "decoder":
            raise ValueError(f"serving needs a decoder arch, got {arch.kind}")
        self.arch, self.params = apply_serving_policy(arch, params, policy)
        self.max_batch = max_batch
        self.max_len = max_len
        # prefill lengths round up to bucket multiples: fewer distinct
        # prompt shapes -> fewer prefill compilations (the masked left-pad
        # keeps bucketed prefill token-exact).
        self.prefill_bucket = max(prefill_bucket,
                                  prompt_granularity(self.arch.cfg))
        self.pool = CachePool(self.arch, max_batch, max_len)
        self.scheduler = Scheduler(max_batch)
        self.on_step = on_step          # callback(dict) per decode step
        self._step = build_serve_step(self.arch.decode_step, mesh)
        self._prefill = build_prefill_fn(self.arch, max_len)

        self._tokens = np.zeros((max_batch, 1), np.int32)
        self._positions = np.zeros((max_batch, 1), np.int32)
        self._emitted = {}              # slot -> list of generated ids
        self._next_rid = 0
        self.steps_run = 0
        self.slot_steps = 0             # decode-step slots that were active

    # ---------------- request lifecycle ----------------

    def submit(self, request: Request):
        if len(request.prompt) + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt {len(request.prompt)} + max_new_tokens "
                f"{request.max_new_tokens} exceeds max_len {self.max_len}")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if request.rid is None:
            request.rid = self._next_rid
            self._next_rid += 1
        request.trace.mark_submit()
        self.scheduler.submit(request)

    def _finish(self, slot: int):
        req = self.scheduler.complete(slot)
        req.generated = np.array(self._emitted.pop(slot), np.int32)
        req.trace.done_t = time.perf_counter()
        self.pool.evict(slot)
        return req

    def _admit(self):
        """Fill free slots from the queue: prefill each request alone and
        insert its cache row. Runs between decode steps (and again right
        away when a 1-token request completes at admission)."""
        while True:
            pairs = self.scheduler.assign()
            if not pairs:
                return
            for slot, req in pairs:
                tokens, positions, lens = pad_prompts(
                    [req.prompt], self.prefill_bucket)
                first, req_cache = self._prefill(
                    self.params, jnp.asarray(tokens), jnp.asarray(positions))
                self.pool.insert(req_cache, slot)
                t0 = int(np.asarray(first)[0])
                req.trace.admit_t = time.perf_counter()
                req.trace.mark_token(req.trace.admit_t)
                self._emitted[slot] = [t0]
                self._tokens[slot, 0] = t0
                self._positions[slot, 0] = int(lens[0])
                if len(self._emitted[slot]) >= req.max_new_tokens:
                    self._finish(slot)   # 1-token request: done at prefill

    def step(self) -> bool:
        """One engine iteration: admissions, then one pooled decode step.
        Returns False when no work remains."""
        self._admit()
        active = sorted(self.scheduler.active)
        if not active:
            return self.scheduler.has_work
        nxt, self.pool.cache = self._step(
            self.params, jnp.asarray(self._tokens),
            jnp.asarray(self._positions), self.pool.cache)
        nxt = np.asarray(nxt)            # host sync: tokens feed next step
        now = time.perf_counter()
        self.steps_run += 1
        self.slot_steps += len(active)
        for slot in active:
            req = self.scheduler.active[slot]
            self._emitted[slot].append(int(nxt[slot]))
            req.trace.mark_token(now)
            self._tokens[slot, 0] = int(nxt[slot])
            self._positions[slot, 0] += 1
            if len(self._emitted[slot]) >= req.max_new_tokens:
                self._finish(slot)
        if self.on_step is not None:
            self.on_step({"step": self.steps_run, "active": len(active),
                          "queued": self.scheduler.queued})
        return self.scheduler.has_work

    def run(self, requests: Optional[List[Request]] = None) -> List[Request]:
        """Drain: submit `requests` (if given) and step until idle."""
        for r in requests or ():
            self.submit(r)
        while self.step():
            pass
        return self.scheduler.completed

    # static-engine-compatible alias (throughput_probe, benchmarks)
    def run_batch(self, requests: List[Request]) -> List[Request]:
        self.run(requests)
        return requests

    def report(self, wall_s: float) -> dict:
        done = self.scheduler.completed
        stats = aggregate([r.trace for r in done], wall_s,
                          sum(len(r.generated) for r in done))
        denom = max(1, self.steps_run * self.max_batch)
        stats["slot_utilization"] = self.slot_steps / denom
        stats["decode_steps"] = self.steps_run
        return stats


class ServeEngine:
    """Static-batch baseline: one padded prefill, lockstep greedy decode.

    Kept as the comparison point for benchmarks/serving_load.py and for
    callers that want the simplest possible batch API. Shares the decode
    step, precision policy and exact left-pad masking with
    ContinuousEngine, so the two produce identical tokens per request."""

    def __init__(self, arch, params, *, max_len: int = 512, policy=None,
                 mesh=None):
        if arch.kind != "decoder":
            raise ValueError(f"serving needs a decoder arch, got {arch.kind}")
        self.arch, self.params = apply_serving_policy(arch, params, policy)
        self.max_len = max_len
        self.granularity = prompt_granularity(self.arch.cfg)
        self._step = build_serve_step(self.arch.decode_step, mesh)
        self._prefill = build_prefill_fn(self.arch, max_len)

    def run_batch(self, requests: List[Request]) -> List[Request]:
        assert requests
        steps = max(r.max_new_tokens for r in requests)
        tokens, positions, lens = pad_prompts(
            [r.prompt for r in requests], self.granularity)
        if tokens.shape[1] + steps > self.max_len:
            raise ValueError(
                f"padded prompt {tokens.shape[1]} + {steps} new tokens "
                f"exceeds max_len {self.max_len}")
        for r in requests:
            # respect an earlier submission timestamp: callers running
            # waves (benchmarks, launch/serve --engine static) stamp the
            # whole workload up front so TTFT includes the queue wait —
            # otherwise wave k's wait behind waves 0..k-1 would vanish
            # from the static/continuous comparison.
            if r.trace.submit_t == 0.0:
                r.trace.mark_submit()
        tok, cache = self._prefill(self.params, jnp.asarray(tokens),
                                   jnp.asarray(positions))
        out = [np.asarray(tok)]
        now = time.perf_counter()
        for r in requests:
            r.trace.admit_t = now
            r.trace.mark_token(now)
        pos_next = lens.copy()
        for _ in range(steps - 1):
            tok, cache = self._step(self.params, tok[:, None],
                                    jnp.asarray(pos_next[:, None]), cache)
            tok_h = np.asarray(tok)
            now = time.perf_counter()
            out.append(tok_h)
            pos_next += 1
            for i, r in enumerate(requests):
                if len(r.trace.token_ts) < r.max_new_tokens:
                    r.trace.mark_token(now)
        gen = np.stack(out, axis=1)      # (B, steps)
        for i, r in enumerate(requests):
            r.generated = gen[i, :r.max_new_tokens]
            r.trace.done_t = r.trace.token_ts[-1]
        return requests


def throughput_probe(engine, requests: List[Request], *,
                     warmup: bool = True) -> dict:
    """Timed run over `requests`; tokens/s + latency percentiles.

    warmup=True first runs a shape-identical clone of the request set so
    jit compilation (both prefill shapes and the decode step) stays out of
    the measured wall clock — compile time used to dominate tokens/s on
    small batches."""
    if warmup:
        clones = [Request(prompt=r.prompt.copy(),
                          max_new_tokens=r.max_new_tokens)
                  for r in requests]
        engine.run_batch(clones)
    t0 = time.perf_counter()
    done = engine.run_batch(requests)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    stats = aggregate([r.trace for r in done], dt, toks)
    stats["warmup"] = warmup
    return stats

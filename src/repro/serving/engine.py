"""Serving engines: continuous batching (paged or dense pool) + baseline.

Two engines share one decode step (`build_serve_step` over
`Arch.decode_step`), one precision path, one sampling scheme and one
prompt handling scheme:

`ContinuousEngine` — the production shape. A fixed pool of `max_batch`
decode slots backed by a preallocated KV/SSM cache. With the default
`cache="paged"` the pool is block-granular (serving/cache_pool.
PagedCachePool): attention KV lives in block arenas addressed through
per-slot block tables, identical prompt prefixes are stored once and
shared across slots (refcounted, copy-free), and eviction returns blocks
to a free list — memory scales with distinct tokens instead of
slots x max_len, so the same arena admits more concurrent requests on
shared-prefix traffic. `cache="dense"` keeps the PR 2 per-slot-rows pool
(the differential baseline).

Admission is POLICY-DRIVEN (serving/scheduler.SchedulingPolicy: fifo /
arrival-deadline / prefix-affinity) and, with the default
`growth="lazy"`, allocates only a request's PROMPT blocks up front:
decode blocks are grown one at a time as each slot's write cursor
crosses block boundaries, so arena memory tracks tokens actually
written instead of budgets promised (`slots_budget` becomes a
high-watermark on blocks in use, not a per-request reservation). When
growth exhausts the arena mid-decode the engine PREEMPTS a victim slot
(policy-chosen, youngest admission by default): its blocks are freed
and the request re-enters the queue at its arrival position with its
generated-so-far tokens as a CONTINUATION PREFILL — on re-admission the
engine prefills prompt + generated and keeps counting tokens from where
it left off, which recomputes exactly the math the evicted slot had
already done, so greedy fp32 output is preempt-invariant (and sampled
output too: sampler keys depend only on (seed, rid, token index)).
`growth="eager"` keeps the PR 3 whole-chain reservation (atomic
admission, decode can never fail, no preemption). Refcount-0 prefix
blocks park on a bounded LRU retained list instead of freeing
(`retain_blocks`), so popular system prompts stay warm ACROSS request
waves and later admissions revive them copy-free. Admission is batched:
one pass prefills ALL queued requests together, bucketed by padded
prompt length AND padded to power-of-two group sizes (compile count
O(buckets x log max_batch) instead of O(buckets x max_batch)), with
admission gated on block availability — a request that does not fit
stays at the policy head of the queue. Either way, one fixed-shape
jitted decode step advances every active slot per iteration — no
recompiles for the lifetime of the engine, block churn (growth and
preemption included).

WORKLOAD FAMILIES: one engine core serves three of them, selected by
the arch kind + `task`. (a) decoder generation — everything above.
(b) encoder-decoder generation (`task="generate"`, encdec arch): the
encoder runs inside the admission prefill and its per-layer cross K/V
is REGISTERED in a content-addressed, refcounted block arena
(serving.cache_pool.EncDecCachePool) keyed by the raw input frames —
two requests decoding the same input (beams, retries) share the
encoder blocks copy-free, exactly like shared prompt prefixes; decode
self-attention stays dense per-slot. (c) bert scoring/embedding
(`task="score"` / `"embed"`): no KV cache at all — admission batches
queued requests into ONE fixed-shape forward and completes them
immediately (a scoring slot's only state is its output, freed at
completion). Each family runs one fixed-shape jitted step compiled
once for the engine's lifetime, and `run_one` gives every family a
batch-1 latency mode (fixed B=1 jits, no scheduler/admission
overhead) whose output is token-identical to the pooled path.

`ServeEngine` — the static baseline (kept for comparison + older
callers): pads the whole request batch to a common length, prefills once,
decodes lockstep for max(max_new_tokens) steps. Requests admitted
together must finish together; the padded prefill is still exact (local
positions, pads masked) so all engines emit token-identical output for
the same request set — asserted in tests/test_serving_engine.py under
fp32 and bf16 policies, for paged and dense pools, and in
tests/test_sampling.py for sampled decode.

Sampling: pass `sampler` (spec string or serving.sampler.Sampler) for
temperature / top-k / top-p decode with per-slot PRNG keys. Keys derive
from (seed, request id, token index) only, so sampled streams are
independent of slot placement, admission order and batch composition —
the property that keeps the engines differential under sampling.
temperature=0 is bit-exact greedy. Sampling always reads fp32 logits.

Speculative decoding (`spec_draft=(draft_arch, draft_params)`,
`spec_k=K`): every decode iteration becomes a DRAFT-VERIFY round. A
small draft model (its own dense CachePool, prefilled at admission
alongside the target) runs K cheap sequential micro-steps proposing
d_1..d_K, then the target verifies all K in ONE batched step — the
verify feeds [t0, d_1..d_{K-1}] as an S=K query block (the S>1 paged
kernel / XLA path, each row causally masked at its own position) and
emits y_1..y_K. The leading run of a agreements (d_i == y_i) yields
n_emit = min(a+1, K, budget) tokens per slot per round: every emitted
token is the TARGET's pick for its position given an all-accepted
context, so the spec stream is bit-identical to the non-spec stream —
greedy trivially, sampled because row i draws with the same
fold(request_key, emitted+i) key the non-spec step would use at that
token index. Rejection rolls back by rewinding cursors and
min-scattering position -1 over the stale rows (target pool AND draft
pool) — never copying a block; sliding-window rings carry a K-1 row
margin so the verify burst cannot overwrite in-window keys
(models/decoder.paged_layout). Requires cache="paged" and
attention-only superblocks on both models (SSM state cannot rewind);
mutually exclusive with chunk_budget.

Precision: pass `policy` (name or `repro.precision.Policy`) — parameters
are cast once at engine construction (bf16/fp16 model copy with fp32
LN/bias overrides, matching training's inference-side policy) and matmuls
run in the policy compute dtype. MoE archs serve with dropless dispatch
(capacity = tokens * top_k) so a token's output never depends on its
batch-mates — the property that makes continuous batching and the static
path byte-comparable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.distributed.steps import (build_serve_step, build_verify_step,
                                     greedy_next)
from repro.serving.admission import AdmissionController, chunk_granularity
from repro.serving.block_allocator import NoBlocksError
from repro.serving.cache_pool import CachePool, PagedCachePool, _live_mesh
from repro.serving.metrics import DepthTracker, RequestTrace, aggregate
from repro.serving.sampler import Sampler, fold_keys
from repro.serving.scheduler import (PolicyContext, Scheduler,
                                     SchedulingPolicy)


@dataclasses.dataclass
class Request:
    """One serving request: a prompt and a generation budget.

    `generated` is filled by the engine on completion ((n,) int32,
    n <= max_new_tokens); `rid` is assigned at submit and seeds the
    sampler's per-request PRNG key; `trace` records submit/admit/token
    timestamps for the latency report.

    Family extras: `frames` is the raw encoder input an encdec request
    decodes against ((n_frames, d_model) float32, required for encdec
    engines); `embedding` is filled by bert engines on completion with
    the fp32 tanh-pooled [CLS] vector (task="score" additionally fills
    `generated` with the per-position masked-LM argmax ids)."""
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int = 16
    generated: Optional[np.ndarray] = None
    rid: Optional[int] = None
    trace: RequestTrace = dataclasses.field(default_factory=RequestTrace)
    frames: Optional[np.ndarray] = None      # encdec encoder input
    embedding: Optional[np.ndarray] = None   # bert pooled [CLS] output


def apply_serving_policy(arch, params, policy=None):
    """Inference-side precision + MoE policy for an (arch, params) pair.

    * policy (optional name/Policy): cast the parameter copy per the policy
      (keep_fp32 overrides intact) and run compute in its compute_dtype.
    * MoE archs: serve dropless — capacity_factor = n_experts makes
      cap = tokens * top_k, so no token is ever dropped and routing is
      independent of batch composition (continuous == static, padded ==
      unpadded). Serving never trains, so the load-balance aux is unused.
    """
    cfg = arch.cfg
    if policy is not None:
        from repro.precision import get_policy
        policy = get_policy(policy)
        cfg = policy.apply_to_cfg(cfg)
        params = policy.cast_params(params)
    if getattr(cfg, "n_experts", 0):
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    if cfg is not arch.cfg:
        arch = dataclasses.replace(arch, cfg=cfg)
    return arch, params


def prompt_granularity(cfg) -> int:
    """Smallest prefill length multiple the arch supports: mamba's chunked
    SSD scan needs S % chunk == 0; attention/MoE take any length."""
    if any(m == "mamba" for m, _ in getattr(cfg, "superblock", ())):
        return int(cfg.mamba_chunk)
    return 1


def build_prefill_fn(arch, max_len: int):
    """Jitted masked prefill shared by both engines: (params, tokens,
    positions) -> (fp32 last-position logits (B, 1, V), pooled cache of
    max_len rows). The caller turns logits into the first token (greedy
    argmax or sampled — see build_first_token_fn). Retraces per padded
    prompt shape — bucket lengths to bound that."""
    def prefill(params, tokens, positions):
        logits, cache = arch.prefill(
            params, {"tokens": tokens}, cache_len=max_len,
            per_slot=True, positions=positions)
        return logits.astype(jnp.float32), cache
    return jax.jit(prefill)


def build_encdec_prefill_fn(arch, max_len: int):
    """Encoder-decoder prefill: one jitted pass runs the ENCODER over
    the raw frames and the masked decoder prefill over the prompt.
    Returns (fp32 last-position logits, cache) where the cache carries
    the per-slot self-attention rows plus dense per-layer cross K/V
    under "cross" — the projections the pool registers as shared,
    read-only arena blocks. Retraces per padded prompt shape, exactly
    like build_prefill_fn."""
    def prefill(params, tokens, positions, frames):
        logits, cache = arch.prefill(
            params, {"tokens": tokens, "frames": frames},
            cache_len=max_len, per_slot=True, positions=positions)
        return logits.astype(jnp.float32), cache
    return jax.jit(prefill)


def build_first_token_fn(sampler: Optional[Sampler]):
    """(jitted first-token fn, wants_keys). Greedy unless a non-greedy
    sampler is given; the sampled variant takes (logits, keys (B, 2)).
    A greedy sampler with stable_tiebreak routes through the sampler's
    one-ulp-band argmax (see serving/sampler.stable_argmax)."""
    if sampler is None or sampler.greedy:
        if sampler is not None and sampler.greedy and sampler.stable_tiebreak:
            return jax.jit(
                lambda logits: sampler.sample(logits[:, -1, :], None)), False
        return jax.jit(greedy_next), False
    return jax.jit(
        lambda logits, keys: sampler.sample(logits[:, -1, :], keys)), True


def first_tokens(first_fn, sampler: Optional[Sampler], wants_keys: bool,
                 logits, requests, token_idx=None):
    """Prefill logits -> first token per request, sampling with each
    request's token-`token_idx` key when a sampler is active (None: 0,
    the fresh-admission case; a preempted request's CONTINUATION prefill
    passes len(generated so far) so the sampled stream resumes exactly
    where eviction cut it).

    Single definition used by BOTH engines: the key derivation
    (fold_in(request key, token index)) must stay bit-identical across
    them for the differential token-equality guarantee to hold. Returns
    (first tokens (B,) np.int32, request base keys (B, 2) np or None).
    """
    if not wants_keys:
        return np.asarray(first_fn(logits)), None
    rkeys = np.stack([np.asarray(sampler.request_key(r.rid))
                      for r in requests])
    tvec = (np.zeros(len(requests), np.int32) if token_idx is None
            else np.asarray(token_idx, np.int32))
    toks = first_fn(logits, fold_keys(jnp.asarray(rkeys),
                                      jnp.asarray(tvec)))
    return np.asarray(toks), rkeys


def synthetic_requests(n: int, vocab: int, *, prompt_len: int,
                       new_tokens: int, seed: int = 0,
                       min_new_frac: float = 0.5, shared_prefix: int = 0):
    """Load-generator workload: mixed prompt lengths in
    [prompt_len/2, prompt_len] and budgets in [new_tokens*min_new_frac,
    new_tokens]. shared_prefix > 0 prepends that many COMMON tokens to
    every prompt (the "same system prompt, different user turns" traffic
    the paged pool deduplicates). Pure function of the arguments, so two
    engines handed the same seed see byte-identical requests."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(5, vocab, size=shared_prefix).astype(np.int32)
    reqs = []
    for _ in range(n):
        plen = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        new = int(rng.integers(max(1, int(new_tokens * min_new_frac)),
                               new_tokens + 1))
        tail = rng.integers(5, vocab, size=plen).astype(np.int32)
        reqs.append(Request(prompt=np.concatenate([prefix, tail]),
                            max_new_tokens=new))
    return reqs


def synthetic_scoring_requests(n: int, vocab: int, *, prompt_len: int,
                               seed: int = 0):
    """Scoring/embedding workload: mixed prompt lengths in
    [prompt_len/2, prompt_len]. Scoring requests carry no generation
    budget (they complete at admission); max_new_tokens=1 is inert.
    Pure function of the arguments, like synthetic_requests."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        plen = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        reqs.append(Request(
            prompt=rng.integers(5, vocab, size=plen).astype(np.int32),
            max_new_tokens=1))
    return reqs


def synthetic_encdec_requests(n: int, vocab: int, *, n_frames: int,
                              d_model: int, prompt_len: int,
                              new_tokens: int,
                              n_inputs: Optional[int] = None,
                              seed: int = 0):
    """Encoder-decoder workload: each request carries an encoder input
    (`frames`) plus a decoder prompt and budget. n_inputs < n reuses
    the inputs round-robin — the "N beams / retries of one utterance"
    traffic whose encoder blocks the cross arena stores once and shares
    (refcounted), exactly like shared prompt prefixes. Pure function of
    the arguments."""
    rng = np.random.default_rng(seed)
    n_inputs = n if n_inputs is None else n_inputs
    frames = [rng.standard_normal((n_frames, d_model)).astype(np.float32)
              for _ in range(n_inputs)]
    reqs = []
    for i in range(n):
        plen = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        new = int(rng.integers(max(1, new_tokens // 2), new_tokens + 1))
        reqs.append(Request(
            prompt=rng.integers(5, vocab, size=plen).astype(np.int32),
            max_new_tokens=new, frames=frames[i % n_inputs]))
    return reqs


def make_spec_pair(arch, params):
    """Benchmark/test fixture for speculative decoding with acceptance
    rate 1.0 BY CONSTRUCTION: returns (target_params, draft_arch,
    draft_params) where

      * target_params are `params` with every period ABOVE the first
        made inert — the attention out-projection (wo) and MLP
        down-projection zeroed, so both residual branches contribute
        exactly 0 and x + 0 == x in every dtype (the upper periods
        become identity blocks without changing shapes or compile
        signatures);
      * draft_arch is the same config truncated to ONE period, and
        draft_params share the embedding / final norm / head with the
        target plus the bottom period's weights verbatim.

    The doctored target therefore computes exactly the draft's function,
    the draft proposes exactly what verify picks, and every speculative
    round emits the full spec_k block — the workload that isolates the
    mechanical cost/benefit of draft-verify from draft quality. Only
    attention(+local)/MLP superblocks are supported (the spec engine
    rejects mamba anyway, and MoE down-projections live elsewhere)."""
    cfg = arch.cfg
    if cfg.n_periods < 2:
        raise ValueError(f"need >= 2 periods to truncate, got "
                         f"{cfg.n_periods}")
    for mixer, ffn in cfg.superblock:
        if mixer not in ("attn", "attn_local") or ffn != "mlp":
            raise ValueError(f"make_spec_pair supports attn/mlp "
                             f"superblocks only, got ({mixer}, {ffn})")

    def inert_upper(sub):      # zero periods 1.. of an output projection
        return jax.tree_util.tree_map(lambda a: a.at[1:].set(0), sub)

    target_params = dict(params)
    draft_params = {"embed": params["embed"],
                    "final_norm": params["final_norm"]}
    if "lm_head" in params:
        draft_params["lm_head"] = params["lm_head"]
    for si in range(len(cfg.superblock)):
        slot = dict(params[f"slot{si}"])
        slot["mixer"] = {**slot["mixer"],
                         "wo": inert_upper(slot["mixer"]["wo"])}
        slot["ffn"] = {**slot["ffn"],
                       "down": inert_upper(slot["ffn"]["down"])}
        target_params[f"slot{si}"] = slot
        draft_params[f"slot{si}"] = jax.tree_util.tree_map(
            lambda a: a[:1], params[f"slot{si}"])
    draft_arch = dataclasses.replace(
        arch, cfg=dataclasses.replace(cfg, n_layers=len(cfg.superblock)))
    return target_params, draft_arch, draft_params


def pad_prompts(prompts: List[np.ndarray], granularity: int = 1,
                pad_len: Optional[int] = None):
    """Left-pad to a common length; returns (tokens, positions, lengths).

    Positions are per-request LOCAL timelines (0..len-1 for real tokens,
    negative for padding) — the contract the masked prefill relies on.
    """
    lens = np.array([len(p) for p in prompts], np.int32)
    plen = pad_len if pad_len is not None else int(lens.max())
    plen = -(-plen // granularity) * granularity
    if plen < int(lens.max()):
        raise ValueError(f"pad_len {plen} < longest prompt {lens.max()}")
    B = len(prompts)
    tokens = np.zeros((B, plen), np.int32)
    positions = np.empty((B, plen), np.int32)
    for i, p in enumerate(prompts):
        tokens[i, plen - len(p):] = p
        positions[i] = np.arange(plen) - (plen - len(p))
    return tokens, positions, lens


def _slice_request(cache, g: int):
    """Batch row g of a batched-prefill pooled cache as a batch-1 cache."""
    return {"slots": jax.tree.map(lambda a: a[:, g:g + 1], cache["slots"]),
            "index": cache["index"][g:g + 1]}


class ContinuousEngine:
    """Continuous-batching decode over a fixed slot pool (paged by
    default; `cache="dense"` for the PR 2 per-slot-rows baseline)."""

    def __init__(self, arch, params, *, max_batch: int = 8,
                 max_len: int = 256, policy=None, mesh=None,
                 prefill_bucket: int = 1, on_step=None,
                 cache: str = "paged", block_size: int = 16,
                 slots_budget: Optional[int] = None,
                 share_prefix: bool = True, sampler=None,
                 attn_kernel: Optional[str] = None,
                 kernel_interpret: Optional[bool] = None,
                 growth: str = "lazy", sched_policy="fifo",
                 slo_ms: Optional[float] = None, preempt: bool = True,
                 retain_blocks: Optional[int] = None, watermark: int = 0,
                 chunk_budget: Optional[int] = None,
                 spec_draft=None, spec_k: int = 4,
                 task: str = "generate"):
        """See the class/module docstring for the serving model. Key args:

        max_batch: decode slot-pool size (the fixed step batch).
        max_len: per-request KV budget (prompt + generation rows).
        policy: precision policy name or repro.precision.Policy.
        cache: "paged" (block arenas + shared prefixes, the default) or
            "dense" (PR 2 per-slot-rows pool, the differential baseline).
        block_size / slots_budget / share_prefix: paged-pool sizing, see
            serving.cache_pool.PagedCachePool. Under lazy growth
            slots_budget is a high-watermark on blocks in use, not a
            per-request reservation.
        sampler: spec string or serving.sampler.Sampler (None = greedy).
        attn_kernel: paged decode attention implementation — "xla"
            gathers arena[table] into a dense (B, ring_len) K/V copy per
            step; "paged" streams blocks inside the fused Pallas kernel
            (kernels/paged_attention_kernel.py). Token-identical output;
            requires cache="paged". None adopts arch.cfg.attn_kernel
            (same convention as PagedCachePool).
        kernel_interpret: Pallas interpret-mode override for
            attn_kernel="paged" (serve.py --interpret): True forces
            interpret mode — the escape hatch for arena layouts that
            fail real-TPU tile alignment. None = auto (interpret
            off-TPU, compiled on TPU). Requires attn_kernel="paged".
        growth: "lazy" (default) allocates decode blocks on demand and
            preempts on exhaustion; "eager" reserves whole chains at
            admission (the PR 3 contract — decode can never fail). Only
            meaningful for the paged pool.
        sched_policy: scheduling policy name (fifo | arrival-deadline |
            prefix-affinity) or a serving.scheduler.SchedulingPolicy.
        slo_ms: per-request SLO; an active slot running longer than this
            since admission is finished early with the tokens it has
            (trace.evicted_slo). None disables SLO eviction.
        preempt: allow mid-decode preemption under lazy growth. With
            preemption disabled, growth exhaustion raises instead —
            differential tests use this to pin lazy == eager output.
        retain_blocks: LRU bound (blocks per attention slot-type) for
            warm prefix blocks kept alive after their last holder
            evicts. None sizes it to one BATCH's worth of full-
            attention blocks (max_batch * max_len / block_size) —
            enough to cover a multi-tenant working set of hot system
            prompts, which one request's worth LRU-thrashes to a zero
            hit rate; 0 disables.
        watermark: free blocks admission holds back per slot-type so
            in-flight slots can usually grow without preempting.
        chunk_budget: per-step TOKEN budget for chunked-prefill
            admission (serving/admission.py). When set, every admission
            prefills chunk by chunk fused into the decode loop's spare
            capacity (chunk tokens + active decodes <= chunk_budget, at
            most one resumable chunk per step) instead of one whole-
            prompt prefill between decode steps — token-identical
            output, bounded ITL. Requires cache="paged" (the dense
            pool's insert needs clamped-window cache shapes). None
            keeps whole-prompt admission. chunk_budget >= max_batch - 1
            + chunk granularity guarantees the prefill task progresses
            every step even with a full decode batch.
        spec_draft: (draft_arch, draft_params) enabling speculative
            draft-verify decode (see the module docstring). The draft is
            cast with the same precision policy as the target. Requires
            cache="paged", attention-only superblocks on both models,
            and a shared vocab; mutually exclusive with chunk_budget.
        spec_k: tokens proposed/verified per round (>= 2). Sliding-
            window rings gain a spec_k - 1 row margin; everything else
            is exactly the non-speculative layout.
        task: workload family. "generate" (default) is autoregressive
            decode — decoder archs, and encdec archs whose encoder
            output lands in the shared cross-attention block arena
            (serving.cache_pool.EncDecCachePool). "score" / "embed"
            need a bert arch: batched masked-LM scoring / [CLS]
            embedding through ONE fixed-shape forward — no KV cache,
            requests complete at admission and their slots free
            immediately.
        """
        if task not in ("generate", "score", "embed"):
            raise ValueError(
                f"task must be 'generate', 'score' or 'embed', got {task!r}")
        if arch.kind == "bert":
            if task == "generate":
                raise ValueError(
                    "bert archs serve scoring/embedding, not generation: "
                    "pass task='score' or task='embed'")
        elif arch.kind in ("decoder", "encdec"):
            if task != "generate":
                raise ValueError(
                    f"task={task!r} needs a bert arch, got {arch.kind!r}")
        else:
            raise ValueError(f"cannot serve arch kind {arch.kind!r}")
        self.task = task
        self.encdec = arch.kind == "encdec"
        self.bert = arch.kind == "bert"
        if arch.kind != "decoder":
            if chunk_budget is not None:
                raise ValueError(
                    f"chunk_budget is decoder-only, got arch kind "
                    f"{arch.kind!r}")
            if spec_draft is not None:
                raise ValueError(
                    f"spec_draft is decoder-only, got arch kind "
                    f"{arch.kind!r}")
            if attn_kernel == "paged":
                raise ValueError(
                    "attn_kernel='paged' is decoder-only: the encdec "
                    "cross arena reads through the dense XLA gather")
        if self.encdec and cache != "paged":
            raise ValueError(
                "encdec serving requires cache='paged': the encoder "
                "output lives in the shared cross-attention block arena")
        if cache not in ("paged", "dense"):
            raise ValueError(f"cache must be 'paged' or 'dense', got {cache}")
        if growth not in ("lazy", "eager"):
            raise ValueError(f"growth must be 'lazy' or 'eager', got {growth}")
        if attn_kernel is None:
            attn_kernel = getattr(arch.cfg, "attn_kernel", "xla")
        if attn_kernel not in ("xla", "paged"):
            raise ValueError(
                f"attn_kernel must be 'xla' or 'paged', got {attn_kernel}")
        if attn_kernel == "paged" and cache != "paged":
            raise ValueError("attn_kernel='paged' requires cache='paged' "
                             "(the dense pool has no block tables)")
        if kernel_interpret is not None and attn_kernel != "paged":
            raise ValueError(
                "kernel_interpret only applies to attn_kernel='paged' "
                "(the XLA gather path has no Pallas kernel to interpret)")
        self.spec = spec_draft is not None
        if self.spec:
            if spec_k < 2:
                raise ValueError(f"spec_k must be >= 2, got {spec_k}")
            if cache != "paged":
                raise ValueError(
                    "speculative decoding requires cache='paged' "
                    "(rollback and the row margin are paged-pool features)")
            if chunk_budget is not None:
                raise ValueError("speculative decoding and chunked "
                                 "prefill are mutually exclusive")
            draft_arch, draft_params = spec_draft
            for who, a in (("target", arch), ("draft", draft_arch)):
                if any(m == "mamba" for m, _ in a.cfg.superblock):
                    raise ValueError(
                        f"speculative decoding needs an attention-only "
                        f"{who}: SSM state cannot be stepped S=K "
                        f"(target) or rewound on rejection (draft)")
            if draft_arch.cfg.vocab != arch.cfg.vocab:
                raise ValueError(
                    f"draft vocab {draft_arch.cfg.vocab} != target "
                    f"vocab {arch.cfg.vocab}")
        self.spec_k = spec_k if self.spec else 1
        self.arch, self.params = apply_serving_policy(arch, params, policy)
        if (arch.kind == "decoder"
                and (attn_kernel != self.arch.cfg.attn_kernel
                     or kernel_interpret != self.arch.cfg.kernel_interpret)):
            self.arch = dataclasses.replace(
                self.arch, cfg=dataclasses.replace(
                    self.arch.cfg, attn_kernel=attn_kernel,
                    kernel_interpret=kernel_interpret))
        # Live mesh: params shard per the distributed param rules, the
        # pool (and every jitted step below) per cache_pspec. Prefill and
        # chunk forwards need no explicit specs — sharded params
        # propagate SPMD partitioning through their plain jits.
        self.mesh = _live_mesh(mesh)
        if self.mesh is not None:
            self.params = jax.device_put(
                self.params, shd.params_sharding(self.params, self.mesh))
        self.max_batch = max_batch
        self.max_len = max_len
        self.paged = cache == "paged" and arch.kind == "decoder"
        self.sampler = Sampler.parse(sampler)
        # prefill lengths round up to bucket multiples: fewer distinct
        # prompt shapes -> fewer prefill compilations (the masked left-pad
        # keeps bucketed prefill token-exact) — and one admission pass
        # prefills every same-bucket request in a single batched call.
        self.prefill_bucket = max(prefill_bucket,
                                  prompt_granularity(self.arch.cfg))
        self.chunk_budget = chunk_budget
        if chunk_budget is not None:
            if not self.paged:
                raise ValueError(
                    "chunk_budget requires cache='paged': the chunked "
                    "prefill cache is unclamped (full-length sliding-"
                    "window rows) and only the paged insert can take "
                    "its window tail")
            # padded prompt lengths must divide into chunk-granularity
            # multiples or the final chunk could be unreachable
            g = chunk_granularity(self.arch.cfg)
            self.prefill_bucket = -(-self.prefill_bucket // g) * g
        if self.bert:
            # scoring/embedding: no KV growth — a slot's only state is
            # its output, freed at completion. There is no cache pool;
            # ONE fixed (max_batch, max_len) forward is the whole step.
            if max_len > self.arch.cfg.max_pos:
                raise ValueError(
                    f"max_len {max_len} exceeds the bert position table "
                    f"({self.arch.cfg.max_pos})")
            self.pool = None
            self.score_len = max_len
            prefill_len = max_len
        elif self.encdec:
            from repro.serving.cache_pool import EncDecCachePool
            if retain_blocks is None:
                # same sizing rationale as the decoder pool below; the
                # pool caps the bound at its cross-arena size anyway
                retain_blocks = max(1, max_batch * (max_len // block_size))
            self.pool = EncDecCachePool(
                self.arch, max_batch, max_len, block_size=block_size,
                slots_budget=slots_budget, share_prefix=share_prefix,
                retain_blocks=retain_blocks, mesh=self.mesh)
            prefill_len = max_len
        elif self.paged:
            if retain_blocks is None:
                # one BATCH's worth, not one request's: the bound must
                # cover the sum of distinct hot prefixes or cyclic
                # multi-tenant waves thrash the LRU to a ZERO hit rate
                # (measured via retained_hit_rate: one request's worth
                # scored 0.0 where one batch's worth scored 0.6 on a
                # 3-tenant wave workload). Oversizing is cheap —
                # retained blocks are reclaimed before any allocation
                # fails, so the bound delays block reuse but never
                # costs capacity.
                retain_blocks = max(1, max_batch * (max_len // block_size))
            self.pool = PagedCachePool(
                self.arch, max_batch, max_len, block_size=block_size,
                slots_budget=slots_budget, share_prefix=share_prefix,
                attn_kernel=attn_kernel, growth=growth,
                retain_blocks=retain_blocks, watermark=watermark,
                row_margin=self.spec_k - 1, mesh=self.mesh)
            # slack rows so the padded prompt never reaches the request
            # cache's last row, which stays pos=-1 (the insert's invalid
            # filler — see PagedCachePool._src_rows)
            prefill_len = max_len + max(block_size, self.prefill_bucket)
        else:
            self.pool = CachePool(self.arch, max_batch, max_len,
                                  mesh=self.mesh)
            prefill_len = max_len
        self._prefill_len = prefill_len
        self.scheduler = Scheduler(max_batch)
        slo_s = slo_ms / 1e3 if slo_ms is not None else None
        self.sched_policy = SchedulingPolicy.parse(sched_policy, slo_s=slo_s)
        self.preempt_enabled = preempt
        self.on_step = on_step          # callback(dict) per decode step
        params_like = cache_like = None
        if self.mesh is not None and not self.bert:
            step_cache = ({**self.pool.cache,
                           "tables": self.pool.device_tables()}
                          if self.paged else self.pool.cache)
            params_like = jax.eval_shape(lambda: self.params)
            cache_like = jax.eval_shape(lambda: step_cache)
        if self.bert:
            # the scoring family's ONE step: a fixed-shape jit at
            # (max_batch, score_len) — short batches replicate their
            # last row (the pow2-group padding idiom collapsed to a
            # single bucket), so _cache_size() stays 1 for the engine's
            # whole lifetime. Sharded params propagate SPMD partitioning
            # through the plain jit like the prefill/chunk forwards.
            self._score = jax.jit(self.arch.score)
            self._step = None
            self._prefill = None
        else:
            self._step = build_serve_step(self.arch.decode_step, self.mesh,
                                          sampler=self.sampler,
                                          params_like=params_like,
                                          cache_like=cache_like)
            self._prefill = (build_encdec_prefill_fn(self.arch, prefill_len)
                             if self.encdec
                             else build_prefill_fn(self.arch, prefill_len))
        self._first, self._wants_keys = build_first_token_fn(self.sampler)
        self._lat_step = None    # batch-1 latency-mode jits, built lazily
        self._lat_score = None   # (run_one) and compiled exactly once
        self._admission = None
        if chunk_budget is not None:
            self._admission = AdmissionController(
                self.arch, self.params, chunk_budget=chunk_budget,
                prefill_len=prefill_len, mesh=self.mesh)
        if self.spec:
            self.draft_arch, self.draft_params = apply_serving_policy(
                draft_arch, draft_params, policy)
            if self.mesh is not None:
                self.draft_params = jax.device_put(
                    self.draft_params,
                    shd.params_sharding(self.draft_params, self.mesh))
            self.draft_pool = CachePool(self.draft_arch, max_batch, max_len,
                                        mesh=self.mesh)
            self._draft_prefill = build_prefill_fn(self.draft_arch, max_len)
            draft_likes = {}
            if self.mesh is not None:
                draft_likes = dict(
                    params_like=jax.eval_shape(lambda: self.draft_params),
                    cache_like=jax.eval_shape(
                        lambda: self.draft_pool.cache))
            self._draft_step = build_serve_step(
                self.draft_arch.decode_step, self.mesh,
                sampler=self.sampler, **draft_likes)
            self._verify = build_verify_step(self.arch.decode_step,
                                             self.mesh,
                                             sampler=self.sampler,
                                             params_like=params_like,
                                             cache_like=cache_like)
            # host mirror of the draft pool's write cursors (PADDED
            # storage rows, unlike _positions' local timeline: the dense
            # pool counts left-pad rows)
            self._draft_rows = np.zeros(max_batch, np.int64)
            self.spec_rounds = 0
            self.drafted_tokens = 0     # proposals verified (fl per slot)
            self.accepted_tokens = 0    # proposals the target agreed with

        self._tokens = np.zeros((max_batch, 1), np.int32)
        self._positions = np.full((max_batch, 1), -1, np.int32)
        self._req_keys = np.zeros((max_batch, 2), np.uint32)
        self._emitted: Dict[int, list] = {}     # slot -> generated ids
        self._resume: Dict[int, list] = {}      # rid -> preempted tokens
        self._admit_seq: Dict[int, int] = {}    # slot -> admission seq no.
        self._admit_time: Dict[int, float] = {}
        self._admit_counter = 0
        self._depth = DepthTracker()            # queue depth per step
        self._next_rid = 0
        self.steps_run = 0
        self.slot_steps = 0             # decode-step slots that were active
        self.max_concurrent = 0         # peak simultaneously-active slots
        self.preemptions = 0            # victims evicted for block space

    # ---------------- request lifecycle ----------------

    def submit(self, request: Request):
        """Queue a request (FIFO). Validates it can ever fit (prompt +
        budget <= max_len); admission happens at the next step()."""
        if self.bert:
            if not 1 <= len(request.prompt) <= self.score_len:
                raise ValueError(
                    f"scoring prompt length {len(request.prompt)} must "
                    f"be in [1, {self.score_len}]")
            if request.rid is None:
                request.rid = self._next_rid
                self._next_rid += 1
            request.trace.mark_submit()
            self.scheduler.submit(request)
            return
        if self.encdec:
            if request.frames is None:
                raise ValueError(
                    "encdec requests need `frames` (the encoder input)")
            nf = self.arch.cfg.n_frames
            if np.asarray(request.frames).shape[0] != nf:
                raise ValueError(
                    f"frames must carry {nf} rows, got "
                    f"{np.asarray(request.frames).shape[0]}")
        if len(request.prompt) + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt {len(request.prompt)} + max_new_tokens "
                f"{request.max_new_tokens} exceeds max_len {self.max_len}")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if request.rid is None:
            request.rid = self._next_rid
            self._next_rid += 1
        request.trace.mark_submit()
        self.scheduler.submit(request)

    def _finish(self, slot: int):
        req = self.scheduler.complete(slot)
        req.generated = np.array(self._emitted.pop(slot), np.int32)
        req.trace.done_t = time.perf_counter()
        self.pool.evict(slot)
        if self.spec:
            self.draft_pool.evict(slot)
            self._draft_rows[slot] = 0
        self._admit_seq.pop(slot, None)
        self._admit_time.pop(slot, None)
        # position -1 marks the slot inactive: its (ignored) decode writes
        # carry an invalid position, which in the paged pool is what keeps
        # the shared null block masked.
        self._positions[slot, 0] = -1
        self._tokens[slot, 0] = 0
        return req

    # -- continuation state (preempted requests) ----------------------

    def _resume_of(self, req: Request) -> list:
        return self._resume.get(req.rid, [])

    def _full_prompt(self, req: Request) -> np.ndarray:
        """The prompt a (re-)admission prefills: the original prompt
        plus any tokens generated before a preemption — the continuation
        prefill recomputes exactly the state the evicted slot held."""
        resume = self._resume_of(req)
        if not resume:
            return np.asarray(req.prompt, np.int32)
        return np.concatenate([np.asarray(req.prompt, np.int32),
                               np.asarray(resume, np.int32)])

    def _plen(self, req: Request) -> int:
        return len(req.prompt) + len(self._resume_of(req))

    def _padded_len(self, req: Request) -> int:
        plen = max(self._plen(req), 1)
        return -(-plen // self.prefill_bucket) * self.prefill_bucket

    def _decode_slots(self) -> list:
        """Active slots that DECODE this step — every scheduler-active
        slot except the one bound to an in-flight chunked-prefill task
        (it holds no pool blocks yet, its _positions row is -1, and it
        must be invisible to growth, preemption and SLO eviction until
        its insert finalizes). Without a controller this is exactly
        sorted(scheduler.active)."""
        skip = None
        if self._admission is not None and self._admission.task is not None:
            skip = self._admission.task.slot
        return sorted(s for s in self.scheduler.active if s != skip)

    def _policy_ctx(self, now: Optional[float] = None,
                    warm_cache: Optional[dict] = None) -> PolicyContext:
        """Immutable decision-point snapshot for the scheduling policy.

        warm_cache (rid -> bool) memoizes the sha256 warm-prefix probes
        across the iterations of ONE admission pass: a request's answer
        is stable within the pass (admissions only ADD warmth, and a
        stale False merely falls back to arrival order), so the probe
        cost is O(queue) per pass instead of O(queue x admissions)."""
        warm = None
        if self.paged and self.pool.maps:
            def warm(req):
                if warm_cache is not None and req.rid in warm_cache:
                    return warm_cache[req.rid]
                w = self.pool.prefix_warm(self._full_prompt(req),
                                          self._plen(req),
                                          self._padded_len(req))
                if warm_cache is not None:
                    warm_cache[req.rid] = w
                return w
        resume_cost = None
        if self._admission is not None:
            # chunked mode: a preemption's continuation prefill is
            # metered chunk work — hand the policy its exact size so
            # the base victim rule can minimize re-chunked tokens
            def resume_cost(slot):
                req = self.scheduler.active.get(slot)
                if req is None:
                    return 0
                return len(req.prompt) + len(self._emitted.get(slot, ()))
        return PolicyContext(
            now=time.perf_counter() if now is None else now,
            admit_seq=self._admit_seq, admit_t=self._admit_time,
            active=self.scheduler.active,
            submit_t=lambda r: r.trace.submit_t, prefix_warm=warm,
            resume_cost=resume_cost)

    def _fits(self, req: Request, pending: dict):
        """Admission gate for the paged pool: would this request's block
        plan fit next to the admissions already planned this pass? Lazy
        growth plans prompt blocks only; eager plans the whole chain.
        Retained warm blocks count as available (they are reclaimed
        under pressure) minus the growth watermark. The count assumes no
        sharing with the in-flight plans (conservative: their prefix
        blocks are not registered yet), so a True can never turn into an
        allocator failure."""
        if self.encdec:
            need = self.pool.admission_plan(
                np.asarray(req.frames, np.float32))
            avail = self.pool.admissible_blocks()
            ok = all(n + pending.get(si, 0) <= avail[si]
                     for si, n in need.items())
            return ok, need
        if not self.paged:
            return True, None
        budget = req.max_new_tokens - len(self._resume_of(req))
        need = self.pool.admission_plan(self._full_prompt(req),
                                        self._plen(req),
                                        self._padded_len(req), budget)
        avail = self.pool.admissible_blocks()
        ok = all(n + pending.get(si, 0) <= avail[si]
                 for si, n in need.items())
        return ok, need

    def _admit(self):
        """Fill free slots from the queue in POLICY order: ONE batched
        prefill per padded-length bucket covers every admitted request
        (group sizes padded to powers of two so prefill compile count is
        O(log max_batch) per bucket), then each cache row is inserted
        into its slot. Runs between decode steps (and loops when 1-token
        requests complete at admission, freeing slots)."""
        while True:
            pairs, pending, warm_cache = [], {}, {}
            while self.scheduler.free_slots and self.scheduler.queued:
                i = self.sched_policy.pick(
                    self.scheduler.queue_items(),
                    self._policy_ctx(warm_cache=warm_cache))
                req = self.scheduler.peek(i)
                ok, need = self._fits(req, pending)
                if not ok:
                    break   # policy head-of-line: wait for evictions
                for si, n in (need or {}).items():
                    pending[si] = pending.get(si, 0) + n
                pairs.append(self.scheduler.assign_at(i))
            if not pairs:
                return
            groups: Dict[int, list] = {}
            for slot, req in pairs:
                groups.setdefault(self._padded_len(req), []).append(
                    (slot, req))
            failed = []
            for padded, grp in groups.items():
                prompts = [self._full_prompt(r) for _, r in grp]
                # pad the admission group to a power-of-two size by
                # replicating the last request (valid compute, outputs
                # discarded): prefill shapes per bucket become (2^k,
                # padded) for k <= ceil(log2 max_batch) — a bounded
                # compile set instead of one compile per group size
                n = len(grp)
                n_pad = 1 << (n - 1).bit_length()
                pad_reqs = [r for _, r in grp] + [grp[-1][1]] * (n_pad - n)
                tokens, positions, lens = pad_prompts(
                    prompts + [prompts[-1]] * (n_pad - n),
                    self.prefill_bucket, pad_len=padded)
                if self.encdec:
                    frames = np.stack([np.asarray(r.frames, np.float32)
                                       for r in pad_reqs])
                    logits, batch_cache = self._prefill(
                        self.params, jnp.asarray(tokens),
                        jnp.asarray(positions), jnp.asarray(frames))
                else:
                    logits, batch_cache = self._prefill(
                        self.params, jnp.asarray(tokens),
                        jnp.asarray(positions))
                draft_cache = None
                if self.spec:
                    # the draft prefills the SAME padded group: its slot
                    # state must encode exactly the prompt (+ resume)
                    # context the target slot holds, or round-1 proposals
                    # would be conditioned on a different prefix
                    _, draft_cache = self._draft_prefill(
                        self.draft_params, jnp.asarray(tokens),
                        jnp.asarray(positions))
                first, rkeys = first_tokens(
                    self._first, self.sampler, self._wants_keys, logits,
                    pad_reqs,
                    token_idx=[len(self._resume_of(r)) for r in pad_reqs])
                now = time.perf_counter()
                for g, (slot, req) in enumerate(grp):
                    req_cache = _slice_request(batch_cache, g)
                    resume = self._resume_of(req)
                    try:
                        if self.encdec:
                            # register the encoder output: the request's
                            # dense cross projections (batch row g of
                            # the prefill cache) land in — or share —
                            # refcounted arena blocks keyed by the raw
                            # input frames
                            self.pool.insert(
                                req_cache, slot,
                                frames=np.asarray(req.frames, np.float32),
                                cross_k=batch_cache["cross"]["k"][:, g],
                                cross_v=batch_cache["cross"]["v"][:, g])
                        elif self.paged:
                            self.pool.insert(
                                req_cache, slot, prompt=prompts[g],
                                plen=len(prompts[g]), padded_len=padded,
                                budget=req.max_new_tokens - len(resume))
                        else:
                            self.pool.insert(req_cache, slot)
                    except NoBlocksError:
                        # gate miscount cannot happen by construction, but
                        # stay safe: put the request back, arrival order
                        # intact (the continuation state stays parked)
                        failed.append(slot)
                        continue
                    if self.spec:
                        self.draft_pool.insert(
                            _slice_request(draft_cache, g), slot)
                        # dense-pool cursor == PADDED rows written
                        self._draft_rows[slot] = padded
                    self._resume.pop(req.rid, None)
                    t0 = int(first[g])
                    if req.trace.admit_t is None:   # keep the FIRST
                        req.trace.admit_t = now     # admission for TTFT
                    req.trace.mark_token(now)
                    self._emitted[slot] = list(resume) + [t0]
                    self._tokens[slot, 0] = t0
                    self._positions[slot, 0] = int(lens[g])
                    self._admit_counter += 1
                    self._admit_seq[slot] = self._admit_counter
                    self._admit_time[slot] = now
                    if rkeys is not None:
                        self._req_keys[slot] = rkeys[g]
                    if len(self._emitted[slot]) >= req.max_new_tokens:
                        self._finish(slot)   # budget reached: done now
            for slot in reversed(failed):
                self.scheduler.requeue(slot)
            if failed:
                return

    # -- chunked admission (serving/admission.py) ---------------------

    def _fits_chunked(self, req: Request) -> bool:
        """Admission gate for a chunked prefill. Unlike _fits, the
        blocks are consumed only at FINALIZE — many steps after this
        decision, during which every decoding slot keeps growing — so
        on top of the pool's static watermark the gate holds back a
        DYNAMIC one: one block per decoding slot (the PR 5 watermark
        follow-up, folded in as a controller input). Plans with
        share=False: chunked blocks are never content-addressed (the
        chunk schedule changes reduction shapes, so sharing would not
        be bit-sound in bf16). A stale True still cannot corrupt
        anything: finalize's NoBlocksError requeues the request and
        the continuation prefill re-chunks identically."""
        budget = req.max_new_tokens - len(self._resume_of(req))
        need = self.pool.admission_plan(self._full_prompt(req),
                                        self._plen(req),
                                        self._padded_len(req), budget,
                                        share=False)
        hold = len(self._decode_slots())
        avail = self.pool.admissible_blocks()
        return all(n + hold <= avail[si] for si, n in need.items())

    def _admit_chunked(self):
        """Chunk-at-a-time admission: start at most one prefill TASK
        (policy-picked, block-gated), advance it by one budget-sized
        chunk, and on the final chunk insert its cache + emit the first
        token — the same bookkeeping as _admit, one request at a time.
        The task's slot joins the decode batch the step it finalizes."""
        ctrl = self._admission
        if ctrl.task is None and self.scheduler.free_slots \
                and self.scheduler.queued:
            i = self.sched_policy.pick(self.scheduler.queue_items(),
                                       self._policy_ctx(warm_cache={}))
            req = self.scheduler.peek(i)
            if self._fits_chunked(req):
                slot, req = self.scheduler.assign_at(i)
                prompt = self._full_prompt(req)
                padded = self._padded_len(req)
                tokens, positions, _ = pad_prompts(
                    [prompt], self.prefill_bucket, pad_len=padded)
                ctrl.start(req, slot, tokens, positions,
                           plen=len(prompt), padded_len=padded,
                           resume_len=len(self._resume_of(req)),
                           prompt=prompt)
        task = ctrl.task
        if task is None:
            return
        ctrl.advance(len(self._decode_slots()))
        if not task.finished:
            return
        req, slot = task.req, task.slot
        resume = self._resume_of(req)
        try:
            self.pool.insert(task.cache, slot, prompt=task.prompt,
                             plen=task.plen, padded_len=task.padded_len,
                             budget=req.max_new_tokens - len(resume),
                             share=False)
        except NoBlocksError:
            # decoding slots grew past the gate's dynamic watermark:
            # requeue at the arrival ticket, keep the continuation
            # state parked — re-admission re-chunks exactly
            self.scheduler.requeue(slot)
            ctrl.drop()
            return
        first, rkeys = first_tokens(self._first, self.sampler,
                                    self._wants_keys, task.last_logits,
                                    [req], token_idx=[task.resume_len])
        now = time.perf_counter()
        self._resume.pop(req.rid, None)
        t0 = int(first[0])
        if req.trace.admit_t is None:   # keep the FIRST admission
            req.trace.admit_t = now     # for TTFT
        req.trace.mark_token(now)
        self._emitted[slot] = list(resume) + [t0]
        self._tokens[slot, 0] = t0
        self._positions[slot, 0] = task.plen
        self._admit_counter += 1
        self._admit_seq[slot] = self._admit_counter
        self._admit_time[slot] = now
        if rkeys is not None:
            self._req_keys[slot] = rkeys[0]
        ctrl.drop()
        if len(self._emitted[slot]) >= req.max_new_tokens:
            self._finish(slot)          # budget reached: done now

    def _preempt(self, slot: int):
        """Evict a mid-decode victim: blocks freed, generated-so-far
        tokens parked as continuation state, request requeued at its
        arrival position. The next admission prefills prompt + generated
        and keeps counting tokens where this slot stopped."""
        req = self.scheduler.active[slot]
        self._resume[req.rid] = self._emitted.pop(slot)
        req.trace.preemptions += 1
        self.preemptions += 1
        self.pool.evict(slot)
        if self.spec:
            self.draft_pool.evict(slot)
            self._draft_rows[slot] = 0
        self.scheduler.preempt(slot)
        self._admit_seq.pop(slot, None)
        self._admit_time.pop(slot, None)
        self._positions[slot, 0] = -1
        self._tokens[slot, 0] = 0

    def _grow_active(self):
        """Back every active slot's next decode write with a block (lazy
        growth), preempting policy-chosen victims when the arena (free
        list + reclaimable retained blocks) exhausts. Oldest admissions
        grow first and the default victim is the youngest, so the oldest
        request always makes progress — no livelock."""
        for slot in sorted(self._decode_slots(),
                           key=lambda s: self._admit_seq.get(s, 0)):
            if slot not in self.scheduler.active:
                continue            # preempted as a victim earlier in loop
            row = int(self._positions[slot, 0])
            n_rows = 1
            if self.spec:
                # back every REAL verify row (q..q+fl-1); the block-pad
                # rows beyond the remaining budget carry position -1 and
                # are scatter-routed to the null block, so they need no
                # backing (models/attention.py paged branch)
                req = self.scheduler.active[slot]
                n_rows = min(self.spec_k,
                             req.max_new_tokens - len(self._emitted[slot]))
            for r in range(row, row + n_rows):
                if slot not in self.scheduler.active:
                    break           # became the sacrifice below
                while True:
                    try:
                        self.pool.grow(slot, r)
                        break
                    except NoBlocksError:
                        if not self.preempt_enabled:
                            raise RuntimeError(
                                "paged arena exhausted mid-decode with "
                                "preemption disabled: raise slots_budget "
                                "/ watermark, or enable preempt")
                        candidates = self._decode_slots()
                        victim = self.sched_policy.victim(candidates,
                                                          self._policy_ctx())
                        if victim == slot and len(candidates) == 1:
                            raise RuntimeError(
                                "single active slot cannot grow: the "
                                "arena is smaller than one request's "
                                "chain (raise slots_budget)")
                        self._preempt(victim)
                        if victim == slot:
                            break   # this slot was the sacrifice

    def _evict_overdue(self):
        """SLO eviction of stuck slots: any active request older (since
        admission) than the policy's SLO is finished early with the
        tokens it has, freeing the slot for queued work."""
        if self.sched_policy.slo_s is None or not self.scheduler.active:
            return
        ctx = self._policy_ctx()
        for slot in self._decode_slots():
            if self.sched_policy.overdue(slot, ctx):
                self.scheduler.active[slot].trace.evicted_slo = True
                self._finish(slot)

    def step(self) -> bool:
        """One engine iteration: SLO evictions, admissions, lazy chain
        growth (with preemption), then one pooled decode step. Returns
        False when no work remains. (bert engines route to the scoring
        iteration: admit, one batched forward, complete.)"""
        if self.bert:
            return self._step_scoring()
        self._evict_overdue()
        if self._admission is not None:
            self._admit_chunked()
        else:
            self._admit()
        if self.paged and self.pool.growth == "lazy":
            self._grow_active()
            self.pool.flush_growth()
        active = self._decode_slots()
        self.max_concurrent = max(self.max_concurrent, len(active))
        self._depth.sample(self.scheduler.queued)
        if not active:
            prefilling = (self._admission is not None
                          and self._admission.task is not None)
            if self.scheduler.queued and not prefilling:
                req = self.scheduler.peek()
                raise RuntimeError(
                    f"request rid={req.rid} (prompt {len(req.prompt)}, "
                    f"budget {req.max_new_tokens}) cannot fit an empty "
                    f"paged arena: raise slots_budget or max_len")
            return self.scheduler.has_work
        if self.spec:
            self._spec_round(active)
        else:
            cache = self.pool.cache
            if self.paged:
                cache = {**cache, "tables": self.pool.device_tables()}
            args = (self.params, jnp.asarray(self._tokens),
                    jnp.asarray(self._positions), cache)
            if self._wants_keys:
                tvec = np.zeros(self.max_batch, np.int32)
                for slot in active:
                    tvec[slot] = len(self._emitted[slot])
                args += (fold_keys(jnp.asarray(self._req_keys),
                                   jnp.asarray(tvec)),)
            nxt, new_cache = self._step(*args)
            if self.encdec:
                # the cross arenas + block table are VALUES inside the
                # donated cache pytree: keep the whole output so they
                # alias through to the next step with zero uploads
                self.pool.cache = new_cache
            else:
                self.pool.cache = {"slots": new_cache["slots"],
                                   "index": new_cache["index"]}
            if self.paged:
                # reuse the pass-through table outputs next step: zero
                # table uploads while no admission/eviction churns the
                # block maps
                self.pool.put_device_tables(new_cache["tables"])
            nxt = np.asarray(nxt)        # host sync: tokens feed next step
            now = time.perf_counter()
            self.steps_run += 1
            self.slot_steps += len(active)
            for slot in active:
                req = self.scheduler.active[slot]
                self._emitted[slot].append(int(nxt[slot]))
                req.trace.mark_token(now)
                self._tokens[slot, 0] = int(nxt[slot])
                self._positions[slot, 0] += 1
                if len(self._emitted[slot]) >= req.max_new_tokens:
                    self._finish(slot)
        if self.on_step is not None:
            info = {"step": self.steps_run, "active": len(active),
                    "queued": self.scheduler.queued,
                    "preemptions": self.preemptions}
            if self.spec:
                info.update(spec_rounds=self.spec_rounds,
                            drafted_tokens=self.drafted_tokens,
                            accepted_tokens=self.accepted_tokens)
            self.on_step(info)
        return self.scheduler.has_work

    def _step_scoring(self) -> bool:
        """One scoring/embedding iteration: admit up to max_batch queued
        requests in POLICY order, run ONE fixed-shape batched forward,
        and complete every admitted request immediately. Scoring holds
        no KV — a slot's only state is its output, so the slots free at
        completion and the next step admits a fresh batch. The batch is
        padded to (max_batch, score_len) by replicating the last row
        (valid compute, outputs discarded), keeping the step at a single
        compiled shape."""
        sched = self.scheduler
        self._depth.sample(sched.queued)
        pairs = []
        while sched.free_slots and sched.queued:
            i = self.sched_policy.pick(sched.queue_items(),
                                       self._policy_ctx(warm_cache={}))
            pairs.append(sched.assign_at(i))
        if not pairs:
            return sched.has_work
        prompts = [np.asarray(r.prompt, np.int32) for _, r in pairs]
        n = len(pairs)
        tokens, positions, lens = pad_prompts(
            prompts + [prompts[-1]] * (self.max_batch - n), 1,
            pad_len=self.score_len)
        ids, pooled = self._score(self.params, jnp.asarray(tokens),
                                  jnp.asarray(positions))
        ids = np.asarray(ids)
        pooled = np.asarray(pooled)
        now = time.perf_counter()
        self.steps_run += 1
        self.slot_steps += n
        self.max_concurrent = max(self.max_concurrent, n)
        for g, (slot, req) in enumerate(pairs):
            plen = int(lens[g])
            req.trace.admit_t = now
            self._admit_counter += 1
            req.embedding = pooled[g].copy()
            if self.task == "score":
                # per-position masked-LM argmax over the VALID tail
                # (the left-pad columns are replica garbage)
                req.generated = ids[g, self.score_len - plen:].copy()
                for _ in range(plen):
                    req.trace.mark_token(now)
            else:
                req.generated = np.zeros(0, np.int32)
                req.trace.mark_token(now)
            sched.complete(slot)
            req.trace.done_t = now
        return sched.has_work

    def _spec_round(self, active):
        """One draft-verify round over the active decode slots.

        Per slot with remaining budget `rem` and cursor position p:
          1. K draft micro-steps (S=1, the draft's dense pool) propose
             d_1..d_K with per-token keys fold(rkey, emitted + i) — the
             SAME keys the target uses, so a draft whose logits match
             the target's proposes exactly what verify picks.
          2. One target verify step feeds [t0, d_1..d_{K-1}] at
             positions p..p+fl-1 (fl = min(K, rem); block-pad rows
             carry position -1 and scatter into the null block) and
             emits y_1..y_K, row i sampled exactly as the non-spec step
             samples token emitted+i.
          3. The leading agreement run a (d_i == y_i) emits y_1..y_n,
             n = min(a+1, fl): a accepted draft tokens plus the
             target's correction (or, at a == fl, the full block). Every
             emitted token saw an all-accepted context, so the stream
             is bit-identical to non-speculative decode.
          4. If any slot stopped short of K, BOTH pools roll back:
             cursors rewind to q + n and the stale rows' positions
             min-scatter to -1 (fixed capacity max_batch * K, compiled
             once). A full-acceptance round skips rollback entirely —
             the device cursors already sit at q + K.
        """
        K = self.spec_k
        B = self.max_batch
        tvec = np.zeros(B, np.int32)
        feed_len = np.zeros(B, np.int32)
        for slot in active:
            req = self.scheduler.active[slot]
            tvec[slot] = len(self._emitted[slot])
            feed_len[slot] = min(K, req.max_new_tokens
                                 - len(self._emitted[slot]))

        # ---- 1. draft micro-steps ----------------------------------
        fed = np.zeros((B, K), np.int32)       # d_0..d_{K-1} (d_0 = t0)
        props = np.zeros((B, K), np.int32)     # d_1..d_K
        tok = self._tokens.copy()
        pos = self._positions.copy()
        live = pos[:, 0] >= 0
        for i in range(K):
            fed[:, i] = tok[:, 0]
            args = (self.draft_params, jnp.asarray(tok), jnp.asarray(pos),
                    self.draft_pool.cache)
            if self._wants_keys:
                args += (fold_keys(jnp.asarray(self._req_keys),
                                   jnp.asarray(tvec + i)),)
            nxt, dcache = self._draft_step(*args)
            self.draft_pool.cache = dcache
            nxt = np.asarray(nxt)
            props[:, i] = nxt
            tok[:, 0] = np.where(live, nxt, 0)
            pos[:, 0] = np.where(live, pos[:, 0] + 1, -1)

        # ---- 2. target verify --------------------------------------
        vpos = np.full((B, K), -1, np.int32)
        for slot in active:
            fl = int(feed_len[slot])
            vpos[slot, :fl] = (int(self._positions[slot, 0])
                               + np.arange(fl, dtype=np.int32))
        cache = {**self.pool.cache, "tables": self.pool.device_tables()}
        args = (self.params, jnp.asarray(fed), jnp.asarray(vpos), cache)
        if self._wants_keys:
            ti = tvec[:, None] + np.arange(K, dtype=np.int32)[None, :]
            flat = fold_keys(
                jnp.asarray(np.repeat(self._req_keys, K, axis=0)),
                jnp.asarray(ti.reshape(-1)))
            args += (flat.reshape(B, K, 2),)
        ys, new_cache = self._verify(*args)
        self.pool.cache = {"slots": new_cache["slots"],
                           "index": new_cache["index"]}
        self.pool.put_device_tables(new_cache["tables"])
        ys = np.asarray(ys)
        now = time.perf_counter()
        self.steps_run += 1
        self.slot_steps += len(active)
        self.spec_rounds += 1

        # ---- 3. acceptance -----------------------------------------
        emits = {}
        for slot in active:
            fl = int(feed_len[slot])
            prop = props[slot, :fl]            # d_1..d_fl
            tgt = ys[slot, :fl]                # y_1..y_fl
            neq = np.nonzero(prop != tgt)[0]
            a = int(neq[0]) if len(neq) else fl
            n_emit = min(a + 1, fl)
            emits[slot] = (n_emit, tgt[:n_emit])
            self.drafted_tokens += fl
            self.accepted_tokens += min(a, n_emit)

        # ---- 4. rollback (reject or budget-truncated rounds) -------
        if any(ne != K for ne, _ in emits.values()):
            stale_t, stale_d = {}, {}
            new_ti = np.zeros(B, np.int32)
            new_di = np.zeros(B, np.int32)
            for slot in active:
                ne = emits[slot][0]
                q = int(self._positions[slot, 0])
                c = int(self._draft_rows[slot])
                stale_t[slot] = range(q + ne, q + K)
                stale_d[slot] = range(c + ne, c + K)
                new_ti[slot] = q + ne
                new_di[slot] = c + ne
            self.pool.rollback_rows(stale_t, new_ti, B * K)
            self.draft_pool.rollback_rows(stale_d, new_di, B * K)

        # ---- bookkeeping (mirrors the non-spec step) ---------------
        for slot in active:
            ne, toks = emits[slot]
            req = self.scheduler.active[slot]
            self._emitted[slot].extend(int(t) for t in toks)
            for _ in range(ne):
                req.trace.mark_token(now)
            self._tokens[slot, 0] = int(toks[-1])
            self._positions[slot, 0] += ne
            self._draft_rows[slot] += ne
            if len(self._emitted[slot]) >= req.max_new_tokens:
                self._finish(slot)

    def run(self, requests: Optional[List[Request]] = None) -> List[Request]:
        """Drain: submit `requests` (if given) and step until idle."""
        for r in requests or ():
            self.submit(r)
        while self.step():
            pass
        return self.scheduler.completed

    def run_batch(self, requests: List[Request]) -> List[Request]:
        """Static-engine-compatible alias for run() (throughput_probe,
        benchmarks): submit + drain, return the same request objects."""
        self.run(requests)
        return requests

    # ---------------- batch-1 latency mode ----------------

    def run_one(self, request: Request) -> Request:
        """Serve ONE request end to end through fixed B=1 jitted steps,
        skipping scheduler/admission/pool bookkeeping entirely — the
        interactive latency path. The B=1 jits build lazily on first use
        and compile exactly once per engine lifetime (their
        _cache_size() stays 1); output is token-identical to pooled
        serving of the same request: same left-pad masking, same sampler
        keys, and — encdec — the same cross contraction length (the
        dense cross K/V is padded out to the arena's blocked frame
        count, pads masked like arena filler)."""
        if request.rid is None:
            request.rid = self._next_rid
            self._next_rid += 1
        if request.trace.submit_t == 0.0:
            request.trace.mark_submit()
        if self.bert:
            return self._run_one_scoring(request)
        if self.encdec and request.frames is None:
            raise ValueError(
                "encdec requests need `frames` (the encoder input)")
        if len(request.prompt) + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt {len(request.prompt)} + max_new_tokens "
                f"{request.max_new_tokens} exceeds max_len {self.max_len}")
        return self._run_one_decode(request)

    def _pad_cross(self, cache):
        """Pad a batch-1 dense cross K/V out to the arena's blocked
        frame count (pad rows carry pos -1, masked exactly like arena
        filler): the decode contraction length matches the pooled
        engine's block gather, which keeps batch-1 output bitwise
        identical to the pooled stream."""
        ck, cv = cache["cross"]["k"], cache["cross"]["v"]
        sm = ck.shape[2]
        pf = self.pool.padded_frames
        if pf == sm:
            return cache
        pos = jnp.concatenate([jnp.arange(sm, dtype=jnp.int32),
                               jnp.full((pf - sm,), -1, jnp.int32)])
        w = ((0, 0), (0, 0), (0, pf - sm), (0, 0), (0, 0))
        return {**cache, "cross": {"k": jnp.pad(ck, w),
                                   "v": jnp.pad(cv, w), "pos": pos}}

    def _run_one_decode(self, request: Request) -> Request:
        prompt = np.asarray(request.prompt, np.int32)
        tokens, positions, lens = pad_prompts([prompt], self.prefill_bucket)
        if tokens.shape[1] + request.max_new_tokens - 1 > self._prefill_len:
            raise ValueError(
                f"padded prompt {tokens.shape[1]} + budget "
                f"{request.max_new_tokens} exceeds the prefill cache "
                f"({self._prefill_len} rows)")
        if self.encdec:
            frames = jnp.asarray(
                np.asarray(request.frames, np.float32)[None])
            logits, cache = self._prefill(self.params, jnp.asarray(tokens),
                                          jnp.asarray(positions), frames)
            cache = self._pad_cross(cache)
        else:
            logits, cache = self._prefill(self.params, jnp.asarray(tokens),
                                          jnp.asarray(positions))
        first, rkeys = first_tokens(self._first, self.sampler,
                                    self._wants_keys, logits, [request])
        now = time.perf_counter()
        request.trace.admit_t = now
        request.trace.mark_token(now)
        emitted = [int(first[0])]
        if self._lat_step is None:
            self._lat_step = build_serve_step(self.arch.decode_step, None,
                                              sampler=self.sampler)
        tok = np.array([[emitted[0]]], np.int32)
        pos = np.array([[int(lens[0])]], np.int32)
        rk = jnp.asarray(rkeys) if rkeys is not None else None
        while len(emitted) < request.max_new_tokens:
            args = (self.params, jnp.asarray(tok), jnp.asarray(pos), cache)
            if self._wants_keys:
                args += (fold_keys(rk, jnp.asarray([len(emitted)],
                                                   jnp.int32)),)
            nxt, cache = self._lat_step(*args)
            t = int(np.asarray(nxt)[0])
            request.trace.mark_token(time.perf_counter())
            emitted.append(t)
            tok[0, 0] = t
            pos[0, 0] += 1
        request.generated = np.array(emitted, np.int32)
        request.trace.done_t = request.trace.token_ts[-1]
        return request

    def _run_one_scoring(self, request: Request) -> Request:
        if self._lat_score is None:
            # a SEPARATE jit from the batched _score: each compiles its
            # one shape once — (1, score_len) here — so both stay at
            # _cache_size() == 1
            self._lat_score = jax.jit(self.arch.score)
        prompt = np.asarray(request.prompt, np.int32)
        tokens, positions, lens = pad_prompts([prompt], 1,
                                              pad_len=self.score_len)
        ids, pooled = self._lat_score(self.params, jnp.asarray(tokens),
                                      jnp.asarray(positions))
        now = time.perf_counter()
        request.trace.admit_t = now
        request.embedding = np.asarray(pooled)[0].copy()
        plen = int(lens[0])
        if self.task == "score":
            request.generated = np.asarray(
                ids)[0, self.score_len - plen:].copy()
            for _ in range(plen):
                request.trace.mark_token(now)
        else:
            request.generated = np.zeros(0, np.int32)
            request.trace.mark_token(now)
        request.trace.done_t = now
        return request

    def report(self, wall_s: float) -> dict:
        """Aggregate throughput/latency stats for completed requests:
        tokens/s, TTFT/ITL percentiles, slot utilization, decode-step
        count, peak concurrency, queue-depth stats, preemption count,
        and (paged) shared/retained prefix block hits."""
        done = self.scheduler.completed
        stats = aggregate([r.trace for r in done], wall_s,
                          sum(len(r.generated) for r in done))
        denom = max(1, self.steps_run * self.max_batch)
        stats["slot_utilization"] = self.slot_steps / denom
        stats["decode_steps"] = self.steps_run
        stats["max_concurrent"] = self.max_concurrent
        stats["preemptions"] = self.preemptions
        stats["sched_policy"] = self.sched_policy.name
        stats["mesh_devices"] = (self.mesh.devices.size
                                 if self.mesh is not None else 1)
        stats.update(self._depth.stats())
        stats["task"] = self.task
        if self.paged:
            stats["growth"] = self.pool.growth
            stats["shared_block_hits"] = self.pool.shared_hits
            stats["retained_block_hits"] = self.pool.retained_hits
            stats["prefix_misses"] = self.pool.prefix_misses
            stats["retained_hit_rate"] = self.pool.retained_hit_rate
        if self.encdec:
            stats["shared_block_hits"] = self.pool.shared_hits
            stats["retained_block_hits"] = self.pool.retained_hits
            stats["prefix_misses"] = self.pool.prefix_misses
            stats["retained_hit_rate"] = self.pool.retained_hit_rate
        if self._admission is not None:
            stats["chunk_budget"] = self.chunk_budget
            stats["chunk_steps"] = self._admission.chunks_run
            stats["chunk_tokens"] = self._admission.chunk_tokens
        if self.spec:
            stats["spec_k"] = self.spec_k
            stats["spec_rounds"] = self.spec_rounds
            stats["drafted_tokens"] = self.drafted_tokens
            stats["accepted_tokens"] = self.accepted_tokens
            stats["acceptance_rate"] = (self.accepted_tokens
                                        / max(1, self.drafted_tokens))
        return stats


class ServeEngine:
    """Static-batch baseline: one padded prefill, lockstep decode.

    Kept as the comparison point for benchmarks/serving_load.py and for
    callers that want the simplest possible batch API. Shares the decode
    step, precision policy, sampler key scheme and exact left-pad masking
    with ContinuousEngine, so the engines produce identical tokens per
    request."""

    def __init__(self, arch, params, *, max_len: int = 512, policy=None,
                 mesh=None, sampler=None):
        # mesh is accepted for signature parity with ContinuousEngine but
        # stays inert (plain jit): the static baseline is the SINGLE-
        # device differential reference the sharded engine is pinned
        # against, so it deliberately never shards.
        if arch.kind != "decoder":
            raise ValueError(f"serving needs a decoder arch, got {arch.kind}")
        self.arch, self.params = apply_serving_policy(arch, params, policy)
        self.max_len = max_len
        self.granularity = prompt_granularity(self.arch.cfg)
        self.sampler = Sampler.parse(sampler)
        self._step = build_serve_step(self.arch.decode_step, mesh,
                                      sampler=self.sampler)
        self._prefill = build_prefill_fn(self.arch, max_len)
        self._first, self._wants_keys = build_first_token_fn(self.sampler)
        self._next_rid = 0

    def run_batch(self, requests: List[Request]) -> List[Request]:
        """Serve one padded batch to completion: a single left-padded
        prefill, then lockstep decode for max(max_new_tokens) steps.
        Fills each request's `generated`/trace in place and returns the
        same list."""
        assert requests
        steps = max(r.max_new_tokens for r in requests)
        tokens, positions, lens = pad_prompts(
            [r.prompt for r in requests], self.granularity)
        if tokens.shape[1] + steps > self.max_len:
            raise ValueError(
                f"padded prompt {tokens.shape[1]} + {steps} new tokens "
                f"exceeds max_len {self.max_len}")
        for r in requests:
            # respect an earlier submission timestamp: callers running
            # waves (benchmarks, launch/serve --engine static) stamp the
            # whole workload up front so TTFT includes the queue wait —
            # otherwise wave k's wait behind waves 0..k-1 would vanish
            # from the static/continuous comparison.
            if r.trace.submit_t == 0.0:
                r.trace.mark_submit()
            if r.rid is None:
                r.rid = self._next_rid
                self._next_rid += 1
        logits, cache = self._prefill(self.params, jnp.asarray(tokens),
                                      jnp.asarray(positions))
        tok, rkeys = first_tokens(self._first, self.sampler,
                                  self._wants_keys, logits, requests)
        if rkeys is not None:
            rkeys = jnp.asarray(rkeys)
        out = [np.asarray(tok)]
        now = time.perf_counter()
        for r in requests:
            r.trace.admit_t = now
            r.trace.mark_token(now)
        pos_next = lens.copy()
        for i in range(steps - 1):
            args = (self.params, tok[:, None],
                    jnp.asarray(pos_next[:, None]), cache)
            if self._wants_keys:
                args += (fold_keys(rkeys, jnp.full(len(requests), i + 1,
                                                   jnp.int32)),)
            tok, cache = self._step(*args)
            tok_h = np.asarray(tok)
            now = time.perf_counter()
            out.append(tok_h)
            pos_next += 1
            for r in requests:
                if len(r.trace.token_ts) < r.max_new_tokens:
                    r.trace.mark_token(now)
        gen = np.stack(out, axis=1)      # (B, steps)
        for i, r in enumerate(requests):
            r.generated = gen[i, :r.max_new_tokens]
            r.trace.done_t = r.trace.token_ts[-1]
        return requests


def throughput_probe(engine, requests: List[Request], *,
                     warmup: bool = True) -> dict:
    """Timed run over `requests`; tokens/s + latency percentiles.

    warmup=True first runs a shape-identical clone of the request set so
    jit compilation (both prefill shapes and the decode step) stays out of
    the measured wall clock — compile time used to dominate tokens/s on
    small batches."""
    if warmup:
        clones = [Request(prompt=r.prompt.copy(),
                          max_new_tokens=r.max_new_tokens)
                  for r in requests]
        engine.run_batch(clones)
    t0 = time.perf_counter()
    done = engine.run_batch(requests)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    stats = aggregate([r.trace for r in done], dt, toks)
    stats["warmup"] = warmup
    return stats

"""Chunked-prefill admission controller (Sarathi-style prefill/decode fusion).

PRs 2-5 admit requests with WHOLE-PROMPT prefills: one long admission
runs a full padded-prompt forward between two decode steps, so every
running stream observes an inter-token gap the size of that prefill —
ITL p99 is unprotected under mixed short/long traffic. This module
meters prefill work instead: each engine step carries at most ONE
prompt chunk, sized so that

    chunk tokens + active decode tokens  <=  chunk_budget

i.e. a per-step token budget is partitioned between the running decodes
(one token each) and a single resumable prefill chunk riding in the
step's spare capacity. The chunk advances a per-request prefill TASK
whose KV/SSM cache state carries across steps; when the last chunk
lands, the task's cache is inserted into the paged pool exactly like a
whole-prompt prefill's and the slot joins the decode batch.

Chunk-boundary exactness (the differential gate): a chunk is just
`Arch.decode_step` over S prompt rows against the task's pooled cache,
which is the SAME incremental cache-write path a whole-prompt prefill
of a short prompt takes — rows land at the write cursor, positions are
the request's local timeline (left-pads < 0 stay masked, the PR 2
invariant), and attention/SSM read back the rows already written. Three
properties make the chunk boundaries token-identical to one whole
prefill:

  * attention attends the CACHE (not in-flight k/v) in the incremental
    branch, so every chunk sees exactly the rows earlier chunks wrote;
    masked rows contribute exact zeros (NEG_INF -> exp == 0.0);
  * the task cache is built with `clamp_window=False`: sliding-window
    slot-types get full-length rows so chunks never hit attention's
    roll-on-overflow branch (which assumes a from-scratch prefill and
    cannot resume) — window locality is enforced by the (qp - kp) <
    window mask instead of the ring, which masks the same keys;
  * chunk sizes are multiples of `chunk_granularity(cfg)` — mamba's
    chunked SSD scan requires S % mamba_chunk == 0 and carries its
    inter-chunk state in fp32, so cfg-aligned boundaries are bit-exact;
    the minimum is 2 even for pure-attention archs because an S == 1
    step is the fp32-accumulated DECODE path, whose bf16 numerics
    differ from prefill's.

What is NOT preserved: the prefill's reduction shapes. A chunked
prefill computes the same values through different einsum shapes, so
its blocks are never content-addressed for prefix sharing (the engine
inserts with share=False) — sharing blocks bit-for-bit with a
whole-prefill peer would not be sound in bf16.

The controller also closes two PR 5 follow-ups as inputs: the
admission gate holds back a DYNAMIC watermark (one block per decoding
slot, on top of the pool's static watermark) because chunked
admissions consume their blocks only at finalize — many steps after
the gate — while decoding slots keep growing; and preemption-victim
selection becomes resume-cost-aware (PolicyContext.resume_cost): the
victim whose continuation prefill re-chunks the fewest tokens loses
the least budget.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.serving.cache_pool import _live_mesh


def chunk_granularity(cfg) -> int:
    """Smallest chunk length the arch supports, never below 2.

    Mamba's chunked SSD scan asserts S % mamba_chunk == 0 (its fp32
    inter-chunk state makes aligned boundaries bit-exact); attention
    archs could take any S >= 2, but S == 1 is excluded: a single-row
    cached step runs the fp32-accumulated decode attention path, whose
    bf16 numerics differ from the prefill path a whole-prompt run uses.
    """
    from repro.serving.engine import prompt_granularity
    return max(2, prompt_granularity(cfg))


def plan_chunk(budget: int, n_active: int, granularity: int,
               remaining: int) -> int:
    """Tokens of prefill to fuse into this step: the budget partition.

    Invariants (property-tested in tests/test_admission.py):
      * size + n_active <= budget   (budget conservation: decodes are
        never displaced — they always get their token first);
      * size % granularity == 0 and size is granularity * 2^k (the
        quantized size set keeps the jitted-chunk compile count at
        log2(budget / granularity) + 1);
      * size <= remaining, and remaining - size stays a granularity
        multiple whenever remaining was one (no unreachable tail);
      * size >= granularity whenever spare capacity allows — so a task
        always progresses once decodes drain below the budget.
    """
    spare = budget - n_active
    if remaining <= 0 or spare < granularity:
        return 0
    cap = min(spare, remaining)
    size = granularity
    while size * 2 <= cap:
        size *= 2
    return size


@dataclasses.dataclass
class PrefillTask:
    """One in-flight chunked admission: a slot-bound prompt being
    prefilled chunk by chunk into its own resumable pooled cache."""
    req: object
    slot: int
    prompt: np.ndarray          # full unpadded prompt (+ continuation)
    tokens: np.ndarray          # (1, padded_len) left-padded
    positions: np.ndarray       # (1, padded_len) local timeline, pads < 0
    plen: int
    padded_len: int
    resume_len: int             # tokens re-prefilled from a preemption
    offset: int = 0             # padded rows already chunked
    cache: Optional[dict] = None
    last_logits: Optional[object] = None   # (1, 1, V) fp32 after last chunk
    chunks_run: int = 0

    @property
    def remaining(self) -> int:
        return self.padded_len - self.offset

    @property
    def finished(self) -> bool:
        return self.offset >= self.padded_len


class AdmissionController:
    """Runs at most one PrefillTask, one chunk per engine step.

    The chunk forward is `arch.decode_step` jitted once per chunk size
    (the quantized set from plan_chunk bounds that to
    log2(budget / granularity) + 1 compiles); the task cache is donated
    through each call, so chunking never double-buffers the KV rows.
    """

    def __init__(self, arch, params, *, chunk_budget: int,
                 prefill_len: int, mesh=None):
        if arch.kind != "decoder":
            # chunks run arch.decode_step against a per-slot self-
            # attention cache: encdec decode wants the pooled cross-
            # arena pytree and bert has no decode step at all, so the
            # resumable-chunk contract only holds for decoder archs
            # (the engine rejects chunk_budget for other families too;
            # this guards direct construction)
            raise ValueError(
                f"chunked prefill needs a decoder arch, got {arch.kind}")
        self.arch = arch
        self.params = params
        self.granularity = chunk_granularity(arch.cfg)
        if chunk_budget < self.granularity:
            raise ValueError(
                f"chunk_budget {chunk_budget} < chunk granularity "
                f"{self.granularity} (mamba archs need chunks of "
                f"cfg.mamba_chunk tokens; attention archs need >= 2)")
        self.chunk_budget = chunk_budget
        self.prefill_len = prefill_len
        # Under a mesh the task cache shards like the main pool's dense
        # layout (batch 1 replicates — size-1 dims never shard — and
        # head_dim goes over "model", matching the arenas), so chunk
        # forwards run the same tensor-parallel partitioning as decode
        # and the finalize insert hands the pool a same-layout cache.
        self.mesh = _live_mesh(mesh)
        self._cache_sh = None
        if self.mesh is not None:
            like = jax.eval_shape(
                lambda: arch.init_cache(1, prefill_len, per_slot=True,
                                        clamp_window=False))
            self._cache_sh = shd.cache_shardings(like, self.mesh)
        self.task: Optional[PrefillTask] = None
        self._fns: Dict[int, Callable] = {}
        self.chunks_run = 0          # lifetime chunk forwards
        self.chunk_tokens = 0        # lifetime padded rows chunked

    def sizes(self):
        """Every chunk size plan_chunk can emit (warmup/compile set)."""
        out, size = [], self.granularity
        while size <= self.chunk_budget:
            out.append(size)
            size *= 2
        return out

    def _fn(self, size: int):
        if size not in self._fns:
            def chunk(params, tokens, positions, cache):
                logits, new_cache = self.arch.decode_step(
                    params, {"tokens": tokens, "positions": positions},
                    cache)
                return logits[:, -1:].astype(jnp.float32), new_cache
            if self.mesh is None:
                self._fns[size] = jax.jit(chunk, donate_argnums=(3,))
            else:
                self._fns[size] = jax.jit(
                    chunk, donate_argnums=(3,),
                    out_shardings=(NamedSharding(self.mesh, P()),
                                   self._cache_sh))
        return self._fns[size]

    def _fresh_cache(self):
        # clamp_window=False: full-length rows for sliding-window
        # slot-types keep every chunk on the resumable incremental
        # write path (see module docstring).
        cache = self.arch.init_cache(1, self.prefill_len, per_slot=True,
                                     clamp_window=False)
        if self.mesh is not None:
            cache = jax.device_put(cache, self._cache_sh)
        return cache

    def warmup(self):
        """Compile every chunk size against a scratch cache so an
        open-loop measurement never eats a mid-stream compile (chunk
        sizes depend on the runtime decode count, so a closed-loop
        warm run does not necessarily visit them all)."""
        for size in self.sizes():
            cache = self._fresh_cache()
            tokens = jnp.zeros((1, size), jnp.int32)
            positions = jnp.broadcast_to(
                jnp.arange(size, dtype=jnp.int32), (1, size))
            logits, _ = self._fn(size)(self.params, tokens, positions,
                                       cache)
            logits.block_until_ready()

    def start(self, req, slot: int, tokens: np.ndarray,
              positions: np.ndarray, *, plen: int, padded_len: int,
              resume_len: int, prompt: np.ndarray):
        if self.task is not None:
            raise RuntimeError("a prefill task is already in flight")
        if padded_len % self.granularity != 0:
            raise ValueError(
                f"padded prompt length {padded_len} not a multiple of "
                f"chunk granularity {self.granularity}")
        self.task = PrefillTask(req=req, slot=slot, prompt=prompt,
                                tokens=tokens, positions=positions,
                                plen=plen, padded_len=padded_len,
                                resume_len=resume_len)

    def advance(self, n_active: int) -> bool:
        """Run this step's chunk (if the budget partition grants one).
        Returns True when the task progressed. Check `task.finished`
        afterwards; the engine finalizes (pool insert + first token)."""
        task = self.task
        if task is None:
            return False
        size = plan_chunk(self.chunk_budget, n_active, self.granularity,
                          task.remaining)
        if size == 0:
            return False
        if task.cache is None:
            task.cache = self._fresh_cache()
        logits, task.cache = self._fn(size)(
            self.params,
            jnp.asarray(task.tokens[:, task.offset:task.offset + size]),
            jnp.asarray(task.positions[:, task.offset:task.offset + size]),
            task.cache)
        task.offset += size
        task.chunks_run += 1
        self.chunks_run += 1
        self.chunk_tokens += size
        if task.finished:
            task.last_logits = logits
        return True

    def drop(self):
        """Forget the current task (finalized, or requeued on a
        NoBlocksError at insert — the continuation prefill re-chunks
        identically, so dropping mid-task never loses exactness)."""
        self.task = None

"""Preallocated KV/SSM cache pool for continuous batching.

The pool is one pytree in the pooled (`per_slot=True`) layout: every
stacked cache leaf is (n_periods, max_batch, ...), the write cursor is
(max_batch,), and attention positions are (max_batch, cache_len) with -1
marking invalid rows. Slot admission *inserts* a freshly prefilled
single-request cache (same layout, batch 1) into one batch row; eviction
re-blanks the row. Both are O(row) scatters jitted once — the decode step
itself never changes shape, so the engine never recompiles after warmup.

The insert is layout-generic: attention k/v/pos rows, mamba ssm/conv
state and the cursor all have the slot on the same axis (axis 1 inside
the stacked "slots" subtree, axis 0 for the top-level cursor), so one
tree_map covers every arch family.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def _insert_row(pool: PyTree, req: PyTree, slot) -> PyTree:
    """Write single-request cache `req` (batch 1) into pool batch row `slot`.

    The explicit astype matches prefill-produced state dtypes (e.g. bf16
    mamba conv tails) to the pool's storage dtype — an exact upcast, and
    required for the donated pool buffer to be reused in place."""
    slots = jax.tree.map(
        lambda P, r: P.at[:, slot].set(r[:, 0].astype(P.dtype)),
        pool["slots"], req["slots"])
    index = pool["index"].at[slot].set(req["index"][0])
    return {"slots": slots, "index": index}


class CachePool:
    """Owns the pooled decode cache and its per-slot insert/evict ops."""

    def __init__(self, arch, max_batch: int, max_len: int):
        self.arch = arch
        self.max_batch = max_batch
        self.max_len = max_len
        self.cache = arch.init_cache(max_batch, max_len, per_slot=True)
        # blank single-request cache used for eviction (pos rows back to -1)
        self._blank = arch.init_cache(1, max_len, per_slot=True)
        # donate the old pool: the row update happens in place instead of
        # double-buffering max_batch * max_len of KV per admission.
        self._insert = jax.jit(_insert_row, donate_argnums=0)

    def insert(self, request_cache: PyTree, slot: int):
        """Admit a prefilled request's cache into `slot`."""
        if not (0 <= slot < self.max_batch):
            raise IndexError(f"slot {slot} out of range [0, {self.max_batch})")
        self.cache = self._insert(self.cache, request_cache, slot)

    def evict(self, slot: int):
        """Blank `slot`: positions return to -1 so every row of the old
        occupant is masked; the next insert overwrites the row anyway."""
        if not (0 <= slot < self.max_batch):
            raise IndexError(f"slot {slot} out of range [0, {self.max_batch})")
        self.cache = self._insert(self.cache, self._blank, slot)

    def lengths(self):
        """Per-slot write cursors (host array) — diagnostic only."""
        import numpy as np
        return np.asarray(self.cache["index"])

"""KV/SSM cache pools for continuous batching: dense and paged.

`CachePool` (PR 2 baseline, kept as the differential reference): every
slot owns its full max_len KV rows. One pytree in the pooled
(`per_slot=True`) layout: every stacked cache leaf is (n_periods,
max_batch, ...), the write cursor is (max_batch,), and attention
positions are (max_batch, cache_len) with -1 marking invalid rows. Slot
admission *inserts* a freshly prefilled single-request cache (same
layout, batch 1) into one batch row; eviction re-blanks the row.

`PagedCachePool` (the production pool): attention KV lives in block
ARENAS of (n_periods, n_blocks, block_size, ...) with per-slot block
TABLES of (max_batch, max_blocks) int32 arena indices, managed by the
refcounted host-side allocator in serving/block_allocator.py. Identical
prompt prefixes are content-addressed and stored ONCE — later requests
retain the existing blocks instead of copying KV — and eviction returns
blocks to the free list instead of blanking rows, so memory scales with
*distinct* tokens, not slots x max_len.

Admission comes in two growth modes. `growth="eager"` (the PR 3
contract) reserves a request's whole chain (prompt + decode budget) up
front: admission either fully fits or the request stays queued, and a
decoding slot can never fail. `growth="lazy"` (the scheduler default)
allocates only the PROMPT blocks at admission; decode blocks are grown
one at a time as the write cursor crosses block boundaries (`grow()`
before every decode step), so arena memory tracks tokens actually
written instead of budgets promised — when budgets exceed typical
outputs the same arena admits far more concurrent requests. Growth can
exhaust the arena mid-decode; the ENGINE handles that by preempting a
victim slot (blocks freed, request requeued with its generated tokens
as a continuation prefill). Writes still only ever land in exclusively
owned blocks, but exclusivity is established at WRITE time, not
admission time: under lazy growth a sliding-window slot may share a
prompt block that its ring wrap later overwrites, and grow() resolves
the conflict with a wrap-time copy-on-write — the slot gets a fresh
block, the arena content is copied by flush_growth(), and the shared
source stays intact for its other holders / the retained LRU. (Eager
growth keeps the PR 3 rule: blocks the budgeted chain would overwrite
are simply never shared, so eager never copies.) SSM/conv state is O(1)
per slot and stays slot-resident (the mamba leaves keep the dense
layout).

Speculative decoding (engine `spec_draft`): the verify step scatters
K > 1 rows per slot per step, so (a) grow() runs for each of the K rows
(several fresh blocks per slot-type per step — flush_growth pads its
scatter to a multiple of max_batch), (b) sliding-window rings carry a
`row_margin` of K - 1 extra rows (models/decoder.paged_layout) so the
write burst — which lands BEFORE attention runs — cannot overwrite a
key an earlier query row of the same block still needs, and (c)
rejected rows roll back by rewinding the cursor and min-scattering
position -1 over the stale rows (rollback_rows) — never by copying or
moving a block.

Retained prefixes (`retain_blocks > 0`): a registered prefix block whose
last holder evicts parks on a bounded LRU list with its arena content
intact instead of returning to the free list — the next request with
the same prefix revives it copy-free (a `retained_hits` hit), and
allocation pressure reclaims the LRU tail before ever failing. Popular
system prompts therefore stay warm ACROSS request waves, not just
across concurrently-resident requests.

Both pools feed the same fixed-shape jitted decode step: inserts,
evictions and lazy growth only change block-table VALUES and arena
contents, never any shape, so the engine never recompiles after warmup
(growth adds one extra fixed-shape jitted position-invalidation op,
also compiled once).
"""
from __future__ import annotations

import hashlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models import decoder as dec_lib
from repro.serving.block_allocator import BlockTableMap, NoBlocksError

PyTree = Any


def _live_mesh(mesh):
    """Normalize the mesh kwarg: a 1-device mesh is the unsharded path
    (no out_shardings pinning, no device_put) — pinning to a trivial
    mesh only adds transfer annotations without changing placement."""
    return mesh if mesh is not None and mesh.devices.size > 1 else None


def _const(fn):
    """Slot-type dispatcher shim for the unsharded path: every slot-type
    shares one jit (shapes differ per slot-type, but jax.jit retraces by
    shape anyway), keeping the `self._op(si)(...)` call style uniform
    with the mesh path's genuinely per-slot-type pinned jits."""
    return lambda si: fn


def _insert_row(pool: PyTree, req: PyTree, slot) -> PyTree:
    """Write single-request cache `req` (batch 1) into pool batch row `slot`.

    The explicit astype matches prefill-produced state dtypes (e.g. bf16
    mamba conv tails) to the pool's storage dtype — an exact upcast, and
    required for the donated pool buffer to be reused in place."""
    slots = jax.tree.map(
        lambda P, r: P.at[:, slot].set(r[:, 0].astype(P.dtype)),
        pool["slots"], req["slots"])
    index = pool["index"].at[slot].set(req["index"][0])
    return {"slots": slots, "index": index}


class CachePool:
    """Owns the pooled decode cache and its per-slot insert/evict ops.

    mesh: optional device mesh. When set (and larger than one device)
    the pool's cache lives under distributed.sharding.cache_pspec — the
    same layout the mesh-built serve step consumes — and every mutation
    jit pins its output there, so admissions/evictions never bounce the
    arena through a replicated intermediate.
    """

    def __init__(self, arch, max_batch: int, max_len: int, *, mesh=None):
        self.arch = arch
        self.max_batch = max_batch
        self.max_len = max_len
        self.mesh = _live_mesh(mesh)
        self.cache = arch.init_cache(max_batch, max_len, per_slot=True)
        # blank single-request cache used for eviction (pos rows back to -1)
        self._blank = arch.init_cache(1, max_len, per_slot=True)
        # donate the old pool: the row update happens in place instead of
        # double-buffering max_batch * max_len of KV per admission.
        if self.mesh is None:
            self._insert = jax.jit(_insert_row, donate_argnums=0)
            self._rollback = jax.jit(_pos_rollback, donate_argnums=0)
        else:
            self._shardings = shd.cache_shardings(
                jax.eval_shape(lambda: self.cache), self.mesh)
            self.cache = jax.device_put(self.cache, self._shardings)
            self._insert = jax.jit(_insert_row, donate_argnums=0,
                                   out_shardings=self._shardings)
            # pos specs are identical across attention slot-types (only
            # the batch dim shards; cache_len never does), so one pinned
            # jit serves every slot-type's rollback despite their
            # differing row counts.
            pos_sh = next((s["pos"] for s in self._shardings["slots"]
                           if isinstance(s, dict) and "pos" in s), None)
            self._rollback = (
                jax.jit(_pos_rollback, donate_argnums=0)
                if pos_sh is None else
                jax.jit(_pos_rollback, donate_argnums=0,
                        out_shardings=pos_sh))

    def insert(self, request_cache: PyTree, slot: int):
        """Admit a prefilled request's cache into `slot`."""
        if not (0 <= slot < self.max_batch):
            raise IndexError(f"slot {slot} out of range [0, {self.max_batch})")
        self.cache = self._insert(self.cache, request_cache, slot)

    def evict(self, slot: int):
        """Blank `slot`: positions return to -1 so every row of the old
        occupant is masked; the next insert overwrites the row anyway."""
        if not (0 <= slot < self.max_batch):
            raise IndexError(f"slot {slot} out of range [0, {self.max_batch})")
        self.cache = self._insert(self.cache, self._blank, slot)

    def lengths(self):
        """Per-slot write cursors (host array) — diagnostic only."""
        return np.asarray(self.cache["index"])

    def rollback_rows(self, rows: dict, new_index, capacity: int):
        """Rewind after a speculative round (this pool holds the DRAFT
        model's cache): min-scatter position -1 over each slot's stale
        STORAGE rows — cursor-relative, taken modulo each slot-type's own
        cache length, which differs between full and sliding-window
        layers — and replace the write cursors wholesale. Attention-only:
        the engine gates spec_draft to attention-only archs (SSM state
        accumulates in place and cannot rewind). Padding entries carry
        val == INT32_MAX, a min() no-op against any resident position, so
        the op is fixed-shape and compiles once per capacity."""
        total = sum(len(r) for r in rows.values())
        assert total <= capacity, (total, capacity)
        slots = list(self.cache["slots"])
        for si, leaf in enumerate(slots):
            if not (isinstance(leaf, dict) and "pos" in leaf):
                raise NotImplementedError(
                    "speculative rollback needs attention-only caches "
                    f"(superblock slot {si} has no position rows)")
            L = leaf["pos"].shape[2]
            bvec = np.zeros(capacity, np.int32)
            rvec = np.zeros(capacity, np.int32)
            vals = np.full(capacity, np.iinfo(np.int32).max, np.int32)
            n = 0
            for slot, rws in rows.items():
                for r in rws:
                    bvec[n] = slot
                    rvec[n] = r % L
                    vals[n] = -1
                    n += 1
            slots[si] = {**leaf, "pos": self._rollback(
                leaf["pos"], jnp.asarray(bvec), jnp.asarray(rvec),
                jnp.asarray(vals))}
        self.cache = {"slots": tuple(slots),
                      "index": jnp.asarray(np.asarray(new_index, np.int32))}


def _arena_insert(arena: PyTree, req: PyTree, src_rows, dst_blocks,
                  row_valid) -> PyTree:
    """Scatter a prefilled request's cache rows into arena blocks.

    arena: {"k","v","pos"} with leading (n_periods, n_blocks) dims.
    req:   the same slot-type's subtree from a dense batch-1 prefill cache,
           leading dims (n_periods, 1, cache_len).
    src_rows (ring_len,): request-cache row feeding each logical row; rows
           of skipped chain positions point at a guaranteed in-bounds row.
    dst_blocks (max_blocks,): arena block per chain position, NULL (0) for
           positions that must not be written (shared blocks, unused tail)
           — their writes land in the null block carrying pos -1, which
           keeps it invalid. The allocator guarantees every non-null dst
           is exclusively owned, so duplicate-index races cannot happen
           outside the null block.
    row_valid (ring_len,) bool: ring rows actually backed by a prompt row
           of the request cache. Unbacked rows of WRITTEN blocks — and
           every null-routed row — get position -1 unconditionally: with
           a row_margin the ring can be longer than the request cache's
           window, so a written boundary block may mix backed and
           unbacked rows, and a fully-rolled zero-pad prefill cache has
           no pos==-1 filler row to route the unbacked ones through
           (garbage K/V there is harmless once the positions are masked).
    """
    nbk = dst_blocks.shape[0]
    bs = arena["k"].shape[2]

    def blocks_of(x, dtype):
        g = x[:, 0][:, src_rows]              # (n_periods, ring_len, ...)
        return g.reshape(g.shape[0], nbk, bs, *g.shape[2:]).astype(dtype)

    ok = (dst_blocks != 0)[None, :, None] & row_valid.reshape(1, nbk, bs)
    pos = jnp.where(ok, blocks_of(req["pos"], arena["pos"].dtype), -1)
    return {"k": arena["k"].at[:, dst_blocks].set(
                blocks_of(req["k"], arena["k"].dtype)),
            "v": arena["v"].at[:, dst_blocks].set(
                blocks_of(req["v"], arena["v"].dtype)),
            "pos": arena["pos"].at[:, dst_blocks].set(pos)}


def _pos_invalidate(pos: PyTree, blocks) -> PyTree:
    """Set every row of the given arena blocks to position -1.

    blocks is a FIXED-SHAPE (max_batch,) int32 vector padded with 0 (the
    null block, whose rows are -1 already — rewriting them is a no-op),
    so lazy growth never retraces: each active slot grows at most one
    block per slot-type per step. A freshly grown block still holds a
    previous occupant's rows; its positions must read as invalid before
    the decode step gathers it (the step then writes the cursor row with
    a live position, leaving the rest masked)."""
    return pos.at[:, blocks].set(-1)


def _cow_copy(arena: PyTree, srcs, dsts) -> PyTree:
    """Copy whole arena blocks src -> dst (k, v, AND positions): the
    wrap-time copy-on-write resolved by flush_growth. srcs/dsts are
    fixed-shape int32 vectors padded with the null block on both sides —
    the padded entries copy the null block onto itself (pos stays -1),
    so padding is a no-op and the op never retraces."""
    return {"k": arena["k"].at[:, dsts].set(arena["k"][:, srcs]),
            "v": arena["v"].at[:, dsts].set(arena["v"][:, srcs]),
            "pos": arena["pos"].at[:, dsts].set(arena["pos"][:, srcs])}


def _pos_rollback(pos: PyTree, blocks, offsets, vals) -> PyTree:
    """Min-scatter over individual arena rows: the speculative-rejection
    rollback. Real entries carry val == -1 (min(pos, -1) forces the row
    invalid); padding carries (null block, offset 0, INT32_MAX) — a
    min() no-op against the null block's resident -1 — so the vectors
    are fixed-shape and duplicates among the pads are harmless (scatter-
    min is commutative)."""
    return pos.at[:, blocks, offsets].min(vals)


def _state_insert(state: PyTree, req_state: PyTree, slot, new_index) -> PyTree:
    """Slot-resident state (mamba SSM/conv) row insert + cursor update.

    new_index is the slot's LOCAL token count (no left-pad offset): the
    paged chain is position-aligned, unlike the dense pool whose cursor
    counts padded storage rows."""
    slots = jax.tree.map(
        lambda P, r: P.at[:, slot].set(r[:, 0].astype(P.dtype)),
        state["slots"], req_state["slots"])
    index = state["index"].at[slot].set(new_index)
    return {"slots": slots, "index": index}


class PagedCachePool:
    """Block-paged decode cache with refcounted shared prompt prefixes.

    slots_budget sizes each attention arena in dense-slot equivalents:
    `slots_budget * ring_len // block_size` data blocks (+1 null). The
    default (== max_batch) matches the dense pool's memory exactly, so a
    no-sharing workload admits the same number of slots while shared
    prefixes admit more. An engine wanting 2x+ concurrency passes
    max_batch > slots_budget and lets the allocator arbitrate.
    """

    def __init__(self, arch, max_batch: int, max_len: int, *,
                 block_size: int = 16, slots_budget: Optional[int] = None,
                 share_prefix: bool = True, attn_kernel: Optional[str] = None,
                 growth: str = "eager", retain_blocks: int = 0,
                 watermark: int = 0, row_margin: int = 0, mesh=None):
        """Args:
          arch: decoder Arch (paged serving is decoder-only).
          max_batch: number of decode slots (block-table rows).
          max_len: per-request logical KV budget in rows.
          block_size: arena block granularity; must divide every
            attention slot-type's ring length (max_len / sliding window).
          slots_budget: arena memory in dense-slot equivalents (None:
            == max_batch, i.e. exactly the dense pool's memory). Under
            lazy growth this is a high-watermark on blocks in use, not a
            per-request reservation.
          share_prefix: content-address identical prompt prefixes and
            store their blocks once (refcounted, copy-free).
          attn_kernel: which paged decode attention the arenas feed —
            "xla" (dense arena[table] gather) or "paged" (the fused
            Pallas kernel). None adopts arch.cfg.attn_kernel. The pool
            layout is identical either way; this is recorded here so the
            pool and the decode step cannot disagree.
          growth: "eager" reserves a request's whole chain at admission
            (atomic; decode can never fail); "lazy" allocates prompt
            blocks only and grows decode blocks on demand — the caller
            must grow()/flush_growth() before each decode step and
            preempt a victim on NoBlocksError.
          retain_blocks: LRU bound (blocks per attention slot-type) for
            warm ref-0 prefix blocks kept alive across requests; 0
            disables retention (PR 3 free-on-last-release).
          watermark: free blocks the ADMISSION accounting holds back per
            slot-type so in-flight slots can usually grow without
            preempting (growth itself ignores it).
          row_margin: extra rows (rounded up to blocks) on sliding-window
            rings so a speculative K-row verify burst cannot wrap onto
            in-window keys; pass spec_k - 1. 0 (non-speculative) keeps
            the exact PR 4-6 layout.
          mesh: optional device mesh; the arenas live under
            distributed.sharding.cache_pspec (blocks over "data",
            head_dim over "model", integer bookkeeping replicated /
            data-sharded only) and every mutation jit pins its output
            there. Mutation jits become PER-SLOT-TYPE under a mesh —
            each slot-type's arena has its own n_blocks, so the blocks
            dim's "data" divisibility (hence its spec) can differ — and
            are accessed as `self._insert_arena(si)(...)` etc.
        """
        if arch.kind != "decoder":
            raise NotImplementedError("paged serving is decoder-only")
        if attn_kernel is None:
            attn_kernel = getattr(arch.cfg, "attn_kernel", "xla")
        if attn_kernel not in ("xla", "paged"):
            raise ValueError(
                f"attn_kernel must be 'xla' or 'paged', got {attn_kernel}")
        if growth not in ("eager", "lazy"):
            raise ValueError(
                f"growth must be 'eager' or 'lazy', got {growth}")
        self.attn_kernel = attn_kernel
        self.arch = arch
        self.max_batch = max_batch
        self.max_len = max_len
        self.block_size = block_size
        self.share_prefix = share_prefix
        self.growth = growth
        budget = slots_budget if slots_budget is not None else max_batch
        self.row_margin = row_margin
        layout = dec_lib.paged_layout(arch.cfg, max_len, block_size,
                                      row_margin)
        base = dec_lib.paged_layout(arch.cfg, max_len, block_size)
        self.maps = {}
        n_blocks = {}
        for entry, base_entry in zip(layout, base):
            if entry is None:
                continue
            si, ring = entry
            n_blocks[si] = budget * (ring // block_size)
            self.maps[si] = BlockTableMap(
                max_batch, ring, block_size, n_blocks[si] + 1,
                retain_limit=min(retain_blocks, max(n_blocks[si] - 1, 0)),
                watermark=min(watermark, max(n_blocks[si] - 1, 0)),
                src_len=base_entry[1])
        full = arch.init_paged_cache(max_batch, max_len,
                                     block_size=block_size,
                                     n_blocks=n_blocks,
                                     row_margin=row_margin)
        tables = full.pop("tables")  # host-owned: see device_tables()
        self.mesh = _live_mesh(mesh)
        if self.mesh is None:
            self._shardings = self._table_shardings = None
        else:
            sh = shd.cache_shardings(
                jax.eval_shape(lambda: {**full, "tables": tables}),
                self.mesh)
            self._table_shardings = sh.pop("tables")
            self._shardings = sh
            full = jax.device_put(full, self._shardings)
        self.cache = full
        self._mamba_slots = tuple(si for si, e in enumerate(layout)
                                  if e is None)
        # Kernel-layout validation happens at POOL CONSTRUCTION, not at
        # first decode: on real TPU a (block_size, head_dim) that misses
        # the (8/16, 128) tile grid or blows the VMEM scratch budget
        # raises here with the fix spelled out (ensure_kernel_fit), while
        # off-TPU — or with the --interpret escape hatch — the same
        # problems are recorded as advisory (tile_problems) because the
        # interpret-mode kernel executes any layout. S is sized for the
        # widest launch this pool will feed: the spec-verify query block
        # (row_margin == spec_k - 1).
        self.tile_problems: list = []
        if attn_kernel == "paged":
            from repro.kernels.paged_attention_kernel import ensure_kernel_fit
            cfg = arch.cfg
            arena_dtype = next(
                s["k"].dtype for si, s in enumerate(full["slots"])
                if si not in self._mamba_slots)
            self.tile_problems = ensure_kernel_fit(
                block_size, cfg.resolved_head_dim, cfg.n_heads,
                cfg.n_kv_heads, arena_dtype, S=row_margin + 1,
                interpret=getattr(cfg, "kernel_interpret", None))
        if self.mesh is None:
            self._insert_arena = _const(jax.jit(_arena_insert,
                                                donate_argnums=0))
            self._invalidate = _const(jax.jit(_pos_invalidate,
                                              donate_argnums=0))
            self._copy_blocks = _const(jax.jit(_cow_copy, donate_argnums=0))
            self._rollback = _const(jax.jit(_pos_rollback, donate_argnums=0))
            self._insert_state = jax.jit(_state_insert, donate_argnums=0)
        else:
            arena_sh = lambda si: self._shardings["slots"][si]
            pos_sh = lambda si: self._shardings["slots"][si]["pos"]
            self._insert_arena = self._per_si(_arena_insert, arena_sh)
            self._invalidate = self._per_si(_pos_invalidate, pos_sh)
            self._copy_blocks = self._per_si(_cow_copy, arena_sh)
            self._rollback = self._per_si(_pos_rollback, pos_sh)
            state_sh = {"slots": {si: self._shardings["slots"][si]
                                  for si in self._mamba_slots},
                        "index": self._shardings["index"]}
            self._insert_state = jax.jit(_state_insert, donate_argnums=0,
                                         out_shardings=state_sh)
        self._pending_grown = {si: [] for si in self.maps}
        # blank batch-1 state used on eviction (hygiene + lengths() diag)
        blank = arch.init_cache(1, max_len, per_slot=True)
        self._blank_state = {
            "slots": {si: blank["slots"][si] for si in self._mamba_slots},
            "index": blank["index"]}
        self.shared_hits = 0    # prefix blocks reused instead of copied
        self._dev_tables = None  # device mirror, valid between mutations

    def _per_si(self, fn, sharding_of):
        """Memoized per-slot-type jit with this pool's out_shardings —
        slot-types differ in arena n_blocks, so their blocks-dim "data"
        divisibility (hence the pinned spec) can differ."""
        jits = {}

        def get(si):
            if si not in jits:
                jits[si] = jax.jit(fn, donate_argnums=0,
                                   out_shardings=sharding_of(si))
            return jits[si]

        return get

    # ---------------- layout helpers ----------------

    def device_tables(self):
        """Per-slot-type block tables as device arrays, None for mamba
        slots. Uploaded from the host mirror only after insert/evict
        mutations (values change as blocks churn; shapes never do) —
        between mutations the engine hands back the decode step's
        pass-through outputs via put_device_tables, so steady-state
        decode moves zero table bytes host->device."""
        if self._dev_tables is None:
            host = tuple(self.maps[si].table if si in self.maps else None
                         for si in range(len(self.arch.cfg.superblock)))
            if self.mesh is None:
                self._dev_tables = jax.tree.map(jnp.asarray, host)
            else:
                # pin tables to the step's cache_pspec layout (slot rows
                # over "data") so the upload lands pre-sharded instead of
                # being replicated then resharded inside the step.
                self._dev_tables = jax.device_put(host,
                                                  self._table_shardings)
        return self._dev_tables

    def put_device_tables(self, tables):
        """Reuse the decode step's pass-through table outputs for the next
        step (they alias the donated inputs; same lifecycle as the
        arenas). Ignored if a host-side mutation already invalidated."""
        if self._dev_tables is not None:
            self._dev_tables = tables

    def _state_tree(self):
        return {"slots": {si: self.cache["slots"][si]
                          for si in self._mamba_slots},
                "index": self.cache["index"]}

    def _put_state(self, state):
        slots = list(self.cache["slots"])
        for si in self._mamba_slots:
            slots[si] = state["slots"][si]
        self.cache = {"slots": tuple(slots), "index": state["index"]}

    def _src_rows(self, ring: int, cache_len: int, plen: int,
                  padded_len: int):
        """(request-cache row backing each logical ring row, backed-row
        mask) — see _arena_insert. `rolled` mirrors attention's prefill
        roll branch (padded_len >= the request cache's row count — only
        sliding-window slot-types, whose request cache is window-sized).
        Rows the request cache cannot back — skipped chain positions,
        and with a row_margin the ring rows beyond the prefill window —
        point at an arbitrary in-bounds filler row and are reported
        unbacked; _arena_insert forces their positions to -1."""
        pad = padded_len - plen
        rolled = padded_len >= cache_len
        if rolled:
            # rows hold the last `cache_len` padded positions, rolled so
            # that storage row == (position + pad) % cache_len.
            filler = (pad - 1) % cache_len
        else:
            filler = cache_len - 1    # never written: engine keeps
            #                           padded_len < cache_len (slack row)
        src = np.full(ring, filler, np.int32)
        backed = np.zeros(ring, bool)
        # the prefill cache retains at most its own row count of prompt
        # rows; a margin-widened ring (ring > cache_len) cannot be backed
        # past that window.
        ps = np.arange(max(0, plen - min(ring, cache_len)), plen)
        rows = (pad + ps) % cache_len if rolled else pad + ps
        src[ps % ring] = rows
        backed[ps % ring] = True
        return src, backed

    # ---------------- admission ----------------

    def admission_plan(self, prompt, plen: int, padded_len: int,
                       budget: int, *, share: Optional[bool] = None) -> dict:
        """{slot-type: fresh blocks + retained revivals} an insert would
        consume from the (free + reclaimable-retained) pool — the
        engine's admission gate compares this against
        admissible_blocks(). Lazy growth counts only prompt-backed
        positions; decode positions are grown (and accounted) later.
        `share` overrides the pool-wide share_prefix for this plan
        (chunked admissions pass share=False: see insert)."""
        if share is None:
            share = self.share_prefix
        return {si: sum(m.admission_plan(prompt, plen, padded_len, budget,
                                         share,
                                         lazy=self.growth == "lazy"))
                for si, m in self.maps.items()}

    def admissible_blocks(self) -> dict:
        """Blocks admission may plan against, per attention slot-type:
        free + reclaimable retained, minus the growth watermark."""
        return {si: m.admissible() for si, m in self.maps.items()}

    def free_blocks(self) -> dict:
        """Currently allocatable blocks per attention slot-type
        (excludes retained blocks, which need an explicit reclaim)."""
        return {si: m.alloc.n_free for si, m in self.maps.items()}

    def prefix_warm(self, prompt, plen: int, padded_len: int) -> bool:
        """Is the request's leading prompt block already resident (live
        shared or retained) in any attention slot-type's registry? The
        prefix-affinity scheduling policy's admission signal."""
        return any(m.prefix_warm(prompt, plen, padded_len)
                   for m in self.maps.values())

    def insert(self, request_cache: PyTree, slot: int, *, prompt,
               plen: int, padded_len: int, budget: int,
               share: Optional[bool] = None):
        """Admit a prefilled request: reserve its block chain (the whole
        prompt + decode budget under eager growth; prompt blocks only
        under lazy growth), write the fresh blocks, retain/revive shared
        prefix blocks without copying, and land the slot-resident state.
        Atomic: on NoBlocksError nothing is left allocated and the
        device cache is untouched. `share` overrides the pool-wide
        share_prefix for this insert — chunked prefills pass False (a
        chunk schedule changes the reduction shapes, so their KV is not
        guaranteed bit-identical to a whole prefill's and must never be
        content-addressed for sharing)."""
        if not (0 <= slot < self.max_batch):
            raise IndexError(f"slot {slot} out of range [0, {self.max_batch})")
        if share is None:
            share = self.share_prefix
        placed = {}
        try:
            for si, m in self.maps.items():
                placed[si] = m.insert(slot, prompt, plen, padded_len, budget,
                                      share,
                                      lazy=self.growth == "lazy")
        except NoBlocksError:
            # cross-map rollback: earlier slot-types' placements succeed
            # before the device write happens, so any prefix block THIS
            # insert registered holds no real content yet and must be
            # freed + unregistered, never parked warm (a later revival
            # is read copy-free and would decode garbage KV); revived
            # blocks re-park and shared retains drop — exactly the
            # intra-map failure rollback, applied per placement.
            for si in placed:
                self.maps[si].rollback_insert(slot, placed[si])
            raise
        self.shared_hits += sum(p.shared for ps in placed.values()
                                for p in ps)
        self._dev_tables = None          # host tables changed: re-upload
        slots = list(self.cache["slots"])
        for si, m in self.maps.items():
            ring = m.ring_len
            cache_len = request_cache["slots"][si]["k"].shape[2]
            src, backed = self._src_rows(ring, cache_len, plen, padded_len)
            dst = np.zeros(m.max_blocks, np.int32)
            for p in placed[si]:
                if not p.shared:
                    dst[p.chain_pos] = p.block
            slots[si] = self._insert_arena(si)(
                slots[si], request_cache["slots"][si],
                jnp.asarray(src), jnp.asarray(dst), jnp.asarray(backed))
        self.cache = {"slots": tuple(slots), "index": self.cache["index"]}
        req_state = {"slots": {si: request_cache["slots"][si]
                               for si in self._mamba_slots},
                     "index": request_cache["index"]}
        self._put_state(self._insert_state(
            self._state_tree(), req_state, slot,
            jnp.asarray(plen, jnp.int32)))

    def evict(self, slot: int):
        """Return the slot's blocks to the allocator and blank its
        slot-resident state. Arena contents of freed blocks are left as-is
        (unreachable: no table references them; re-allocation rewrites
        them fully, including positions, at the next insert)."""
        if not (0 <= slot < self.max_batch):
            raise IndexError(f"slot {slot} out of range [0, {self.max_batch})")
        self._dev_tables = None          # host tables changed: re-upload
        for m in self.maps.values():
            m.evict(slot)
        self._put_state(self._insert_state(
            self._state_tree(), self._blank_state, slot,
            jnp.asarray(0, jnp.int32)))

    # ---------------- lazy growth ----------------

    def grow(self, slot: int, row: int) -> bool:
        """Back logical `row` (the slot's next decode write) with a
        block in every attention slot-type, allocating on demand.

        Returns True when any map changed its table — a fresh block was
        allocated (its stale positions are buffered for invalidation) or
        a shared block was copy-on-write replaced at a ring wrap (the
        src -> dst content copy is buffered on the map's _pending_cow).
        flush_growth() MUST run before the next decode step either way.
        Raises NoBlocksError when some slot-type cannot allocate even
        after reclaiming retained blocks — the engine preempts a victim
        and retries; blocks grown by the partial attempt stay in the
        table (eviction returns them). Whole-chain (eager) slots always
        return False: every position is already backed."""
        grew = False
        for si, m in self.maps.items():
            n_cow = len(m._pending_cow)
            b = m.grow(slot, row)
            if len(m._pending_cow) != n_cow:
                grew = True       # COW: dst gets its pos FROM the copy —
                #                   it must NOT be invalidated
            elif b is not None:
                self._pending_grown[si].append(b)
                grew = True
        return grew

    def flush_growth(self):
        """Apply every table change grow() buffered since the last flush,
        then re-upload the changed block tables. Two fixed-shape jitted
        ops per slot-type, in this order:

        1. wrap-COW content copies (src -> dst over k/v/pos) — the dst
           block inherits the shared prompt rows it is about to start
           overwriting, so it must be populated BEFORE any invalidation
           and never position-invalidated itself;
        2. position invalidation of plainly-grown blocks (stale rows
           from previous occupants must read pos == -1).

        Vectors are padded with the null block to a multiple of
        max_batch: one grown block per slot per step is the non-
        speculative common case (compiled once), and a speculative
        K-row burst tops out at a small fixed number of shapes."""
        pending_cow = any(m._pending_cow for m in self.maps.values())
        if not pending_cow and not any(self._pending_grown.values()):
            return
        self._dev_tables = None          # host tables changed: re-upload
        slots = list(self.cache["slots"])
        for si, m in self.maps.items():
            if m._pending_cow:
                srcs, dsts = map(list, zip(*m._pending_cow))
                m._pending_cow.clear()
                n = -(-len(srcs) // self.max_batch) * self.max_batch
                sv = np.zeros(n, np.int32)
                dv = np.zeros(n, np.int32)
                sv[:len(srcs)] = srcs
                dv[:len(dsts)] = dsts
                slots[si] = {**slots[si], **self._copy_blocks(si)(
                    {k: slots[si][k] for k in ("k", "v", "pos")},
                    jnp.asarray(sv), jnp.asarray(dv))}
            grown = self._pending_grown[si]
            if grown:
                n = -(-len(grown) // self.max_batch) * self.max_batch
                vec = np.zeros(n, np.int32)
                vec[:len(grown)] = grown
                slots[si] = {**slots[si],
                             "pos": self._invalidate(si)(slots[si]["pos"],
                                                         jnp.asarray(vec))}
                self._pending_grown[si] = []
        self.cache = {"slots": tuple(slots), "index": self.cache["index"]}

    # ---------------- speculative rollback ----------------

    def rollback_rows(self, rows: dict, new_index, capacity: int):
        """Rewind after a speculative verify round: min-scatter position
        -1 over each slot's stale logical rows and replace the write
        cursors wholesale.

        rows: {slot: iterable of stale LOCAL row indices} — the rows the
          verify scatter wrote beyond the accepted prefix (q + n_emit ..
          q + K - 1). Rolling back is ONLY an invalidation: with the
          row_margin in place no future query row can still need the
          content those writes overwrote, so no block is copied or moved
          and sharing state is untouched.
        new_index: (max_batch,) host int32 — every slot's rewound cursor
          (q + n_emit for round participants, unchanged elsewhere). The
          device cursor advanced by K inside the verify step, so it is
          replaced even for slots whose rows all landed.
        capacity: fixed scatter width (>= total stale rows; the engine
          passes max_batch * spec_k) so the op compiles once."""
        total = sum(len(r) for r in rows.values())
        assert total <= capacity, (total, capacity)
        slots = list(self.cache["slots"])
        for si, m in self.maps.items():
            blks = np.zeros(capacity, np.int32)
            offs = np.zeros(capacity, np.int32)
            vals = np.full(capacity, np.iinfo(np.int32).max, np.int32)
            n = 0
            for slot, rws in rows.items():
                for r in rws:
                    rr = r % m.ring_len
                    blks[n] = m.table[slot, rr // m.block_size]
                    offs[n] = rr % m.block_size
                    vals[n] = -1
                    n += 1
            slots[si] = {**slots[si], "pos": self._rollback(si)(
                slots[si]["pos"], jnp.asarray(blks), jnp.asarray(offs),
                jnp.asarray(vals))}
        self.cache = {"slots": tuple(slots),
                      "index": jnp.asarray(np.asarray(new_index, np.int32))}

    @property
    def retained_hits(self) -> int:
        """Warm prefix blocks revived from the retained LRU (content
        survived refcount 0) across all slot-types."""
        return sum(m.retained_hits for m in self.maps.values())

    @property
    def prefix_misses(self) -> int:
        """Registered prefix blocks that had to be freshly written (no
        live share, no warm revival) across all slot-types — the misses
        to retained_hits' hits."""
        return sum(m.prefix_misses for m in self.maps.values())

    @property
    def retained_hit_rate(self) -> float:
        """retained_hits / (retained_hits + prefix_misses): the fraction
        of shareable-prefix block demand the retained LRU served warm.
        The retain_blocks sizing signal (docs/serving.md)."""
        from repro.serving.metrics import hit_rate
        return hit_rate(self.retained_hits, self.prefix_misses)

    def retained_blocks(self) -> dict:
        """Currently parked warm blocks per attention slot-type."""
        return {si: m.n_retained for si, m in self.maps.items()}

    def lengths(self):
        """Per-slot LOCAL token counts (host array) — diagnostic only."""
        return np.asarray(self.cache["index"])

    def check_invariants(self):
        """Assert every slot-type's allocator/table/registry invariants
        (see BlockTableMap.check_invariants) — test hook."""
        for m in self.maps.values():
            m.check_invariants()


def frames_key(frames, padded_frames: int):
    """Content key for one encoder input, in BlockTableMap token form.

    The registry's incremental chain hash keys DECODER prompts by their
    token prefix; an encoder input has no tokens, and its cross K/V only
    ever match another request's when the WHOLE input is identical (every
    frame feeds every cross block through the encoder's global
    attention). So the key is the sha256 of the raw frame bytes, spread
    over `padded_frames` int64 pseudo-tokens: every chain block of the
    same input hashes identically, and two inputs differing anywhere
    share nothing — block granularity collapses to whole-input identity,
    which is exactly the beams/retries sharing the tentpole wants."""
    d = hashlib.sha256(
        np.ascontiguousarray(frames, np.float32).tobytes()).digest()
    return np.resize(np.frombuffer(d, np.int64), padded_frames)


def _cross_insert(arena: PyTree, ck, cv, dst_blocks, pos_rows) -> PyTree:
    """Write one request's FRESH cross-attention blocks into the arena.

    arena: {"k","v"} (n_layers, n_blocks, bs, H, hd) + "pos"
           (n_blocks, bs) — pos carries no layer dim (frame positions
           are layer-invariant).
    ck/cv: (n_layers, Sm, H, hd) dense projections from the admission
           prefill, zero-padded here to the blocked length (pad rows get
           pos -1 and never attend).
    dst_blocks (max_blocks,): arena block per chain position, NULL (0)
           for shared positions — their writes land in the null block,
           whose pos_rows entries are -1, keeping it invalid.
    pos_rows (max_blocks, bs): frame position per written row, -1 for
           pads and null-routed rows.
    """
    nbk = dst_blocks.shape[0]
    bs = arena["k"].shape[2]
    pad = nbk * bs - ck.shape[1]

    def blocks_of(x, dtype):
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        return x.reshape(x.shape[0], nbk, bs, *x.shape[2:]).astype(dtype)

    return {"k": arena["k"].at[:, dst_blocks].set(
                blocks_of(ck, arena["k"].dtype)),
            "v": arena["v"].at[:, dst_blocks].set(
                blocks_of(cv, arena["v"].dtype)),
            "pos": arena["pos"].at[dst_blocks].set(pos_rows)}


class EncDecCachePool:
    """Pooled serving cache for the encoder-decoder family.

    SELF-attention KV is dense per-slot (the CachePool layout: encdec
    decode budgets are short), but CROSS-attention K/V — one encoder
    pass's projections, read-only for the request's whole lifetime —
    live in a refcounted, content-addressed block arena keyed by a
    digest of the raw input frames (frames_key). Two requests decoding
    the SAME input (beams, retries, resends) share the encoder blocks
    instead of copying them, exactly like shared prompt prefixes in
    PagedCachePool: the second insert's placements come back
    shared=True and the blocks' refcounts bump to 2. retain_blocks
    parks a fully-drained input's blocks on the warm LRU, so a
    follow-up request revives them copy-free (no re-encode write).

    The device cache is ONE pytree the jitted decode step consumes and
    passes through donated (arenas and table never round-trip the host
    between mutations):
      {"slots": {"self": (L, B, rows, ...) KV}, "index": (B,),
       "cross": {"k"/"v": (L, n_blocks+1, bs, H, hd),
                 "pos": (n_blocks+1, bs), "table": (B, max_blocks)}}
    """

    def __init__(self, arch, max_batch: int, max_len: int, *,
                 block_size: int = 16, slots_budget: Optional[int] = None,
                 share_prefix: bool = True, retain_blocks: int = 0,
                 mesh=None):
        if arch.kind != "encdec":
            raise ValueError(
                f"EncDecCachePool needs an encdec arch, got {arch.kind}")
        cfg = arch.cfg
        self.arch = arch
        self.max_batch = max_batch
        self.max_len = max_len
        self.block_size = block_size
        self.share_prefix = share_prefix
        self.n_frames = cfg.n_frames
        self.padded_frames = -(-cfg.n_frames // block_size) * block_size
        budget = slots_budget if slots_budget is not None else max_batch
        n_blocks = budget * (self.padded_frames // block_size)
        # budget=1, plen=padded_len=ring_len=padded_frames: no decode
        # rows ever overwrite the chain and the layout is never rolled,
        # so EVERY block is content-keyed and shareable.
        self.map = BlockTableMap(
            max_batch, self.padded_frames, block_size, n_blocks + 1,
            retain_limit=min(retain_blocks, max(n_blocks - 1, 0)),
            src_len=self.padded_frames)
        cache = arch.init_cache(max_batch, max_len, per_slot=True)
        L, H, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
        dt = cfg.compute_dtype
        cache["cross"] = {
            "k": jnp.zeros((L, n_blocks + 1, block_size, H, hd), dt),
            "v": jnp.zeros((L, n_blocks + 1, block_size, H, hd), dt),
            "pos": jnp.full((n_blocks + 1, block_size), -1, jnp.int32),
            "table": jnp.asarray(self.map.table),
        }
        self._blank = arch.init_cache(1, max_len, per_slot=True)
        self.mesh = _live_mesh(mesh)
        if self.mesh is None:
            self._shardings = None
            self._insert = jax.jit(_insert_row, donate_argnums=0)
            self._cross = jax.jit(_cross_insert, donate_argnums=0)
        else:
            sh = shd.cache_shardings(jax.eval_shape(lambda: cache),
                                     self.mesh)
            self._shardings = sh
            cache = jax.device_put(cache, sh)
            self._insert = jax.jit(
                _insert_row, donate_argnums=0,
                out_shardings={"slots": sh["slots"], "index": sh["index"]})
            self._cross = jax.jit(
                _cross_insert, donate_argnums=0,
                out_shardings={n: sh["cross"][n]
                               for n in ("k", "v", "pos")})
        self.cache = cache
        self.shared_hits = 0   # cross blocks reused instead of re-encoded

    def _table_device(self):
        if self.mesh is None:
            return jnp.asarray(self.map.table)
        return jax.device_put(np.ascontiguousarray(self.map.table),
                              self._shardings["cross"]["table"])

    # ---------------- admission ----------------

    def admission_plan(self, frames) -> dict:
        """{"cross": fresh blocks + retained revivals} an insert of this
        input would consume — the engine's admission gate compares it
        against admissible_blocks()."""
        key = frames_key(frames, self.padded_frames)
        return {"cross": sum(self.map.admission_plan(
            key, self.padded_frames, self.padded_frames, 1,
            self.share_prefix))}

    def admissible_blocks(self) -> dict:
        return {"cross": self.map.admissible()}

    def free_blocks(self) -> dict:
        return {"cross": self.map.alloc.n_free}

    def insert(self, request_cache: PyTree, slot: int, *, frames,
               cross_k, cross_v):
        """Admit one prefilled request: reserve/retain its cross block
        chain, write the fresh blocks (shared placements skip the write
        entirely — the arena content is already there), and land the
        self-attention rows. Atomic: on NoBlocksError nothing is left
        allocated and the device cache is untouched.

        request_cache: {"slots","index"} batch-1 slice of the admission
          prefill cache. cross_k/cross_v: (L, Sm, H, hd) the request's
          dense cross projections (the prefill cache's "cross" leaves
          sliced on the batch axis). frames: the raw (n_frames, d)
          input, used only for content keying."""
        if not (0 <= slot < self.max_batch):
            raise IndexError(f"slot {slot} out of range [0, {self.max_batch})")
        key = frames_key(frames, self.padded_frames)
        placed = self.map.insert(slot, key, self.padded_frames,
                                 self.padded_frames, 1, self.share_prefix)
        self.shared_hits += sum(p.shared for p in placed)
        dst = np.zeros(self.map.max_blocks, np.int32)
        for p in placed:
            if not p.shared and not p.revived:
                dst[p.chain_pos] = p.block
        selfpart = self._insert(
            {"slots": self.cache["slots"], "index": self.cache["index"]},
            request_cache, slot)
        cross = self.cache["cross"]
        if dst.any():
            bs = self.block_size
            rows = np.arange(self.map.max_blocks * bs,
                             dtype=np.int32).reshape(-1, bs)
            pos_rows = np.where((dst != 0)[:, None] & (rows < self.n_frames),
                                rows, -1).astype(np.int32)
            arena = self._cross({n: cross[n] for n in ("k", "v", "pos")},
                                cross_k, cross_v, jnp.asarray(dst),
                                jnp.asarray(pos_rows))
            cross = dict(arena)
        else:
            cross = {n: cross[n] for n in ("k", "v", "pos")}
        cross["table"] = self._table_device()
        self.cache = {**selfpart, "cross": cross}

    def evict(self, slot: int):
        """Release the slot's cross blocks (last holder parks them warm
        when retention is on) and blank its self-attention rows."""
        if not (0 <= slot < self.max_batch):
            raise IndexError(f"slot {slot} out of range [0, {self.max_batch})")
        self.map.evict(slot)
        selfpart = self._insert(
            {"slots": self.cache["slots"], "index": self.cache["index"]},
            self._blank, slot)
        cross = {n: self.cache["cross"][n] for n in ("k", "v", "pos")}
        cross["table"] = self._table_device()
        self.cache = {**selfpart, "cross": cross}

    # ---------------- introspection ----------------

    def lengths(self):
        """Per-slot write cursors (host array) — diagnostic only."""
        return np.asarray(self.cache["index"])

    @property
    def retained_hits(self) -> int:
        return self.map.retained_hits

    @property
    def prefix_misses(self) -> int:
        return self.map.prefix_misses

    @property
    def retained_hit_rate(self) -> float:
        from repro.serving.metrics import hit_rate
        return hit_rate(self.retained_hits, self.prefix_misses)

    def retained_blocks(self) -> dict:
        return {"cross": self.map.n_retained}

    def check_invariants(self):
        """Assert the cross map's allocator/table/registry invariants
        (see BlockTableMap.check_invariants) — test hook."""
        self.map.check_invariants()

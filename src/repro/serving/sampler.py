"""Token sampling for the serving decode step.

One frozen `Sampler` config is baked into the jitted decode step as a
static closure (it never changes for the engine's lifetime); the only
per-step input is a `(B, 2)` uint32 array of per-slot PRNG keys. The
engines derive those keys deterministically —

    request key   = fold_in(PRNGKey(sampler.seed), request.rid)
    token-t key   = fold_in(request key, t)

— so the sampled stream is a pure function of (seed, rid, token index):
independent of slot placement, admission order, batched-vs-single
prefill, and of whichever other requests happen to share the batch.
That is what makes the continuous, paged and static engines
token-identical under sampling, and two runs of the same workload
byte-reproducible (asserted in tests/test_sampling.py).

`temperature == 0` short-circuits to argmax — bit-exact greedy, the same
computation `greedy_next` performs — so `--sampler temperature=0`
degrades to the PR 2 greedy path by construction.

`stable=1` arms a tie-tolerant greedy argmax for bf16 cross-layout
differentials: two execution layouts (dense vs paged gather, chunked vs
whole prefill) can legitimately round a logit one ulp apart, and when
the two top logits sit within that ulp, plain argmax flips the token on
layout alone. `stable_argmax` treats every logit within one bf16 ulp of
the max as tied and picks the LOWEST index — the same winner under
either rounding — so cross-layout differential gates can pin bf16 runs
too (docs/serving.md).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# one bf16 unit-in-last-place at magnitude ~1 (8-bit mantissa including
# the hidden bit): the largest layout-induced wobble a single logit can
# pick up from a bf16 rounding difference.
BF16_EPS = 2.0 ** -7


def stable_argmax(logits):
    """(B, V) fp32 -> (B,) int32: lowest index within one bf16 ulp of
    the row max. Ties broken by INDEX, not by sub-ulp noise, so the
    winner is invariant to one-ulp cross-layout rounding differences."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    band = BF16_EPS * jnp.maximum(jnp.abs(m), 1.0)
    tied = logits >= m - band
    idx = jnp.arange(logits.shape[-1], dtype=jnp.int32)
    v = jnp.int32(logits.shape[-1])
    return jnp.min(jnp.where(tied, idx, v), axis=-1).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class Sampler:
    """temperature / top-k / top-p sampling with per-slot PRNG keys."""
    temperature: float = 1.0
    top_k: int = 0          # 0 disables
    top_p: float = 1.0      # 1.0 disables
    seed: int = 0
    stable_tiebreak: bool = False   # greedy: bf16-ulp tie band, min index

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0

    @classmethod
    def parse(cls, spec) -> "Sampler":
        """"greedy" | "k=v,..." with keys temperature/top_k/top_p/seed/
        stable, e.g. --sampler temperature=0,stable=1 (greedy with the
        bf16 tie-tolerant argmax)."""
        if spec is None or isinstance(spec, Sampler):
            return spec
        if spec == "greedy":
            return cls(temperature=0.0)
        kwargs = {}
        for part in spec.split(","):
            k, _, v = part.partition("=")
            if not _:
                raise ValueError(f"bad sampler spec item {part!r}")
            k = k.strip()
            if k not in ("temperature", "top_k", "top_p", "seed", "stable"):
                raise ValueError(f"unknown sampler key {k!r}")
            if k == "stable":
                kwargs["stable_tiebreak"] = bool(int(v))
            else:
                kwargs[k] = int(v) if k in ("top_k", "seed") else float(v)
        return cls(**kwargs)

    def sample(self, logits, keys):
        """logits (B, V) fp32, keys (B, 2) uint32 -> (B,) int32 tokens.

        Masking happens in logit space before one categorical draw per
        row, so a token's probability under top-k/top-p is exactly the
        renormalized softmax over the kept set.
        """
        if self.greedy:
            if self.stable_tiebreak:
                return stable_argmax(logits)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t = logits / jnp.float32(self.temperature)
        top_k = min(self.top_k, logits.shape[-1])  # k >= vocab: keep all
        if top_k:
            kth = jnp.sort(t, axis=-1)[..., -top_k, None]
            t = jnp.where(t < kth, -jnp.inf, t)
        if self.top_p < 1.0:
            srt = jnp.sort(t, axis=-1)[..., ::-1]          # descending
            probs = jax.nn.softmax(srt, axis=-1)
            cum = jnp.cumsum(probs, axis=-1)
            # keep the smallest prefix whose mass reaches top_p (the
            # first token always survives: cum - probs is 0 there)
            keep = (cum - probs) < self.top_p
            thr = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1,
                          keepdims=True)
            t = jnp.where(t < thr, -jnp.inf, t)
        draw = jax.vmap(lambda k, row: jax.random.categorical(k, row))
        return draw(keys, t).astype(jnp.int32)

    # ---------------- key derivation (host side, both engines) ----------

    def request_key(self, rid: int):
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), rid)


def fold_keys(request_keys, token_indices):
    """(B, 2) request keys + (B,) token indices -> (B, 2) step keys."""
    return jax.vmap(jax.random.fold_in)(request_keys, token_indices)

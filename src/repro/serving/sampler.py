"""Token sampling for the serving decode step.

One frozen `Sampler` config is baked into the jitted decode step as a
static closure (it never changes for the engine's lifetime); the only
per-step input is a `(B, 2)` uint32 array of per-slot PRNG keys. The
engines derive those keys deterministically —

    request key   = fold_in(PRNGKey(sampler.seed), request.rid)
    token-t key   = fold_in(request key, t)

— so the sampled stream is a pure function of (seed, rid, token index):
independent of slot placement, admission order, batched-vs-single
prefill, and of whichever other requests happen to share the batch.
That is what makes the continuous, paged and static engines
token-identical under sampling, and two runs of the same workload
byte-reproducible (asserted in tests/test_sampling.py).

`temperature == 0` short-circuits to argmax — bit-exact greedy, the same
computation `greedy_next` performs — so `--sampler temperature=0`
degrades to the PR 2 greedy path by construction.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Sampler:
    """temperature / top-k / top-p sampling with per-slot PRNG keys."""
    temperature: float = 1.0
    top_k: int = 0          # 0 disables
    top_p: float = 1.0      # 1.0 disables
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0

    @classmethod
    def parse(cls, spec) -> "Sampler":
        """"greedy" | "k=v,..." with keys temperature/top_k/top_p/seed,
        e.g. --sampler temperature=0.8,top_k=40,top_p=0.95,seed=1."""
        if spec is None or isinstance(spec, Sampler):
            return spec
        if spec == "greedy":
            return cls(temperature=0.0)
        kwargs = {}
        for part in spec.split(","):
            k, _, v = part.partition("=")
            if not _:
                raise ValueError(f"bad sampler spec item {part!r}")
            k = k.strip()
            if k not in ("temperature", "top_k", "top_p", "seed"):
                raise ValueError(f"unknown sampler key {k!r}")
            kwargs[k] = int(v) if k in ("top_k", "seed") else float(v)
        return cls(**kwargs)

    def sample(self, logits, keys):
        """logits (B, V) fp32, keys (B, 2) uint32 -> (B,) int32 tokens.

        Masking happens in logit space before one categorical draw per
        row, so a token's probability under top-k/top-p is exactly the
        renormalized softmax over the kept set.
        """
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t = logits / jnp.float32(self.temperature)
        top_k = min(self.top_k, logits.shape[-1])  # k >= vocab: keep all
        if top_k:
            kth = jnp.sort(t, axis=-1)[..., -top_k, None]
            t = jnp.where(t < kth, -jnp.inf, t)
        if self.top_p < 1.0:
            srt = jnp.sort(t, axis=-1)[..., ::-1]          # descending
            probs = jax.nn.softmax(srt, axis=-1)
            cum = jnp.cumsum(probs, axis=-1)
            # keep the smallest prefix whose mass reaches top_p (the
            # first token always survives: cum - probs is 0 there)
            keep = (cum - probs) < self.top_p
            thr = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1,
                          keepdims=True)
            t = jnp.where(t < thr, -jnp.inf, t)
        draw = jax.vmap(lambda k, row: jax.random.categorical(k, row))
        return draw(keys, t).astype(jnp.int32)

    # ---------------- key derivation (host side, both engines) ----------

    def request_key(self, rid: int):
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), rid)


def fold_keys(request_keys, token_indices):
    """(B, 2) request keys + (B,) token indices -> (B, 2) step keys."""
    return jax.vmap(jax.random.fold_in)(request_keys, token_indices)

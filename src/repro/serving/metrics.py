"""Serving-side latency/throughput accounting.

Each request carries a `RequestTrace` of wall-clock events: submission,
admission (prefill done, first token available) and one timestamp per
generated token. `aggregate()` folds a set of traces into the numbers a
serving dashboard wants:

  tokens_per_s   generated tokens / wall
  ttft_*_ms      time-to-first-token percentiles (submit -> first token)
  itl_*_ms       inter-token latency percentiles (gaps between tokens of
                 the same request — the per-token latency of the decode
                 loop, which is what slot reuse and low-precision decode
                 are meant to shrink)

No jnp here: this is pure host bookkeeping and must stay off the decode
hot path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class RequestTrace:
    submit_t: float = 0.0
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None
    token_ts: List[float] = dataclasses.field(default_factory=list)

    def mark_submit(self, now=None):
        self.submit_t = time.perf_counter() if now is None else now

    def mark_token(self, now=None):
        now = time.perf_counter() if now is None else now
        if self.first_token_t is None:
            self.first_token_t = now
        self.token_ts.append(now)

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def inter_token_s(self) -> List[float]:
        return [b - a for a, b in zip(self.token_ts, self.token_ts[1:])]


def percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile; 0.0 on empty input (keeps JSON simple)."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    k = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[k]


def aggregate(traces: List[RequestTrace], wall_s: float,
              n_tokens: int) -> Dict[str, float]:
    ttfts = [t.ttft_s for t in traces if t.ttft_s is not None]
    itls: List[float] = []
    for t in traces:
        itls.extend(t.inter_token_s)
    return {
        "requests": len(traces),
        "tokens": n_tokens,
        "wall_s": wall_s,
        "tokens_per_s": n_tokens / wall_s if wall_s > 0 else 0.0,
        "ttft_p50_ms": percentile(ttfts, 50) * 1e3,
        "ttft_p99_ms": percentile(ttfts, 99) * 1e3,
        "itl_p50_ms": percentile(itls, 50) * 1e3,
        "itl_p99_ms": percentile(itls, 99) * 1e3,
    }

"""Serving-side latency/throughput accounting.

Each request carries a `RequestTrace` of wall-clock events: submission,
admission (prefill done, first token available) and one timestamp per
generated token. `aggregate()` folds a set of traces into the numbers a
serving dashboard wants:

  tokens_per_s   generated tokens / wall
  ttft_*_ms      time-to-first-token percentiles (submit -> first token;
                 includes queue wait, so under bursty arrivals this is
                 the number scheduling policy changes move)
  itl_*_ms       inter-token latency percentiles (gaps between tokens of
                 the same request — the per-token latency of the decode
                 loop, which is what slot reuse and low-precision decode
                 are meant to shrink)

The trace also counts scheduler interventions per request: `preemptions`
(times the request was evicted mid-decode and requeued as a continuation
prefill) and `evicted_slo` (the slot blew its SLO and was finished
early with the tokens it had). `DepthTracker` folds per-step queue-depth
samples into max/mean/p50 — the congestion signal the policy-driven
scheduler reports next to TTFT.

No jnp here: this is pure host bookkeeping and must stay off the decode
hot path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class RequestTrace:
    submit_t: float = 0.0
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None
    token_ts: List[float] = dataclasses.field(default_factory=list)
    preemptions: int = 0        # mid-decode evict + continuation requeues
    evicted_slo: bool = False   # finished early by SLO eviction

    def mark_submit(self, now=None):
        self.submit_t = time.perf_counter() if now is None else now

    def mark_token(self, now=None):
        now = time.perf_counter() if now is None else now
        if self.first_token_t is None:
            self.first_token_t = now
        self.token_ts.append(now)

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def inter_token_s(self) -> List[float]:
        return [b - a for a, b in zip(self.token_ts, self.token_ts[1:])]


def percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile; 0.0 on empty input (keeps JSON simple)."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    k = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[k]


def hit_rate(hits: int, misses: int) -> float:
    """hits / (hits + misses); 0.0 when there was no demand at all.
    Used for the retained-prefix LRU telemetry (cache_pool)."""
    total = hits + misses
    return hits / total if total > 0 else 0.0


class DepthTracker:
    """Folds per-step queue-depth samples into max/mean/p50 with O(1)
    memory per sample: max/sum/count stream, and the p50 reads a
    bounded ring of the most recent samples (a long-lived engine takes
    one sample per decode step forever — an unbounded list would be a
    slow leak, and recent depth is the operationally relevant median
    anyway)."""

    RING = 4096        # p50 window; max/mean remain exact over all time

    def __init__(self):
        self.count = 0
        self.total = 0
        self.peak = 0
        self._ring: List[int] = [0] * self.RING
        self._i = 0

    def sample(self, depth: int):
        depth = int(depth)
        self.count += 1
        self.total += depth
        if depth > self.peak:
            self.peak = depth
        self._ring[self._i % self.RING] = depth
        self._i += 1

    def stats(self, prefix: str = "queue_depth") -> Dict[str, float]:
        recent = self._ring[:min(self.count, self.RING)]
        return {
            f"{prefix}_max": self.peak,
            f"{prefix}_mean": self.total / self.count if self.count else 0.0,
            f"{prefix}_p50": percentile([float(x) for x in recent], 50),
        }


def aggregate(traces: List[RequestTrace], wall_s: float,
              n_tokens: int) -> Dict[str, float]:
    ttfts = [t.ttft_s for t in traces if t.ttft_s is not None]
    itls: List[float] = []
    for t in traces:
        itls.extend(t.inter_token_s)
    return {
        "requests": len(traces),
        "tokens": n_tokens,
        "wall_s": wall_s,
        "tokens_per_s": n_tokens / wall_s if wall_s > 0 else 0.0,
        "ttft_p50_ms": percentile(ttfts, 50) * 1e3,
        "ttft_p99_ms": percentile(ttfts, 99) * 1e3,
        "itl_p50_ms": percentile(itls, 50) * 1e3,
        "itl_p99_ms": percentile(itls, 99) * 1e3,
        "preemptions": sum(t.preemptions for t in traces),
        "slo_evictions": sum(1 for t in traces if t.evicted_slo),
    }

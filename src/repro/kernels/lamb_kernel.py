"""Pallas TPU kernels for the fused LAMB baseline (Algorithm 1).

Two phases (LAMB needs no gradient-norm pre-pass — only the trust-ratio
norms, which depend on the *updated* moments):

  phase 1  lamb_phase1 : update m, v; emit partial sums-of-squares of
                         u = r + lam*x and of x
  phase 2  lamb_phase2 : trust = ||x|| / ||u||; x <- x - eta * trust * u

Global gradient clipping (a cross-block quantity) is the caller's job and is
folded into the scalar `clip` operand so the kernel stays single-block.
Scalars layout: [bc1, bc2, eta, lam, trust_flag, clip, 0, 0].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.lans_kernel import LANES, TILE_ROWS, _guarded_inv


def _lamb_phase1_kernel(scal_ref, g_ref, m_ref, v_ref, x_ref,
                        m_out, v_out, part_out, *, beta1, beta2, eps):
    i = pl.program_id(0)
    bc1 = scal_ref[0, 0]
    bc2 = scal_ref[0, 1]
    lam = scal_ref[0, 3]
    clip = scal_ref[0, 5]

    g = g_ref[...].astype(jnp.float32) * clip
    m = m_ref[...]
    v = v_ref[...]
    x = x_ref[...].astype(jnp.float32)

    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * (g * g)
    m_out[...] = m_new
    v_out[...] = v_new

    r = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    u = r + lam * x

    @pl.when(i == 0)
    def _init():
        part_out[...] = jnp.zeros_like(part_out)

    part_out[0, 0] += jnp.sum(u * u)
    part_out[0, 1] += jnp.sum(x * x)


def lamb_phase1(scalars, g2d, m2d, v2d, x2d, *, beta1, beta2, eps,
                interpret: bool = True):
    rows, lanes = g2d.shape
    assert lanes == LANES and rows % TILE_ROWS == 0
    grid = (rows // TILE_ROWS,)
    tile = pl.BlockSpec((TILE_ROWS, LANES), lambda i: (i, 0))
    kern = functools.partial(_lamb_phase1_kernel, beta1=beta1, beta2=beta2, eps=eps)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 8), lambda i: (0, 0)), tile, tile, tile, tile],
        out_specs=[tile, tile, pl.BlockSpec((1, 8), lambda i: (0, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((1, 8), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, g2d, m2d, v2d, x2d)


def _lamb_phase2_kernel(scal_ref, norm_ref, m_ref, v_ref, x_ref, x_out,
                        *, beta1, beta2, eps):
    del beta1, beta2
    bc1 = scal_ref[0, 0]
    bc2 = scal_ref[0, 1]
    eta = scal_ref[0, 2]
    lam = scal_ref[0, 3]
    trust_flag = scal_ref[0, 4]

    u_sq = norm_ref[0, 0]
    x_sq = norm_ref[0, 1]

    m = m_ref[...]
    v = v_ref[...]
    x = x_ref[...].astype(jnp.float32)

    r = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    u = r + lam * x

    x_norm = jnp.sqrt(x_sq)
    trust = jnp.where(u_sq > 0.0, x_norm * _guarded_inv(u_sq), 1.0)
    trust = jnp.where(trust_flag > 0.0, trust, 1.0)

    x_out[...] = (x - eta * trust * u).astype(x_out.dtype)


def lamb_phase2(scalars, norms, m2d, v2d, x2d, *, beta1, beta2, eps,
                interpret: bool = True):
    rows, lanes = m2d.shape
    assert lanes == LANES and rows % TILE_ROWS == 0
    grid = (rows // TILE_ROWS,)
    tile = pl.BlockSpec((TILE_ROWS, LANES), lambda i: (i, 0))
    kern = functools.partial(_lamb_phase2_kernel, beta1=beta1, beta2=beta2, eps=eps)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            tile, tile, tile,
        ],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), x2d.dtype),
        interpret=interpret,
    )(scalars, norms, m2d, v2d, x2d)

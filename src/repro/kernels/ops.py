"""jit'd public wrappers over the fused optimizer kernels.

Handles the HBM layout contract for the kernels: every parameter tensor is
flattened, zero-padded to a multiple of (TILE_ROWS * 128) elements and viewed
as (rows, 128). Zero padding is exact for every phase (padded lanes carry
g = m = v = x = 0, contributing nothing to any norm and receiving a zero
update), so no masking pass is needed.

`interpret` defaults to True: this container is CPU-only; on real TPU call
with interpret=False.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import lamb_kernel, lans_kernel
from repro.kernels.lans_kernel import LANES, TILE_ROWS
from repro.kernels.ref import StepOut

_CHUNK = TILE_ROWS * LANES


def _to_tiles(x: jnp.ndarray) -> tuple:
    """Flatten + zero-pad to (rows, 128); returns (tiles, orig_size)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    padded = (n + _CHUNK - 1) // _CHUNK * _CHUNK
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(-1, LANES), n


def _from_tiles(t2d: jnp.ndarray, n: int, shape, dtype) -> jnp.ndarray:
    return t2d.reshape(-1)[:n].reshape(shape).astype(dtype)


@functools.partial(
    jax.jit,
    static_argnames=("beta1", "beta2", "eps", "lam", "apply_trust", "interpret"),
)
def fused_lans_step(
    g, m, v, x, *, eta, step,
    beta1: float = 0.9, beta2: float = 0.999, eps: float = 1e-6,
    lam: float = 0.01, apply_trust: bool = True, interpret: bool = True,
) -> StepOut:
    """One fused LANS update for a single parameter tensor (any shape/dtype).

    ``step`` is the 1-indexed iteration (traced ok); ``eta`` traced ok.
    Returns StepOut(x_new, m_new, v_new) with x_new in x.dtype, moments fp32.
    """
    g2d, n = _to_tiles(g)
    m2d, _ = _to_tiles(m)
    v2d, _ = _to_tiles(v)
    x2d, _ = _to_tiles(x)

    stepf = jnp.asarray(step, jnp.float32)
    bc1 = 1.0 - jnp.power(jnp.float32(beta1), stepf)
    bc2 = 1.0 - jnp.power(jnp.float32(beta2), stepf)

    g_sq = lans_kernel.sq_norm(g2d, interpret=interpret)

    scalars = jnp.zeros((1, 8), jnp.float32)
    scalars = scalars.at[0, 0].set(bc1)
    scalars = scalars.at[0, 1].set(bc2)
    scalars = scalars.at[0, 2].set(jnp.asarray(eta, jnp.float32))
    scalars = scalars.at[0, 3].set(jnp.float32(lam))
    scalars = scalars.at[0, 4].set(jnp.float32(1.0 if apply_trust else 0.0))
    scalars = scalars.at[0, 5].set(g_sq)

    m_new, v_new, partials = lans_kernel.lans_phase1(
        scalars, g2d, m2d, v2d, x2d,
        beta1=beta1, beta2=beta2, eps=eps, interpret=interpret)

    x_new2d = lans_kernel.lans_phase2(
        scalars, partials, g2d, m_new, v_new, x2d,
        beta1=beta1, beta2=beta2, eps=eps, interpret=interpret)

    return StepOut(
        _from_tiles(x_new2d, n, x.shape, x.dtype),
        _from_tiles(m_new, n, m.shape, jnp.float32),
        _from_tiles(v_new, n, v.shape, jnp.float32),
    )


class MixedStepOut(NamedTuple):
    """fused_lans_mixed_step result: fp32 master + low-precision copy."""

    x: jnp.ndarray     # new master weights, fp32
    m: jnp.ndarray     # new first moment, fp32
    v: jnp.ndarray     # new second moment, fp32
    x_lp: jnp.ndarray  # new model copy, lp_dtype (cast fused into phase 2)


@functools.partial(
    jax.jit,
    static_argnames=("lp_dtype", "beta1", "beta2", "eps", "lam",
                     "apply_trust", "interpret"),
)
def fused_lans_mixed_step(
    g, m, v, x, *, eta, step, lp_dtype,
    beta1: float = 0.9, beta2: float = 0.999, eps: float = 1e-6,
    lam: float = 0.01, apply_trust: bool = True, interpret: bool = True,
) -> MixedStepOut:
    """Fused LANS step on fp32 master `x` that ALSO emits the lp_dtype model
    copy from the same phase-2 pass — the cast-and-apply path mixed-precision
    training runs every step (no separate cast kernel / extra HBM read)."""
    g2d, n = _to_tiles(g)
    m2d, _ = _to_tiles(m)
    v2d, _ = _to_tiles(v)
    x2d, _ = _to_tiles(x)

    stepf = jnp.asarray(step, jnp.float32)
    bc1 = 1.0 - jnp.power(jnp.float32(beta1), stepf)
    bc2 = 1.0 - jnp.power(jnp.float32(beta2), stepf)

    g_sq = lans_kernel.sq_norm(g2d, interpret=interpret)

    scalars = jnp.zeros((1, 8), jnp.float32)
    scalars = scalars.at[0, 0].set(bc1)
    scalars = scalars.at[0, 1].set(bc2)
    scalars = scalars.at[0, 2].set(jnp.asarray(eta, jnp.float32))
    scalars = scalars.at[0, 3].set(jnp.float32(lam))
    scalars = scalars.at[0, 4].set(jnp.float32(1.0 if apply_trust else 0.0))
    scalars = scalars.at[0, 5].set(g_sq)

    m_new, v_new, partials = lans_kernel.lans_phase1(
        scalars, g2d, m2d, v2d, x2d,
        beta1=beta1, beta2=beta2, eps=eps, interpret=interpret)

    x_new2d, x_lp2d = lans_kernel.lans_phase2_cast(
        scalars, partials, g2d, m_new, v_new, x2d,
        lp_dtype=lp_dtype, beta1=beta1, beta2=beta2, eps=eps,
        interpret=interpret)

    return MixedStepOut(
        _from_tiles(x_new2d, n, x.shape, jnp.float32),
        _from_tiles(m_new, n, m.shape, jnp.float32),
        _from_tiles(v_new, n, v.shape, jnp.float32),
        _from_tiles(x_lp2d, n, x.shape, lp_dtype),
    )


@functools.partial(
    jax.jit,
    static_argnames=("beta1", "beta2", "eps", "lam", "apply_trust", "interpret"),
)
def fused_lamb_step(
    g, m, v, x, *, eta, step, clip=1.0,
    beta1: float = 0.9, beta2: float = 0.999, eps: float = 1e-6,
    lam: float = 0.01, apply_trust: bool = True, interpret: bool = True,
) -> StepOut:
    """One fused LAMB update; ``clip`` is the caller-computed global-clip factor."""
    g2d, n = _to_tiles(g)
    m2d, _ = _to_tiles(m)
    v2d, _ = _to_tiles(v)
    x2d, _ = _to_tiles(x)

    stepf = jnp.asarray(step, jnp.float32)
    bc1 = 1.0 - jnp.power(jnp.float32(beta1), stepf)
    bc2 = 1.0 - jnp.power(jnp.float32(beta2), stepf)

    scalars = jnp.zeros((1, 8), jnp.float32)
    scalars = scalars.at[0, 0].set(bc1)
    scalars = scalars.at[0, 1].set(bc2)
    scalars = scalars.at[0, 2].set(jnp.asarray(eta, jnp.float32))
    scalars = scalars.at[0, 3].set(jnp.float32(lam))
    scalars = scalars.at[0, 4].set(jnp.float32(1.0 if apply_trust else 0.0))
    scalars = scalars.at[0, 5].set(jnp.asarray(clip, jnp.float32))

    m_new, v_new, partials = lamb_kernel.lamb_phase1(
        scalars, g2d, m2d, v2d, x2d,
        beta1=beta1, beta2=beta2, eps=eps, interpret=interpret)

    x_new2d = lamb_kernel.lamb_phase2(
        scalars, partials, m_new, v_new, x2d,
        beta1=beta1, beta2=beta2, eps=eps, interpret=interpret)

    return StepOut(
        _from_tiles(x_new2d, n, x.shape, x.dtype),
        _from_tiles(m_new, n, m.shape, jnp.float32),
        _from_tiles(v_new, n, v.shape, jnp.float32),
    )


def block_sq_norm(x, *, interpret: bool = True) -> jnp.ndarray:
    """Kernel-backed sum-of-squares for arbitrary-shape tensors."""
    x2d, _ = _to_tiles(x)
    return lans_kernel.sq_norm(x2d, interpret=interpret)

"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the Pallas kernels are validated against
(tests/test_kernels.py sweeps shapes & dtypes with assert_allclose).
The optimizer oracles are single-tensor, fp32-internal, and mirror
repro.core.optim exactly; `paged_attention_ref` mirrors the XLA
dense-gather decode branch of models/attention.py.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import NEG_INF


class StepOut(NamedTuple):
    x: jnp.ndarray
    m: jnp.ndarray
    v: jnp.ndarray


def _norm(x):
    return jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))


def lans_step_ref(
    g, m, v, x, *, eta, beta1=0.9, beta2=0.999, eps=1e-6, lam=0.01,
    step=1, apply_trust=True,
) -> StepOut:
    """One LANS block update (paper Algorithm 2), t = ``step`` (1-indexed)."""
    g = g.astype(jnp.float32)
    x32 = x.astype(jnp.float32)

    g_norm = _norm(g)
    g_t = jnp.where(g_norm > 0, g / jnp.maximum(g_norm, 1e-38), jnp.zeros_like(g))

    m_new = beta1 * m + (1 - beta1) * g_t
    v_new = beta2 * v + (1 - beta2) * jnp.square(g_t)

    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    denom = jnp.sqrt(v_new / bc2) + eps
    r = (m_new / bc1) / denom
    c = g_t / denom

    r_full = r + lam * x32
    c_full = c + lam * x32

    if apply_trust:
        x_norm = _norm(x32)
        rn, cn = _norm(r_full), _norm(c_full)
        sr = jnp.where(rn > 0, x_norm / jnp.maximum(rn, 1e-38), 1.0)
        sc = jnp.where(cn > 0, x_norm / jnp.maximum(cn, 1e-38), 1.0)
    else:
        sr = sc = jnp.float32(1.0)

    d = beta1 * sr * r_full + (1 - beta1) * sc * c_full
    x_new = (x32 - eta * d).astype(x.dtype)
    return StepOut(x_new, m_new, v_new)


def lamb_step_ref(
    g, m, v, x, *, eta, beta1=0.9, beta2=0.999, eps=1e-6, lam=0.01,
    step=1, apply_trust=True,
) -> StepOut:
    """One LAMB block update (Algorithm 1); global clip handled by caller."""
    g = g.astype(jnp.float32)
    x32 = x.astype(jnp.float32)

    m_new = beta1 * m + (1 - beta1) * g
    v_new = beta2 * v + (1 - beta2) * jnp.square(g)

    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    r = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    u = r + lam * x32

    if apply_trust:
        x_norm = _norm(x32)
        un = _norm(u)
        trust = jnp.where(un > 0, x_norm / jnp.maximum(un, 1e-38), 1.0)
    else:
        trust = jnp.float32(1.0)

    x_new = (x32 - eta * trust * u).astype(x.dtype)
    return StepOut(x_new, m_new, v_new)


def sq_norm_ref(x) -> jnp.ndarray:
    """Sum of squares (fp32) — oracle for the reduction kernel."""
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def paged_attention_ref(q, k_arena, v_arena, pos_arena, tables, q_pos, *,
                        scale, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None) -> jnp.ndarray:
    """Dense-gather oracle for the paged-attention decode kernel.

    Materializes `arena[tables]` into the (B, ring_len, ...) copy the
    XLA path pays for, then runs masked softmax attention with the same
    fp32 accumulation as models/attention.py's kernel="xla" decode
    branch. Shapes/semantics as paged_attention_kernel.paged_attention:
    q is (B, h, hd) with q_pos (B,) for single-token decode, or
    (B, S, h, hd) with q_pos (B, S) for a speculative-verify query
    block (each of the S query rows is masked against ITS OWN position,
    so row s attends to keys at positions <= q_pos[b, s]).

    Dead query rows (q_pos such that no key is valid — inactive slots
    carry all-(-1) positions) return exactly 0 — a contract of the
    KERNEL/ORACLE pair only. The XLA branch instead yields the
    uniform-softmax mean of the gathered V for such rows; the engine
    discards dead-slot outputs either way, which is why the two
    implementations still emit identical tokens.
    """
    squeeze = q.ndim == 3
    if squeeze:
        q, q_pos = q[:, None], q_pos[:, None]
    B, S, h, hd = q.shape
    n_kv = k_arena.shape[2]
    ring = tables.shape[1] * k_arena.shape[1]
    k = k_arena[tables].reshape(B, ring, n_kv, hd)
    v = v_arena[tables].reshape(B, ring, n_kv, hd)
    kp = pos_arena[tables].reshape(B, ring)
    if n_kv != h:
        k = jnp.repeat(k, h // n_kv, axis=2)
        v = jnp.repeat(v, h // n_kv, axis=2)
    logits = jnp.einsum("bshd,bkhd->bshk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    ok = jnp.broadcast_to((kp >= 0)[:, None, :], (B, S, ring))
    if causal:
        ok = ok & (kp[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        ok = ok & ((q_pos[:, :, None] - kp[:, None, :]) < window)
    logits = jnp.where(ok[:, :, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bshk,bkhd->bshd", probs, v,
                     preferred_element_type=jnp.float32)
    live = jnp.any(ok, axis=2)                 # (B, S): row has a valid key
    out = jnp.where(live[:, :, None, None], out, 0.0)
    return out[:, 0] if squeeze else out


def paged_attention_fused_ref(q, k_new, v_new, k_arena, v_arena, pos_arena,
                              tables, q_pos, cursor, *, scale,
                              causal: bool = True,
                              window: Optional[int] = None,
                              softcap: Optional[float] = None):
    """Scatter-then-attend oracle for `paged_attention_fused`: the
    oracle CARRIES THE WRITE, so arena mutation is part of the pinned
    contract rather than a side effect the tests could miss.

    Mirrors the XLA decode branch's scatter exactly — row s of slot b
    lands at logical ring row r = (cursor[b] + s) % ring_len, i.e.
    arena[tables[b, r // block_size], r % block_size]; rows with
    q_pos < 0 are routed to null row (0, 0) just like the XLA branch —
    EXCEPT that the null block is then restored: the fused kernel never
    writes new bytes into block 0 (a slot with no valid rows copies the
    streamed null block through unchanged), so the oracle's arenas match
    the kernel's bit-for-bit on EVERY block, null included. Attention
    then runs `paged_attention_ref` on the post-scatter arenas.

    Returns (out, k_arena, v_arena, pos_arena).
    """
    squeeze = q.ndim == 3
    if squeeze:
        q_pos_2d = q_pos[:, None]
        k_new_4d, v_new_4d = k_new[:, None], v_new[:, None]
    else:
        q_pos_2d, k_new_4d, v_new_4d = q_pos, k_new, v_new
    B, S = q_pos_2d.shape
    bs = k_arena.shape[1]
    ring = tables.shape[1] * bs
    r = jax.lax.rem(cursor[:, None].astype(jnp.int32)
                    + jnp.arange(S, dtype=jnp.int32), ring)
    blk = jnp.take_along_axis(tables, r // bs, axis=1)
    off = jax.lax.rem(r, bs)
    valid = q_pos_2d >= 0
    blk = jnp.where(valid, blk, 0)
    off = jnp.where(valid, off, 0)
    k2 = k_arena.at[blk, off].set(k_new_4d.astype(k_arena.dtype))
    v2 = v_arena.at[blk, off].set(v_new_4d.astype(v_arena.dtype))
    p2 = pos_arena.at[blk, off].set(q_pos_2d.astype(pos_arena.dtype))
    k2 = k2.at[0].set(k_arena[0])              # null block is immutable
    v2 = v2.at[0].set(v_arena[0])
    p2 = p2.at[0].set(pos_arena[0])
    out = paged_attention_ref(q, k2, v2, p2, tables, q_pos, scale=scale,
                              causal=causal, window=window, softcap=softcap)
    return out, k2, v2, p2

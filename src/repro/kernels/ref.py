"""Pure-jnp oracles for the fused optimizer kernels.

These are the ground truth the Pallas kernels are validated against
(tests/test_kernels.py sweeps shapes & dtypes with assert_allclose).
Single-tensor, fp32-internal, mirrors repro.core.optim exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class StepOut(NamedTuple):
    x: jnp.ndarray
    m: jnp.ndarray
    v: jnp.ndarray


def _norm(x):
    return jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))


def lans_step_ref(
    g, m, v, x, *, eta, beta1=0.9, beta2=0.999, eps=1e-6, lam=0.01,
    step=1, apply_trust=True,
) -> StepOut:
    """One LANS block update (paper Algorithm 2), t = ``step`` (1-indexed)."""
    g = g.astype(jnp.float32)
    x32 = x.astype(jnp.float32)

    g_norm = _norm(g)
    g_t = jnp.where(g_norm > 0, g / jnp.maximum(g_norm, 1e-38), jnp.zeros_like(g))

    m_new = beta1 * m + (1 - beta1) * g_t
    v_new = beta2 * v + (1 - beta2) * jnp.square(g_t)

    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    denom = jnp.sqrt(v_new / bc2) + eps
    r = (m_new / bc1) / denom
    c = g_t / denom

    r_full = r + lam * x32
    c_full = c + lam * x32

    if apply_trust:
        x_norm = _norm(x32)
        rn, cn = _norm(r_full), _norm(c_full)
        sr = jnp.where(rn > 0, x_norm / jnp.maximum(rn, 1e-38), 1.0)
        sc = jnp.where(cn > 0, x_norm / jnp.maximum(cn, 1e-38), 1.0)
    else:
        sr = sc = jnp.float32(1.0)

    d = beta1 * sr * r_full + (1 - beta1) * sc * c_full
    x_new = (x32 - eta * d).astype(x.dtype)
    return StepOut(x_new, m_new, v_new)


def lamb_step_ref(
    g, m, v, x, *, eta, beta1=0.9, beta2=0.999, eps=1e-6, lam=0.01,
    step=1, apply_trust=True,
) -> StepOut:
    """One LAMB block update (Algorithm 1); global clip handled by caller."""
    g = g.astype(jnp.float32)
    x32 = x.astype(jnp.float32)

    m_new = beta1 * m + (1 - beta1) * g
    v_new = beta2 * v + (1 - beta2) * jnp.square(g)

    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    r = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    u = r + lam * x32

    if apply_trust:
        x_norm = _norm(x32)
        un = _norm(u)
        trust = jnp.where(un > 0, x_norm / jnp.maximum(un, 1e-38), 1.0)
    else:
        trust = jnp.float32(1.0)

    x_new = (x32 - eta * trust * u).astype(x.dtype)
    return StepOut(x_new, m_new, v_new)


def sq_norm_ref(x) -> jnp.ndarray:
    """Sum of squares (fp32) — oracle for the reduction kernel."""
    return jnp.sum(jnp.square(x.astype(jnp.float32)))

"""Pallas TPU kernels for the repo's compute hot-spots.

  lans_kernel / lamb_kernel  fused 3-phase optimizer step (paper's apex
                             fused_lans analogue) + mixed-precision
                             cast-and-apply phase-2 variant
  paged_attention_kernel     fused paged-attention decode (streams KV
                             blocks via block-table scalar prefetch)
  ops                        jit'd public wrappers (tiling/layout contract)
  ref                        pure-jnp oracles the kernels are tested against

Authoring conventions (interpret-mode default, block-spec patterns, how
ref.py gates correctness, benchmark wiring) are documented in
docs/kernels.md.
"""

# Shared attention-mask value: large but FINITE negative, so masked-lane
# arithmetic underflows to exactly 0 (exp(NEG_INF - m) == 0) instead of
# producing inf - inf = NaN. Single-sourced here because the Pallas
# paged-attention kernel, its jnp oracle (ref.py) and the XLA paths in
# models/attention.py must underflow identically for the bit-exact
# paged-pallas == paged-xla greedy-token contract to hold.
NEG_INF = -2.3819763e38

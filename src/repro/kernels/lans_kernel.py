"""Pallas TPU kernels for the fused LANS optimizer step.

TPU adaptation of the paper's apex `fused_lans` CUDA kernel. A CUDA fused
optimizer interleaves block-wide reductions with elementwise math via
grid-wide synchronization; Pallas/TPU has no grid-wide barrier, so the step
is restructured into a 3-phase pipeline, each phase a `pl.pallas_call` tiled
for VMEM with (8,128)-aligned blocks:

  phase 0  sq_norm      : tiled sum-of-squares reduction  -> ||g||^2
  phase 1  lans_phase1  : g~ = g/||g||, update m,v; emit partial
                          sums-of-squares of (r+lam*x), (c+lam*x), x
  phase 2  lans_phase2  : given the three norms, form the convex-combination
                          direction d (paper eq. 7) and apply x <- x - eta*d

Reductions use the sequential-grid accumulation idiom (output block mapped to
(0,0) for every grid step, initialised at i==0). All arithmetic is fp32 in
VREGs regardless of storage dtype; traced scalars (bias corrections, eta,
flags) ride in a (1, 8) fp32 operand so the kernel needs no retracing across
steps.

Tile size: (256, 128) fp32 = 128 KiB; phase 1 holds 4 input + 2 output tiles
(~0.75 MiB), far under the ~16 MiB v5e VMEM budget, leaving room for
double-buffering by the pipeline emitter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 256
LANES = 128


def _guarded_inv(sq: jnp.ndarray, eps_floor: float = 1e-38) -> jnp.ndarray:
    """1/sqrt(sq) with sq==0 -> 0 (normalizing an all-zero block)."""
    return jnp.where(sq > 0.0, jax.lax.rsqrt(jnp.maximum(sq, eps_floor)), 0.0)


def _guarded_scale(x: jnp.ndarray, sq: jnp.ndarray) -> jnp.ndarray:
    """x / sqrt(sq), selecting 0 when sq is 0 or non-finite.

    Select (not multiply): x * 0 would propagate NaN from a NaN gradient
    block, whereas the reference optimizer (safe_div) zeroes it — the two
    paths must agree bit-for-bit on NaN handling (tests/test_fused_integration).
    """
    inv = jax.lax.rsqrt(jnp.maximum(sq, 1e-38))
    return jnp.where(sq > 0.0, x * inv, jnp.zeros_like(x))


# ---------------------------------------------------------------------------
# phase 0: sum-of-squares reduction
# ---------------------------------------------------------------------------

def _sq_norm_kernel(x_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)
    out_ref[0, 0] += jnp.sum(x * x)


def sq_norm(x2d: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """Sum of squares of a (rows, 128) array, rows % TILE_ROWS == 0."""
    rows, lanes = x2d.shape
    assert lanes == LANES and rows % TILE_ROWS == 0, x2d.shape
    grid = (rows // TILE_ROWS,)
    out = pl.pallas_call(
        _sq_norm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x2d)
    return out[0, 0]


# ---------------------------------------------------------------------------
# phase 1: moment update + partial norms
# scalars layout: [bc1, bc2, eta, lam, trust_flag, g_sq, 0, 0]
# ---------------------------------------------------------------------------

def _lans_phase1_kernel(scal_ref, g_ref, m_ref, v_ref, x_ref,
                        m_out, v_out, part_out, *, beta1, beta2, eps):
    i = pl.program_id(0)

    bc1 = scal_ref[0, 0]
    bc2 = scal_ref[0, 1]
    lam = scal_ref[0, 3]
    g_sq = scal_ref[0, 5]

    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...]
    v = v_ref[...]
    x = x_ref[...].astype(jnp.float32)

    g_t = _guarded_scale(g, g_sq)
    m_new = beta1 * m + (1.0 - beta1) * g_t
    v_new = beta2 * v + (1.0 - beta2) * (g_t * g_t)
    m_out[...] = m_new
    v_out[...] = v_new

    denom = jnp.sqrt(v_new / bc2) + eps
    r_full = (m_new / bc1) / denom + lam * x
    c_full = g_t / denom + lam * x

    @pl.when(i == 0)
    def _init():
        part_out[...] = jnp.zeros_like(part_out)

    part_out[0, 0] += jnp.sum(r_full * r_full)
    part_out[0, 1] += jnp.sum(c_full * c_full)
    part_out[0, 2] += jnp.sum(x * x)


def lans_phase1(scalars, g2d, m2d, v2d, x2d, *, beta1, beta2, eps,
                interpret: bool = True):
    rows, lanes = g2d.shape
    assert lanes == LANES and rows % TILE_ROWS == 0
    grid = (rows // TILE_ROWS,)
    tile = pl.BlockSpec((TILE_ROWS, LANES), lambda i: (i, 0))
    kern = functools.partial(_lans_phase1_kernel, beta1=beta1, beta2=beta2, eps=eps)
    m_new, v_new, partials = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8), lambda i: (0, 0)),  # traced scalars
            tile, tile, tile, tile,
        ],
        out_specs=[tile, tile, pl.BlockSpec((1, 8), lambda i: (0, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((1, 8), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, g2d, m2d, v2d, x2d)
    return m_new, v_new, partials


# ---------------------------------------------------------------------------
# phase 2: apply the update
# scalars layout: [bc1, bc2, eta, lam, trust_flag, g_sq, r_sq+c_sq+x_sq via norms]
# norms layout:   [r_sq, c_sq, x_sq, 0, 0, 0, 0, 0]
# ---------------------------------------------------------------------------

def _phase2_x_new(scal_ref, norm_ref, g_ref, m_ref, v_ref, x_ref,
                  *, beta1, eps):
    """Shared phase-2 body: returns the fp32 updated tile x - eta*d."""
    bc1 = scal_ref[0, 0]
    bc2 = scal_ref[0, 1]
    eta = scal_ref[0, 2]
    lam = scal_ref[0, 3]
    trust_flag = scal_ref[0, 4]
    g_sq = scal_ref[0, 5]

    r_sq = norm_ref[0, 0]
    c_sq = norm_ref[0, 1]
    x_sq = norm_ref[0, 2]

    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...]
    v = v_ref[...]
    x = x_ref[...].astype(jnp.float32)

    g_t = _guarded_scale(g, g_sq)
    denom = jnp.sqrt(v / bc2) + eps
    r_full = (m / bc1) / denom + lam * x
    c_full = g_t / denom + lam * x

    x_norm = jnp.sqrt(x_sq)
    sr = jnp.where(r_sq > 0.0, x_norm * _guarded_inv(r_sq), 1.0)
    sc = jnp.where(c_sq > 0.0, x_norm * _guarded_inv(c_sq), 1.0)
    sr = jnp.where(trust_flag > 0.0, sr, 1.0)
    sc = jnp.where(trust_flag > 0.0, sc, 1.0)

    d = beta1 * sr * r_full + (1.0 - beta1) * sc * c_full
    return x - eta * d


def _lans_phase2_kernel(scal_ref, norm_ref, g_ref, m_ref, v_ref, x_ref,
                        x_out, *, beta1, beta2, eps):
    del beta2
    x_new = _phase2_x_new(scal_ref, norm_ref, g_ref, m_ref, v_ref, x_ref,
                          beta1=beta1, eps=eps)
    x_out[...] = x_new.astype(x_out.dtype)


def _lans_phase2_cast_kernel(scal_ref, norm_ref, g_ref, m_ref, v_ref, x_ref,
                             x_out, lp_out, *, beta1, beta2, eps):
    """Mixed-precision phase 2: one pass writes BOTH the fp32 master update
    and its low-precision cast. Saves re-reading x_new from HBM for the
    model-copy cast that fp16/bf16 training needs every step."""
    del beta2
    x_new = _phase2_x_new(scal_ref, norm_ref, g_ref, m_ref, v_ref, x_ref,
                          beta1=beta1, eps=eps)
    x_out[...] = x_new.astype(x_out.dtype)
    lp_out[...] = x_new.astype(lp_out.dtype)


def lans_phase2(scalars, norms, g2d, m2d, v2d, x2d, *, beta1, beta2, eps,
                interpret: bool = True):
    rows, lanes = g2d.shape
    assert lanes == LANES and rows % TILE_ROWS == 0
    grid = (rows // TILE_ROWS,)
    tile = pl.BlockSpec((TILE_ROWS, LANES), lambda i: (i, 0))
    kern = functools.partial(_lans_phase2_kernel, beta1=beta1, beta2=beta2, eps=eps)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            tile, tile, tile, tile,
        ],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), x2d.dtype),
        interpret=interpret,
    )(scalars, norms, g2d, m2d, v2d, x2d)


def lans_phase2_cast(scalars, norms, g2d, m2d, v2d, x2d, *, lp_dtype,
                     beta1, beta2, eps, interpret: bool = True):
    """Phase 2 with fused low-precision cast: returns (x_new_f32, x_new_lp).

    TILE_ROWS=256 respects the (16,128) bf16 / fp16 minimum tile, so the
    same grid works for every lp_dtype.
    """
    rows, lanes = g2d.shape
    assert lanes == LANES and rows % TILE_ROWS == 0
    grid = (rows // TILE_ROWS,)
    tile = pl.BlockSpec((TILE_ROWS, LANES), lambda i: (i, 0))
    kern = functools.partial(_lans_phase2_cast_kernel,
                             beta1=beta1, beta2=beta2, eps=eps)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            tile, tile, tile, tile,
        ],
        out_specs=[tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), lp_dtype),
        ],
        interpret=interpret,
    )(scalars, norms, g2d, m2d, v2d, x2d)

"""Pallas TPU kernels: paged-attention decode, plain and scatter-fused.

The serving decode step stores attention KV in block ARENAS of
(n_blocks, block_size, n_kv, head_dim) addressed through per-slot block
TABLES (serving/cache_pool.PagedCachePool). The XLA path lowers the
block-table gather as `arena[table]`, which materializes a dense
(B, ring_len, n_kv, head_dim) K **and** V copy in HBM every layer every
step — read arena + write dense + read dense is ~3x the unavoidable K/V
traffic, and decode is memory-bound (Pati et al. 2021), so that copy IS
the step time at scale.

Two kernels remove the materialization:

`paged_attention` (PR 4/7) is the READ-side kernel: the block table
rides in as a scalar-prefetch operand, the K/V/pos BlockSpec index maps
select arena block `table[b, j]` for grid step (b, j), and the pipeline
emitter streams exactly the referenced blocks HBM -> VMEM
(double-buffered) while the kernel body folds each block into an
online-softmax accumulator. Nothing of size (B, ring_len, ...) ever
exists. It still expects POST-scatter arenas: the decode token's K/V
were written by three separate XLA scatters that read-modify-write the
full arenas in HBM, then the kernel re-reads those same rows.

`paged_attention_fused` (PR 10) folds that scatter into the kernel's
EPILOGUE: the new K/V rows and the cursor ride in as operands, the
arenas are aliased in/out via `input_output_aliases`, and the grid step
that streams a destination block overlays the new rows in VMEM — the
updated arenas come back alongside the attention output and the three
arena round-trips disappear. The new rows join the softmax as a
"virtual block" folded once at j == 0 (key positions = q_pos), which is
legal because every STALE row at a destination offset is already
masked: previously-unwritten/rolled-back rows carry pos == -1, and a
wrapped sliding-window row satisfies q_pos - pos_old >= ring_len -
(S - 1) >= window by the pool's `row_margin = spec_k - 1` contract.

Write routing: a scalar-prefetch FLUSH MAP W (B, max_blocks) gives, for
every grid step j, the arena block the k/v/pos output buffers map to —
the destination block with the largest table position <= j (the region
below the first destination joins its region, so each destination block
is filled before its region ends and flushed exactly once on real TPU's
flush-on-index-change pipelining). A slot with no valid row maps W to
the null block 0 and copies the streamed null block through unchanged —
the fused kernel NEVER writes new bytes into block 0 (unlike the XLA
scatter branch, which dumps invalid rows' K/V into null row 0; both
keep its positions -1, so the difference is invisible to attention).
Valid rows must target real (nonzero, exclusively-owned) blocks — the
allocator/growth contract.

Aliasing rules that make this safe (see docs/kernels.md for the worked
example): `input_output_aliases` indices count the FLATTENED inputs
including scalar-prefetch operands; interpret mode initialises aliased
outputs from their input buffers, so blocks the grid never maps stay
bit-identical; input blocks are read from the pristine pre-call arenas
(interpret snapshots; on TPU the only flush that targets a destination
block happens after its input-read step, and destination blocks are
exclusively owned so no other slot streams them).

Grid: (B, max_blocks), sequential on TPU — the per-slot running state
(m, l, acc) lives in VMEM scratch, initialised at j == 0 and written to
the output block at j == max_blocks - 1 (the same revisited-output
idiom as the lans reduction kernels). The query block is (S, h, hd)
with S >= 1: speculative verify feeds the K draft tokens of a slot as
S = K query rows sharing one HBM sweep of the slot's K/V blocks, each
row causally masked against its own position (q_pos is (B, S)). S = 1
is the plain decode special case — same kernel, same numerics.

Masking happens ON-CHIP from the streamed position block: position -1
rows (the reserved null block, unwritten ring rows, evicted slots) drop
out of the softmax exactly — `exp(NEG_INF - m) == 0` — and causality /
sliding windows test the block positions against the slot's query
position, also a scalar-prefetch operand. A slot with no valid key at
all (an inactive decode slot: every table entry is the null block)
returns exactly 0 rather than NaN.

Numerics: all arithmetic is fp32 in VREGs regardless of the arena
storage dtype, mirroring the XLA decode branch (which accumulates its
logit and PV contractions in fp32 via preferred_element_type) — the two
paths agree to fp32 summation-order tolerance, which is what keeps
greedy decode token-identical between kernel="xla" and kernel="paged"
(tests/test_paged_cache.py runs both engines differentially). The
epilogue writes are bitwise: rows are SELECTED (jnp.where), never
scaled, so the fused arenas match the XLA scatter bit-for-bit on every
data block.

`interpret` defaults by backend: True off-TPU (this CPU container),
False on real TPU. `grid_order` (None = consult the checked-in tuned
table, fall back to "arbitrary") selects the Mosaic dimension
semantics: "arbitrary" runs the whole grid sequentially; "parallel"
lets megacore split the batch dimension (safe: slots only write their
own exclusively-owned destination blocks, and concurrent null-block
copies write identical bytes). kernels/ref.py:paged_attention_ref /
paged_attention_fused_ref are the dense pure-jnp oracles tests gate
against — the fused oracle CARRIES THE WRITE so arena mutation is part
of the pinned contract, not a side effect.
"""
from __future__ import annotations

import functools
import json
import pathlib
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import NEG_INF

_VALID_FLOOR = -1e37     # any real logit is far above this

# TPU register/VMEM tiling: the last ("lane") dim tiles by 128 always;
# the second-to-last ("sublane") dim tiles by 8 for 4-byte dtypes and 16
# for 2-byte dtypes. Interpret mode does not check these — real TPU does.
TILE_LANE = 128
# VMEM is ~16 MiB/core on current TPUs; leave headroom for the compiler.
VMEM_BUDGET = int(16 * 1024 * 1024 * 0.9)

_TUNED_TABLE = pathlib.Path(__file__).resolve().parent.parent / \
    "configs" / "paged_attn_tuned.json"


def default_interpret() -> bool:
    """Pallas interpret mode unless running on real TPU."""
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------
# tile alignment / VMEM sizing (validated at PagedCachePool construction)
# --------------------------------------------------------------------------

def tile_sublane(dtype) -> int:
    """Minimum sublane multiple for a dtype (8 fp32-class, 16 bf16-class)."""
    return 8 if jnp.dtype(dtype).itemsize >= 4 else 16


def tile_alignment_problems(block_size: int, head_dim: int, dtype) -> list:
    """Why (block_size, head_dim) K/V blocks won't tile on real TPU.

    Arena blocks reach the kernel as (block_size, n_kv, head_dim) VMEM
    windows: head_dim is the lane dim (must be a multiple of 128) and
    block_size lands on a sublane dim (multiple of 8 for fp32 arenas,
    16 for bf16). Empty list = clean; interpret mode tolerates anything.
    """
    problems = []
    sub = tile_sublane(dtype)
    if head_dim % TILE_LANE:
        problems.append(
            f"head_dim {head_dim} is not a multiple of the {TILE_LANE} "
            f"lane tile: pad the head dim (or fold heads into the lane "
            f"axis) before running compiled on TPU")
    if block_size % sub:
        problems.append(
            f"block_size {block_size} is not a multiple of the {sub} "
            f"sublane tile for {jnp.dtype(dtype).name} arenas: use "
            f"block_size {-(-block_size // sub) * sub}")
    return problems


def kernel_fit_problems(block_size: int, head_dim: int, n_heads: int,
                        n_kv: int, dtype, *, S: int = 1,
                        vmem_budget: int = VMEM_BUDGET) -> list:
    """Tile-alignment plus VMEM-scratch sizing for one kernel launch.

    The VMEM estimate covers the fused kernel at production head counts:
    fp32 online-softmax scratch (m, l, acc), the double-buffered K/V/pos
    input stream, the aliased K/V/pos output buffers, and the q / new-row
    / attention-out blocks.
    """
    problems = tile_alignment_problems(block_size, head_dim, dtype)
    isz = jnp.dtype(dtype).itemsize
    blk = block_size * n_kv * head_dim * isz + block_size * 4  # K|V + pos
    scratch = 4 * S * n_heads * (2 + head_dim)                 # m, l, acc fp32
    vmem = (scratch
            + 2 * 2 * blk            # k/v in, double-buffered
            + 2 * blk                # k/v/pos out buffers
            + S * n_heads * head_dim * (isz + 4)   # q in + fp32 out
            + 2 * S * n_kv * head_dim * isz)       # new K/V rows
    if vmem > vmem_budget:
        problems.append(
            f"kernel VMEM estimate {vmem} bytes exceeds the "
            f"{vmem_budget}-byte budget: shrink block_size or S")
    return problems


def ensure_kernel_fit(block_size: int, head_dim: int, n_heads: int,
                      n_kv: int, dtype, *, S: int = 1,
                      interpret: Optional[bool] = None) -> list:
    """Raise on real TPU for a layout the compiled kernel cannot take.

    Returns the problem list either way; off-TPU (or with the
    `interpret` escape hatch forced on) problems are advisory — the
    interpret-mode kernel executes any layout.
    """
    problems = kernel_fit_problems(block_size, head_dim, n_heads, n_kv,
                                   dtype, S=S)
    if interpret is None:
        interpret = default_interpret()
    if problems and not interpret:
        raise ValueError(
            "paged-attention kernel layout cannot compile on TPU: "
            + "; ".join(problems)
            + " (pass interpret/--interpret to force interpret mode)")
    return problems


# --------------------------------------------------------------------------
# tuned-config table (written by `benchmarks/kernel_throughput --autotune`)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def tuned_table() -> dict:
    """The checked-in autotuner results: backend -> hd<d>_kv<k> ->
    bs<bs>_S<S> -> {"grid_order": ..., "us": ...}."""
    try:
        with open(_TUNED_TABLE) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def tuned_grid_order(backend: str, head_dim: int, n_kv: int,
                     block_size: int, S: int) -> str:
    """Trace-time table consult: exact (backend, head_dim, n_kv,
    block_size, S) match, else the documented "arbitrary" fallback (the
    fully-sequential grid every correctness test runs)."""
    entry = (tuned_table().get(backend, {})
             .get(f"hd{head_dim}_kv{n_kv}", {})
             .get(f"bs{block_size}_S{S}", {}))
    return entry.get("grid_order", "arbitrary")


def _compiler_params(grid_order: str):
    if grid_order == "parallel":
        return pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    if grid_order != "arbitrary":
        raise ValueError(
            f"grid_order must be 'arbitrary' or 'parallel', got {grid_order}")
    return pltpu.TPUCompilerParams(
        dimension_semantics=("arbitrary", "arbitrary"))


# --------------------------------------------------------------------------
# shared online-softmax fold
# --------------------------------------------------------------------------

def _online_fold(q, k, v, kp, qp, m_ref, l_ref, acc_ref, *,
                 scale, causal, window, softcap, n_kv):
    """Fold one key block into the (m, l, acc) scratch state.

    q (S, h, hd) fp32; k/v (T, n_kv, hd) any float (upcast here);
    kp (1, T) int32 key positions (-1 = invalid row); qp (S,) int32.
    """
    S, h, hd = q.shape
    g = h // n_kv
    k = k.astype(jnp.float32)

    # GQA without materializing repeated heads: head r = kv*g + i reads
    # kv head r // g — the same layout jnp.repeat(k, g, axis=2) yields.
    # The S query rows batch through the same contraction: regroup
    # (S, h, hd) -> (n_kv, S*g, hd) so n_kv stays the dot batch dim.
    logits = jax.lax.dot_general(
        q.reshape(S, n_kv, g, hd).swapaxes(0, 1).reshape(n_kv, S * g, hd),
        k,
        dimension_numbers=(((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32,    # (n_kv, S*g, T)
    ).reshape(n_kv, S, g, -1).swapaxes(0, 1).reshape(S, h, -1) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    ok = jnp.broadcast_to(kp >= 0, (S, kp.shape[1]))
    if causal:                                 # row s masks against ITS pos
        ok = ok & (kp <= qp[:, None])
    if window is not None:
        ok = ok & ((qp[:, None] - kp) < window)
    logits = jnp.where(ok[:, None, :], logits, NEG_INF)

    m_prev = m_ref[...].reshape(S, h)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=2))
    # A fully-masked prefix keeps m at NEG_INF; shift by 0 there so the
    # masked exp still underflows to exactly 0 instead of exp(0) == 1.
    m_safe = jnp.where(m_new > _VALID_FLOOR, m_new, 0.0)
    alpha = jnp.exp(m_prev - m_safe)           # 0 when m_prev is NEG_INF
    e = jnp.exp(logits - m_safe[:, :, None])   # masked entries -> exactly 0

    v = v.astype(jnp.float32)
    pv = jax.lax.dot_general(
        e.reshape(S, n_kv, g, -1).swapaxes(0, 1).reshape(n_kv, S * g, -1),
        v,
        dimension_numbers=(((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32,    # (n_kv, S*g, hd)
    ).reshape(n_kv, S, g, hd).swapaxes(0, 1).reshape(S, h, hd)

    m_ref[...] = m_new.reshape(S * h, 1)
    l_ref[...] = (alpha * l_ref[...].reshape(S, h)
                  + jnp.sum(e, axis=2)).reshape(S * h, 1)
    acc_ref[...] = (alpha.reshape(S * h, 1) * acc_ref[...]
                    + pv.reshape(S * h, hd))


def _finish_out(out_ref, m_ref, l_ref, acc_ref, S, h, hd):
    lsum = l_ref[...].reshape(S, h)
    live = lsum > 0.0                          # False only for dead rows
    out = (acc_ref[...].reshape(S, h, hd)
           / jnp.where(live, lsum, 1.0)[:, :, None])
    out_ref[0] = jnp.where(live[:, :, None], out, 0.0).astype(out_ref.dtype)


# --------------------------------------------------------------------------
# read-side kernel (PR 4/7): arenas already scattered
# --------------------------------------------------------------------------

def _paged_attn_kernel(tbl_ref, qpos_ref, q_ref, k_ref, v_ref, pos_ref,
                       out_ref, m_ref, l_ref, acc_ref, *,
                       scale, causal, window, softcap, n_kv):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)           # (S, h, hd)
    S, h, hd = q.shape
    qp = qpos_ref[b]                           # (S,) this slot's positions
    _online_fold(q, k_ref[0], v_ref[0], pos_ref[...], qp,
                 m_ref, l_ref, acc_ref, scale=scale, causal=causal,
                 window=window, softcap=softcap, n_kv=n_kv)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        _finish_out(out_ref, m_ref, l_ref, acc_ref, S, h, hd)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap", "interpret",
                     "grid_order"))
def paged_attention(q, k_arena, v_arena, pos_arena, tables, q_pos, *,
                    scale, causal=True, window=None, softcap=None,
                    interpret=None, grid_order=None):
    """Fused paged decode attention, S=1 or a small-S query block.

    Args:
      q: (B, h, head_dim) query for the single decode token, or
        (B, S, h, head_dim) for an S-token speculative-verify block;
        any float dtype (upcast to fp32 on-chip).
      k_arena / v_arena: (n_blocks, block_size, n_kv, head_dim) block
        arenas, POST-scatter (the decode tokens' K/V already written).
      pos_arena: (n_blocks, block_size) int32 absolute key positions;
        -1 marks invalid rows (null block, unwritten ring slots) and is
        masked unconditionally.
      tables: (B, max_blocks) int32 arena indices, 0 = the null block.
      q_pos: (B,) — or (B, S) matching a 4-D q — int32 absolute query
        positions; with S > 1 each query row is masked causally against
        its OWN position, so one kernel launch verifies all S draft
        tokens per slot.
      scale / causal / window / softcap: static attention config,
        matching models/attention.AttnConfig semantics.
      interpret: Pallas interpret mode; None = auto (True off-TPU).
      grid_order: Mosaic dimension semantics — "arbitrary" (sequential
        grid) or "parallel" (megacore may split the batch dim). None
        consults the checked-in tuned table (configs/
        paged_attn_tuned.json) by (backend, head_dim, n_kv, block_size,
        S) and falls back to "arbitrary" on a miss.

    Returns (B, h, head_dim) or (B, S, h, head_dim) fp32, matching q.
    Query rows whose table references no valid key (inactive decode
    slots) return exactly 0 — see kernels/ref.py:paged_attention_ref,
    the oracle that pins this contract.
    """
    if interpret is None:
        interpret = default_interpret()
    squeeze = q.ndim == 3
    if squeeze:
        q, q_pos = q[:, None], q_pos[:, None]
    B, S, h, hd = q.shape
    _, bs, n_kv, _ = k_arena.shape
    nb = tables.shape[1]
    if h % n_kv:
        raise ValueError(f"n_heads {h} not a multiple of n_kv {n_kv}")
    if grid_order is None:
        grid_order = tuned_grid_order(jax.default_backend(), hd, n_kv, bs, S)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # tables, q_pos
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, S, h, hd), lambda b, j, tbl, qp: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, n_kv, hd),
                         lambda b, j, tbl, qp: (tbl[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, n_kv, hd),
                         lambda b, j, tbl, qp: (tbl[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs), lambda b, j, tbl, qp: (tbl[b, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, S, h, hd),
                               lambda b, j, tbl, qp: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((S * h, 1), jnp.float32),   # running max m
            pltpu.VMEM((S * h, 1), jnp.float32),   # running normalizer l
            pltpu.VMEM((S * h, hd), jnp.float32),  # unnormalized out acc
        ],
    )
    kern = functools.partial(
        _paged_attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, n_kv=n_kv)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, h, hd), jnp.float32),
        compiler_params=_compiler_params(grid_order),
        interpret=interpret,
    )(tables.astype(jnp.int32), q_pos.astype(jnp.int32),
      q, k_arena, v_arena, pos_arena)
    return out[:, 0] if squeeze else out


# --------------------------------------------------------------------------
# scatter-in-epilogue kernel (PR 10): the kernel carries the write
# --------------------------------------------------------------------------

def _flush_map(tables, q_pos, cursor, bs: int, nb: int):
    """(B, nb) int32: the arena block the k/v/pos OUT buffers map to at
    grid step j — the destination block with the largest table position
    <= j among this slot's valid rows; steps below the first destination
    join its region; a slot with no valid row maps the null block 0
    (identity rewrite). Regions are contiguous runs, so real TPU's
    flush-on-index-change writes each destination block exactly once,
    strictly after the step that filled its buffer."""
    B, S = q_pos.shape
    ring = nb * bs
    r = jax.lax.rem(cursor[:, None].astype(jnp.int32)
                    + jnp.arange(S, dtype=jnp.int32), ring)
    jblk = r // bs                                       # (B, S) table pos
    valid = q_pos >= 0
    dest = jnp.take_along_axis(tables, jblk, axis=1)     # (B, S)
    jj = jnp.arange(nb, dtype=jnp.int32)[None, :, None]  # (1, nb, 1)
    cand = jnp.where(valid[:, None, :] & (jblk[:, None, :] <= jj),
                     jblk[:, None, :], -1)               # (B, nb, S)
    has_le = jnp.max(cand, axis=2) >= 0                  # (B, nb)
    pick_le = jnp.argmax(cand, axis=2)                   # s of largest <= j
    pick_min = jnp.argmin(jnp.where(valid, jblk, nb), axis=1)  # (B,)
    pick = jnp.where(has_le, pick_le, pick_min[:, None])
    W = jnp.take_along_axis(dest, pick, axis=1)
    return jnp.where(jnp.any(valid, axis=1)[:, None], W, 0).astype(jnp.int32)


def _paged_attn_fused_kernel(tbl_ref, qpos_ref, cur_ref, w_ref,
                             q_ref, kn_ref, vn_ref, k_ref, v_ref, pos_ref,
                             out_ref, ko_ref, vo_ref, po_ref,
                             m_ref, l_ref, acc_ref, *,
                             scale, causal, window, softcap, n_kv, bs, nb):
    b = pl.program_id(0)
    j = pl.program_id(1)
    ring = nb * bs

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)           # (S, h, hd)
    S, h, hd = q.shape
    qp = qpos_ref[b]                           # (S,) this slot's positions

    # The S new rows fold ONCE as a virtual key block (positions q_pos):
    # the streamed destination blocks still hold pre-scatter bytes at the
    # destination offsets, and those stale rows are masked — pos == -1
    # for never-written/rolled-back rows, out-of-window by the
    # row_margin contract for wrapped ring rows (module docstring).
    @pl.when(j == 0)
    def _fold_new_rows():
        _online_fold(q, kn_ref[0], vn_ref[0], qp.reshape(1, S), qp,
                     m_ref, l_ref, acc_ref, scale=scale, causal=causal,
                     window=window, softcap=softcap, n_kv=n_kv)

    _online_fold(q, k_ref[0], v_ref[0], pos_ref[...], qp,
                 m_ref, l_ref, acc_ref, scale=scale, causal=causal,
                 window=window, softcap=softcap, n_kv=n_kv)

    # Epilogue scatter: when the streamed block is a destination block,
    # refresh the aliased out buffers from the (pristine) streamed input
    # and overlay the rows that land here. Selection is bitwise
    # (jnp.where), matching the XLA scatter exactly. A slot with no
    # valid row copies the null block through at j == 0 so its W region
    # (the whole slot) flushes identical bytes back to block 0.
    cur = cur_ref[b]
    hits, all_invalid = [], True
    for s in range(S):
        r_s = jax.lax.rem(cur + s, ring)
        hits.append(((qpos_ref[b, s] >= 0) & (r_s // bs == j),
                     jax.lax.rem(r_s, bs), s))
    any_hit = functools.reduce(jnp.logical_or, [h_ for h_, _, _ in hits])
    none_valid = functools.reduce(
        jnp.logical_and, [qpos_ref[b, s] < 0 for s in range(S)])
    fill = any_hit | ((j == 0) & none_valid)

    @pl.when(fill)
    def _write_epilogue():
        kbuf = k_ref[0]                        # (bs, n_kv, hd) arena dtype
        vbuf = v_ref[0]
        pbuf = pos_ref[...]                    # (1, bs) int32
        rows3 = jax.lax.broadcasted_iota(jnp.int32, (bs, 1, 1), 0)
        rows2 = jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        for hit, off, s in hits:               # S static and small: unrolled
            m3 = hit & (rows3 == off)
            kbuf = jnp.where(m3, kn_ref[0, s].astype(kbuf.dtype), kbuf)
            vbuf = jnp.where(m3, vn_ref[0, s].astype(vbuf.dtype), vbuf)
            pbuf = jnp.where(hit & (rows2 == off), qpos_ref[b, s], pbuf)
        ko_ref[0] = kbuf
        vo_ref[0] = vbuf
        po_ref[...] = pbuf

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        _finish_out(out_ref, m_ref, l_ref, acc_ref, S, h, hd)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap", "interpret",
                     "grid_order"))
def paged_attention_fused(q, k_new, v_new, k_arena, v_arena, pos_arena,
                          tables, q_pos, cursor, *, scale, causal=True,
                          window=None, softcap=None, interpret=None,
                          grid_order=None):
    """Paged decode attention with the K/V/pos scatter fused into the
    kernel epilogue: arenas are PRE-scatter and come back updated.

    Args (beyond `paged_attention`):
      k_new / v_new: (B, n_kv, head_dim) — or (B, S, n_kv, head_dim)
        matching a 4-D q — the decode tokens' K/V rows, already in the
        arena storage dtype (written bit-exact).
      cursor: (B,) int32 per-slot write cursors; row s of slot b lands
        at logical ring row (cursor[b] + s) % ring_len, i.e. arena
        [tables[b, r // bs], r % bs]. Rows with q_pos < 0 write nothing
        (the XLA branch routes them to null row 0 instead — same masked
        visibility, see module docstring).

    Returns (out, k_arena, v_arena, pos_arena): attention output as
    `paged_attention`, plus the post-write arenas (aliased in/out — on
    TPU and under donation the update is in place; no extra arena
    round-trip exists in the lowered HLO).
    """
    if interpret is None:
        interpret = default_interpret()
    squeeze = q.ndim == 3
    if squeeze:
        q, q_pos = q[:, None], q_pos[:, None]
        k_new, v_new = k_new[:, None], v_new[:, None]
    B, S, h, hd = q.shape
    _, bs, n_kv, _ = k_arena.shape
    nb = tables.shape[1]
    if h % n_kv:
        raise ValueError(f"n_heads {h} not a multiple of n_kv {n_kv}")
    if S > bs * nb:
        raise ValueError(f"S={S} exceeds the ring ({nb}x{bs} rows)")
    if grid_order is None:
        grid_order = tuned_grid_order(jax.default_backend(), hd, n_kv, bs, S)

    tables = tables.astype(jnp.int32)
    q_pos = q_pos.astype(jnp.int32)
    cursor = cursor.astype(jnp.int32)
    wmap = _flush_map(tables, q_pos, cursor, bs, nb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,                 # tables, q_pos, cursor, W
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, S, h, hd),
                         lambda b, j, tbl, qp, cur, w: (b, 0, 0, 0)),
            pl.BlockSpec((1, S, n_kv, hd),
                         lambda b, j, tbl, qp, cur, w: (b, 0, 0, 0)),
            pl.BlockSpec((1, S, n_kv, hd),
                         lambda b, j, tbl, qp, cur, w: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, n_kv, hd),
                         lambda b, j, tbl, qp, cur, w: (tbl[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, n_kv, hd),
                         lambda b, j, tbl, qp, cur, w: (tbl[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs),
                         lambda b, j, tbl, qp, cur, w: (tbl[b, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, h, hd),
                         lambda b, j, tbl, qp, cur, w: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, n_kv, hd),
                         lambda b, j, tbl, qp, cur, w: (w[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, n_kv, hd),
                         lambda b, j, tbl, qp, cur, w: (w[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs),
                         lambda b, j, tbl, qp, cur, w: (w[b, j], 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((S * h, 1), jnp.float32),   # running max m
            pltpu.VMEM((S * h, 1), jnp.float32),   # running normalizer l
            pltpu.VMEM((S * h, hd), jnp.float32),  # unnormalized out acc
        ],
    )
    kern = functools.partial(
        _paged_attn_fused_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, n_kv=n_kv, bs=bs, nb=nb)
    out, k_out, v_out, pos_out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, S, h, hd), jnp.float32),
            jax.ShapeDtypeStruct(k_arena.shape, k_arena.dtype),
            jax.ShapeDtypeStruct(v_arena.shape, v_arena.dtype),
            jax.ShapeDtypeStruct(pos_arena.shape, pos_arena.dtype),
        ],
        # Flattened-input indices INCLUDE the 4 scalar-prefetch operands:
        # inputs are [tbl, qp, cur, W, q, k_new, v_new, k, v, pos] so the
        # arenas sit at 7/8/9; outputs [out, k, v, pos] at 1/2/3.
        input_output_aliases={7: 1, 8: 2, 9: 3},
        compiler_params=_compiler_params(grid_order),
        interpret=interpret,
    )(tables, q_pos, cursor, wmap, q, k_new, v_new,
      k_arena, v_arena, pos_arena)
    return (out[:, 0] if squeeze else out), k_out, v_out, pos_out

"""Pallas TPU kernel: fused paged-attention decode (S=1 or small-S).

The serving decode step stores attention KV in block ARENAS of
(n_blocks, block_size, n_kv, head_dim) addressed through per-slot block
TABLES (serving/cache_pool.PagedCachePool). The XLA path lowers the
block-table gather as `arena[table]`, which materializes a dense
(B, ring_len, n_kv, head_dim) K **and** V copy in HBM every layer every
step — read arena + write dense + read dense is ~3x the unavoidable K/V
traffic, and decode is memory-bound (Pati et al. 2021), so that copy IS
the step time at scale.

This kernel removes the materialization: the block table rides in as a
scalar-prefetch operand, the K/V/pos BlockSpec index maps select arena
block `table[b, j]` for grid step (b, j), and the pipeline emitter
streams exactly the referenced blocks HBM -> VMEM (double-buffered)
while the kernel body folds each block into an online-softmax
accumulator. Nothing of size (B, ring_len, ...) ever exists.

Grid: (B, max_blocks), sequential on TPU — the per-slot running state
(m, l, acc) lives in VMEM scratch, initialised at j == 0 and written to
the output block at j == max_blocks - 1 (the same revisited-output
idiom as the lans reduction kernels). The query block is (S, h, hd)
with S >= 1: speculative verify feeds the K draft tokens of a slot as
S = K query rows sharing one HBM sweep of the slot's K/V blocks, each
row causally masked against its own position (q_pos is (B, S)). S = 1
is the plain decode special case — same kernel, same numerics.

Masking happens ON-CHIP from the streamed position block: position -1
rows (the reserved null block, unwritten ring rows, evicted slots) drop
out of the softmax exactly — `exp(NEG_INF - m) == 0` — and causality /
sliding windows test the block positions against the slot's query
position, also a scalar-prefetch operand. A slot with no valid key at
all (an inactive decode slot: every table entry is the null block)
returns exactly 0 rather than NaN.

Numerics: all arithmetic is fp32 in VREGs regardless of the arena
storage dtype, mirroring the XLA decode branch (which accumulates its
logit and PV contractions in fp32 via preferred_element_type) — the two
paths agree to fp32 summation-order tolerance, which is what keeps
greedy decode token-identical between kernel="xla" and kernel="paged"
(tests/test_paged_cache.py runs both engines differentially).

`interpret` defaults by backend: True off-TPU (this CPU container),
False on real TPU. kernels/ref.py:paged_attention_ref is the dense
pure-jnp oracle tests gate against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import NEG_INF

_VALID_FLOOR = -1e37     # any real logit is far above this


def default_interpret() -> bool:
    """Pallas interpret mode unless running on real TPU."""
    return jax.default_backend() != "tpu"


def _paged_attn_kernel(tbl_ref, qpos_ref, q_ref, k_ref, v_ref, pos_ref,
                       out_ref, m_ref, l_ref, acc_ref, *,
                       scale, causal, window, softcap, n_kv):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)           # (S, h, hd)
    k = k_ref[0].astype(jnp.float32)           # (bs, n_kv, hd)
    pos = pos_ref[...]                         # (1, bs) int32
    S, h, hd = q.shape
    g = h // n_kv

    # GQA without materializing repeated heads: head r = kv*g + i reads
    # kv head r // g — the same layout jnp.repeat(k, g, axis=2) yields.
    # The S query rows batch through the same contraction: regroup
    # (S, h, hd) -> (n_kv, S*g, hd) so n_kv stays the dot batch dim.
    logits = jax.lax.dot_general(
        q.reshape(S, n_kv, g, hd).swapaxes(0, 1).reshape(n_kv, S * g, hd),
        k,
        dimension_numbers=(((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32,    # (n_kv, S*g, bs)
    ).reshape(n_kv, S, g, -1).swapaxes(0, 1).reshape(S, h, -1) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    qp = qpos_ref[b]                           # (S,) this slot's positions
    ok = jnp.broadcast_to(pos >= 0, (S, pos.shape[1]))
    if causal:                                 # row s masks against ITS pos
        ok = ok & (pos <= qp[:, None])
    if window is not None:
        ok = ok & ((qp[:, None] - pos) < window)
    logits = jnp.where(ok[:, None, :], logits, NEG_INF)

    m_prev = m_ref[...].reshape(S, h)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=2))
    # A fully-masked prefix keeps m at NEG_INF; shift by 0 there so the
    # masked exp still underflows to exactly 0 instead of exp(0) == 1.
    m_safe = jnp.where(m_new > _VALID_FLOOR, m_new, 0.0)
    alpha = jnp.exp(m_prev - m_safe)           # 0 when m_prev is NEG_INF
    e = jnp.exp(logits - m_safe[:, :, None])   # masked entries -> exactly 0

    v = v_ref[0].astype(jnp.float32)           # (bs, n_kv, hd)
    pv = jax.lax.dot_general(
        e.reshape(S, n_kv, g, -1).swapaxes(0, 1).reshape(n_kv, S * g, -1),
        v,
        dimension_numbers=(((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32,    # (n_kv, S*g, hd)
    ).reshape(n_kv, S, g, hd).swapaxes(0, 1).reshape(S, h, hd)

    m_ref[...] = m_new.reshape(S * h, 1)
    l_ref[...] = (alpha * l_ref[...].reshape(S, h)
                  + jnp.sum(e, axis=2)).reshape(S * h, 1)
    acc_ref[...] = (alpha.reshape(S * h, 1) * acc_ref[...]
                    + pv.reshape(S * h, hd))

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        lsum = l_ref[...].reshape(S, h)
        live = lsum > 0.0                      # False only for dead rows
        out = (acc_ref[...].reshape(S, h, hd)
               / jnp.where(live, lsum, 1.0)[:, :, None])
        out_ref[0] = jnp.where(live[:, :, None], out,
                               0.0).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap", "interpret"))
def paged_attention(q, k_arena, v_arena, pos_arena, tables, q_pos, *,
                    scale, causal=True, window=None, softcap=None,
                    interpret=None):
    """Fused paged decode attention, S=1 or a small-S query block.

    Args:
      q: (B, h, head_dim) query for the single decode token, or
        (B, S, h, head_dim) for an S-token speculative-verify block;
        any float dtype (upcast to fp32 on-chip).
      k_arena / v_arena: (n_blocks, block_size, n_kv, head_dim) block
        arenas, POST-scatter (the decode tokens' K/V already written).
      pos_arena: (n_blocks, block_size) int32 absolute key positions;
        -1 marks invalid rows (null block, unwritten ring slots) and is
        masked unconditionally.
      tables: (B, max_blocks) int32 arena indices, 0 = the null block.
      q_pos: (B,) — or (B, S) matching a 4-D q — int32 absolute query
        positions; with S > 1 each query row is masked causally against
        its OWN position, so one kernel launch verifies all S draft
        tokens per slot.
      scale / causal / window / softcap: static attention config,
        matching models/attention.AttnConfig semantics.
      interpret: Pallas interpret mode; None = auto (True off-TPU).

    Returns (B, h, head_dim) or (B, S, h, head_dim) fp32, matching q.
    Query rows whose table references no valid key (inactive decode
    slots) return exactly 0 — see kernels/ref.py:paged_attention_ref,
    the oracle that pins this contract.
    """
    if interpret is None:
        interpret = default_interpret()
    squeeze = q.ndim == 3
    if squeeze:
        q, q_pos = q[:, None], q_pos[:, None]
    B, S, h, hd = q.shape
    _, bs, n_kv, _ = k_arena.shape
    nb = tables.shape[1]
    if h % n_kv:
        raise ValueError(f"n_heads {h} not a multiple of n_kv {n_kv}")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # tables, q_pos
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, S, h, hd), lambda b, j, tbl, qp: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, n_kv, hd),
                         lambda b, j, tbl, qp: (tbl[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, n_kv, hd),
                         lambda b, j, tbl, qp: (tbl[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs), lambda b, j, tbl, qp: (tbl[b, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, S, h, hd),
                               lambda b, j, tbl, qp: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((S * h, 1), jnp.float32),   # running max m
            pltpu.VMEM((S * h, 1), jnp.float32),   # running normalizer l
            pltpu.VMEM((S * h, hd), jnp.float32),  # unnormalized out acc
        ],
    )
    kern = functools.partial(
        _paged_attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, n_kv=n_kv)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, h, hd), jnp.float32),
        interpret=interpret,
    )(tables.astype(jnp.int32), q_pos.astype(jnp.int32),
      q, k_arena, v_arena, pos_arena)
    return out[:, 0] if squeeze else out

"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000; local+global alternating attention, logit softcap.
[arXiv:2408.00118]

Superblock of 2: sliding-window(4096) layer then global layer (13 periods).
Soft-capping: 50.0 on attention logits, 30.0 on final logits; pre+post
block RMSNorms; embeddings scaled by sqrt(d_model); GeGLU MLP.
long_500k: local layers hold a 4096 ring-buffer cache; global layers hold
the full 500k cache (linear per decode token).
"""
from repro.configs.base import Arch
from repro.models.decoder import DecoderConfig

CONFIG = DecoderConfig(
    name="gemma2-2b",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_block_norm=True,
    scale_embeds=True,
    activation="gelu",
    gated_mlp=True,
    superblock=(("attn_local", "mlp"), ("attn", "mlp")),
    max_seq=8192,
)

ARCH = Arch(
    name="gemma2-2b",
    kind="decoder",
    cfg=CONFIG,
    source="arXiv:2408.00118",
    long_context_ok=True,
)

"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; 128k native context.  [hf:mistralai/Mistral-Nemo-Base-2407]

long_500k opt-in: serving uses a sliding window of 131072 (the model's
native context) so the ring-buffer KV cache stays bounded — the documented
beyond-paper variant that makes a dense arch eligible for the long-decode
shape (DESIGN.md §Arch-applicability). For train_4k / prefill_32k the
window exceeds the sequence, so it is numerically identical to full
attention.
"""
from repro.configs.base import Arch
from repro.models.decoder import DecoderConfig

CONFIG = DecoderConfig(
    name="mistral-nemo-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1000000.0,
    sliding_window=131072,
    activation="silu",
    superblock=(("attn_local", "mlp"),),
    max_seq=131072,
)

ARCH = Arch(
    name="mistral-nemo-12b",
    kind="decoder",
    cfg=CONFIG,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
    long_context_ok=True,
)

"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536; early-fusion with VQ image tokens.  [arXiv:2405.09818]

Early fusion means image patches are VQ-quantized into the SAME token
vocabulary the text uses, so the backbone is a standard dense decoder over
interleaved token ids. The VQ image tokenizer is the allowed frontend STUB:
input_specs() provides precomputed embedding sequences (embeds_input=True)
for the train shape, exactly the (B, S, d) the projector would emit.
Chameleon adds QK-norm for training stability — included.
"""
import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.decoder import DecoderConfig

CONFIG = DecoderConfig(
    name="chameleon-34b",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    activation="silu",
    superblock=(("attn", "mlp"),),
    max_seq=8192,
    param_dtype=jnp.bfloat16,  # no fp32 master at 34B on 16GB chips
)

ARCH = Arch(
    name="chameleon-34b",
    kind="decoder",
    cfg=CONFIG,
    source="arXiv:2405.09818",
    zero1=True,  # ZeRO-1 (moments sharded) beats zero3 here: EXPERIMENTS.md iter 2
    train_microbatches=16,
    embeds_input=True,
    notes="early-fusion VQ tokens share the text vocab; frontend stubbed "
          "per the assignment carve-out.",
)
